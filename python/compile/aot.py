"""AOT lowering: JAX (L2+L1) → HLO text artifacts + manifest.json.

Interchange format is HLO **text**, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (bound
by the ``xla`` 0.1.6 crate) rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``cd python && python -m compile.aot --out ../artifacts``

Emits, per block-shape configuration, one ``<name>_r{R}_k{K}_c{C}.hlo.txt``
for every compilation unit in :func:`compile.model.compilation_units`, plus
``manifest.json`` describing op names, file names, and input/output shapes —
the Rust runtime is entirely manifest-driven.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# Block-shape configurations to emit. (rows, k, cols): the large config is
# the throughput path; the small config minimizes padding waste for small
# matrices (synthetic CF has m=100).
CONFIGS = [
    (2048, 32, 512),
    (256, 32, 512),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_unit(fn, specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def shape_of(spec) -> dict:
    return {"dims": list(spec.shape), "dtype": str(spec.dtype)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = []
    for r, k, c in CONFIGS:
        for name, fn, specs in model.compilation_units(r, k, c):
            fname = f"{name}_r{r}_k{k}_c{c}.hlo.txt"
            text = lower_unit(fn, specs)
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(text)
            # Abstract-evaluate to record output shapes for the manifest.
            out_specs = jax.eval_shape(fn, *specs)
            entries.append(
                {
                    "op": name,
                    "file": fname,
                    "rows": r,
                    "k": k,
                    "cols": c,
                    "inputs": [shape_of(s) for s in specs],
                    "outputs": [shape_of(s) for s in out_specs],
                }
            )
            print(f"  wrote {fname} ({len(text)} chars)")

    manifest = {"version": 1, "tuple_output": True, "entries": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(entries)} entries to {args.out}")


if __name__ == "__main__":
    main()
