"""Layer-2 JAX graphs for matsketch.

Each public function here is one AOT compilation unit: ``aot.py`` lowers it
at fixed block shapes to HLO text that the Rust runtime
(`rust/src/runtime/`) loads and executes via PJRT. The graphs call the
Layer-1 Pallas kernels so kernel + surrounding compute lower into a single
HLO module.

Design constraint: xla_extension 0.5.1 (the version the published ``xla``
crate binds) cannot execute typed-FFI custom-calls, which is what
``jnp.linalg.cholesky`` / ``triangular_solve`` / ``eigh`` lower to on CPU.
Every graph here is therefore pure matmul / elementwise / control-flow HLO;
the tiny K×K factorizations live in Rust (``linalg::{cholesky, jacobi}``).
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import apply_block, gram_block, probs_block, proj_block


def gram(y):
    """G = YᵀY for one (R, K) row block. Accumulated over blocks in Rust."""
    return (gram_block(y),)


def apply_factor(y, t):
    """Q-block = Y·T for one (R, K) row block and K×K factor T."""
    return (apply_block(y, t),)


def proj(q, a):
    """P += Qᵀ·A for one (R, K) Q block and (R, C) dense A block."""
    return (proj_block(q, a),)


def probs_l1(a, w):
    """Entrywise probability table w_i·|A_ij| (L1 family) for one block."""
    return (probs_block(a, w, power=1),)


def probs_l2(a, w):
    """Entrywise probability table w_i·A_ij² (L2 family) for one block."""
    return (probs_block(a, w, power=2),)


def power_iter(g, v0, *, iters: int = 96):
    """Dominant eigenpair of a symmetric PSD K×K matrix.

    Runs a fixed-trip-count power iteration as an HLO ``while`` loop —
    demonstrates control flow surviving the AOT path and gives Rust a
    spectral-norm primitive for Gram matrices (‖Y‖₂ = sqrt(λ_max(YᵀY))).
    Returns (λ, v).
    """

    def body(_, carry):
        v, _lam = carry
        w = g @ v
        lam = jnp.sqrt(jnp.sum(w * w))
        return w / jnp.maximum(lam, 1e-30), lam

    v0 = v0 / jnp.maximum(jnp.sqrt(jnp.sum(v0 * v0)), 1e-30)
    v, lam = lax.fori_loop(0, iters, body, (v0, jnp.float32(0.0)))
    return (lam, v)


def subspace_round(y, t, a):
    """Fused evaluation round used by the fast path: Q = Y·T; P = Qᵀ·A.

    Fusing apply+proj halves the number of PJRT executions (and host↔device
    copies) on the Figure-1 hot loop.
    """
    q = apply_block(y, t)
    return (q, proj_block(q, a))


# ---------------------------------------------------------------------------
# Registry used by aot.py: name -> (fn, abstract input shapes builder)
# ---------------------------------------------------------------------------


def compilation_units(r: int, k: int, c: int):
    """Return the list of (name, fn, example_specs) lowered by aot.py.

    ``r``: rows per block, ``k``: subspace width, ``c``: dense column block.
    """
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    return [
        ("gram", gram, (spec((r, k), f32),)),
        ("apply", apply_factor, (spec((r, k), f32), spec((k, k), f32))),
        ("proj", proj, (spec((r, k), f32), spec((r, c), f32))),
        ("probs_l1", probs_l1, (spec((r, c), f32), spec((r, 1), f32))),
        ("probs_l2", probs_l2, (spec((r, c), f32), spec((r, 1), f32))),
        ("power_iter", power_iter, (spec((k, k), f32), spec((k,), f32))),
        (
            "subspace_round",
            subspace_round,
            (spec((r, k), f32), spec((k, k), f32), spec((r, c), f32)),
        ),
    ]
