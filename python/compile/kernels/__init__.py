"""Layer-1 Pallas kernels for matsketch.

All kernels are written for TPU-style tiling (row tiles resident in VMEM,
MXU-friendly matmul accumulation with f32 preferred element type) but are
lowered with ``interpret=True`` so the resulting HLO is plain ops executable
by the CPU PJRT client in the Rust runtime. See DESIGN.md
§Hardware-Adaptation.
"""

from .gram import gram_block
from .apply import apply_block
from .proj import proj_block
from .probs import probs_block

__all__ = ["gram_block", "apply_block", "proj_block", "probs_block"]
