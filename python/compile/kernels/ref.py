"""Pure-jnp oracles for the Pallas kernels. The pytest suite asserts
allclose between each kernel and its oracle across a seeded sweep of
shapes/dtypes; this is the core L1 correctness signal.
"""

import jax.numpy as jnp


def gram_ref(y):
    return jnp.asarray(y, jnp.float32).T @ jnp.asarray(y, jnp.float32)


def apply_ref(y, t):
    return jnp.asarray(y, jnp.float32) @ jnp.asarray(t, jnp.float32)


def proj_ref(q, a):
    return jnp.asarray(q, jnp.float32).T @ jnp.asarray(a, jnp.float32)


def probs_ref(a, w, power=1):
    a = jnp.asarray(a, jnp.float32)
    mag = jnp.abs(a) if power == 1 else a * a
    return mag * jnp.asarray(w, jnp.float32)


def power_iter_ref(g, v0, iters=96):
    """Dominant eigenpair of a symmetric PSD K×K matrix by power iteration."""
    v = v0 / jnp.linalg.norm(v0)
    lam = jnp.float32(0.0)
    for _ in range(iters):
        w = g @ v
        lam = jnp.linalg.norm(w)
        v = w / jnp.maximum(lam, 1e-30)
    return lam, v
