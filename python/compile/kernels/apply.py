"""Pallas kernel: apply a small K×K factor to a tall-skinny block, Q = Y T.

Second half of the Cholesky-QR step: Rust computes T = L⁻ᵀ (K×K, trivially
small) from the Gram matrix produced by :mod:`gram`, then streams the same
row blocks of Y through this kernel to materialize the orthonormal basis Q.

Tiling mirrors gram.py: the grid walks TR-row tiles; T is broadcast to every
step (constant index map). Per-step VMEM: TR*K*2 + K*K floats ≈ 68 KB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _apply_kernel(y_ref, t_ref, o_ref):
    o_ref[...] = jnp.dot(y_ref[...], t_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_rows",))
def apply_block(y, t, *, tile_rows: int = 256):
    """Compute ``y @ t`` where ``y`` is (R, K) and ``t`` is (K, K), f32."""
    rows, k = y.shape
    assert t.shape == (k, k), (y.shape, t.shape)
    assert rows % tile_rows == 0, (rows, tile_rows)
    grid = (rows // tile_rows,)
    return pl.pallas_call(
        _apply_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_rows, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, k), jnp.float32),
        interpret=True,
    )(y, t)
