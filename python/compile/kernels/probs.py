"""Pallas kernel: entrywise sampling probabilities for a dense block.

Algorithm 1 (step 3) assigns p_ij = ρ_i · |A_ij| / ‖A_(i)‖₁. The Rust
coordinator precomputes the per-row scale w_i = ρ_i / ‖A_(i)‖₁ (and the
analogous scales for the baseline distributions — plain-L1, Row-L1, L2 with
w as 1/Z etc.) and streams dense blocks of A through this kernel to build
probability tables for the offline (alias-method) sampler.

The ``power`` switch selects |A_ij| (L1 family) vs A_ij² (L2 family) so one
artifact serves all distributions in the paper's §6 comparison.

Tiling: 2-D grid over (TR row tiles × C columns); the row-scale vector rides
along as a (TR, 1) block broadcast across the columns of each tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probs_kernel(a_ref, w_ref, p_ref, *, power: int):
    a = a_ref[...]
    mag = jnp.abs(a) if power == 1 else a * a
    p_ref[...] = mag * w_ref[...]  # (TR, C) * (TR, 1) broadcast


@functools.partial(jax.jit, static_argnames=("tile_rows", "power"))
def probs_block(a, w, *, tile_rows: int = 256, power: int = 1):
    """Entrywise probability table ``w_i * |a_ij|^power`` for f32 blocks.

    ``a`` is (R, C), ``w`` is (R, 1); returns (R, C).
    """
    rows, c = a.shape
    assert w.shape == (rows, 1), (a.shape, w.shape)
    assert rows % tile_rows == 0, (rows, tile_rows)
    assert power in (1, 2)
    grid = (rows // tile_rows,)
    kernel = functools.partial(_probs_kernel, power=power)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((tile_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, c), jnp.float32),
        interpret=True,
    )(a, w)
