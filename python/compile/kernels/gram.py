"""Pallas kernel: Gram matrix of a tall-skinny block, G = Yᵀ Y.

Used by the Rust evaluation pipeline's Cholesky-QR step: the coordinator
streams row blocks of the subspace-iteration iterate Y (R×K) through this
kernel and accumulates the K×K Gram matrices; the tiny Cholesky itself is
done in Rust (xla_extension 0.5.1 cannot run the LAPACK FFI custom-calls
that ``jnp.linalg.cholesky`` lowers to on CPU).

Tiling: the grid walks TR-row tiles of Y. Each step loads one (TR, K) tile
into VMEM and accumulates its (K, K) outer Gram into the single output
block. VMEM working set per step: TR*K + K*K floats (256*32 + 32*32 ≈ 36 KB)
— far below the ~16 MB VMEM budget; on a real TPU the jnp.dot maps to one
MXU pass per tile (K padded to the 128 lane on real hardware).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(y_ref, o_ref):
    # First grid step initializes the accumulator; later steps accumulate.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tile = y_ref[...]
    o_ref[...] += jnp.dot(tile.T, tile, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_rows",))
def gram_block(y, *, tile_rows: int = 256):
    """Compute ``y.T @ y`` for a tall-skinny f32 block ``y`` of shape (R, K).

    R must be a multiple of ``tile_rows``; the Rust side zero-pads tails
    (zero rows contribute nothing to the Gram sum, so padding is exact).
    """
    rows, k = y.shape
    assert rows % tile_rows == 0, (rows, tile_rows)
    grid = (rows // tile_rows,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_rows, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((k, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, k), jnp.float32),
        interpret=True,
    )(y)
