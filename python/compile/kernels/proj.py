"""Pallas kernel: projection coefficients P = Qᵀ A for one column block.

The Figure-1 quality metric ‖P_k^B A‖_F / ‖A_k‖_F reduces to accumulating
‖Qᵀ A‖_F² over column blocks of A (Q orthonormal m×k). Rust densifies A
block-by-block from CSR and streams (R×C) blocks through this kernel
together with the matching (R×K) row blocks of Q; the K×C products are
accumulated over row tiles here and over row *blocks* in Rust.

Tiling: grid over TR-row tiles; each step does a (K×TR)·(TR×C) MXU pass and
accumulates into the K×C output. Per-step VMEM: TR*(K+C) + K*C floats
(256*(32+512) + 32*512 ≈ 620 KB) — sized to stay comfortably inside VMEM
while keeping the MXU busy with a C=512-wide pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _proj_kernel(q_ref, a_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        q_ref[...].T, a_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tile_rows",))
def proj_block(q, a, *, tile_rows: int = 256):
    """Compute ``q.T @ a`` for f32 blocks q (R, K), a (R, C)."""
    rows, k = q.shape
    rows_a, c = a.shape
    assert rows == rows_a, (q.shape, a.shape)
    assert rows % tile_rows == 0, (rows, tile_rows)
    grid = (rows // tile_rows,)
    return pl.pallas_call(
        _proj_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_rows, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, c), jnp.float32),
        interpret=True,
    )(q, a)
