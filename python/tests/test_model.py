"""L2 correctness: composed graphs (cholesky-QR round trip, power_iter,
subspace_round fusion) against numpy references."""

import numpy as np

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def rng_mat(seed, shape, scale=1.0):
    r = np.random.default_rng(seed)
    return (r.standard_normal(shape) * scale).astype(np.float32)


def test_cholesky_qr_roundtrip_via_graphs():
    """gram → (numpy cholesky, standing in for Rust) → apply ⇒ Q orthonormal."""
    y = rng_mat(0, (2048, 32))
    (g,) = model.gram(jnp.asarray(y))
    g = np.asarray(g).astype(np.float64)
    l = np.linalg.cholesky(g + 1e-8 * np.eye(32))
    t = np.linalg.inv(l).T.astype(np.float32)  # Rust computes this k×k inverse
    (q,) = model.apply_factor(jnp.asarray(y), jnp.asarray(t))
    q = np.asarray(q)
    np.testing.assert_allclose(q.T @ q, np.eye(32), atol=5e-3)


def test_power_iter_matches_eigh():
    g0 = rng_mat(1, (32, 32))
    g = (g0 @ g0.T).astype(np.float32)
    lam, v = model.power_iter(jnp.asarray(g), jnp.ones(32, np.float32))
    lam = float(lam)
    evals = np.linalg.eigvalsh(g.astype(np.float64))
    assert abs(lam - evals[-1]) / evals[-1] < 1e-3
    # v is a unit eigenvector for lam
    v = np.asarray(v, np.float64)
    np.testing.assert_allclose(np.linalg.norm(v), 1.0, rtol=1e-4)
    np.testing.assert_allclose(g @ v, lam * v, rtol=0, atol=1e-2 * lam)


def test_power_iter_ref_agrees():
    g0 = rng_mat(2, (16, 16))
    g = (g0 @ g0.T).astype(np.float32)
    lam_ref, _ = ref.power_iter_ref(jnp.asarray(g), jnp.ones(16, np.float32))
    lam, _ = model.power_iter(jnp.asarray(g), jnp.ones(16, np.float32), iters=96)
    np.testing.assert_allclose(float(lam), float(lam_ref), rtol=1e-4)


def test_subspace_round_fusion_equals_two_calls():
    y = rng_mat(3, (512, 32))
    t = rng_mat(4, (32, 32), scale=0.1)
    a = rng_mat(5, (512, 512))
    q1, p1 = model.subspace_round(jnp.asarray(y), jnp.asarray(t), jnp.asarray(a))
    (q2,) = model.apply_factor(jnp.asarray(y), jnp.asarray(t))
    (p2,) = model.proj(q2, jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-5, atol=1e-3)


def test_probs_graphs_sum_to_one_when_normalized():
    """With w_i = ρ_i/‖A_(i)‖₁ the block table sums to Σρ_i (=1 over all rows)."""
    a = rng_mat(6, (256, 512), scale=2.0)
    row_l1 = np.abs(a).sum(axis=1, keepdims=True)
    rho = np.full((256, 1), 1.0 / 256, np.float32)
    w = (rho / row_l1).astype(np.float32)
    (p,) = model.probs_l1(jnp.asarray(a), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(p).sum(), 1.0, rtol=1e-4)
