"""L1 correctness: each Pallas kernel vs its pure-jnp oracle.

hypothesis is unavailable in this image, so shape/dtype/seed coverage is a
seeded deterministic sweep (same coverage intent: many shapes including
non-square, tile-boundary, and degenerate-content cases).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.kernels import apply_block, gram_block, probs_block, proj_block
from compile.kernels import ref

SEEDS = [0, 1, 2]
RK_SHAPES = [(256, 32), (512, 32), (2048, 32), (256, 8), (1024, 16), (256, 1)]
TILES = [256]


def rng_mat(seed, shape, scale=1.0, dtype=np.float32):
    r = np.random.default_rng(seed)
    return (r.standard_normal(shape) * scale).astype(dtype)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("rows,k", RK_SHAPES)
def test_gram_matches_ref(seed, rows, k):
    y = rng_mat(seed, (rows, k))
    got = np.asarray(gram_block(jnp.asarray(y)))
    want = np.asarray(ref.gram_ref(y))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("rows,k", RK_SHAPES)
def test_apply_matches_ref(seed, rows, k):
    y = rng_mat(seed, (rows, k))
    t = rng_mat(seed + 100, (k, k))
    got = np.asarray(apply_block(jnp.asarray(y), jnp.asarray(t)))
    want = np.asarray(ref.apply_ref(y, t))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize(
    "rows,k,c", [(256, 32, 512), (512, 32, 128), (2048, 32, 512), (256, 8, 64)]
)
def test_proj_matches_ref(seed, rows, k, c):
    q = rng_mat(seed, (rows, k))
    a = rng_mat(seed + 7, (rows, c))
    got = np.asarray(proj_block(jnp.asarray(q), jnp.asarray(a)))
    want = np.asarray(ref.proj_ref(q, a))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-3)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("power", [1, 2])
@pytest.mark.parametrize("rows,c", [(256, 512), (2048, 512), (512, 64)])
def test_probs_matches_ref(seed, power, rows, c):
    a = rng_mat(seed, (rows, c), scale=3.0)
    w = np.abs(rng_mat(seed + 1, (rows, 1))) + 0.01
    got = np.asarray(probs_block(jnp.asarray(a), jnp.asarray(w), power=power))
    want = np.asarray(ref.probs_ref(a, w, power=power))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_gram_zero_padding_exact():
    """Zero rows must contribute nothing — Rust relies on this for tails."""
    y = rng_mat(3, (512, 32))
    y_padded = np.zeros((2048, 32), np.float32)
    y_padded[:512] = y
    got = np.asarray(gram_block(jnp.asarray(y_padded)))
    want = np.asarray(ref.gram_ref(y))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_proj_zero_padding_exact():
    q = rng_mat(4, (300, 32)).astype(np.float32)
    a = rng_mat(5, (300, 128)).astype(np.float32)
    qp = np.zeros((512, 32), np.float32)
    ap = np.zeros((512, 128), np.float32)
    qp[:300], ap[:300] = q, a
    got = np.asarray(proj_block(jnp.asarray(qp), jnp.asarray(ap)))
    want = np.asarray(ref.proj_ref(q, a))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-3)


def test_probs_negative_entries_abs():
    a = -np.abs(rng_mat(0, (256, 64)))
    w = np.ones((256, 1), np.float32)
    got = np.asarray(probs_block(jnp.asarray(a), jnp.asarray(w), power=1))
    assert (got >= 0).all()
    np.testing.assert_allclose(got, np.abs(a), rtol=1e-6)


def test_gram_psd():
    y = rng_mat(9, (1024, 16))
    g = np.asarray(gram_block(jnp.asarray(y)))
    np.testing.assert_allclose(g, g.T, rtol=1e-5, atol=1e-4)
    evals = np.linalg.eigvalsh(g.astype(np.float64))
    assert evals.min() >= -1e-2
