"""AOT path: lowering produces parseable HLO text with the expected
entry-computation signatures, and the manifest describes it faithfully."""

import json
import os
import subprocess
import sys
import tempfile

import jax

from compile import aot, model


def test_lower_unit_produces_hlo_text():
    units = model.compilation_units(256, 32, 512)
    name, fn, specs = units[0]
    text = aot.lower_unit(fn, specs)
    assert "HloModule" in text
    assert "f32[256,32]" in text


def test_power_iter_hlo_has_while_loop():
    units = {n: (f, s) for n, f, s in model.compilation_units(256, 32, 512)}
    fn, specs = units["power_iter"]
    text = aot.lower_unit(fn, specs)
    assert "while" in text  # control flow survives lowering


def test_aot_main_writes_manifest(tmp_path):
    out = str(tmp_path / "arts")
    argv = sys.argv
    sys.argv = ["aot", "--out", out]
    try:
        aot.main()
    finally:
        sys.argv = argv
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    names = {e["op"] for e in manifest["entries"]}
    assert {"gram", "apply", "proj", "probs_l1", "probs_l2",
            "power_iter", "subspace_round"} <= names
    for e in manifest["entries"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path), e["file"]
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head
        assert e["inputs"], e
        assert e["outputs"], e
