//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by matsketch.
#[derive(Error, Debug)]
pub enum Error {
    /// Matrix shapes are inconsistent for the requested operation.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Invalid argument / configuration value.
    #[error("invalid argument: {0}")]
    InvalidArg(String),

    /// A numeric routine failed to converge or hit a degenerate input.
    #[error("numeric failure: {0}")]
    Numeric(String),

    /// The AOT artifact directory / manifest is missing or malformed.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// JSON / config / matrix-market parse error.
    #[error("parse error: {0}")]
    Parse(String),

    /// Underlying XLA / PJRT error.
    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),

    /// I/O error.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// Streaming pipeline failure (worker panic, channel torn down, ...).
    #[error("pipeline error: {0}")]
    Pipeline(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper: shape-mismatch error with a formatted message.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// Helper: invalid-argument error with a formatted message.
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::InvalidArg(msg.into())
    }
}
