//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls — `thiserror` is not available in
//! the offline build image (DESIGN.md §4).

use std::fmt;

/// Errors produced by matsketch.
#[derive(Debug)]
pub enum Error {
    /// Matrix shapes are inconsistent for the requested operation.
    Shape(String),

    /// Invalid argument / configuration value.
    InvalidArg(String),

    /// A numeric routine failed to converge or hit a degenerate input.
    Numeric(String),

    /// The AOT artifact directory / manifest is missing or malformed.
    Artifact(String),

    /// JSON / config / matrix-market parse error.
    Parse(String),

    /// Underlying XLA / PJRT error (only produced by the `pjrt` feature).
    Xla(String),

    /// I/O error.
    Io(std::io::Error),

    /// Streaming pipeline failure (worker panic, channel torn down, ...).
    Pipeline(String),

    /// A generation pin that cannot be served: not yet published, or
    /// retired out of the live chain's retained window.
    Generation(String),

    /// The server shed this request under load (wire `Overloaded`, v6).
    /// Retryable; `retry_after_us` is the server's backoff hint (0 =
    /// none given).
    Overloaded {
        /// Human-readable detail from the server.
        message: String,
        /// Server-suggested backoff before retrying, in microseconds.
        retry_after_us: u64,
    },

    /// A per-request deadline expired before the operation completed
    /// (including any retry backoff the client would still have spent).
    Deadline(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::InvalidArg(m) => write!(f, "invalid argument: {m}"),
            Error::Numeric(m) => write!(f, "numeric failure: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Pipeline(m) => write!(f, "pipeline error: {m}"),
            Error::Generation(m) => write!(f, "generation error: {m}"),
            Error::Overloaded {
                message,
                retry_after_us,
            } => write!(f, "overloaded: {message} (retry after {retry_after_us}\u{b5}s)"),
            Error::Deadline(m) => write!(f, "deadline exceeded: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper: shape-mismatch error with a formatted message.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// Helper: invalid-argument error with a formatted message.
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::InvalidArg(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_are_stable() {
        assert_eq!(Error::shape("a != b").to_string(), "shape mismatch: a != b");
        assert_eq!(Error::invalid("bad s").to_string(), "invalid argument: bad s");
        assert_eq!(Error::Parse("x".into()).to_string(), "parse error: x");
        assert_eq!(
            Error::Overloaded {
                message: "shed".into(),
                retry_after_us: 250
            }
            .to_string(),
            "overloaded: shed (retry after 250\u{b5}s)"
        );
        assert_eq!(
            Error::Deadline("query".into()).to_string(),
            "deadline exceeded: query"
        );
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "eof");
        let e: Error = io.into();
        assert!(e.to_string().starts_with("io error:"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
