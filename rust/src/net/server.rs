//! The TCP serving front: a multi-threaded acceptor that owns a
//! [`SketchStore`], lazily opens stored sketches into shared immutable
//! [`ServableSketch`]es, and dispatches decoded wire requests onto the
//! existing in-process [`QueryServer`] worker pools.
//!
//! One handler thread per connection reads frames, answers them **in
//! order** (so client-side pipelining gets in-order responses), and
//! applies the wire error discipline: payload faults answer with the
//! echoed request id and keep the connection; frame faults (bad magic /
//! version / oversized) answer best-effort and close, because the frame
//! boundary is lost. A connection limit, read/write timeouts, and a
//! graceful shutdown path (the wire `Shutdown` sentinel, or
//! [`NetServer::shutdown`] in-process) bound resource use.
//!
//! The in-process path stays the single source of truth: every answer is
//! produced by the same [`ServableSketch::answer`] the local
//! [`QueryServer`] runs, and the loopback integration test pins remote
//! bytes to in-process bytes for every query kind.
//!
//! Live chains ([`crate::serve::live`]) attach through
//! [`NetServer::attach_live`]: opening their key routes queries to the
//! chain's [`LiveReader`] instead of a frozen store load, generation pins
//! and `GenPoll` work over the wire, and a pin the chain cannot honour is
//! a payload-level `generation` fault that keeps the connection alive.
//!
//! Query frames carrying a nonzero trace context (protocol v5) get a
//! server-side span tree: a `request` root opened at the frame clock,
//! with `frame_decode`, queue / execution spans from the worker pool,
//! and `reply_write` as children. Completed trees land in the process
//! trace ring ([`crate::obs::trace`]), from which the `TraceDump`
//! opcode serves them back to clients.
//!
//! Two resilience layers ride the same loop. **Load shedding**
//! ([`NetServerConfig::shed_high_water`]): queries in flight across all
//! connections are counted, and past the high-water mark new queries
//! are answered with a typed `Overloaded` fault (protocol v6) carrying
//! a depth-proportional retry-after hint instead of being queued —
//! Ping, Stats, and the other control ops always answer, so an
//! overloaded server stays observable. **Fault injection**
//! ([`NetServerConfig::chaos`]): an attached [`FaultPlan`] is consulted
//! once per decoded frame with this connection's accept-order id and
//! the frame's index, and the verdict (disconnect / partial write /
//! corrupted frame / tarpit) is applied deterministically — the chaos
//! suites replay exact failure schedules against a real server.

use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::{QueryRequest, SketchInfo};
use crate::error::{Error, Result};
use crate::obs::trace::{self, SpanCtx};
use crate::obs::{self, Counter, Gauge, Hist};
use crate::serve::{LiveReader, QueryServer, ServableSketch, SketchStore, StoreKey};
use crate::{debug_log, info, warn_log};

use super::chaos::{FaultKind, FaultPlan};
use super::wire::{
    self, encode_response, encode_response_v, ErrCode, Request, Response, WireFault,
    FRAME_HEADER_LEN, MAX_PAYLOAD, WIRE_VERSION,
};

/// Tuning knobs for [`NetServer`].
#[derive(Clone, Debug)]
pub struct NetServerConfig {
    /// Query workers spawned per opened sketch (min 1).
    pub workers_per_sketch: usize,
    /// Concurrent connections accepted before new ones get a typed
    /// `busy` error.
    pub max_connections: usize,
    /// Per-connection read timeout (idle connections are reaped after
    /// this long); `None` = wait forever.
    pub read_timeout: Option<Duration>,
    /// Per-connection write timeout.
    pub write_timeout: Option<Duration>,
    /// Minimum occupied row groups before a matvec is row-parallelized
    /// across the worker pool (see
    /// [`QueryServer::DEFAULT_SPLIT_MIN_GROUPS`]). Lowering it (down to
    /// 1) forces splitting on small sketches — the lever the trace
    /// integration suite uses to pin per-window span trees.
    pub split_min_groups: usize,
    /// Load-shedding high-water mark: when this many queries are in
    /// flight across all connections, further queries are answered with
    /// a typed `Overloaded` fault (with a retry-after hint) instead of
    /// queued. 0 disables shedding. Control ops (Ping, Stats, opens,
    /// shutdown) are never shed.
    pub shed_high_water: usize,
    /// Deterministic fault-injection plan (`matsketch serve --chaos`,
    /// chaos test suites). `None` — the default — injects nothing and
    /// costs nothing.
    pub chaos: Option<Arc<FaultPlan>>,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            workers_per_sketch: 4,
            max_connections: 64,
            read_timeout: Some(Duration::from_secs(60)),
            write_timeout: Some(Duration::from_secs(60)),
            split_min_groups: QueryServer::DEFAULT_SPLIT_MIN_GROUPS,
            shed_high_water: 0,
            chaos: None,
        }
    }
}

/// Counters reported at shutdown.
#[derive(Clone, Debug, Default)]
pub struct NetServerStats {
    /// Connections accepted (including ones turned away busy).
    pub connections: u64,
    /// Frames answered (all response kinds).
    pub frames: u64,
    /// Typed error responses among them.
    pub faults: u64,
}

/// One opened sketch: its in-process query worker pool (which owns the
/// shared immutable [`ServableSketch`]) plus wire-facing identity.
/// Dropping the last `Arc` drops the pool's job sender, which winds the
/// workers down.
struct SketchService {
    server: QueryServer,
    info: SketchInfo,
    fingerprint: u64,
}

/// One connection-scoped handle slot: a frozen store-backed sketch
/// (generation 0 forever) or a live generation chain.
enum Opened {
    Frozen(Arc<SketchService>),
    Live { reader: LiveReader, info: SketchInfo },
}

impl Opened {
    fn info(&self) -> &SketchInfo {
        match self {
            Opened::Frozen(svc) => &svc.info,
            Opened::Live { info, .. } => info,
        }
    }
}

struct Shared {
    store: SketchStore,
    cfg: NetServerConfig,
    addr: SocketAddr,
    shutdown: AtomicBool,
    conn_seq: AtomicU64,
    conns: AtomicUsize,
    /// Queries currently executing (all connections); the load-shedding
    /// gauge compared against `cfg.shed_high_water`.
    inflight: AtomicUsize,
    connections: AtomicU64,
    frames: AtomicU64,
    faults: AtomicU64,
    /// Lazily opened sketches, shared across connections, keyed by store
    /// file name.
    services: Mutex<HashMap<String, Arc<SketchService>>>,
    /// Live generation chains attached in-process, keyed by store file
    /// name; opening their key routes to the chain instead of the store.
    live_chains: Mutex<HashMap<String, (StoreKey, LiveReader)>>,
    /// Live connection sockets, closed to unblock handlers at shutdown.
    live: Mutex<HashMap<u64, TcpStream>>,
}

impl Shared {
    fn shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// Flip the shutdown flag and poke the acceptor awake with a
    /// throwaway loopback connection.
    fn trigger_shutdown(&self) {
        if !self.shutdown.swap(true, Ordering::AcqRel) {
            let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        }
    }
}

/// The network server: binds, accepts, serves until shut down.
pub struct NetServer {
    shared: Arc<Shared>,
    acceptor: JoinHandle<()>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7300"`, port 0 for ephemeral) over
    /// `store` and start accepting in a background thread.
    pub fn bind(store: SketchStore, addr: &str, cfg: NetServerConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shared = Arc::new(Shared {
            store,
            cfg,
            addr: local,
            shutdown: AtomicBool::new(false),
            conn_seq: AtomicU64::new(0),
            conns: AtomicUsize::new(0),
            inflight: AtomicUsize::new(0),
            connections: AtomicU64::new(0),
            frames: AtomicU64::new(0),
            faults: AtomicU64::new(0),
            services: Mutex::new(HashMap::new()),
            live_chains: Mutex::new(HashMap::new()),
            live: Mutex::new(HashMap::new()),
        });
        let acceptor = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(listener, shared))
        };
        info!("net: serving on {local}");
        Ok(NetServer { shared, acceptor })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Attach a live generation chain under `key`: remote opens of that
    /// key route to the chain's reader (pins, `GenPoll`, per-generation
    /// answers) instead of loading a frozen sketch from the store.
    /// Re-attaching replaces the previous chain.
    pub fn attach_live(&self, key: &StoreKey, reader: LiveReader) {
        self.shared
            .live_chains
            .lock()
            .expect("live-chain registry poisoned")
            .insert(key.file_name(), (key.clone(), reader));
    }

    /// Whether a shutdown has been requested (wire sentinel or local).
    pub fn is_shutting_down(&self) -> bool {
        self.shared.shutting_down()
    }

    /// Request a graceful shutdown and wait for the acceptor and every
    /// connection handler to finish.
    pub fn shutdown(self) -> NetServerStats {
        self.shared.trigger_shutdown();
        self.wait()
    }

    /// Wait until a shutdown is requested (e.g. by the wire sentinel)
    /// and teardown completes, then report stats.
    pub fn wait(self) -> NetServerStats {
        let _ = self.acceptor.join();
        NetServerStats {
            // the acceptor join above already synchronizes these writers
            connections: self.shared.connections.load(Ordering::Relaxed),
            frames: self.shared.frames.load(Ordering::Relaxed),
            faults: self.shared.faults.load(Ordering::Relaxed),
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(pair) => pair,
            Err(e) => {
                if shared.shutting_down() {
                    break;
                }
                warn_log!("net: accept failed: {e}");
                continue;
            }
        };
        if shared.shutting_down() {
            // the wake-up poke, or a client racing the shutdown
            refuse(stream, ErrCode::ShuttingDown, "server is shutting down");
            break;
        }
        shared.connections.fetch_add(1, Ordering::Relaxed);
        obs::global().inc(Counter::NetConnAccepted);
        // reap finished handler threads so a long-lived server doesn't
        // accumulate join handles
        handlers.retain(|h| !h.is_finished());
        if shared.conns.load(Ordering::Relaxed) >= shared.cfg.max_connections {
            shared.faults.fetch_add(1, Ordering::Relaxed);
            refuse(stream, ErrCode::Busy, "connection limit reached");
            continue;
        }
        shared.conns.fetch_add(1, Ordering::Relaxed);
        obs::global().gauge_add(Gauge::NetConnections, 1);
        let id = shared.conn_seq.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            shared.live.lock().expect("live registry poisoned").insert(id, clone);
        }
        debug_log!("net: connection {id} from {peer}");
        let shared2 = Arc::clone(&shared);
        handlers.push(std::thread::spawn(move || {
            handle_connection(&shared2, stream, id);
            shared2.conns.fetch_sub(1, Ordering::Relaxed);
            obs::global().gauge_add(Gauge::NetConnections, -1);
            obs::global().inc(Counter::NetConnClosed);
            shared2.live.lock().expect("live registry poisoned").remove(&id);
            debug_log!("net: connection {id} closed");
        }));
    }
    // teardown: close every live socket to unblock blocked readers, then
    // join the handlers
    for (_, s) in shared.live.lock().expect("live registry poisoned").drain() {
        let _ = s.shutdown(Shutdown::Both);
    }
    for h in handlers {
        let _ = h.join();
    }
    // dropping the services drops each QueryServer's job sender, winding
    // the worker pools down
    shared.services.lock().expect("services registry poisoned").clear();
    info!("net: shut down cleanly");
}

/// Turn a connection away with one typed error frame (request id 0: no
/// request was read).
fn refuse(stream: TcpStream, code: ErrCode, message: &str) {
    obs::global().inc(fault_counter(code));
    debug_log!("net: refusing connection: {message} ({})", code.name());
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let mut w = BufWriter::new(stream);
    let resp = Response::Error { code, message: message.into(), retry_after_us: 0 };
    let bytes = encode_response(0, &resp);
    if wire::write_frame(&mut w, &bytes).is_ok() {
        obs::global().add(Counter::NetBytesOut, bytes.len() as u64);
    }
}

/// The per-code fault counter a typed error response increments.
fn fault_counter(code: ErrCode) -> Counter {
    match code {
        ErrCode::Malformed => Counter::FaultMalformed,
        ErrCode::BadVersion => Counter::FaultBadVersion,
        ErrCode::Oversized => Counter::FaultOversized,
        ErrCode::UnknownOpcode => Counter::FaultUnknownOpcode,
        ErrCode::BadHandle => Counter::FaultBadHandle,
        ErrCode::Store => Counter::FaultStore,
        ErrCode::Query => Counter::FaultQuery,
        ErrCode::Busy => Counter::FaultBusy,
        ErrCode::ShuttingDown => Counter::FaultShuttingDown,
        ErrCode::Generation => Counter::FaultGeneration,
        ErrCode::Overloaded => Counter::FaultOverloaded,
        ErrCode::Timeout => Counter::FaultTimeout,
    }
}

/// The per-opcode request counter a decoded request increments.
fn request_counter(req: &Request) -> Counter {
    match req {
        Request::Ping => Counter::ReqPing,
        Request::ListSketches => Counter::ReqList,
        Request::OpenSketch(_) => Counter::ReqOpen,
        Request::Shutdown => Counter::ReqShutdown,
        Request::Stats => Counter::ReqStats,
        Request::TraceDump { .. } => Counter::ReqTraceDump,
        Request::GenPoll { .. } => Counter::ReqGenPoll,
        Request::Query { query, .. } => match query {
            QueryRequest::Matvec(_) => Counter::ReqMatvec,
            QueryRequest::MatvecT(_) => Counter::ReqMatvecT,
            QueryRequest::MatvecBatch(_) => Counter::ReqMatvecBatch,
            QueryRequest::Row(_) => Counter::ReqRow,
            QueryRequest::Col(_) => Counter::ReqCol,
            QueryRequest::TopK(_) => Counter::ReqTopK,
        },
    }
}

fn handle_connection(shared: &Shared, stream: TcpStream, conn_id: u64) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(shared.cfg.read_timeout);
    let _ = stream.set_write_timeout(shared.cfg.write_timeout);
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            warn_log!("net: could not clone connection stream: {e}");
            return;
        }
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = BufWriter::new(stream);
    // connection-scoped handle table: index = handle value
    let mut handles: Vec<Opened> = Vec::new();
    // decoded-frame index on this connection: the chaos plan's second
    // coordinate
    let mut frame_idx: u64 = 0;

    let reg = obs::global();
    loop {
        let header = match wire::read_frame_header(&mut reader) {
            Ok(None) => break, // clean close
            Ok(Some(h)) => h,
            Err(e) => {
                // a half-written header (truncated-length corpus case):
                // reply best-effort, then close — the framing is gone
                // (and so is the peer's version: reply at ours).
                // Timeouts reap idle connections silently.
                if e.kind() == io::ErrorKind::UnexpectedEof {
                    send_fault(
                        shared,
                        &mut writer,
                        WIRE_VERSION,
                        0,
                        ErrCode::Malformed,
                        &e.to_string(),
                    );
                }
                break;
            }
        };
        reg.add(Counter::NetBytesIn, FRAME_HEADER_LEN as u64);
        // one chaos verdict per frame, at deterministic coordinates; a
        // tarpit stalls here (the "slow server" the client's deadline
        // machinery is tested against), a disconnect drops the
        // connection before the frame is even parsed, and the
        // write-side faults are applied after the reply is encoded
        let injected = shared.cfg.chaos.as_ref().and_then(|plan| {
            let verdict = plan.fault_for(conn_id, frame_idx);
            if let Some(FaultKind::Tarpit(ms)) = verdict {
                std::thread::sleep(Duration::from_millis(ms));
            }
            verdict
        });
        frame_idx += 1;
        if matches!(injected, Some(FaultKind::Disconnect)) {
            debug_log!("net: chaos disconnect on connection {conn_id} frame {}", frame_idx - 1);
            break;
        }
        // answers go out at the version the request arrived in, so a v1
        // peer never receives a v2 frame; frame faults (version unknown
        // or unacceptable) reply best-effort at the current version
        let mut started: Option<Instant> = None;
        // a sampled request's span tree: the root guard stays open until
        // the reply is on the wire, then the tree goes to the trace ring
        let mut traced: Option<(Arc<trace::ActiveTrace>, trace::Span)> = None;
        let (version, request_id, mut resp, close_after) =
            match wire::parse_frame_header(&header) {
                Err(WireFault { code, message }) => {
                    // frame fault: typed reply, then drop the connection
                    (
                        WIRE_VERSION,
                        0,
                        Response::Error { code, message, retry_after_us: 0 },
                        true,
                    )
                }
                Ok(h) => {
                    let payload = match wire::read_payload(&mut reader, h.len) {
                        Ok(p) => p,
                        Err(e) => {
                            // mid-payload disconnect / timeout
                            if e.kind() == io::ErrorKind::UnexpectedEof {
                                send_fault(
                                    shared,
                                    &mut writer,
                                    h.version,
                                    h.request_id,
                                    ErrCode::Malformed,
                                    &e.to_string(),
                                );
                            }
                            break;
                        }
                    };
                    reg.add(Counter::NetBytesIn, u64::from(h.len));
                    started = reg.enabled().then(Instant::now);
                    match wire::decode_request(h.version, h.opcode, &payload) {
                        // payload fault: typed reply, connection stays up
                        Err(WireFault { code, message }) => (
                            h.version,
                            h.request_id,
                            Response::Error { code, message, retry_after_us: 0 },
                            false,
                        ),
                        Ok(req) => {
                            let is_shutdown = matches!(req, Request::Shutdown);
                            reg.inc(request_counter(&req));
                            if let Request::Query { trace: id, query, .. } = &req {
                                if *id != 0 {
                                    // the client chose this request: open
                                    // the server-side root at the frame
                                    // clock and back-date the decode span
                                    let t0 = started.unwrap_or_else(Instant::now);
                                    let active = trace::ActiveTrace::begin_at(*id, t0);
                                    let mut root = active.span_at(0, "request", t0);
                                    root.note("op", query.op_name());
                                    root.note("request_id", h.request_id.to_string());
                                    active.record(root.id(), "frame_decode", t0, Instant::now());
                                    traced = Some((active, root));
                                }
                            }
                            let ctx = traced.as_ref().map(|(_, root)| root.ctx());
                            (
                                h.version,
                                h.request_id,
                                answer_with_shedding(shared, &mut handles, req, ctx),
                                is_shutdown,
                            )
                        }
                    }
                }
            };
        let is_shutdown_ack = matches!(resp, Response::ShuttingDown);
        let mut frame_bytes = encode_response_v(version, request_id, &resp);
        if frame_bytes.len() - FRAME_HEADER_LEN > MAX_PAYLOAD as usize {
            // the answer itself busts the frame cap (giant matvec result /
            // slice): the wire contract still owes the client a typed
            // error, not a frame its own parser must reject
            resp = Response::Error {
                code: ErrCode::Oversized,
                message: format!(
                    "answer of {} bytes exceeds the {MAX_PAYLOAD}-byte frame cap; \
                     narrow the query",
                    frame_bytes.len() - FRAME_HEADER_LEN
                ),
                retry_after_us: 0,
            };
            frame_bytes = encode_response_v(version, request_id, &resp);
        }
        if let Response::Error { code, message, .. } = &resp {
            shared.faults.fetch_add(1, Ordering::Relaxed);
            reg.inc(fault_counter(*code));
            debug_log!("net: request {request_id} faulted: {message} ({})", code.name());
        }
        shared.frames.fetch_add(1, Ordering::Relaxed);
        if let Some(t0) = started {
            reg.record_duration(Hist::NetRequestUs, t0.elapsed());
        }
        match injected {
            // the write-side chaos faults: put a torn or corrupted reply
            // on the wire, then drop the connection — the client must
            // classify either as retryable wire damage, never as data
            Some(FaultKind::Partial) => {
                use std::io::Write as _;
                let half = frame_bytes.len() / 2;
                let head = frame_bytes.get(..half).unwrap_or(&frame_bytes);
                if writer.write_all(head).is_ok() {
                    let _ = writer.flush();
                }
                debug_log!("net: chaos partial write on connection {conn_id}");
                break;
            }
            Some(FaultKind::Corrupt) => {
                // flip the first magic byte: the damage is guaranteed
                // detectable (a header fault), never a silently wrong
                // payload value
                if let Some(b) = frame_bytes.first_mut() {
                    *b ^= 0xFF;
                }
                let _ = wire::write_frame(&mut writer, &frame_bytes);
                debug_log!("net: chaos corrupt frame on connection {conn_id}");
                break;
            }
            _ => {}
        }
        let reply_t0 = traced.as_ref().map(|_| Instant::now());
        let write_err = wire::write_frame(&mut writer, &frame_bytes).err();
        let wrote = write_err.is_none();
        if wrote {
            reg.add(Counter::NetBytesOut, frame_bytes.len() as u64);
        }
        if let Some((active, mut root)) = traced.take() {
            if let Some(t0) = reply_t0 {
                active.record(root.id(), "reply_write", t0, Instant::now());
            }
            root.note("bytes_out", frame_bytes.len().to_string());
            root.finish();
            trace::finish(&active);
        }
        if is_shutdown_ack {
            // trigger only after the acknowledgement is on the wire, so
            // teardown (which force-closes live sockets) cannot race the
            // client out of its reply
            shared.trigger_shutdown();
        }
        if let Some(e) = write_err {
            // a stalled peer hit the write timeout: owe it the typed
            // fault before closing (best-effort — the socket may still
            // be wedged, but the fault is counted either way)
            if matches!(e.kind(), io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock) {
                send_fault(
                    shared,
                    &mut writer,
                    version,
                    request_id,
                    ErrCode::Timeout,
                    "response write timed out; closing connection",
                );
            }
            break;
        }
        if close_after {
            break;
        }
    }
}

/// Best-effort typed error reply for faults where the connection is about
/// to close anyway; write errors are ignored (the peer may be gone). The
/// reply goes out at `version` — the faulting frame's own, when its
/// header parsed far enough to know it — so even error frames honour the
/// "a v1 peer never receives a v2 frame" contract.
fn send_fault(
    shared: &Shared,
    writer: &mut BufWriter<TcpStream>,
    version: u16,
    request_id: u64,
    code: ErrCode,
    message: &str,
) {
    shared.faults.fetch_add(1, Ordering::Relaxed);
    shared.frames.fetch_add(1, Ordering::Relaxed);
    obs::global().inc(fault_counter(code));
    debug_log!("net: request {request_id} faulted: {message} ({})", code.name());
    let resp = Response::Error { code, message: message.into(), retry_after_us: 0 };
    let bytes = encode_response_v(version, request_id, &resp);
    if wire::write_frame(writer, &bytes).is_ok() {
        obs::global().add(Counter::NetBytesOut, bytes.len() as u64);
    }
}

/// Dispatch one request through the load-shedding gate: queries past
/// the in-flight high-water mark are answered with a typed `Overloaded`
/// fault carrying a depth-proportional retry-after hint; control ops
/// (Ping, Stats, opens, shutdown) always execute, so an overloaded
/// server stays observable and stoppable.
fn answer_with_shedding(
    shared: &Shared,
    handles: &mut Vec<Opened>,
    req: Request,
    ctx: Option<SpanCtx>,
) -> Response {
    if !matches!(req, Request::Query { .. }) {
        return answer(shared, handles, req, ctx);
    }
    let high = shared.cfg.shed_high_water;
    let depth = shared.inflight.load(Ordering::Relaxed);
    if high > 0 && depth >= high {
        // the hint grows with the backlog past the mark, so a burst of
        // shed clients spreads its retries instead of re-synchronizing
        let hint = 500u64.saturating_mul(depth.saturating_sub(high) as u64 + 1);
        return Response::Error {
            code: ErrCode::Overloaded,
            message: format!("{depth} queries in flight over high water {high}; request shed"),
            retry_after_us: hint,
        };
    }
    shared.inflight.fetch_add(1, Ordering::Relaxed);
    let resp = answer(shared, handles, req, ctx);
    shared.inflight.fetch_sub(1, Ordering::Relaxed);
    resp
}

/// Map a query-path failure onto its wire fault class: generation-pin
/// rejections keep their own code (clients distinguish "pin retired"
/// from "query malformed"), everything else is a query fault.
fn query_fault(e: Error) -> Response {
    let code = match e {
        Error::Generation(_) => ErrCode::Generation,
        _ => ErrCode::Query,
    };
    Response::Error { code, message: e.to_string(), retry_after_us: 0 }
}

/// Execute one decoded request against the shared state. `ctx` (present
/// only for sampled v5 queries) is the server-side root span the queue /
/// execution spans attach under.
fn answer(
    shared: &Shared,
    handles: &mut Vec<Opened>,
    req: Request,
    ctx: Option<SpanCtx>,
) -> Response {
    match req {
        Request::Ping => Response::Pong,
        // the scrape itself is cheap (a relaxed read sweep) and answered
        // inline, never queued behind query work
        Request::Stats => Response::Stats(obs::global().snapshot()),
        // likewise inline: the rings hold already-frozen trees
        Request::TraceDump { id, slowest } => Response::Traces(if id != 0 {
            trace::dump_by_id(id)
        } else {
            trace::dump_slowest(slowest as usize)
        }),
        Request::Shutdown => {
            // the actual trigger happens in handle_connection *after* the
            // acknowledgement frame is written
            info!("net: shutdown sentinel received");
            Response::ShuttingDown
        }
        Request::ListSketches => match list_sketches(shared) {
            Ok(infos) => Response::SketchList(infos),
            Err(e) => {
                Response::Error { code: ErrCode::Store, message: e.to_string(), retry_after_us: 0 }
            }
        },
        Request::OpenSketch(key) => match open_handle(shared, &key) {
            Ok(opened) => {
                let info = opened.info().clone();
                // re-opening an already-open sketch reuses (and
                // refreshes, after an eviction) its handle slot, so a
                // client looping OpenSketch cannot grow the table
                let existing = handles.iter().position(|h| {
                    let i = h.info();
                    i.dataset == info.dataset
                        && i.method == info.method
                        && i.s == info.s
                        && i.seed == info.seed
                });
                let handle = match existing {
                    Some(pos) => {
                        handles[pos] = opened;
                        pos
                    }
                    None => {
                        handles.push(opened);
                        handles.len() - 1
                    }
                };
                Response::SketchOpened { handle: handle as u32, info }
            }
            Err(e) => {
                Response::Error { code: ErrCode::Store, message: e.to_string(), retry_after_us: 0 }
            }
        },
        Request::Query { handle, pin, query, .. } => {
            let Some(opened) = handles.get(handle as usize) else {
                return bad_handle(handle, handles.len());
            };
            match opened {
                // dispatch onto the sketch's QueryServer worker pool; the
                // handler thread blocks on this one answer, which keeps
                // per-connection responses in order for pipelined clients
                Opened::Frozen(svc) => {
                    if pin != 0 {
                        return Response::Error {
                            code: ErrCode::Generation,
                            retry_after_us: 0,
                            message: format!(
                                "generation {pin} not served: frozen sketches stay at \
                                 generation 0"
                            ),
                        };
                    }
                    match svc.server.submit_traced(query, ctx).wait() {
                        Ok(outcome) => Response::Answer { generation: 0, answer: outcome },
                        Err(e) => query_fault(e),
                    }
                }
                // live chains answer on the snapshot the pin selects and
                // report the generation; wire pin 0 means "latest"
                Opened::Live { reader, .. } => {
                    let pin_opt = if pin == 0 { None } else { Some(pin) };
                    match reader.answer_at_traced(pin_opt, &query, ctx) {
                        Ok((outcome, generation)) => {
                            Response::Answer { generation, answer: outcome }
                        }
                        Err(e) => query_fault(e),
                    }
                }
            }
        }
        Request::GenPoll { handle, min_gen, timeout_ms } => {
            let Some(opened) = handles.get(handle as usize) else {
                return bad_handle(handle, handles.len());
            };
            match opened {
                // frozen sketches never advance: answer generation 0 at
                // once instead of parking the handler for the timeout
                Opened::Frozen(_) => Response::Generation(0),
                Opened::Live { reader, .. } => {
                    // cap the park so one poll cannot outlive the
                    // connection's own read timeout budget
                    let timeout = Duration::from_millis(u64::from(timeout_ms.min(30_000)));
                    match reader.wait_for(min_gen, timeout) {
                        Ok(g) => Response::Generation(g),
                        Err(e) => query_fault(e),
                    }
                }
            }
        }
    }
}

fn bad_handle(handle: u32, open: usize) -> Response {
    Response::Error {
        code: ErrCode::BadHandle,
        message: format!("handle {handle} not opened on this connection ({open} open)"),
        retry_after_us: 0,
    }
}

fn sketch_info(key: &StoreKey, sketch: &ServableSketch) -> SketchInfo {
    let (m, n) = sketch.shape();
    SketchInfo {
        dataset: key.dataset.clone(),
        method: key.method.clone(),
        s: key.s,
        seed: key.seed,
        m: m as u64,
        n: n as u64,
        compact: sketch.enc.compact,
    }
}

/// Resolve `key` to a handle slot: an attached live chain wins over the
/// store (the chain *is* the freshest truth for its key), everything else
/// loads frozen through [`open_service`].
fn open_handle(shared: &Shared, key: &StoreKey) -> Result<Opened> {
    let chain = {
        let chains = shared.live_chains.lock().expect("live-chain registry poisoned");
        chains.get(&key.file_name()).map(|(k, r)| (k.clone(), r.clone()))
    };
    if let Some((recorded, reader)) = chain {
        if !recorded.same_identity(key) {
            return Err(Error::invalid(format!(
                "live chain {} holds ({}, {}, s={}, seed={}), not the requested \
                 ({}, {}, s={}, seed={}) (file-name collision?)",
                key.file_name(),
                recorded.dataset,
                recorded.method,
                recorded.s,
                recorded.seed,
                key.dataset,
                key.method,
                key.s,
                key.seed,
            )));
        }
        let info = reader.info(&key.dataset)?;
        return Ok(Opened::Live { reader, info });
    }
    Ok(Opened::Frozen(open_service(shared, key)?))
}

/// Open (or reuse) the shared service for `key`: the sketch is normally
/// loaded from the store once and its worker pool is shared by every
/// connection that opens it. A cached service whose recorded input
/// fingerprint conflicts with the request is evicted and reloaded from
/// disk — so a re-sketched input is picked up by a long-lived server
/// without a restart (fingerprint-less opens keep the cached payload).
///
/// The registry lock is **not** held across the disk load: opening one
/// multi-GB sketch must not stall every other connection's open. Two
/// connections racing the same first open may both read the file; the
/// loser adopts the winner's service so each sketch still ends up with
/// exactly one worker pool.
fn open_service(shared: &Shared, key: &StoreKey) -> Result<Arc<SketchService>> {
    let file = key.file_name();
    {
        let mut services = shared.services.lock().expect("services registry poisoned");
        if let Some(svc) = services.get(&file).cloned() {
            let recorded = StoreKey::new(
                &svc.info.dataset,
                &svc.info.method,
                svc.info.s,
                svc.info.seed,
            );
            if !recorded.same_identity(key) {
                return Err(crate::error::Error::invalid(format!(
                    "stored sketch {file} holds ({}, {}, s={}, seed={}), not the requested \
                     ({}, {}, s={}, seed={}) (file-name collision?)",
                    recorded.dataset,
                    recorded.method,
                    recorded.s,
                    recorded.seed,
                    key.dataset,
                    key.method,
                    key.s,
                    key.seed,
                )));
            }
            if key.fingerprint != 0
                && svc.fingerprint != 0
                && key.fingerprint != svc.fingerprint
            {
                // the input was re-sketched since this service loaded (or
                // the client is stale): drop the cached payload and fall
                // through to a fresh store read, which settles who is
                // right
                info!("net: evicting cached {file} (input fingerprint changed)");
                obs::global().inc(Counter::OpenCacheEvict);
                services.remove(&file);
            } else {
                obs::global().inc(Counter::OpenCacheHit);
                return Ok(svc);
            }
        }
    }
    obs::global().inc(Counter::OpenCacheMiss);

    // slow path, lock released: read + validate + index the sketch
    let stored = shared.store.get(key)?.ok_or_else(|| {
        crate::error::Error::invalid(format!(
            "no stored sketch {file} under {} (absent or stale) — run `matsketch sketch` first",
            shared.store.dir().display()
        ))
    })?;
    let fingerprint = stored.fingerprint;
    let sketch = Arc::new(ServableSketch::from_stored(stored)?);
    let info = sketch_info(key, &sketch);
    info!(
        "net: opened {file} ({}x{}, s={}) with {} workers",
        info.m, info.n, info.s, shared.cfg.workers_per_sketch
    );
    let server = QueryServer::start_with(
        sketch,
        shared.cfg.workers_per_sketch,
        shared.cfg.split_min_groups,
    );
    let svc = Arc::new(SketchService { server, info, fingerprint });

    let mut services = shared.services.lock().expect("services registry poisoned");
    if let Some(winner) = services.get(&file) {
        // a racing open finished first; both loads came from the same
        // file, so adopt the winner's pool and drop ours
        return Ok(Arc::clone(winner));
    }
    services.insert(file, Arc::clone(&svc));
    Ok(svc)
}

/// Enumerate the store by reading each entry's container header only —
/// listing a store of huge entries never touches their payloads — then
/// append every attached live chain (which may not exist on disk at all).
fn list_sketches(shared: &Shared) -> Result<Vec<SketchInfo>> {
    let mut out = Vec::new();
    for path in shared.store.entries()? {
        match crate::serve::store::read_header(&path) {
            Ok(info) => out.push(SketchInfo {
                dataset: info.dataset,
                method: info.method,
                s: info.s,
                seed: info.seed,
                m: info.m as u64,
                n: info.n as u64,
                compact: info.compact,
            }),
            Err(e) => warn_log!("net: skipping unreadable store entry {}: {e}", path.display()),
        }
    }
    let chains = shared.live_chains.lock().expect("live-chain registry poisoned");
    for (key, reader) in chains.values() {
        match reader.info(&key.dataset) {
            Ok(info) => out.push(info),
            Err(e) => warn_log!("net: skipping live chain {}: {e}", key.file_name()),
        }
    }
    Ok(out)
}
