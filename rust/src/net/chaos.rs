//! Deterministic fault injection for the serving stack.
//!
//! The paper's sampling guarantees are probabilistic; the serving
//! stack's resilience guarantees must not be. A [`FaultPlan`] is a
//! seeded, replayable schedule of injected failures: given the same
//! seed and the same (connection, frame) coordinates, it makes the
//! same injection decisions every run — so an integration test can
//! assert "under exactly this failure schedule, every idempotent query
//! still answers bit-identically to a fault-free run", and a flake is
//! a bug, not weather.
//!
//! Two injection surfaces:
//!
//! * **connection faults** — the server asks [`FaultPlan::fault_for`]
//!   once per decoded frame and applies the verdict: `Disconnect`
//!   (drop the connection with no reply), `Partial` (write half the
//!   response bytes, then drop), `Corrupt` (flip the response frame's
//!   first byte — the magic — then drop, so the damage is always
//!   detectable client-side; the wire has no checksum, so flipping a
//!   payload byte could silently change an answer), `Tarpit` (stall
//!   the handler for a scripted number of milliseconds before
//!   answering normally).
//! * **store faults** — a process-global hook ([`install_store_fault`])
//!   makes [`crate::serve::store::write_encoded`] fail mid-write:
//!   `Fail` cuts a deterministic fraction of writes short with an
//!   `ErrorKind::Other` error, `KillAt(offset)` writes exactly
//!   `offset` bytes of the temp file then errors — simulating a crash
//!   at that byte, which is how the kill-at-every-offset durability
//!   test walks the whole file.
//!
//! Faults come from two rule sets, checked in order:
//!
//! 1. **scripted** rules (`at=CONN:FRAME:KIND[:MS]`) pin one fault to
//!    exact coordinates — connection ids are assigned in accept order
//!    and frame indices count decoded frames per connection, so with a
//!    deterministic client the coordinates are stable;
//! 2. **probabilistic** rules (`disconnect=P`, `partial=P`, ...) draw
//!    from a splitmix64-style hash of (seed, conn, frame, kind-salt) —
//!    no shared RNG state, so the decision for a coordinate never
//!    depends on which other coordinates were asked first, even under
//!    concurrent connections.
//!
//! Every injection is recorded in an in-plan log; [`FaultPlan::injected`]
//! returns it sorted by coordinates, so two runs of the same schedule
//! produce byte-identical logs regardless of thread interleaving.
//!
//! The plan is compiled in always and costs nothing when absent: the
//! server holds an `Option<Arc<FaultPlan>>` and the store hook is a
//! `Mutex<Option<..>>` checked only on writes (a cold path).

use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::obs::{self, Counter};

/// One kind of injected connection fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    /// Drop the connection before answering this frame.
    Disconnect,
    /// Write only half of this frame's response bytes, then drop.
    Partial,
    /// Flip the first byte (the magic) of this frame's response, then
    /// drop — always detectable client-side as a header fault.
    Corrupt,
    /// Stall the handler for this many milliseconds, then answer
    /// normally.
    Tarpit(u64),
}

impl FaultKind {
    /// Stable lower-case name (logs, reports).
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Disconnect => "disconnect",
            FaultKind::Partial => "partial",
            FaultKind::Corrupt => "corrupt",
            FaultKind::Tarpit(_) => "tarpit",
        }
    }
}

/// One recorded injection: which fault fired at which coordinates.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct InjectedFault {
    /// Accept-order connection id the fault fired on.
    pub conn: u64,
    /// Zero-based decoded-frame index within that connection.
    pub frame: u64,
    /// What was injected.
    pub kind: FaultKind,
}

/// A scripted rule: exactly one fault at exact coordinates.
#[derive(Clone, Copy, Debug)]
struct ScriptedFault {
    conn: u64,
    frame: u64,
    kind: FaultKind,
}

/// A seeded, replayable schedule of connection faults.
///
/// Constructed from a SPEC string ([`FaultPlan::parse`]) or built in
/// code by tests; handed to the server via
/// [`crate::net::NetServerConfig::chaos`]. Decision functions are pure
/// in (seed, conn, frame), so the schedule replays identically across
/// runs and thread interleavings.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// Seed for the probabilistic rules' hash draws.
    seed: u64,
    /// Scripted rules, checked before any probabilistic draw.
    scripted: Vec<ScriptedFault>,
    /// Probability a frame's connection is dropped before answering.
    disconnect_p: f64,
    /// Probability a frame's response is cut short mid-write.
    partial_p: f64,
    /// Probability one response payload byte is flipped.
    corrupt_p: f64,
    /// Probability the handler stalls before answering.
    tarpit_p: f64,
    /// Stall length for probabilistic tarpits, in milliseconds.
    tarpit_ms: u64,
    /// Every injection that actually fired, in firing order.
    log: Mutex<Vec<InjectedFault>>,
}

/// splitmix64 finalizer: a cheap, well-mixed hash of one word.
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Deterministic uniform draw in [0, 1) for one (seed, conn, frame,
/// salt) coordinate.
fn draw(seed: u64, conn: u64, frame: u64, salt: u64) -> f64 {
    let h = mix(
        seed.wrapping_mul(0x9e37_79b9_7f4a_7c15)
            ^ mix(conn.wrapping_add(0xc0a7))
            ^ mix(frame.wrapping_add(0xf7a3e))
            ^ mix(salt),
    );
    // 53 mantissa bits → exact f64 in [0, 1)
    (h >> 11) as f64 / (1u64 << 53) as f64
}

// per-kind salts keep the four probabilistic draws at one coordinate
// independent of each other
const SALT_DISCONNECT: u64 = 0xD15C;
const SALT_PARTIAL: u64 = 0x9A27;
const SALT_CORRUPT: u64 = 0xC0AA;
const SALT_TARPIT: u64 = 0x7A29;

impl FaultPlan {
    /// A plan with only a seed — no rules, injects nothing until rates
    /// or scripted faults are added.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    /// Add one scripted fault at exact (connection, frame) coordinates.
    pub fn at(mut self, conn: u64, frame: u64, kind: FaultKind) -> FaultPlan {
        self.scripted.push(ScriptedFault { conn, frame, kind });
        self
    }

    /// Set the probabilistic disconnect rate.
    pub fn disconnect(mut self, p: f64) -> FaultPlan {
        self.disconnect_p = p;
        self
    }

    /// Set the probabilistic partial-write rate.
    pub fn partial(mut self, p: f64) -> FaultPlan {
        self.partial_p = p;
        self
    }

    /// Set the probabilistic corrupt-frame rate.
    pub fn corrupt(mut self, p: f64) -> FaultPlan {
        self.corrupt_p = p;
        self
    }

    /// Set the probabilistic tarpit rate and stall length.
    pub fn tarpit(mut self, p: f64, ms: u64) -> FaultPlan {
        self.tarpit_p = p;
        self.tarpit_ms = ms;
        self
    }

    /// Parse a chaos SPEC string: comma-separated `key=value` rules.
    ///
    /// Grammar (all parts optional, any order):
    ///
    /// ```text
    /// seed=N                     hash seed for probabilistic rules
    /// disconnect=P               drop the connection, probability P
    /// partial=P                  cut the response short, probability P
    /// corrupt=P                  flip a response byte, probability P
    /// tarpit=P:MS                stall MS milliseconds, probability P
    /// store=P                    fail a store write, probability P
    /// at=CONN:FRAME:KIND[:MS]    scripted fault at exact coordinates
    ///                            (KIND: disconnect|partial|corrupt|tarpit)
    /// ```
    ///
    /// `store=P` returns separately as the second tuple element — store
    /// writes are process-global (not per-connection), so the caller
    /// installs it via [`install_store_fault`].
    pub fn parse(spec: &str) -> Result<(FaultPlan, Option<StoreFault>)> {
        let mut plan = FaultPlan::default();
        let mut store = None;
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| Error::invalid(format!("chaos rule `{part}`: expected key=value")))?;
            let bad_p = |v: &str| Error::invalid(format!("chaos {key}={v}: not a rate in [0,1]"));
            let rate = |v: &str| -> Result<f64> {
                let p: f64 = v.parse().map_err(|_| bad_p(v))?;
                if (0.0..=1.0).contains(&p) {
                    Ok(p)
                } else {
                    Err(bad_p(v))
                }
            };
            match key {
                "seed" => {
                    plan.seed = value
                        .parse()
                        .map_err(|_| Error::invalid(format!("chaos seed={value}: not a u64")))?;
                }
                "disconnect" => plan.disconnect_p = rate(value)?,
                "partial" => plan.partial_p = rate(value)?,
                "corrupt" => plan.corrupt_p = rate(value)?,
                "tarpit" => {
                    let (p, ms) = value.split_once(':').ok_or_else(|| {
                        Error::invalid(format!("chaos tarpit={value}: expected P:MS"))
                    })?;
                    plan.tarpit_p = rate(p)?;
                    plan.tarpit_ms = ms
                        .parse()
                        .map_err(|_| Error::invalid(format!("chaos tarpit ms `{ms}`: not a u64")))?;
                }
                "store" => {
                    store = Some(StoreFault::Fail { seed: plan.seed, p: rate(value)?, writes: 0 });
                }
                "at" => {
                    let fields: Vec<&str> = value.split(':').collect();
                    if fields.len() < 3 {
                        return Err(Error::invalid(format!(
                            "chaos at={value}: expected CONN:FRAME:KIND[:MS]"
                        )));
                    }
                    let coord = |i: usize, what: &str| -> Result<u64> {
                        fields
                            .get(i)
                            .and_then(|f| f.parse().ok())
                            .ok_or_else(|| Error::invalid(format!("chaos at={value}: bad {what}")))
                    };
                    let conn = coord(0, "connection id")?;
                    let frame = coord(1, "frame index")?;
                    let kind = match fields.get(2).copied() {
                        Some("disconnect") => FaultKind::Disconnect,
                        Some("partial") => FaultKind::Partial,
                        Some("corrupt") => FaultKind::Corrupt,
                        Some("tarpit") => FaultKind::Tarpit(coord(3, "tarpit ms")?),
                        _ => {
                            return Err(Error::invalid(format!(
                                "chaos at={value}: unknown fault kind"
                            )))
                        }
                    };
                    plan.scripted.push(ScriptedFault { conn, frame, kind });
                }
                _ => return Err(Error::invalid(format!("chaos rule `{part}`: unknown key"))),
            }
        }
        // the `store=P` draw reuses the plan seed, so fix the ordering
        // dependency: a seed written after store= must still apply
        if let Some(StoreFault::Fail { seed, .. }) = &mut store {
            *seed = plan.seed;
        }
        Ok((plan, store))
    }

    /// The fault (if any) to inject at one (connection, frame)
    /// coordinate. Pure in (seed, conn, frame) — scripted rules win
    /// over probabilistic draws, and at most one fault fires per frame
    /// (priority: disconnect, partial, corrupt, tarpit). Records the
    /// verdict in the plan's log and the global
    /// [`Counter::ChaosInjected`].
    pub fn fault_for(&self, conn: u64, frame: u64) -> Option<FaultKind> {
        let kind = self
            .scripted
            .iter()
            .find(|s| s.conn == conn && s.frame == frame)
            .map(|s| s.kind)
            .or_else(|| {
                if draw(self.seed, conn, frame, SALT_DISCONNECT) < self.disconnect_p {
                    Some(FaultKind::Disconnect)
                } else if draw(self.seed, conn, frame, SALT_PARTIAL) < self.partial_p {
                    Some(FaultKind::Partial)
                } else if draw(self.seed, conn, frame, SALT_CORRUPT) < self.corrupt_p {
                    Some(FaultKind::Corrupt)
                } else if draw(self.seed, conn, frame, SALT_TARPIT) < self.tarpit_p {
                    Some(FaultKind::Tarpit(self.tarpit_ms))
                } else {
                    None
                }
            })?;
        obs::global().inc(Counter::ChaosInjected);
        if let Ok(mut log) = self.log.lock() {
            log.push(InjectedFault { conn, frame, kind });
        }
        Some(kind)
    }

    /// Every injection that fired so far, sorted by (conn, frame,
    /// kind) — the sort makes the log independent of thread
    /// interleaving, so two runs of the same schedule compare equal.
    pub fn injected(&self) -> Vec<InjectedFault> {
        let mut log = self.log.lock().map(|l| l.clone()).unwrap_or_default();
        log.sort_unstable();
        log
    }

    /// True when no rule can ever fire — lets callers skip per-frame
    /// bookkeeping entirely for a rule-less plan.
    pub fn is_inert(&self) -> bool {
        self.scripted.is_empty()
            && self.disconnect_p == 0.0
            && self.partial_p == 0.0
            && self.corrupt_p == 0.0
            && self.tarpit_p == 0.0
    }
}

// ---------------------------------------------------------------------
// store faults
// ---------------------------------------------------------------------

/// A store-write fault mode, installed process-globally.
#[derive(Clone, Copy, Debug)]
pub enum StoreFault {
    /// Deterministically fail a `p` fraction of writes: the doomed
    /// write puts half its bytes in the temp file, then returns an
    /// `ErrorKind::Other` error. `writes` counts attempts (the draw
    /// coordinate), so the schedule replays across runs.
    Fail {
        /// Hash seed for the per-write draw.
        seed: u64,
        /// Fraction of writes to fail.
        p: f64,
        /// Write attempts so far (incremented per consultation).
        writes: u64,
    },
    /// The next write puts exactly this many bytes in the temp file,
    /// then returns an `ErrorKind::Other` error — a crash at that byte
    /// offset. One-shot: consumed by the write it kills.
    KillAt(u64),
}

/// The installed store-fault hook. A `Mutex<Option<..>>` (not an
/// atomic) keeps this out of the lint's atomics-ordering allowlist;
/// store writes are a cold path, so the lock is free in practice.
static STORE_CHAOS: Mutex<Option<StoreFault>> = Mutex::new(None);

/// Serializes tests that install/clear the process-global store fault,
/// so parallel test threads can't see each other's hooks. Test-only by
/// convention; harmless to hold elsewhere.
#[doc(hidden)]
pub static STORE_FAULT_TEST_LOCK: Mutex<()> = Mutex::new(());

/// Install a store-write fault mode (replacing any current one).
pub fn install_store_fault(fault: StoreFault) {
    if let Ok(mut slot) = STORE_CHAOS.lock() {
        *slot = Some(fault);
    }
}

/// Remove the store-write fault hook.
pub fn clear_store_fault() {
    if let Ok(mut slot) = STORE_CHAOS.lock() {
        *slot = None;
    }
}

/// Consulted by the store once per write attempt: `Some(cap)` means
/// "write exactly `cap` bytes of the `len`-byte payload, then fail".
/// Advances `Fail` mode's write counter; consumes a `KillAt`.
pub fn store_write_cap(len: u64) -> Option<u64> {
    let mut slot = STORE_CHAOS.lock().ok()?;
    match slot.as_mut()? {
        StoreFault::Fail { seed, p, writes } => {
            let n = *writes;
            *writes += 1;
            if draw(*seed, n, 0, 0x570E) < *p {
                obs::global().inc(Counter::ChaosInjected);
                Some(len / 2)
            } else {
                None
            }
        }
        StoreFault::KillAt(offset) => {
            let cap = (*offset).min(len);
            *slot = None;
            obs::global().inc(Counter::ChaosInjected);
            Some(cap)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_schedule() {
        let spec = "seed=7,disconnect=0.2,partial=0.1,corrupt=0.05,tarpit=0.1:3";
        let (a, _) = FaultPlan::parse(spec).unwrap();
        let (b, _) = FaultPlan::parse(spec).unwrap();
        for conn in 0..8u64 {
            for frame in 0..64u64 {
                assert_eq!(a.fault_for(conn, frame), b.fault_for(conn, frame));
            }
        }
        let log = a.injected();
        assert_eq!(log, b.injected());
        assert!(!log.is_empty(), "rates this high must fire in 512 draws");
        assert!(log.len() < 512, "rates this low must not always fire");
    }

    #[test]
    fn draw_is_order_independent() {
        let (a, _) = FaultPlan::parse("seed=9,disconnect=0.3").unwrap();
        let (b, _) = FaultPlan::parse("seed=9,disconnect=0.3").unwrap();
        let mut forward = Vec::new();
        for frame in 0..32u64 {
            forward.push(a.fault_for(1, frame));
        }
        let mut backward = Vec::new();
        for frame in (0..32u64).rev() {
            backward.push(b.fault_for(1, frame));
        }
        backward.reverse();
        assert_eq!(forward, backward);
        assert_eq!(a.injected(), b.injected(), "sorted logs match across orderings");
    }

    #[test]
    fn scripted_rules_win_and_parse() {
        let (plan, store) =
            FaultPlan::parse("seed=3,at=2:5:disconnect,at=2:6:tarpit:40,store=0.5").unwrap();
        assert_eq!(plan.fault_for(2, 5), Some(FaultKind::Disconnect));
        assert_eq!(plan.fault_for(2, 6), Some(FaultKind::Tarpit(40)));
        assert_eq!(plan.fault_for(2, 7), None);
        assert!(matches!(store, Some(StoreFault::Fail { seed: 3, .. })));
        let log = plan.injected();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0], InjectedFault { conn: 2, frame: 5, kind: FaultKind::Disconnect });
    }

    #[test]
    fn bad_specs_are_typed_errors() {
        for spec in [
            "nonsense",
            "frob=1",
            "disconnect=1.5",
            "disconnect=x",
            "tarpit=0.5",
            "at=1:2:explode",
            "at=1:2",
            "seed=pi",
        ] {
            assert!(FaultPlan::parse(spec).is_err(), "spec `{spec}` must be rejected");
        }
        let (plan, store) = FaultPlan::parse("").unwrap();
        assert!(plan.is_inert());
        assert!(store.is_none());
    }

    #[test]
    fn store_kill_at_caps_and_consumes() {
        let _guard = STORE_FAULT_TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        clear_store_fault();
        assert_eq!(store_write_cap(100), None, "no hook installed");
        install_store_fault(StoreFault::KillAt(37));
        assert_eq!(store_write_cap(100), Some(37));
        assert_eq!(store_write_cap(100), None, "KillAt is one-shot");
        install_store_fault(StoreFault::KillAt(500));
        assert_eq!(store_write_cap(100), Some(100), "cap clamps to the payload");
        clear_store_fault();
    }
}
