//! The network serving front: remote access to the sketch service.
//!
//! PR 2 made the repo a sketch *service*, but only in-process — the
//! paper's operational payoff (a sketch small enough to hold resident
//! and cheap enough to query under heavy traffic, §1) needs remote
//! clients hitting a long-lived server that owns the compressed payload.
//! This module adds that layer with **zero external dependencies**
//! (std-only TCP):
//!
//! * [`wire`] — the versioned, length-prefixed binary protocol (v6): one
//!   opcode per [`crate::api::QueryRequest`] variant (matvec /
//!   transpose-matvec / batched matvec / row / col / top-k, plus `Ping`,
//!   `ListSketches`, `OpenSketch`, `GenPoll`, `Stats`, `TraceDump`, and
//!   the `Shutdown` sentinel), with typed error responses for malformed,
//!   truncated, oversized, or wrong-version frames. v3 carries
//!   live-sketch generation pins and per-answer generation tags; v4 adds
//!   `Stats` telemetry scraping; v5 adds a trace-context word on `Query`
//!   frames plus `TraceDump` retrieval of retained span trees; v6 adds
//!   the `Overloaded` / `Timeout` fault codes and a retry-after hint on
//!   error payloads; v1–v5 frames stay decodable and are answered at
//!   their own version.
//! * [`server`] — [`NetServer`]: a multi-threaded `TcpListener` acceptor
//!   owning a [`crate::serve::SketchStore`], lazily opening sketches
//!   into shared [`crate::serve::ServableSketch`]es and dispatching onto
//!   the in-process [`crate::serve::QueryServer`] worker pools;
//!   connection limit, read/write timeouts, graceful shutdown. Live
//!   chains ([`crate::serve::live`]) attach via [`NetServer::attach_live`]
//!   and serve generation-pinned queries remotely.
//! * [`client`] — [`RemoteSketchClient`]: the blocking, pipelining,
//!   reconnecting transport behind [`crate::api::RemoteClient`]. Callers
//!   outside this module and [`crate::api`] go through the
//!   [`crate::api::SketchClient`] trait, not this type. Idempotent
//!   operations retry under a bounded [`client::RetryPolicy`]
//!   (exponential backoff, seeded jitter, retry budget, optional
//!   per-request deadline); generation pins are sticky per key and are
//!   re-established — together with handle re-opens — inside the retry
//!   loop, so a reconnect can never answer a query unpinned.
//! * [`chaos`] — [`FaultPlan`]: seeded, replayable fault injection
//!   (disconnects, partial writes, corrupted frames, tarpits, store
//!   write failures) wired into the server's connection loop and the
//!   store's write path; `matsketch serve --chaos SPEC` and the
//!   integration/chaos-bench suites replay exact failure schedules.
//! * [`loadgen`] — closed-loop multi-client load generation over
//!   `dyn SketchClient`, with an optional background ingest writer
//!   driving a live chain while queries run, reporting throughput +
//!   latency percentiles (`matsketch net-bench`, eval drivers in
//!   `eval::netbench` / `eval::serving`). [`scrape_stats`] pulls the
//!   server's [`crate::obs`] telemetry snapshot before/after a run so
//!   server-side counters land next to the client-side numbers.
//!
//! The wire layer adds no second compute path: every remote answer is
//! produced by the same [`crate::serve::ServableSketch::answer`] as the
//! in-process one, and the backend-equivalence suite
//! (`rust/tests/integration_api.rs`) pins the two byte-for-byte equal.

pub mod chaos;
pub mod client;
pub mod loadgen;
pub mod server;
pub mod wire;

pub use chaos::{FaultKind, FaultPlan, InjectedFault, StoreFault};
pub use client::{RemoteSketchClient, RetryPolicy};
pub use loadgen::{
    run_live_load, run_load, run_load_with, scrape_stats, LiveLoadReport, LoadGenConfig, LoadOp,
    LoadReport,
};
pub use server::{NetServer, NetServerConfig, NetServerStats};
pub use wire::{ErrCode, Request, Response, WIRE_VERSION};
