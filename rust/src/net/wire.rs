//! The wire protocol: a versioned, length-prefixed binary framing with
//! one codec per serving operation. Zero dependencies — plain `std::io`
//! over big-endian bytes.
//!
//! ## Frame layout
//!
//! Every message — request or response — is one frame:
//!
//! | field       | size | contents                                    |
//! |-------------|------|---------------------------------------------|
//! | magic       | 4 B  | `"MSKW"`                                    |
//! | version     | 2 B  | protocol version (currently 6; 1–5 accepted)|
//! | opcode      | 1 B  | message kind (below)                        |
//! | reserved    | 1 B  | 0 (ignored on read)                         |
//! | request id  | 8 B  | caller-chosen; echoed verbatim in responses |
//! | payload len | 4 B  | body length in bytes (≤ [`MAX_PAYLOAD`])    |
//! | payload     | var. | opcode-specific body                        |
//!
//! Request opcodes: `0x01` Ping, `0x02` ListSketches, `0x03` OpenSketch,
//! `0x04` Shutdown (the graceful-stop sentinel), `0x05` Stats (v4+),
//! `0x06` TraceDump (v5+), `0x10` Matvec, `0x11` MatvecT,
//! `0x12` RowSlice, `0x13` ColSlice, `0x14` TopK,
//! `0x15` MatvecBatch (v2+), `0x16` GenPoll (v3+).
//! Response opcodes: `0x81` Pong, `0x82` SketchList,
//! `0x83` SketchOpened, `0x84` ShuttingDown, `0x90` Vector,
//! `0x91` Entries, `0x92` Vectors (v2+), `0x93` Generation (v3+),
//! `0x94` StatsSnapshot (v4+), `0x95` Traces (v5+), `0xFF` Error.
//!
//! ## Versioning
//!
//! Version 2 adds the batched matvec (`MatvecBatch` → `Vectors`).
//! Version 3 adds **generations** for live sketches
//! ([`crate::serve::live`]): every query request payload in a v3 frame
//! carries a leading `u64` generation pin after its handle (0 =
//! unpinned / latest), every v3 query answer carries a leading `u64`
//! with the generation it was answered at, and the `GenPoll` /
//! `Generation` pair blocks until a chain reaches a minimum generation.
//! Version 4 adds **telemetry scraping**: `Stats` → `StatsSnapshot`
//! ships the server's [`crate::obs`] metrics (counters, gauges, latency
//! histograms) in the snapshot's own versioned encoding
//! ([`crate::obs::MetricsSnapshot::encode`]), so the snapshot layout
//! can evolve without another protocol bump.
//! Version 5 adds **request tracing** ([`crate::obs::trace`]): every
//! query request payload in a v5 frame carries a `u64` trace id after
//! its generation pin (0 = untraced; old-version frames decode with
//! trace 0, so untraced traffic is byte-identical to v4), and the
//! `TraceDump` / `Traces` pair reads completed span timelines back out
//! of the server's trace rings in the trace layer's own versioned
//! encoding ([`crate::obs::trace::encode_traces`]).
//! Version 6 adds **resilience faults**: two new [`ErrCode`]s —
//! `Overloaded` (the server shed this request past its load high-water
//! mark) and `Timeout` (a read/write deadline expired mid-connection) —
//! and a trailing `u64` retry-after hint in microseconds on every v6
//! [`Response::Error`] payload (0 = no hint). Error frames encoded at
//! v5 or below omit the hint, and the two new codes downgrade to the
//! closest legacy fault (`Busy`, also a "try again later") so a v1–v5
//! peer never sees a code its `from_u16` would misread as `Malformed`.
//! Interop works in both directions: the server accepts any version
//! from [`MIN_WIRE_VERSION`] through [`WIRE_VERSION`] and answers each
//! request at the version the request arrived in, while clients encode
//! each request at the minimum version its operation needs
//! ([`request_version`]) — so an unpinned matvec still travels as a v1
//! frame, a v1/v2 peer never sees a v3 frame, and an upgraded client
//! speaks to an old server for every old-era operation. Opcodes newer
//! than a frame's marked version are a typed `unknown-opcode` fault,
//! not a silent accept.
//!
//! f64 values travel as their IEEE-754 bit patterns, so a remote answer
//! is **byte-for-byte identical** to the in-process one — the
//! backend-equivalence suite pins this for every request kind.
//!
//! ## Error discipline
//!
//! A malformed, truncated, oversized, or wrong-version frame must produce
//! a typed [`Response::Error`] — never a panic, never a silent drop.
//! Faults split into two severities, which is why header parsing and
//! payload decoding are separate steps:
//!
//! * **frame faults** (bad magic / version / oversized length): framing
//!   is lost, so the server replies best-effort and closes the
//!   connection;
//! * **payload faults** (unknown opcode, short/trailing/garbled body, a
//!   batch count the payload cannot hold): the frame boundary is intact,
//!   so the server replies with the echoed request id and keeps serving
//!   the connection.

use std::io::{self, Read, Write};

use crate::api::{QueryRequest, QueryResponse, SketchInfo};
use crate::error::Error;
use crate::obs::trace::{decode_traces, encode_traces};
use crate::obs::{MetricsSnapshot, TraceRecord};
use crate::serve::StoreKey;
use crate::sketch::SketchEntry;

/// Frame magic: "MSKW" (matsketch wire).
pub const WIRE_MAGIC: [u8; 4] = *b"MSKW";

/// Current protocol version (v6: resilience faults — `Overloaded` /
/// `Timeout` codes and the retry-after hint on error payloads).
pub const WIRE_VERSION: u16 = 6;

/// Oldest protocol version still accepted on the wire.
pub const MIN_WIRE_VERSION: u16 = 1;

/// Fixed frame-header size in bytes.
pub const FRAME_HEADER_LEN: usize = 20;

/// Largest accepted payload (64 MiB): bounds allocation on both sides
/// and turns a garbage length field into a typed error instead of an
/// out-of-memory attempt.
pub const MAX_PAYLOAD: u32 = 64 << 20;

// --- request opcodes ---
const OP_PING: u8 = 0x01;
const OP_LIST: u8 = 0x02;
const OP_OPEN: u8 = 0x03;
const OP_SHUTDOWN: u8 = 0x04;
const OP_STATS: u8 = 0x05;
const OP_TRACE_DUMP: u8 = 0x06;
const OP_MATVEC: u8 = 0x10;
const OP_MATVEC_T: u8 = 0x11;
const OP_ROW: u8 = 0x12;
const OP_COL: u8 = 0x13;
const OP_TOP_K: u8 = 0x14;
const OP_MATVEC_BATCH: u8 = 0x15;
const OP_GEN_POLL: u8 = 0x16;

// --- response opcodes ---
const OP_PONG: u8 = 0x81;
const OP_SKETCH_LIST: u8 = 0x82;
const OP_SKETCH_OPENED: u8 = 0x83;
const OP_SHUTTING_DOWN: u8 = 0x84;
const OP_VECTOR: u8 = 0x90;
const OP_ENTRIES: u8 = 0x91;
const OP_VECTORS: u8 = 0x92;
const OP_GENERATION: u8 = 0x93;
const OP_STATS_SNAPSHOT: u8 = 0x94;
const OP_TRACES: u8 = 0x95;
const OP_ERROR: u8 = 0xFF;

/// Typed error codes carried by [`Response::Error`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrCode {
    /// Frame or payload failed to parse (bad magic, short body, trailing
    /// bytes, bad counts).
    Malformed,
    /// Protocol version not spoken by this server.
    BadVersion,
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    Oversized,
    /// Opcode not recognised (or a response opcode sent as a request, or
    /// a v2-only opcode inside a v1 frame).
    UnknownOpcode,
    /// Sketch handle not opened on this connection.
    BadHandle,
    /// Sketch store lookup failed (absent, corrupt, collided).
    Store,
    /// Query execution failed (shape mismatch, bad payload).
    Query,
    /// Connection limit reached.
    Busy,
    /// Server is shutting down.
    ShuttingDown,
    /// A generation pin the serving side cannot honour: ahead of the
    /// live chain, retired out of its retained window, or nonzero
    /// against a frozen sketch.
    Generation,
    /// The server shed this request past its load high-water mark
    /// (v6+; downgrades to `Busy` on older frames). Retryable — the
    /// error payload's retry-after hint says how long to back off.
    Overloaded,
    /// A connection read/write deadline expired (v6+; downgrades to
    /// `Busy` on older frames). The server closes the connection after
    /// sending this.
    Timeout,
}

impl ErrCode {
    /// Wire value.
    pub fn as_u16(self) -> u16 {
        match self {
            ErrCode::Malformed => 1,
            ErrCode::BadVersion => 2,
            ErrCode::Oversized => 3,
            ErrCode::UnknownOpcode => 4,
            ErrCode::BadHandle => 5,
            ErrCode::Store => 6,
            ErrCode::Query => 7,
            ErrCode::Busy => 8,
            ErrCode::ShuttingDown => 9,
            ErrCode::Generation => 10,
            ErrCode::Overloaded => 11,
            ErrCode::Timeout => 12,
        }
    }

    /// Inverse of [`ErrCode::as_u16`]; unknown values map to `Malformed`
    /// (a protocol-level fault either way).
    pub fn from_u16(v: u16) -> ErrCode {
        match v {
            2 => ErrCode::BadVersion,
            3 => ErrCode::Oversized,
            4 => ErrCode::UnknownOpcode,
            5 => ErrCode::BadHandle,
            6 => ErrCode::Store,
            7 => ErrCode::Query,
            8 => ErrCode::Busy,
            9 => ErrCode::ShuttingDown,
            10 => ErrCode::Generation,
            11 => ErrCode::Overloaded,
            12 => ErrCode::Timeout,
            _ => ErrCode::Malformed,
        }
    }

    /// Stable lower-case name (reports, error messages).
    pub fn name(self) -> &'static str {
        match self {
            ErrCode::Malformed => "malformed",
            ErrCode::BadVersion => "bad-version",
            ErrCode::Oversized => "oversized",
            ErrCode::UnknownOpcode => "unknown-opcode",
            ErrCode::BadHandle => "bad-handle",
            ErrCode::Store => "store",
            ErrCode::Query => "query",
            ErrCode::Busy => "busy",
            ErrCode::ShuttingDown => "shutting-down",
            ErrCode::Generation => "generation",
            ErrCode::Overloaded => "overloaded",
            ErrCode::Timeout => "timeout",
        }
    }
}

/// A typed protocol fault: what went wrong, as both a machine-readable
/// code (for the [`Response::Error`] reply) and a human message.
#[derive(Clone, Debug)]
pub struct WireFault {
    /// Machine-readable fault class.
    pub code: ErrCode,
    /// Human-readable detail.
    pub message: String,
}

impl WireFault {
    fn new(code: ErrCode, message: impl Into<String>) -> WireFault {
        WireFault { code, message: message.into() }
    }
}

impl From<WireFault> for Error {
    fn from(f: WireFault) -> Error {
        Error::Parse(format!("wire: {} ({})", f.message, f.code.name()))
    }
}

/// Shorthand for fallible wire-level parsing.
pub type WireResult<T> = std::result::Result<T, WireFault>;

/// One decoded request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Liveness probe.
    Ping,
    /// Enumerate the sketches the server's store holds.
    ListSketches,
    /// Open a stored sketch for querying; answers with a handle.
    OpenSketch(StoreKey),
    /// Execute one query against an opened handle.
    Query {
        /// Handle from a prior [`Response::SketchOpened`].
        handle: u32,
        /// Generation pin: 0 = unpinned (answer on the latest snapshot),
        /// nonzero = answer on exactly that retained generation. A
        /// nonzero pin forces a v3 frame; old-version frames decode with
        /// pin 0.
        pin: u64,
        /// Trace id from [`crate::obs::trace::sample`]: 0 = untraced,
        /// nonzero = the server opens a span tree under this id. A
        /// nonzero trace forces a v5 frame; old-version frames decode
        /// with trace 0.
        trace: u64,
        /// The operation, in the shared [`QueryRequest`] vocabulary.
        query: QueryRequest,
    },
    /// Block (up to a timeout) until the sketch under `handle` has
    /// published generation ≥ `min_gen`; answers with
    /// [`Response::Generation`] carrying the latest generation either
    /// way (v3+).
    GenPoll {
        /// Handle from a prior [`Response::SketchOpened`].
        handle: u32,
        /// Minimum generation to wait for.
        min_gen: u64,
        /// Longest the server may block, in milliseconds.
        timeout_ms: u32,
    },
    /// Scrape the server's telemetry registry; answers with
    /// [`Response::Stats`] (v4+).
    Stats,
    /// Read completed span timelines out of the server's trace rings;
    /// answers with [`Response::Traces`] (v5+).
    TraceDump {
        /// Nonzero: every retained trace with exactly this id.
        id: u64,
        /// When `id` is 0: the N slowest retained traces by root
        /// duration (slow log first).
        slowest: u32,
    },
    /// Graceful-shutdown sentinel: the server finishes in-flight work,
    /// acknowledges with [`Response::ShuttingDown`], and stops accepting.
    Shutdown,
}

/// One decoded response.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Liveness answer.
    Pong,
    /// The store's current contents.
    SketchList(Vec<SketchInfo>),
    /// A sketch is ready for queries under `handle` (valid on this
    /// connection only).
    SketchOpened {
        /// Connection-scoped handle to pass with queries.
        handle: u32,
        /// Identity + shape of the opened sketch.
        info: SketchInfo,
    },
    /// A query answer, in the shared [`QueryResponse`] vocabulary,
    /// tagged with the generation it was answered at (0 for frozen
    /// store-backed sketches; dropped on the wire below v3).
    Answer {
        /// Generation the answer was computed against.
        generation: u64,
        /// The answer itself.
        answer: QueryResponse,
    },
    /// The latest published generation of a polled sketch (v3+).
    Generation(u64),
    /// A telemetry snapshot of the server's [`crate::obs`] registry
    /// (v4+); travels in the snapshot's own versioned encoding.
    Stats(MetricsSnapshot),
    /// Completed span timelines from the server's trace rings (v5+);
    /// travel in the trace layer's own versioned encoding.
    Traces(Vec<TraceRecord>),
    /// Acknowledges a [`Request::Shutdown`].
    ShuttingDown,
    /// Typed failure; the request id in the frame says which request
    /// (0 when the fault predates knowing one).
    Error {
        /// Fault class.
        code: ErrCode,
        /// Human-readable detail.
        message: String,
        /// Server-suggested backoff before retrying, in microseconds
        /// (0 = no hint). Carried on the wire at v6+ only; dropped —
        /// along with a downgrade of the v6-only codes to `Busy` —
        /// when the error is encoded for an older peer.
        retry_after_us: u64,
    },
}

/// A parsed frame header.
#[derive(Clone, Copy, Debug)]
pub struct FrameHeader {
    /// Protocol version the frame was sent in (within the accepted
    /// range; responses echo it so old peers never see new frames).
    pub version: u16,
    /// Message kind.
    pub opcode: u8,
    /// Caller-chosen id, echoed in responses.
    pub request_id: u64,
    /// Payload length in bytes.
    pub len: u32,
}

// ---------------------------------------------------------------------
// byte-level writers / readers
// ---------------------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_be_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_be_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_be_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_be_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    // labels over 64 KiB cannot exist in a StoreKey (the store enforces
    // the same u16 bound), so truncation can never trigger here
    let bytes = s.as_bytes();
    let len = bytes.len().min(u16::MAX as usize);
    put_u16(out, len as u16);
    out.extend_from_slice(bytes.get(..len).unwrap_or(bytes));
}

/// Cursor over one received payload; every read is bounds-checked and
/// the caller finishes with [`Rd::done`] so trailing garbage is a typed
/// fault, not silently ignored.
struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Rd<'a> {
        Rd { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> WireResult<&'a [u8]> {
        let s = self.buf.get(self.pos..self.pos.saturating_add(n)).ok_or_else(|| {
            WireFault::new(
                ErrCode::Malformed,
                format!("payload short: wanted {n} more bytes, have {}", self.remaining()),
            )
        })?;
        self.pos += n;
        Ok(s)
    }

    /// [`Rd::take`], as a fixed-size array (for `from_be_bytes`).
    fn take_arr<const N: usize>(&mut self) -> WireResult<[u8; N]> {
        self.take(N)?.try_into().map_err(|_| {
            WireFault::new(ErrCode::Malformed, "payload short: fixed field truncated")
        })
    }

    fn u8(&mut self) -> WireResult<u8> {
        let [b] = self.take_arr::<1>()?;
        Ok(b)
    }

    fn u16(&mut self) -> WireResult<u16> {
        Ok(u16::from_be_bytes(self.take_arr()?))
    }

    fn u32(&mut self) -> WireResult<u32> {
        Ok(u32::from_be_bytes(self.take_arr()?))
    }

    fn u64(&mut self) -> WireResult<u64> {
        Ok(u64::from_be_bytes(self.take_arr()?))
    }

    fn f64(&mut self) -> WireResult<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self) -> WireResult<String> {
        let len = self.u16()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireFault::new(ErrCode::Malformed, "string is not valid UTF-8"))
    }

    /// A count field about to drive `count * elem_bytes` of reads: reject
    /// counts the remaining payload cannot possibly hold, *before*
    /// allocating for them.
    fn count(&mut self, elem_bytes: usize) -> WireResult<usize> {
        let count = self.u32()? as usize;
        if count.saturating_mul(elem_bytes) > self.remaining() {
            return Err(WireFault::new(
                ErrCode::Malformed,
                format!("count {count} exceeds payload ({} bytes left)", self.remaining()),
            ));
        }
        Ok(count)
    }

    fn vec_f64(&mut self) -> WireResult<Vec<f64>> {
        let count = self.count(8)?;
        let mut xs = Vec::with_capacity(count);
        for _ in 0..count {
            xs.push(self.f64()?);
        }
        Ok(xs)
    }

    fn done(self) -> WireResult<()> {
        if self.pos != self.buf.len() {
            return Err(WireFault::new(
                ErrCode::Malformed,
                format!("{} trailing payload bytes", self.buf.len() - self.pos),
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// frame encoding
// ---------------------------------------------------------------------

// NOTE: no length assertion here — an over-cap frame is legal to *build*
// (the server detects it post-encode and substitutes a typed Oversized
// error; a peer receiving one rejects it at parse_frame_header).
fn frame(version: u16, opcode: u8, request_id: u64, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    out.extend_from_slice(&WIRE_MAGIC);
    put_u16(&mut out, version);
    out.push(opcode);
    out.push(0); // reserved
    put_u64(&mut out, request_id);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    out
}

fn put_vec_f64(out: &mut Vec<u8>, xs: &[f64]) {
    put_u32(out, xs.len() as u32);
    for &v in xs {
        put_f64(out, v);
    }
}

fn put_info(out: &mut Vec<u8>, info: &SketchInfo) {
    put_str(out, &info.dataset);
    put_str(out, &info.method);
    put_u64(out, info.s);
    put_u64(out, info.seed);
    put_u64(out, info.m);
    put_u64(out, info.n);
    out.push(info.compact as u8);
}

fn get_info(rd: &mut Rd<'_>) -> WireResult<SketchInfo> {
    Ok(SketchInfo {
        dataset: rd.str()?,
        method: rd.str()?,
        s: rd.u64()?,
        seed: rd.u64()?,
        m: rd.u64()?,
        n: rd.u64()?,
        compact: rd.u8()? != 0,
    })
}

/// The lowest protocol version that can carry `req`. Requests go out at
/// this version (not blanket [`WIRE_VERSION`]) so an upgraded client
/// keeps talking to an old server for every old-era operation — only
/// the genuinely new ones force the newer protocol. In particular an
/// unpinned query never rides a v3 frame just because the client knows
/// about generations.
pub fn request_version(req: &Request) -> u16 {
    match req {
        Request::TraceDump { .. } => 5,
        Request::Query { trace, .. } if *trace != 0 => 5,
        Request::Stats => 4,
        Request::Query { pin, .. } if *pin != 0 => 3,
        Request::GenPoll { .. } => 3,
        Request::Query { query: QueryRequest::MatvecBatch(_), .. } => 2,
        _ => MIN_WIRE_VERSION,
    }
}

/// Encode one request as a complete frame, at the minimum version its
/// operation needs (see [`request_version`]).
pub fn encode_request(request_id: u64, req: &Request) -> Vec<u8> {
    encode_request_at(request_id, req, request_version(req))
}

/// [`encode_request`] at an explicit protocol version, floored at the
/// minimum the operation needs and capped at [`WIRE_VERSION`].
/// Generation-aware callers use this to raise even *unpinned* queries to
/// v3, so the answer's generation tag survives the wire instead of being
/// dropped by a v1/v2 response frame.
pub fn encode_request_at(request_id: u64, req: &Request, version: u16) -> Vec<u8> {
    let version = version.clamp(request_version(req), WIRE_VERSION);
    match req {
        Request::Ping => frame(version, OP_PING, request_id, Vec::new()),
        Request::ListSketches => frame(version, OP_LIST, request_id, Vec::new()),
        Request::Shutdown => frame(version, OP_SHUTDOWN, request_id, Vec::new()),
        Request::Stats => frame(version, OP_STATS, request_id, Vec::new()),
        Request::TraceDump { id, slowest } => {
            let mut p = Vec::new();
            put_u64(&mut p, *id);
            put_u32(&mut p, *slowest);
            frame(version, OP_TRACE_DUMP, request_id, p)
        }
        Request::OpenSketch(key) => {
            let mut p = Vec::new();
            put_str(&mut p, &key.dataset);
            put_str(&mut p, &key.method);
            put_u64(&mut p, key.s);
            put_u64(&mut p, key.seed);
            put_u64(&mut p, key.fingerprint);
            frame(version, OP_OPEN, request_id, p)
        }
        Request::GenPoll { handle, min_gen, timeout_ms } => {
            let mut p = Vec::new();
            put_u32(&mut p, *handle);
            put_u64(&mut p, *min_gen);
            put_u32(&mut p, *timeout_ms);
            frame(version, OP_GEN_POLL, request_id, p)
        }
        Request::Query { handle, pin, trace, query } => {
            let mut p = Vec::new();
            put_u32(&mut p, *handle);
            if version >= 3 {
                put_u64(&mut p, *pin);
            }
            if version >= 5 {
                put_u64(&mut p, *trace);
            }
            let opcode = match query {
                QueryRequest::Matvec(x) => {
                    put_vec_f64(&mut p, x);
                    OP_MATVEC
                }
                QueryRequest::MatvecT(x) => {
                    put_vec_f64(&mut p, x);
                    OP_MATVEC_T
                }
                QueryRequest::MatvecBatch(xs) => {
                    put_u32(&mut p, xs.len() as u32);
                    for x in xs {
                        put_vec_f64(&mut p, x);
                    }
                    OP_MATVEC_BATCH
                }
                QueryRequest::Row(i) => {
                    put_u32(&mut p, *i);
                    OP_ROW
                }
                QueryRequest::Col(j) => {
                    put_u32(&mut p, *j);
                    OP_COL
                }
                QueryRequest::TopK(k) => {
                    put_u64(&mut p, *k as u64);
                    OP_TOP_K
                }
            };
            frame(version, opcode, request_id, p)
        }
    }
}

/// Encode one response as a complete frame at `version` — servers echo
/// the version the request arrived in, so a v1 peer never receives a v2
/// frame its parser would reject.
pub fn encode_response_v(version: u16, request_id: u64, resp: &Response) -> Vec<u8> {
    match resp {
        Response::Pong => frame(version, OP_PONG, request_id, Vec::new()),
        Response::ShuttingDown => frame(version, OP_SHUTTING_DOWN, request_id, Vec::new()),
        Response::SketchList(infos) => {
            let mut p = Vec::new();
            put_u32(&mut p, infos.len() as u32);
            for info in infos {
                put_info(&mut p, info);
            }
            frame(version, OP_SKETCH_LIST, request_id, p)
        }
        Response::SketchOpened { handle, info } => {
            let mut p = Vec::new();
            put_u32(&mut p, *handle);
            put_info(&mut p, info);
            frame(version, OP_SKETCH_OPENED, request_id, p)
        }
        Response::Answer { generation, answer } => {
            let mut p = Vec::new();
            if version >= 3 {
                put_u64(&mut p, *generation);
            }
            let opcode = match answer {
                QueryResponse::Vector(y) => {
                    put_vec_f64(&mut p, y);
                    OP_VECTOR
                }
                QueryResponse::Vectors(ys) => {
                    put_u32(&mut p, ys.len() as u32);
                    for y in ys {
                        put_vec_f64(&mut p, y);
                    }
                    OP_VECTORS
                }
                QueryResponse::Entries(es) => {
                    put_u32(&mut p, es.len() as u32);
                    for e in es {
                        put_u32(&mut p, e.row);
                        put_u32(&mut p, e.col);
                        put_u32(&mut p, e.count);
                        put_f64(&mut p, e.value);
                    }
                    OP_ENTRIES
                }
            };
            frame(version, opcode, request_id, p)
        }
        Response::Generation(gen) => {
            let mut p = Vec::new();
            put_u64(&mut p, *gen);
            frame(version, OP_GENERATION, request_id, p)
        }
        Response::Stats(snap) => frame(version, OP_STATS_SNAPSHOT, request_id, snap.encode()),
        Response::Traces(traces) => frame(version, OP_TRACES, request_id, encode_traces(traces)),
        Response::Error { code, message, retry_after_us } => {
            // Old peers map unknown codes to Malformed (a hard fault);
            // Busy is the closest legacy "try again later".
            let code = match code {
                ErrCode::Overloaded | ErrCode::Timeout if version < 6 => ErrCode::Busy,
                c => *c,
            };
            let mut p = Vec::new();
            put_u16(&mut p, code.as_u16());
            put_str(&mut p, message);
            if version >= 6 {
                put_u64(&mut p, *retry_after_us);
            }
            frame(version, OP_ERROR, request_id, p)
        }
    }
}

/// [`encode_response_v`] at the current [`WIRE_VERSION`].
pub fn encode_response(request_id: u64, resp: &Response) -> Vec<u8> {
    encode_response_v(WIRE_VERSION, request_id, resp)
}

// ---------------------------------------------------------------------
// frame decoding
// ---------------------------------------------------------------------

/// Read one fixed-size frame header. `Ok(None)` on a clean close (EOF
/// before the first byte); an EOF mid-header is a truncated frame and
/// surfaces as `UnexpectedEof`.
pub fn read_frame_header(r: &mut impl Read) -> io::Result<Option<[u8; FRAME_HEADER_LEN]>> {
    let mut buf = [0u8; FRAME_HEADER_LEN];
    let mut filled = 0usize;
    while let Some(rest) = buf.get_mut(filled..).filter(|r| !r.is_empty()) {
        match r.read(rest) {
            Ok(0) if filled == 0 => return Ok(None),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("connection closed {filled} bytes into a frame header"),
                ));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(Some(buf))
}

/// Validate a frame header's magic / version / length bounds.
pub fn parse_frame_header(buf: &[u8; FRAME_HEADER_LEN]) -> WireResult<FrameHeader> {
    let mut rd = Rd::new(buf);
    if rd.take(4)? != WIRE_MAGIC {
        return Err(WireFault::new(
            ErrCode::Malformed,
            "bad magic (not a matsketch wire frame)",
        ));
    }
    let version = rd.u16()?;
    if !(MIN_WIRE_VERSION..=WIRE_VERSION).contains(&version) {
        return Err(WireFault::new(
            ErrCode::BadVersion,
            format!(
                "protocol version {version} (this peer speaks \
                 {MIN_WIRE_VERSION}..={WIRE_VERSION})"
            ),
        ));
    }
    let opcode = rd.u8()?;
    let _reserved = rd.u8()?;
    let request_id = rd.u64()?;
    let len = rd.u32()?;
    if len > MAX_PAYLOAD {
        return Err(WireFault::new(
            ErrCode::Oversized,
            format!("declared payload of {len} bytes exceeds the {MAX_PAYLOAD}-byte cap"),
        ));
    }
    Ok(FrameHeader { version, opcode, request_id, len })
}

/// Read a frame's payload (`len` already validated by
/// [`parse_frame_header`]).
pub fn read_payload(r: &mut impl Read, len: u32) -> io::Result<Vec<u8>> {
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Decode a request payload. `version` is the frame's declared protocol
/// version: opcodes newer than it are rejected as unknown (a v1 peer
/// cannot legally send a v2-only operation).
pub fn decode_request(version: u16, opcode: u8, payload: &[u8]) -> WireResult<Request> {
    let mut rd = Rd::new(payload);
    let req = match opcode {
        OP_PING => Request::Ping,
        OP_LIST => Request::ListSketches,
        OP_SHUTDOWN => Request::Shutdown,
        OP_STATS if version >= 4 => Request::Stats,
        OP_TRACE_DUMP if version >= 5 => {
            let id = rd.u64()?;
            let slowest = rd.u32()?;
            Request::TraceDump { id, slowest }
        }
        OP_OPEN => {
            let dataset = rd.str()?;
            let method = rd.str()?;
            let s = rd.u64()?;
            let seed = rd.u64()?;
            let fingerprint = rd.u64()?;
            Request::OpenSketch(
                StoreKey::new(&dataset, &method, s, seed).with_fingerprint(fingerprint),
            )
        }
        OP_MATVEC | OP_MATVEC_T => {
            let handle = rd.u32()?;
            let pin = if version >= 3 { rd.u64()? } else { 0 };
            let trace = if version >= 5 { rd.u64()? } else { 0 };
            let x = rd.vec_f64()?;
            let query = if opcode == OP_MATVEC {
                QueryRequest::Matvec(x)
            } else {
                QueryRequest::MatvecT(x)
            };
            Request::Query { handle, pin, trace, query }
        }
        OP_MATVEC_BATCH if version >= 2 => {
            let handle = rd.u32()?;
            let pin = if version >= 3 { rd.u64()? } else { 0 };
            let trace = if version >= 5 { rd.u64()? } else { 0 };
            // each batched vector carries at least its own 4-byte length
            let count = rd.count(4)?;
            let mut xs = Vec::with_capacity(count);
            for _ in 0..count {
                xs.push(rd.vec_f64()?);
            }
            Request::Query { handle, pin, trace, query: QueryRequest::MatvecBatch(xs) }
        }
        OP_ROW | OP_COL => {
            let handle = rd.u32()?;
            let pin = if version >= 3 { rd.u64()? } else { 0 };
            let trace = if version >= 5 { rd.u64()? } else { 0 };
            let index = rd.u32()?;
            let query = if opcode == OP_ROW {
                QueryRequest::Row(index)
            } else {
                QueryRequest::Col(index)
            };
            Request::Query { handle, pin, trace, query }
        }
        OP_TOP_K => {
            let handle = rd.u32()?;
            let pin = if version >= 3 { rd.u64()? } else { 0 };
            let trace = if version >= 5 { rd.u64()? } else { 0 };
            let k = rd.u64()?;
            Request::Query { handle, pin, trace, query: QueryRequest::TopK(k as usize) }
        }
        OP_GEN_POLL if version >= 3 => {
            let handle = rd.u32()?;
            let min_gen = rd.u64()?;
            let timeout_ms = rd.u32()?;
            Request::GenPoll { handle, min_gen, timeout_ms }
        }
        other => {
            let hint = if other == OP_MATVEC_BATCH {
                " (MatvecBatch needs protocol v2)"
            } else if other == OP_GEN_POLL {
                " (GenPoll needs protocol v3)"
            } else if other == OP_STATS {
                " (Stats needs protocol v4)"
            } else if other == OP_TRACE_DUMP {
                " (TraceDump needs protocol v5)"
            } else {
                ""
            };
            return Err(WireFault::new(
                ErrCode::UnknownOpcode,
                format!("unknown request opcode {other:#04x}{hint}"),
            ));
        }
    };
    rd.done()?;
    Ok(req)
}

/// Decode a response payload. `version` is the frame's declared protocol
/// version: v3 query answers carry a leading generation tag, older ones
/// decode with generation 0, and opcodes newer than the marked version
/// are rejected as unknown.
pub fn decode_response(version: u16, opcode: u8, payload: &[u8]) -> WireResult<Response> {
    let mut rd = Rd::new(payload);
    let resp = match opcode {
        OP_PONG => Response::Pong,
        OP_SHUTTING_DOWN => Response::ShuttingDown,
        OP_SKETCH_LIST => {
            // a SketchInfo is at least 4 length/flag bytes + 4 u64s
            let count = rd.count(2 + 2 + 8 * 4 + 1)?;
            let mut infos = Vec::with_capacity(count);
            for _ in 0..count {
                infos.push(get_info(&mut rd)?);
            }
            Response::SketchList(infos)
        }
        OP_SKETCH_OPENED => {
            let handle = rd.u32()?;
            let info = get_info(&mut rd)?;
            Response::SketchOpened { handle, info }
        }
        OP_VECTOR => {
            let generation = if version >= 3 { rd.u64()? } else { 0 };
            Response::Answer {
                generation,
                answer: QueryResponse::Vector(rd.vec_f64()?),
            }
        }
        OP_VECTORS => {
            let generation = if version >= 3 { rd.u64()? } else { 0 };
            let count = rd.count(4)?;
            let mut ys = Vec::with_capacity(count);
            for _ in 0..count {
                ys.push(rd.vec_f64()?);
            }
            Response::Answer { generation, answer: QueryResponse::Vectors(ys) }
        }
        OP_ENTRIES => {
            let generation = if version >= 3 { rd.u64()? } else { 0 };
            let count = rd.count(4 + 4 + 4 + 8)?;
            let mut es = Vec::with_capacity(count);
            for _ in 0..count {
                es.push(SketchEntry {
                    row: rd.u32()?,
                    col: rd.u32()?,
                    count: rd.u32()?,
                    value: rd.f64()?,
                });
            }
            Response::Answer { generation, answer: QueryResponse::Entries(es) }
        }
        OP_GENERATION if version >= 3 => Response::Generation(rd.u64()?),
        OP_STATS_SNAPSHOT if version >= 4 => {
            let bytes = rd.take(rd.remaining())?;
            let snap = MetricsSnapshot::decode(bytes).map_err(|e| {
                WireFault::new(ErrCode::Malformed, format!("bad metrics snapshot: {e}"))
            })?;
            Response::Stats(snap)
        }
        OP_TRACES if version >= 5 => {
            let bytes = rd.take(rd.remaining())?;
            let traces = decode_traces(bytes).map_err(|e| {
                WireFault::new(ErrCode::Malformed, format!("bad trace dump: {e}"))
            })?;
            Response::Traces(traces)
        }
        OP_ERROR => {
            let code = ErrCode::from_u16(rd.u16()?);
            let message = rd.str()?;
            let retry_after_us = if version >= 6 { rd.u64()? } else { 0 };
            Response::Error { code, message, retry_after_us }
        }
        other => {
            let hint = if other == OP_GENERATION {
                " (Generation needs protocol v3)"
            } else if other == OP_STATS_SNAPSHOT {
                " (StatsSnapshot needs protocol v4)"
            } else if other == OP_TRACES {
                " (Traces needs protocol v5)"
            } else {
                ""
            };
            return Err(WireFault::new(
                ErrCode::UnknownOpcode,
                format!("unknown response opcode {other:#04x}{hint}"),
            ));
        }
    };
    rd.done()?;
    Ok(resp)
}

/// Write a complete frame (already encoded) and flush it.
pub fn write_frame(w: &mut impl Write, bytes: &[u8]) -> io::Result<()> {
    w.write_all(bytes)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(req: &Request) -> Request {
        let bytes = encode_request(42, req);
        let header: [u8; FRAME_HEADER_LEN] = bytes[..FRAME_HEADER_LEN].try_into().unwrap();
        let h = parse_frame_header(&header).unwrap();
        assert_eq!(h.version, request_version(req));
        assert_eq!(h.request_id, 42);
        assert_eq!(h.len as usize, bytes.len() - FRAME_HEADER_LEN);
        decode_request(h.version, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap()
    }

    fn roundtrip_response(resp: &Response) -> Response {
        let bytes = encode_response(7, resp);
        let header: [u8; FRAME_HEADER_LEN] = bytes[..FRAME_HEADER_LEN].try_into().unwrap();
        let h = parse_frame_header(&header).unwrap();
        assert_eq!(h.request_id, 7);
        decode_response(h.version, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap()
    }

    fn info() -> SketchInfo {
        SketchInfo {
            dataset: "enron".into(),
            method: "Bernstein".into(),
            s: 123_456,
            seed: 9,
            m: 400,
            n: 65_000,
            compact: true,
        }
    }

    #[test]
    fn every_request_opcode_roundtrips() {
        let key = StoreKey::new("wiki", "L2 trim 0.1", 9_999, 3).with_fingerprint(0xBEEF);
        let cases = vec![
            Request::Ping,
            Request::ListSketches,
            Request::Shutdown,
            Request::OpenSketch(key.clone()),
            Request::Query {
                handle: 5,
                pin: 0,
                trace: 0,
                query: QueryRequest::Matvec(vec![1.5, -2.25, f64::MIN]),
            },
            Request::Query {
                handle: 6,
                pin: 0,
                trace: 0,
                query: QueryRequest::MatvecT(vec![0.0, 3.75]),
            },
            Request::Query {
                handle: 10,
                pin: 0,
                trace: 0,
                query: QueryRequest::MatvecBatch(vec![
                    vec![1.0, 2.0],
                    vec![-0.5, 0.25],
                    Vec::new(),
                ]),
            },
            Request::Query {
                handle: 11,
                pin: 0,
                trace: 0,
                query: QueryRequest::MatvecBatch(Vec::new()),
            },
            Request::Query { handle: 7, pin: 0, trace: 0, query: QueryRequest::Row(11) },
            Request::Query { handle: 8, pin: 0, trace: 0, query: QueryRequest::Col(0) },
            Request::Query { handle: 9, pin: 0, trace: 0, query: QueryRequest::TopK(1_000) },
            // pinned queries ride v3 frames and keep the pin
            Request::Query {
                handle: 5,
                pin: 42,
                trace: 0,
                query: QueryRequest::Matvec(vec![0.5]),
            },
            Request::Query {
                handle: 10,
                pin: 7,
                trace: 0,
                query: QueryRequest::MatvecBatch(vec![vec![1.0]]),
            },
            Request::Query { handle: 7, pin: 1, trace: 0, query: QueryRequest::Row(3) },
            Request::Query { handle: 9, pin: u64::MAX, trace: 0, query: QueryRequest::TopK(4) },
            // traced queries ride v5 frames and keep the trace id
            Request::Query {
                handle: 5,
                pin: 0,
                trace: 0xDEAD_BEEF,
                query: QueryRequest::Matvec(vec![2.5]),
            },
            Request::Query { handle: 7, pin: 3, trace: u64::MAX, query: QueryRequest::Row(1) },
            Request::GenPoll { handle: 2, min_gen: 9, timeout_ms: 250 },
            Request::Stats,
            Request::TraceDump { id: 0, slowest: 10 },
            Request::TraceDump { id: 0xFACE, slowest: 0 },
        ];
        for req in &cases {
            assert_eq!(roundtrip_request(req), *req);
        }
    }

    #[test]
    fn every_response_opcode_roundtrips() {
        let entries = vec![
            SketchEntry { row: 0, col: 3, count: 2, value: -1.25 },
            SketchEntry { row: 9, col: 0, count: 1, value: f64::MAX },
        ];
        let cases = vec![
            Response::Pong,
            Response::ShuttingDown,
            Response::SketchList(vec![info(), SketchInfo { compact: false, ..info() }]),
            Response::SketchOpened { handle: 3, info: info() },
            Response::Answer {
                generation: 0,
                answer: QueryResponse::Vector(vec![0.5, -0.0, 1e300]),
            },
            Response::Answer {
                generation: 12,
                answer: QueryResponse::Vectors(vec![vec![1.0], vec![], vec![2.0, 3.0]]),
            },
            Response::Answer {
                generation: u64::MAX,
                answer: QueryResponse::Entries(entries.clone()),
            },
            Response::Generation(77),
            Response::Stats(MetricsSnapshot {
                counters: vec![("req_matvec".into(), 41), ("fault_query".into(), 2)],
                gauges: vec![("net_connections".into(), 3)],
                hists: vec![("exec_matvec_us".into(), vec![0, 1, 5, 2])],
            }),
            Response::Stats(MetricsSnapshot::default()),
            Response::Traces(vec![crate::obs::TraceRecord {
                trace: 0xABCD,
                spans: vec![
                    crate::obs::SpanRecord {
                        id: 1,
                        parent: 0,
                        name: "request".into(),
                        start_us: 0,
                        end_us: 900,
                        notes: vec![("op".into(), "matvec".into())],
                    },
                    crate::obs::SpanRecord {
                        id: 2,
                        parent: 1,
                        name: "queue_wait".into(),
                        start_us: 3,
                        end_us: 40,
                        notes: Vec::new(),
                    },
                ],
            }]),
            Response::Traces(Vec::new()),
            Response::Error {
                code: ErrCode::BadHandle,
                message: "no handle 4".into(),
                retry_after_us: 0,
            },
            Response::Error {
                code: ErrCode::Generation,
                message: "gen 9 retired".into(),
                retry_after_us: 0,
            },
            Response::Error {
                code: ErrCode::Overloaded,
                message: "inflight 9 over high water 8".into(),
                retry_after_us: 1_500,
            },
            Response::Error {
                code: ErrCode::Timeout,
                message: "response write timed out".into(),
                retry_after_us: 0,
            },
        ];
        for resp in &cases {
            assert_eq!(roundtrip_response(resp), *resp);
        }
    }

    #[test]
    fn f64_bits_survive_exactly() {
        // byte-identity over the wire hinges on bit-pattern transport:
        // NaN payloads, signed zero, subnormals all round-trip
        let tricky = vec![f64::NAN, -0.0, f64::MIN_POSITIVE / 2.0, f64::INFINITY];
        let bytes = encode_response(
            1,
            &Response::Answer {
                generation: 0,
                answer: QueryResponse::Vector(tricky.clone()),
            },
        );
        let header: [u8; FRAME_HEADER_LEN] = bytes[..FRAME_HEADER_LEN].try_into().unwrap();
        let h = parse_frame_header(&header).unwrap();
        match decode_response(h.version, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap() {
            Response::Answer { answer: QueryResponse::Vector(y), .. } => {
                assert_eq!(y.len(), tricky.len());
                for (a, b) in tricky.iter().zip(&y) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn header_faults_are_typed() {
        let good = encode_request(1, &Request::Ping);
        let mut h: [u8; FRAME_HEADER_LEN] = good[..FRAME_HEADER_LEN].try_into().unwrap();

        let mut bad_magic = h;
        bad_magic[0] = b'X';
        assert_eq!(parse_frame_header(&bad_magic).unwrap_err().code, ErrCode::Malformed);

        let mut bad_version = h;
        bad_version[5] = 99;
        assert_eq!(parse_frame_header(&bad_version).unwrap_err().code, ErrCode::BadVersion);

        let mut zero_version = h;
        zero_version[4] = 0;
        zero_version[5] = 0;
        assert_eq!(
            parse_frame_header(&zero_version).unwrap_err().code,
            ErrCode::BadVersion
        );

        // giant declared length
        h[16..20].copy_from_slice(&u32::MAX.to_be_bytes());
        assert_eq!(parse_frame_header(&h).unwrap_err().code, ErrCode::Oversized);
    }

    #[test]
    fn v1_frames_stay_decodable_and_gate_v2_opcodes() {
        // a v1-marked Ping parses and decodes
        let mut bytes = encode_request(3, &Request::Ping);
        bytes[4..6].copy_from_slice(&1u16.to_be_bytes());
        let header: [u8; FRAME_HEADER_LEN] = bytes[..FRAME_HEADER_LEN].try_into().unwrap();
        let h = parse_frame_header(&header).unwrap();
        assert_eq!(h.version, 1);
        assert_eq!(
            decode_request(h.version, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap(),
            Request::Ping
        );

        // ... but the v2-only MatvecBatch opcode inside it is rejected
        let batch = Request::Query {
            handle: 1,
            pin: 0,
            trace: 0,
            query: QueryRequest::MatvecBatch(vec![vec![1.0]]),
        };
        let bytes = encode_request(4, &batch);
        let header: [u8; FRAME_HEADER_LEN] = bytes[..FRAME_HEADER_LEN].try_into().unwrap();
        let h = parse_frame_header(&header).unwrap();
        let fault = decode_request(1, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap_err();
        assert_eq!(fault.code, ErrCode::UnknownOpcode);
        assert!(fault.message.contains("v2"), "{}", fault.message);
        // the same payload under v2 decodes fine
        assert_eq!(
            decode_request(2, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap(),
            batch
        );

        // responses echo the requested version
        let v1_resp = encode_response_v(1, 9, &Response::Pong);
        assert_eq!(u16::from_be_bytes([v1_resp[4], v1_resp[5]]), 1);
    }

    #[test]
    fn v2_frames_stay_decodable_and_gate_v3_opcodes() {
        // an unpinned query never pays the v3 tax: it still encodes at
        // the old minimum its operation needs
        let unpinned = Request::Query { handle: 2, pin: 0, trace: 0, query: QueryRequest::Row(4) };
        assert_eq!(request_version(&unpinned), 1);
        let unpinned_batch = Request::Query {
            handle: 2,
            pin: 0,
            trace: 0,
            query: QueryRequest::MatvecBatch(vec![vec![1.0]]),
        };
        assert_eq!(request_version(&unpinned_batch), 2);

        // … unless a generation-aware caller raises it explicitly: the
        // frame then carries the (zero) pin and decodes unchanged at v3
        let raised = encode_request_at(7, &unpinned, 3);
        let header: [u8; FRAME_HEADER_LEN] = raised[..FRAME_HEADER_LEN].try_into().unwrap();
        let h = parse_frame_header(&header).unwrap();
        assert_eq!(h.version, 3);
        assert_eq!(
            decode_request(h.version, h.opcode, &raised[FRAME_HEADER_LEN..]).unwrap(),
            unpinned
        );
        // the floor still wins: a version below the op's minimum is raised
        let floored = encode_request_at(8, &unpinned_batch, 1);
        assert_eq!(u16::from_be_bytes([floored[4], floored[5]]), 2);

        // a pin forces v3, and the pin survives the round trip
        let pinned = Request::Query { handle: 2, pin: 6, trace: 0, query: QueryRequest::Row(4) };
        assert_eq!(request_version(&pinned), 3);
        let bytes = encode_request(5, &pinned);
        let header: [u8; FRAME_HEADER_LEN] = bytes[..FRAME_HEADER_LEN].try_into().unwrap();
        let h = parse_frame_header(&header).unwrap();
        assert_eq!(h.version, 3);
        assert_eq!(
            decode_request(h.version, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap(),
            pinned
        );

        // the v3-only GenPoll opcode inside a v2-marked frame is rejected
        let poll = Request::GenPoll { handle: 1, min_gen: 3, timeout_ms: 10 };
        let bytes = encode_request(6, &poll);
        let header: [u8; FRAME_HEADER_LEN] = bytes[..FRAME_HEADER_LEN].try_into().unwrap();
        let h = parse_frame_header(&header).unwrap();
        let fault = decode_request(2, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap_err();
        assert_eq!(fault.code, ErrCode::UnknownOpcode);
        assert!(fault.message.contains("v3"), "{}", fault.message);
        // the same payload under v3 decodes fine
        assert_eq!(
            decode_request(3, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap(),
            poll
        );

        // answers at v2 drop the generation tag: a v2 peer reads the same
        // vector bytes it always did, and re-decoding yields generation 0
        let answer = Response::Answer {
            generation: 9,
            answer: QueryResponse::Vector(vec![1.5, -2.0]),
        };
        let v2_bytes = encode_response_v(2, 8, &answer);
        assert_eq!(u16::from_be_bytes([v2_bytes[4], v2_bytes[5]]), 2);
        match decode_response(2, v2_bytes[6], &v2_bytes[FRAME_HEADER_LEN..]).unwrap() {
            Response::Answer { generation, answer: QueryResponse::Vector(y) } => {
                assert_eq!(generation, 0);
                assert_eq!(y, vec![1.5, -2.0]);
            }
            other => panic!("unexpected {other:?}"),
        }

        // ... while a v3 frame carries it
        let v3_bytes = encode_response_v(3, 8, &answer);
        match decode_response(3, v3_bytes[6], &v3_bytes[FRAME_HEADER_LEN..]).unwrap() {
            Response::Answer { generation, .. } => assert_eq!(generation, 9),
            other => panic!("unexpected {other:?}"),
        }

        // a v2 peer that somehow receives the Generation opcode rejects it
        let gen_bytes = encode_response_v(3, 8, &Response::Generation(4));
        let fault =
            decode_response(2, gen_bytes[6], &gen_bytes[FRAME_HEADER_LEN..]).unwrap_err();
        assert_eq!(fault.code, ErrCode::UnknownOpcode);
    }

    #[test]
    fn v3_frames_stay_decodable_and_gate_v4_opcodes() {
        // everything v3 and below never pays the v4 tax: old operations
        // keep their old minimum versions
        assert_eq!(request_version(&Request::Ping), 1);
        let pinned = Request::Query { handle: 1, pin: 3, trace: 0, query: QueryRequest::Row(0) };
        assert_eq!(request_version(&pinned), 3);
        // ... while Stats rides a v4 frame
        assert_eq!(request_version(&Request::Stats), 4);

        // the v4-only Stats opcode inside a v3-marked frame is rejected
        // with a version hint
        let bytes = encode_request(11, &Request::Stats);
        let header: [u8; FRAME_HEADER_LEN] = bytes[..FRAME_HEADER_LEN].try_into().unwrap();
        let h = parse_frame_header(&header).unwrap();
        assert_eq!(h.version, 4);
        let fault = decode_request(3, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap_err();
        assert_eq!(fault.code, ErrCode::UnknownOpcode);
        assert!(fault.message.contains("v4"), "{}", fault.message);
        // the same payload under v4 decodes fine
        assert_eq!(
            decode_request(4, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap(),
            Request::Stats
        );

        // a v3 peer that somehow receives the StatsSnapshot opcode
        // rejects it instead of misreading the payload
        let snap = MetricsSnapshot {
            counters: vec![("req_ping".into(), 1)],
            ..Default::default()
        };
        let resp_bytes = encode_response_v(4, 12, &Response::Stats(snap.clone()));
        let fault =
            decode_response(3, resp_bytes[6], &resp_bytes[FRAME_HEADER_LEN..]).unwrap_err();
        assert_eq!(fault.code, ErrCode::UnknownOpcode);
        assert!(fault.message.contains("v4"), "{}", fault.message);
        match decode_response(4, resp_bytes[6], &resp_bytes[FRAME_HEADER_LEN..]).unwrap() {
            Response::Stats(back) => assert_eq!(back, snap),
            other => panic!("unexpected {other:?}"),
        }

        // a corrupt snapshot payload is a typed Malformed fault
        let fault = decode_response(4, OP_STATS_SNAPSHOT, &[0xFF, 0xFF, 0x00]).unwrap_err();
        assert_eq!(fault.code, ErrCode::Malformed);

        // v3 query frames (pin + generation tag) are untouched by the
        // bump: a pinned row query round-trips at exactly v3
        let bytes = encode_request(13, &pinned);
        let header: [u8; FRAME_HEADER_LEN] = bytes[..FRAME_HEADER_LEN].try_into().unwrap();
        let h = parse_frame_header(&header).unwrap();
        assert_eq!(h.version, 3);
        assert_eq!(
            decode_request(h.version, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap(),
            pinned
        );
    }

    #[test]
    fn v4_frames_stay_decodable_and_gate_v5_opcodes() {
        // everything v4 and below never pays the v5 tax: untraced
        // operations keep their old minimum versions
        let untraced = Request::Query { handle: 1, pin: 0, trace: 0, query: QueryRequest::Row(0) };
        assert_eq!(request_version(&untraced), 1);
        let pinned = Request::Query { handle: 1, pin: 3, trace: 0, query: QueryRequest::Row(0) };
        assert_eq!(request_version(&pinned), 3);
        assert_eq!(request_version(&Request::Stats), 4);
        // ... while a trace id or a TraceDump forces a v5 frame
        let traced = Request::Query { handle: 1, pin: 0, trace: 9, query: QueryRequest::Row(0) };
        assert_eq!(request_version(&traced), 5);
        assert_eq!(request_version(&Request::TraceDump { id: 0, slowest: 5 }), 5);

        // asking for v4 cannot drop a live trace id on the floor: the
        // operation's v5 floor wins over the requested version
        let v4_traced = encode_request_at(1, &traced, 4);
        let v4_untraced = encode_request_at(1, &untraced, 4);
        assert_eq!(h_version(&v4_traced), 5, "the v5 floor wins over the requested v4");
        assert_eq!(h_version(&v4_untraced), 4);
        let v5_untraced = encode_request_at(1, &untraced, 5);
        assert_eq!(
            v4_untraced[FRAME_HEADER_LEN..].len() + 8,
            v5_untraced[FRAME_HEADER_LEN..].len(),
            "v5 adds exactly the 8-byte trace id"
        );
        // ... and a v4-decoded v5-shaped payload is impossible to confuse:
        // decoding the untraced query at its own version round-trips
        let h = parse_frame_header(&v5_untraced[..FRAME_HEADER_LEN].try_into().unwrap()).unwrap();
        assert_eq!(
            decode_request(h.version, h.opcode, &v5_untraced[FRAME_HEADER_LEN..]).unwrap(),
            untraced
        );

        // the v5-only TraceDump opcode inside a v4-marked frame is
        // rejected with a version hint
        let dump = Request::TraceDump { id: 7, slowest: 0 };
        let bytes = encode_request(21, &dump);
        let header: [u8; FRAME_HEADER_LEN] = bytes[..FRAME_HEADER_LEN].try_into().unwrap();
        let h = parse_frame_header(&header).unwrap();
        assert_eq!(h.version, 5);
        let fault = decode_request(4, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap_err();
        assert_eq!(fault.code, ErrCode::UnknownOpcode);
        assert!(fault.message.contains("v5"), "{}", fault.message);
        // the same payload under v5 decodes fine
        assert_eq!(
            decode_request(5, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap(),
            dump
        );

        // a v4 peer that somehow receives the Traces opcode rejects it
        // instead of misreading the payload
        let traces = vec![crate::obs::TraceRecord { trace: 3, spans: Vec::new() }];
        let resp_bytes = encode_response_v(5, 22, &Response::Traces(traces.clone()));
        let fault =
            decode_response(4, resp_bytes[6], &resp_bytes[FRAME_HEADER_LEN..]).unwrap_err();
        assert_eq!(fault.code, ErrCode::UnknownOpcode);
        assert!(fault.message.contains("v5"), "{}", fault.message);
        match decode_response(5, resp_bytes[6], &resp_bytes[FRAME_HEADER_LEN..]).unwrap() {
            Response::Traces(back) => assert_eq!(back, traces),
            other => panic!("unexpected {other:?}"),
        }

        // a corrupt trace payload is a typed Malformed fault
        let fault = decode_response(5, OP_TRACES, &[0x00]).unwrap_err();
        assert_eq!(fault.code, ErrCode::Malformed);

        // a traced query round-trips at exactly v5 with both pin and
        // trace intact
        let both = Request::Query { handle: 2, pin: 4, trace: 11, query: QueryRequest::Col(1) };
        let bytes = encode_request(23, &both);
        let header: [u8; FRAME_HEADER_LEN] = bytes[..FRAME_HEADER_LEN].try_into().unwrap();
        let h = parse_frame_header(&header).unwrap();
        assert_eq!(h.version, 5);
        assert_eq!(
            decode_request(h.version, h.opcode, &bytes[FRAME_HEADER_LEN..]).unwrap(),
            both
        );
    }

    #[test]
    fn v5_frames_stay_decodable_and_gate_v6_error_hints() {
        // a v6 error carries the retry-after hint and the new codes
        let shed = Response::Error {
            code: ErrCode::Overloaded,
            message: "inflight 9 over high water 8".into(),
            retry_after_us: 2_000,
        };
        let v6 = encode_response_v(6, 31, &shed);
        assert_eq!(h_version(&v6), 6);
        assert_eq!(decode_response(6, v6[6], &v6[FRAME_HEADER_LEN..]).unwrap(), shed);

        // encoded for a v5 peer the hint is dropped and the v6-only code
        // downgrades to Busy — never a value a legacy from_u16 would
        // misread as Malformed
        let v5 = encode_response_v(5, 31, &shed);
        assert_eq!(h_version(&v5), 5);
        assert_eq!(
            v5[FRAME_HEADER_LEN..].len() + 8,
            v6[FRAME_HEADER_LEN..].len(),
            "v6 adds exactly the 8-byte retry-after hint"
        );
        match decode_response(5, v5[6], &v5[FRAME_HEADER_LEN..]).unwrap() {
            Response::Error { code, retry_after_us, .. } => {
                assert_eq!(code, ErrCode::Busy);
                assert_eq!(retry_after_us, 0, "v5 frames decode with no hint");
            }
            other => panic!("unexpected {other:?}"),
        }

        // Timeout downgrades the same way
        let timeout = Response::Error {
            code: ErrCode::Timeout,
            message: "write deadline".into(),
            retry_after_us: 0,
        };
        let v4 = encode_response_v(4, 32, &timeout);
        match decode_response(4, v4[6], &v4[FRAME_HEADER_LEN..]).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrCode::Busy),
            other => panic!("unexpected {other:?}"),
        }

        // a v6-shaped error payload inside a v5-marked frame is a typed
        // trailing-bytes fault, not a silent accept
        let fault = decode_response(5, v6[6], &v6[FRAME_HEADER_LEN..]).unwrap_err();
        assert_eq!(fault.code, ErrCode::Malformed);

        // ... and a v6 error truncated before its hint is a typed short
        // fault at v6
        let body = &v5[FRAME_HEADER_LEN..]; // code + message, no hint
        let fault = decode_response(6, OP_ERROR, body).unwrap_err();
        assert_eq!(fault.code, ErrCode::Malformed);

        // legacy codes round-trip unchanged at both versions, hint 0
        let busy = Response::Error {
            code: ErrCode::Busy,
            message: "connection limit".into(),
            retry_after_us: 0,
        };
        for v in [5u16, 6] {
            let bytes = encode_response_v(v, 33, &busy);
            assert_eq!(
                decode_response(v, bytes[6], &bytes[FRAME_HEADER_LEN..]).unwrap(),
                busy
            );
        }

        // the new codes' wire values round-trip through as_u16/from_u16
        for code in [ErrCode::Overloaded, ErrCode::Timeout] {
            assert_eq!(ErrCode::from_u16(code.as_u16()), code);
        }
    }

    fn h_version(frame: &[u8]) -> u16 {
        u16::from_be_bytes([frame[4], frame[5]])
    }

    #[test]
    fn payload_faults_are_typed() {
        // trailing bytes (unpinned Row rides a v1 frame; decode at that
        // version so the fault is the trailing byte, not a missing pin)
        let req = Request::Query { handle: 1, pin: 0, trace: 0, query: QueryRequest::Row(2) };
        let mut bytes = encode_request(1, &req);
        bytes.push(0xAA);
        let v = request_version(&req);
        let fault = decode_request(v, OP_ROW, &bytes[FRAME_HEADER_LEN..]).unwrap_err();
        assert_eq!(fault.code, ErrCode::Malformed);

        // short payload
        let fault = decode_request(WIRE_VERSION, OP_ROW, &[0, 0]).unwrap_err();
        assert_eq!(fault.code, ErrCode::Malformed);

        // count that can't fit the payload (giant vector claim)
        let mut p = Vec::new();
        put_u32(&mut p, 1); // handle
        put_u64(&mut p, 0); // pin (v3+ frames carry it)
        put_u64(&mut p, 0); // trace (v5 frames carry it)
        put_u32(&mut p, u32::MAX); // claimed element count
        let fault = decode_request(WIRE_VERSION, OP_MATVEC, &p).unwrap_err();
        assert_eq!(fault.code, ErrCode::Malformed);

        // batch count the payload cannot hold (the v2 corpus entry)
        let mut p = Vec::new();
        put_u32(&mut p, 1); // handle
        put_u64(&mut p, 0); // pin
        put_u64(&mut p, 0); // trace
        put_u32(&mut p, 1_000_000); // claimed batch of a million vectors
        let fault = decode_request(WIRE_VERSION, OP_MATVEC_BATCH, &p).unwrap_err();
        assert_eq!(fault.code, ErrCode::Malformed);

        // inner vector length overrunning the batch payload
        let mut p = Vec::new();
        put_u32(&mut p, 1); // handle
        put_u64(&mut p, 0); // pin
        put_u64(&mut p, 0); // trace
        put_u32(&mut p, 1); // one vector
        put_u32(&mut p, 500); // ... claiming 500 f64s with none present
        let fault = decode_request(WIRE_VERSION, OP_MATVEC_BATCH, &p).unwrap_err();
        assert_eq!(fault.code, ErrCode::Malformed);

        // unknown opcode
        let fault = decode_request(WIRE_VERSION, 0x6F, &[]).unwrap_err();
        assert_eq!(fault.code, ErrCode::UnknownOpcode);
    }

    #[test]
    fn clean_close_vs_truncated_header() {
        let mut empty: &[u8] = &[];
        assert!(read_frame_header(&mut empty).unwrap().is_none());

        let good = encode_request(1, &Request::Ping);
        let mut partial: &[u8] = &good[..7];
        let err = read_frame_header(&mut partial).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    /// Every request opcode with the lowest protocol version whose
    /// decode arm accepts it. The wire-discipline lint (`matsketch
    /// lint`) checks that each `OP_*` const is exercised inside this
    /// test region — keep these tables exhaustive when adding opcodes.
    const REQUEST_OPS: &[(u8, u16)] = &[
        (OP_PING, 1),
        (OP_LIST, 1),
        (OP_OPEN, 1),
        (OP_SHUTDOWN, 1),
        (OP_STATS, 4),
        (OP_TRACE_DUMP, 5),
        (OP_MATVEC, 1),
        (OP_MATVEC_T, 1),
        (OP_ROW, 1),
        (OP_COL, 1),
        (OP_TOP_K, 1),
        (OP_MATVEC_BATCH, 2),
        (OP_GEN_POLL, 3),
    ];

    /// Response twin of [`REQUEST_OPS`].
    const RESPONSE_OPS: &[(u8, u16)] = &[
        (OP_PONG, 1),
        (OP_SKETCH_LIST, 1),
        (OP_SKETCH_OPENED, 1),
        (OP_SHUTTING_DOWN, 1),
        (OP_VECTOR, 1),
        (OP_ENTRIES, 1),
        (OP_VECTORS, 1),
        (OP_GENERATION, 3),
        (OP_STATS_SNAPSHOT, 4),
        (OP_TRACES, 5),
        (OP_ERROR, 1),
    ];

    #[test]
    fn malformed_corpus_covers_every_opcode() {
        // hostile payloads: empty, trailing garbage, truncated fields,
        // and a pathological length claim — every opcode must answer
        // each with a typed fault or a clean decode, never a panic
        let corpus: &[&[u8]] =
            &[&[], &[0xAB], &[0xFF; 3], &[0xFF; 64], &u32::MAX.to_be_bytes()];
        let mut faults = 0usize;
        for &(op, min_v) in REQUEST_OPS {
            for payload in corpus {
                if let Err(fault) = decode_request(min_v, op, payload) {
                    assert!(!fault.message.is_empty(), "{op:#04x}: empty fault message");
                    faults += 1;
                }
                if min_v > MIN_WIRE_VERSION {
                    // below its gate the opcode is rejected, not misread
                    let fault = decode_request(min_v - 1, op, payload).unwrap_err();
                    assert_eq!(fault.code, ErrCode::UnknownOpcode, "{op:#04x}");
                }
            }
        }
        for &(op, min_v) in RESPONSE_OPS {
            for payload in corpus {
                if let Err(fault) = decode_response(min_v, op, payload) {
                    assert!(!fault.message.is_empty(), "{op:#04x}: empty fault message");
                    faults += 1;
                }
                if min_v > MIN_WIRE_VERSION {
                    let fault = decode_response(min_v - 1, op, payload).unwrap_err();
                    assert_eq!(fault.code, ErrCode::UnknownOpcode, "{op:#04x}");
                }
            }
        }
        assert!(faults > 40, "corpus unexpectedly tame: only {faults} faults");
    }
}
