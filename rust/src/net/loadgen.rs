//! Closed-loop load generation against any [`crate::api::SketchClient`]
//! backend.
//!
//! `N` client threads each hold one backend client (a fresh TCP
//! connection for remote runs, a [`crate::api::LocalClient`] for
//! in-process baselines), open the target sketch, and issue queries
//! back-to-back (closed loop: the next query starts when the previous
//! answer lands). Per-query wall latencies are recorded and aggregated
//! into throughput plus a latency histogram (p50/p95/p99 via
//! [`crate::util::stats::quantiles_in_place`], which selects order
//! statistics in the owned latency buffer instead of sorting a clone) —
//! the numbers
//! `matsketch net-bench` reports into the eval tables next to the
//! in-process `serving.*` ones. Because the harness only sees
//! `dyn SketchClient`, the same loop measures either backend and the
//! two reports are directly comparable.

use std::time::{Duration, Instant};

use crate::api::{BoxedSketchClient, QueryRequest, RemoteClient};
use crate::error::{Error, Result};
use crate::serve::{LiveSketch, StoreKey};
use crate::sparse::Entry;
use crate::util::rng::Rng;
use crate::util::stats::quantiles_in_place;
use crate::warn_log;

/// Which operation mix a load run issues.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoadOp {
    /// `B·x` with a client-seeded dense probe vector.
    Matvec,
    /// `Bᵀ·x`.
    MatvecT,
    /// Batched `B·X` (`batch_k` right-hand sides in one request).
    MatvecBatch,
    /// Random row slice.
    Row,
    /// Random column slice.
    Col,
    /// Top-k heaviest entries.
    TopK,
}

impl LoadOp {
    /// Parse a CLI token (`matvec`, `matvec-t`, `matvec-batch`, `row`,
    /// `col`, `top-k`).
    pub fn parse(tok: &str) -> Option<LoadOp> {
        match tok.trim().to_ascii_lowercase().as_str() {
            "matvec" => Some(LoadOp::Matvec),
            "matvec-t" | "matvect" => Some(LoadOp::MatvecT),
            "matvec-batch" | "matvecbatch" | "batch" => Some(LoadOp::MatvecBatch),
            "row" => Some(LoadOp::Row),
            "col" => Some(LoadOp::Col),
            "top-k" | "topk" => Some(LoadOp::TopK),
            _ => None,
        }
    }

    /// Stable name (reports).
    pub fn name(self) -> &'static str {
        match self {
            LoadOp::Matvec => "matvec",
            LoadOp::MatvecT => "matvec-t",
            LoadOp::MatvecBatch => "matvec-batch",
            LoadOp::Row => "row",
            LoadOp::Col => "col",
            LoadOp::TopK => "top-k",
        }
    }
}

/// Load-run knobs.
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    /// Concurrent client connections.
    pub clients: usize,
    /// Queries per client (ignored when `duration` is set).
    pub queries_per_client: usize,
    /// Run for this long instead of a fixed count (the CI smoke mode).
    pub duration: Option<Duration>,
    /// Operation mix, cycled per query.
    pub ops: Vec<LoadOp>,
    /// `k` for [`LoadOp::TopK`] queries.
    pub top_k: usize,
    /// Right-hand sides per [`LoadOp::MatvecBatch`] request.
    pub batch_k: usize,
    /// Base RNG seed (each client derives its own stream).
    pub seed: u64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            clients: 4,
            queries_per_client: 64,
            duration: None,
            ops: vec![LoadOp::Matvec, LoadOp::Row, LoadOp::TopK],
            top_k: 10,
            batch_k: 4,
            seed: 0,
        }
    }
}

/// Aggregated result of one load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Concurrent clients.
    pub clients: usize,
    /// Queries answered successfully.
    pub queries: u64,
    /// Queries that errored (excluded from latencies).
    pub errors: u64,
    /// Wall-clock of the whole run in seconds.
    pub wall_secs: f64,
    /// Successful queries per second.
    pub qps: f64,
    /// Latency histogram over successful queries, microseconds.
    pub p50_us: f64,
    /// 95th percentile latency (µs).
    pub p95_us: f64,
    /// 99th percentile latency (µs).
    pub p99_us: f64,
    /// Mean latency (µs).
    pub mean_us: f64,
    /// Worst observed latency (µs).
    pub max_us: f64,
}

/// After this many *consecutive* failures a client gives up instead of
/// spinning on a dead server.
const MAX_CONSECUTIVE_ERRORS: u32 = 10;

/// Run one closed-loop measurement of `key` served at the wire address
/// `addr` (each load client dials its own connection).
pub fn run_load(addr: &str, key: &StoreKey, cfg: &LoadGenConfig) -> Result<LoadReport> {
    run_load_with(
        |_| Ok(Box::new(RemoteClient::connect(addr)?) as BoxedSketchClient),
        key,
        cfg,
    )
}

/// Run one closed-loop measurement of `key` against whatever backend
/// `make_client` produces — one client per load thread (`RemoteClient`
/// for a live server, [`crate::api::LocalClient`] for the in-process
/// baseline the remote numbers are compared to).
pub fn run_load_with<F>(make_client: F, key: &StoreKey, cfg: &LoadGenConfig) -> Result<LoadReport>
where
    F: Fn(usize) -> Result<BoxedSketchClient> + Sync,
{
    if cfg.clients == 0 || cfg.ops.is_empty() {
        return Err(Error::invalid("load run needs ≥ 1 client and a non-empty op mix"));
    }
    let t0 = Instant::now();
    let deadline = cfg.duration.map(|d| t0 + d);
    let mut latencies_us: Vec<f64> = Vec::new();
    let mut errors = 0u64;
    let mut first_err: Option<Error> = None;

    std::thread::scope(|scope| {
        let mut workers = Vec::with_capacity(cfg.clients);
        for c in 0..cfg.clients {
            let make_client = &make_client;
            workers.push(scope.spawn(move || -> Result<(Vec<f64>, u64)> {
                let mut client = make_client(c)?;
                client_loop(client.as_mut(), key, cfg, c as u64, deadline)
            }));
        }
        for w in workers {
            match w.join() {
                Ok(Ok((lats, errs))) => {
                    latencies_us.extend(lats);
                    errors += errs;
                }
                Ok(Err(e)) => {
                    errors += 1;
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    errors += 1;
                    if first_err.is_none() {
                        first_err = Some(Error::Pipeline("load client panicked".into()));
                    }
                }
            }
        }
    });

    let wall_secs = t0.elapsed().as_secs_f64();
    if latencies_us.is_empty() {
        // nothing succeeded: surface the root cause instead of a report
        // full of zeros
        return Err(first_err.unwrap_or_else(|| {
            Error::Pipeline("load run produced no successful queries".into())
        }));
    }
    if let Some(e) = first_err {
        warn_log!("net-bench: some load clients failed: {e}");
    }
    // mean/max are permutation-invariant, so the owned latency buffer
    // doubles as the selection scratch: no clone, no sort
    let mean_us = latencies_us.iter().sum::<f64>() / latencies_us.len() as f64;
    let max_us = latencies_us.iter().cloned().fold(0.0, f64::max);
    let qs = quantiles_in_place(&mut latencies_us, &[0.5, 0.95, 0.99]);
    Ok(LoadReport {
        clients: cfg.clients,
        queries: latencies_us.len() as u64,
        errors,
        wall_secs,
        qps: if wall_secs > 0.0 { latencies_us.len() as f64 / wall_secs } else { 0.0 },
        p50_us: qs[0],
        p95_us: qs[1],
        p99_us: qs[2],
        mean_us,
        max_us,
    })
}

/// Scrape the telemetry snapshot of the server at `addr` (the `Stats`
/// wire opcode, protocol v4). Load harnesses scrape once before and once
/// after a run and [`diff`](crate::obs::MetricsSnapshot::diff) the two,
/// so the reported server-side counters cover exactly the run in
/// between — `eval::netbench` cross-checks them against the client-side
/// issue counts.
pub fn scrape_stats(addr: &str) -> Result<crate::obs::MetricsSnapshot> {
    let mut client = crate::net::RemoteSketchClient::connect(addr)?;
    client.stats()
}

/// Result of a mixed ingest+query run: the query-side [`LoadReport`]
/// measured *while* a live chain was ingesting, plus the ingest side's
/// freshness numbers.
#[derive(Clone, Debug)]
pub struct LiveLoadReport {
    /// Query-side throughput + latency, measured under concurrent ingest.
    pub load: LoadReport,
    /// Generations published during the run.
    pub generations: u64,
    /// Stream entries ingested during the run.
    pub entries_ingested: u64,
    /// Median publish lag (seconds from an epoch's first entry to its
    /// generation going live).
    pub lag_p50_s: f64,
    /// 95th-percentile publish lag (seconds).
    pub lag_p95_s: f64,
}

/// Run a mixed ingest+query measurement: one writer thread streams
/// `entries` into `live` (in `ingest_batch`-sized pushes, publishing on
/// the chain's epoch tick) while the usual closed-loop clients from
/// `make_client` query `key` — which every backend must resolve to the
/// same chain, locally via `LocalClient::attach_live` or remotely via
/// `NetServer::attach_live`. The query numbers therefore measure serving
/// under publication pressure: snapshot publication is one pointer swap,
/// so a tail-latency cliff here is a regression.
pub fn run_live_load<F>(
    make_client: F,
    key: &StoreKey,
    cfg: &LoadGenConfig,
    mut live: LiveSketch,
    entries: &[Entry],
    ingest_batch: usize,
) -> Result<LiveLoadReport>
where
    F: Fn(usize) -> Result<BoxedSketchClient> + Sync,
{
    let reader = live.reader();
    let (load, ingest) = std::thread::scope(|scope| {
        let writer = scope.spawn(move || -> Result<u64> {
            for chunk in entries.chunks(ingest_batch.max(1)) {
                live.push(chunk)?;
            }
            live.flush()?;
            Ok(live.ingested() as u64)
        });
        let load = run_load_with(make_client, key, cfg);
        let ingest = writer
            .join()
            .unwrap_or_else(|_| Err(Error::Pipeline("live ingest writer panicked".into())));
        (load, ingest)
    });
    let load = load?;
    let entries_ingested = ingest?;
    let mut lags = reader.freshness_lags()?;
    let (lag_p50_s, lag_p95_s) = if lags.is_empty() {
        (0.0, 0.0)
    } else {
        let qs = quantiles_in_place(&mut lags, &[0.5, 0.95]);
        (qs[0], qs[1])
    };
    Ok(LiveLoadReport {
        load,
        generations: reader.generation(),
        entries_ingested,
        lag_p50_s,
        lag_p95_s,
    })
}

/// One client's closed loop over the trait surface. Returns (per-query
/// latencies µs, error count).
fn client_loop(
    client: &mut dyn crate::api::SketchClient,
    key: &StoreKey,
    cfg: &LoadGenConfig,
    client_idx: u64,
    deadline: Option<Instant>,
) -> Result<(Vec<f64>, u64)> {
    let info = client.open(key)?;
    let (m, n) = (info.m as usize, info.n as usize);
    let mut rng = Rng::new(cfg.seed ^ (0x10AD_0000 + client_idx));
    // fixed dense probes per client: the run measures serving, not
    // client-side vector generation
    let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let xt: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let xs: Vec<Vec<f64>> = (0..cfg.batch_k.max(1))
        .map(|_| (0..n).map(|_| rng.normal()).collect())
        .collect();

    let mut latencies = Vec::new();
    let mut errors = 0u64;
    let mut consecutive = 0u32;
    let mut i = 0usize;
    loop {
        match deadline {
            Some(d) => {
                if Instant::now() >= d {
                    break;
                }
            }
            None => {
                if i >= cfg.queries_per_client {
                    break;
                }
            }
        }
        let query = match cfg.ops[i % cfg.ops.len()] {
            LoadOp::Matvec => QueryRequest::Matvec(x.clone()),
            LoadOp::MatvecT => QueryRequest::MatvecT(xt.clone()),
            LoadOp::MatvecBatch => QueryRequest::MatvecBatch(xs.clone()),
            LoadOp::Row => QueryRequest::Row(rng.usize_below(m.max(1)) as u32),
            LoadOp::Col => QueryRequest::Col(rng.usize_below(n.max(1)) as u32),
            LoadOp::TopK => QueryRequest::TopK(cfg.top_k),
        };
        let t = Instant::now();
        match client.query(key, &query) {
            Ok(_) => {
                latencies.push(t.elapsed().as_secs_f64() * 1e6);
                consecutive = 0;
            }
            Err(e) => {
                errors += 1;
                consecutive += 1;
                if consecutive >= MAX_CONSECUTIVE_ERRORS {
                    warn_log!(
                        "net-bench: client {client_idx} giving up after \
                         {consecutive} consecutive errors: {e}"
                    );
                    break;
                }
            }
        }
        i += 1;
    }
    let _ = client.close();
    Ok((latencies, errors))
}
