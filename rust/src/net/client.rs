//! The blocking remote query client.
//!
//! [`RemoteSketchClient`] speaks the [`super::wire`] protocol over one
//! TCP connection: open sketches by [`StoreKey`], run every
//! [`QueryRequest`] kind, and **pipeline** batches (all requests written before
//! any response is read — the server answers in order, so one round trip
//! covers the whole batch).
//!
//! Idempotent operations (every query, open, poll, and control call —
//! the server computes pure answers over immutable sketch generations)
//! retry under a bounded [`RetryPolicy`]: exponential backoff with
//! deterministic seeded jitter, a retry budget that fails fast when the
//! far end is persistently sick, and an optional per-request deadline
//! ([`RemoteSketchClient::set_deadline`]) that bounds the whole
//! attempt-and-backoff loop. Connection-level failures (`Io`) and
//! corrupted frames (`Parse`) redial and rebuild connection state
//! *inside* the retry iteration — handles are re-opened and sticky
//! generation pins re-applied before the request goes back out, so a
//! reconnect can never answer a pinned query at the wrong generation.
//! Server pushback ([`crate::error::Error::Overloaded`], carrying the
//! v6 retry-after hint) backs off without redialling. Everything else —
//! malformed-request faults, generation faults, bad handles — is
//! non-retryable and surfaces immediately. Retries and abandoned
//! deadlines are counted in [`crate::obs`] (`client_retry`,
//! `client_deadline`).
//!
//! Generation pins ([`RemoteSketchClient::set_pin`] and the explicit
//! `query_at` / `poll_generation` calls) live in their own per-key map,
//! deliberately **not** cleared by the reconnect path: handles are
//! connection-scoped, pins are client intent. After a redial the client
//! re-opens the handle and keeps answering at the pinned generation
//! instead of silently resetting to latest.
//!
//! The client is also where **trace context is born** (protocol v5):
//! each query consults the process sampler ([`crate::obs::trace`]), and
//! a sampled request carries its nonzero trace id on the wire, opens a
//! `client_send` span covering the round trip locally, and gets a
//! matching server-side span tree — fetched back with
//! [`RemoteSketchClient::trace_dump`].

use std::collections::VecDeque;
use std::io::{self, BufReader, BufWriter};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::thread;
use std::time::{Duration, Instant};

use crate::api::{QueryRequest, QueryResponse, SketchInfo};
use crate::error::{Error, Result};
use crate::obs::trace::{self, TraceRecord};
use crate::obs::{self, Counter};
use crate::serve::StoreKey;
use crate::util::rng::Rng;

use super::wire::{self, ErrCode, Request, Response};

/// Default connect / read / write timeout.
const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

/// Maximum requests in flight during [`RemoteSketchClient::pipeline`]:
/// the server answers strictly in order and fully writes each answer
/// before reading the next request, so unbounded write-ahead could fill
/// both sockets' buffers and deadlock. Eight keeps the latency win while
/// bounding outstanding responses.
const PIPELINE_WINDOW: usize = 8;

/// Bounded retry behaviour for idempotent remote operations.
///
/// Every knob is deterministic: the jitter stream is seeded, so a fixed
/// `(policy, fault schedule)` pair replays the exact same delays — which
/// is what lets the chaos suite pin client behaviour byte-for-byte.
#[derive(Clone, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts per operation, including the first (1 = never
    /// retry).
    pub max_attempts: u32,
    /// Backoff before the first retry; doubles on each further retry.
    pub base_backoff: Duration,
    /// Ceiling on the computed backoff. A server retry-after hint may
    /// exceed it — the server knows its own queue depth better.
    pub max_backoff: Duration,
    /// Seed for the deterministic jitter stream (full jitter over the
    /// upper half of the exponential delay).
    pub jitter_seed: u64,
    /// Retry-budget cap, in tokens. Each retry spends one token; each
    /// success refunds a tenth. A drained budget surfaces the error
    /// instead of piling retries onto a struggling server.
    pub budget: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(500),
            jitter_seed: 0x7E57_5EED,
            budget: 20,
        }
    }
}

impl RetryPolicy {
    /// The delay before retry number `retry_index` (0-based): exponential
    /// growth capped at [`max_backoff`](Self::max_backoff), full jitter
    /// over the upper half, floored by the server's retry-after hint.
    fn delay_for(&self, retry_index: u32, hint_us: u64, jitter: &mut Rng) -> Duration {
        let base = self.base_backoff.as_micros() as u64;
        let max = self.max_backoff.as_micros() as u64;
        let exp = base.saturating_mul(1u64 << retry_index.min(16)).min(max);
        let half = exp / 2;
        let jittered = half + jitter.u64_below(exp - half + 1);
        Duration::from_micros(jittered.max(hint_us))
    }
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

/// A blocking wire-protocol client with request pipelining and
/// policy-driven retries (reconnect + handle re-open + pin re-apply
/// inside the retry loop).
pub struct RemoteSketchClient {
    addr: SocketAddr,
    timeout: Option<Duration>,
    conn: Option<Conn>,
    next_id: u64,
    /// Sketches opened on the *current* connection: `(key, handle)`.
    /// Cleared on reconnect (handles are connection-scoped server-side)
    /// and re-established lazily.
    opened: Vec<(StoreKey, u32)>,
    /// Sticky per-key generation pins: `(key, generation)`. Unlike
    /// `opened` this survives [`reset`](Self::reset) — a pin is caller
    /// intent, not connection state — so a reconnect restores the pinned
    /// generation on re-open instead of drifting to latest.
    pins: Vec<(StoreKey, u64)>,
    retry: RetryPolicy,
    /// Deterministic jitter stream, seeded from the policy.
    jitter: Rng,
    /// Remaining retry budget in tenths of a token (see
    /// [`RetryPolicy::budget`]).
    budget_tenths: u32,
    /// Optional per-request wall-clock budget covering all attempts and
    /// backoff sleeps of one logical operation.
    request_deadline: Option<Duration>,
}

impl RemoteSketchClient {
    /// Resolve `addr` (e.g. `"127.0.0.1:7300"`) and connect with the
    /// default timeout and default [`RetryPolicy`].
    pub fn connect(addr: &str) -> Result<RemoteSketchClient> {
        Self::connect_with_timeout(addr, Some(DEFAULT_TIMEOUT))
    }

    /// [`RemoteSketchClient::connect`] with an explicit timeout
    /// (`None` = block forever).
    pub fn connect_with_timeout(
        addr: &str,
        timeout: Option<Duration>,
    ) -> Result<RemoteSketchClient> {
        let resolved = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| Error::invalid(format!("address {addr:?} resolves to nothing")))?;
        let policy = RetryPolicy::default();
        let mut client = RemoteSketchClient {
            addr: resolved,
            timeout,
            conn: None,
            next_id: 0,
            opened: Vec::new(),
            pins: Vec::new(),
            jitter: Rng::new(policy.jitter_seed),
            budget_tenths: policy.budget.saturating_mul(10),
            retry: policy,
            request_deadline: None,
        };
        client.ensure_conn()?;
        Ok(client)
    }

    /// The server address this client dials.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Replace the retry policy. Reseeds the jitter stream and refills
    /// the retry budget to the new cap.
    pub fn set_retry_policy(&mut self, policy: RetryPolicy) {
        self.jitter = Rng::new(policy.jitter_seed);
        self.budget_tenths = policy.budget.saturating_mul(10);
        self.retry = policy;
    }

    /// The active retry policy.
    pub fn retry_policy(&self) -> &RetryPolicy {
        &self.retry
    }

    /// Set (or with `None` clear) the per-request deadline: the total
    /// wall-clock budget one logical operation may spend across all its
    /// attempts and backoff sleeps. When a would-be retry cannot fit,
    /// the call fails with [`Error::Deadline`] instead of sleeping past
    /// the budget.
    pub fn set_deadline(&mut self, deadline: Option<Duration>) {
        self.request_deadline = deadline;
    }

    /// The per-request deadline currently set, if any.
    pub fn deadline(&self) -> Option<Duration> {
        self.request_deadline
    }

    fn ensure_conn(&mut self) -> Result<&mut Conn> {
        if self.conn.is_none() {
            let stream = match self.timeout {
                Some(t) => TcpStream::connect_timeout(&self.addr, t)?,
                None => TcpStream::connect(self.addr)?,
            };
            let _ = stream.set_nodelay(true);
            stream.set_read_timeout(self.timeout)?;
            stream.set_write_timeout(self.timeout)?;
            let reader = BufReader::new(stream.try_clone()?);
            self.conn = Some(Conn { reader, writer: BufWriter::new(stream) });
            self.opened.clear();
        }
        Ok(self.conn.as_mut().expect("connection just ensured"))
    }

    /// Drop the connection (and its connection-scoped handles); the next
    /// call redials. Generation pins stay: they are caller intent, not
    /// connection state.
    fn reset(&mut self) {
        self.conn = None;
        self.opened.clear();
    }

    /// Set (or with `None` clear) the sticky generation pin for `key`:
    /// every later query against the key answers at that generation
    /// until the pin is cleared — across reconnects too.
    pub fn set_pin(&mut self, key: &StoreKey, pin: Option<u64>) {
        self.pins.retain(|(k, _)| !k.same_identity(key));
        if let Some(g) = pin {
            self.pins.push((key.clone(), g));
        }
    }

    /// The sticky generation pin currently set for `key`, if any.
    pub fn pin_for(&self, key: &StoreKey) -> Option<u64> {
        self.pins.iter().find(|(k, _)| k.same_identity(key)).map(|(_, g)| *g)
    }

    /// Hang up now. The client stays usable — any later call redials and
    /// re-opens handles lazily. This is what
    /// [`crate::api::SketchClient::close`] maps to.
    pub fn disconnect(&mut self) {
        self.reset();
    }

    fn fresh_id(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }

    /// Write one request frame at its operation's minimum version.
    fn send(&mut self, req: &Request) -> Result<u64> {
        self.send_at(req, wire::request_version(req))
    }

    /// Write one request frame at an explicit protocol version (floored
    /// at the operation's minimum by the encoder).
    fn send_at(&mut self, req: &Request, version: u16) -> Result<u64> {
        let id = self.fresh_id();
        let bytes = wire::encode_request_at(id, req, version);
        let conn = self.ensure_conn()?;
        wire::write_frame(&mut conn.writer, &bytes)?;
        Ok(id)
    }

    /// Read one response frame, enforcing the expected echoed id.
    fn recv(&mut self, expect_id: u64) -> Result<Response> {
        let conn = self
            .conn
            .as_mut()
            .ok_or_else(|| Error::Pipeline("recv without a connection".into()))?;
        let header = wire::read_frame_header(&mut conn.reader)?.ok_or_else(|| {
            Error::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            ))
        })?;
        let h = wire::parse_frame_header(&header).map_err(Error::from)?;
        let payload = wire::read_payload(&mut conn.reader, h.len)?;
        let resp = wire::decode_response(h.version, h.opcode, &payload).map_err(Error::from)?;
        if h.request_id != expect_id {
            // a refusal the server issued before reading any request
            // (busy, frame fault) carries id 0: surface the typed error,
            // not a bogus desync complaint
            if matches!(resp, Response::Error { .. }) {
                return Err(Self::remote_err(resp));
            }
            return Err(Error::Pipeline(format!(
                "response id {} does not match request id {expect_id} \
                 (pipelining desynchronised)",
                h.request_id
            )));
        }
        Ok(resp)
    }

    /// One request/response exchange, no retries.
    fn call(&mut self, req: &Request) -> Result<Response> {
        let id = self.send(req)?;
        self.recv(id)
    }

    /// Run `op` under the retry policy. One iteration of the loop is the
    /// atomic unit: `op` itself redials, re-opens handles, and re-applies
    /// pins (via [`ensure_conn`](Self::ensure_conn) /
    /// [`handle_for`](Self::handle_for)) before sending, so a retry never
    /// observes half-rebuilt connection state. Connection-level errors
    /// (`Io`) and corrupted frames (`Parse`) reset the connection and
    /// retry; [`Error::Overloaded`] backs off without redialling,
    /// honouring the server's retry-after hint; anything else surfaces
    /// immediately. The per-request deadline bounds the whole loop.
    fn with_retry<T>(&mut self, mut op: impl FnMut(&mut Self) -> Result<T>) -> Result<T> {
        let start = Instant::now();
        let mut attempt: u32 = 0;
        loop {
            let err = match op(self) {
                Ok(v) => {
                    self.refund_budget();
                    return Ok(v);
                }
                Err(e) => e,
            };
            attempt += 1;
            let (retryable, reset, hint_us) = match &err {
                Error::Io(_) | Error::Parse(_) => (true, true, 0),
                Error::Overloaded { retry_after_us, .. } => (true, false, *retry_after_us),
                _ => (false, false, 0),
            };
            if !retryable || attempt >= self.retry.max_attempts.max(1) {
                return Err(err);
            }
            let delay = self.retry.delay_for(attempt - 1, hint_us, &mut self.jitter);
            if let Some(budget) = self.request_deadline {
                if start.elapsed().saturating_add(delay) >= budget {
                    obs::global().inc(Counter::ClientDeadline);
                    return Err(Error::Deadline(format!(
                        "request budget {budget:?} leaves no room for retry {attempt} \
                         (backoff {delay:?}); last error: {err}"
                    )));
                }
            }
            if !self.spend_budget() {
                return Err(err);
            }
            obs::global().inc(Counter::ClientRetry);
            if reset {
                self.reset();
            }
            if !delay.is_zero() {
                thread::sleep(delay);
            }
        }
    }

    /// Spend one retry token (ten tenths); `false` means the budget is
    /// drained and the caller should surface the error instead.
    fn spend_budget(&mut self) -> bool {
        if self.budget_tenths >= 10 {
            self.budget_tenths -= 10;
            true
        } else {
            false
        }
    }

    /// Refund a tenth of a token on success, up to the policy cap.
    fn refund_budget(&mut self) {
        let cap = self.retry.budget.saturating_mul(10);
        self.budget_tenths = (self.budget_tenths + 1).min(cap);
    }

    /// Turn a remote error response into a local [`Error`]. Generation
    /// faults keep their typed variant so callers can tell a retired /
    /// future pin from an ordinary query failure, same as in-process;
    /// overload pushback (and the legacy `busy` refusal) becomes
    /// [`Error::Overloaded`] carrying the server's retry-after hint so
    /// the retry loop can honour it.
    fn remote_err(resp: Response) -> Error {
        match resp {
            Response::Error { code: ErrCode::Generation, message, .. } => {
                Error::Generation(format!("remote: {message}"))
            }
            Response::Error {
                code: code @ (ErrCode::Overloaded | ErrCode::Busy),
                message,
                retry_after_us,
            } => Error::Overloaded {
                message: format!("remote: {message} ({})", code.name()),
                retry_after_us,
            },
            Response::Error { code, message, .. } => {
                Error::Pipeline(format!("remote: {message} ({})", code.name()))
            }
            other => Error::Pipeline(format!("remote: unexpected response {other:?}")),
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        self.with_retry(|c| match c.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(Self::remote_err(other)),
        })
    }

    /// Enumerate the sketches the server's store holds.
    pub fn list_sketches(&mut self) -> Result<Vec<SketchInfo>> {
        self.with_retry(|c| match c.call(&Request::ListSketches)? {
            Response::SketchList(infos) => Ok(infos),
            other => Err(Self::remote_err(other)),
        })
    }

    /// Ask the server to shut down gracefully (the wire sentinel).
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.with_retry(|c| match c.call(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            other => Err(Self::remote_err(other)),
        })
    }

    /// Scrape the server's telemetry registry (protocol v4): one
    /// name-keyed snapshot of every counter, gauge, and latency
    /// histogram. Old servers answer with an unknown-opcode fault, which
    /// surfaces as a typed error here.
    pub fn stats(&mut self) -> Result<crate::obs::MetricsSnapshot> {
        self.with_retry(|c| match c.call(&Request::Stats)? {
            Response::Stats(snap) => Ok(snap),
            other => Err(Self::remote_err(other)),
        })
    }

    /// Fetch completed traces from the server's retention rings
    /// (protocol v5): the tree(s) recorded under exact trace `id`, or —
    /// with `id == 0` — the `slowest` N by root duration (slow-query log
    /// first). Old servers answer with an unknown-opcode fault, which
    /// surfaces as a typed error here.
    pub fn trace_dump(&mut self, id: u64, slowest: u32) -> Result<Vec<TraceRecord>> {
        self.with_retry(|c| match c.call(&Request::TraceDump { id, slowest })? {
            Response::Traces(traces) => Ok(traces),
            other => Err(Self::remote_err(other)),
        })
    }

    /// Open `key` on the server (idempotent per connection) and return
    /// its identity + shape. Retries under the policy; the re-open runs
    /// on whatever connection the retry iteration establishes.
    pub fn open(&mut self, key: &StoreKey) -> Result<SketchInfo> {
        self.with_retry(|c| c.open_once(key))
    }

    /// One open exchange, no retries — the building block both
    /// [`open`](Self::open) and [`handle_for`](Self::handle_for) run
    /// inside a single retry iteration, so reconnect, re-open, and
    /// pinned-query send can never interleave with another redial.
    fn open_once(&mut self, key: &StoreKey) -> Result<SketchInfo> {
        // make sure the connection is up *before* consulting the handle
        // cache: a dead connection invalidates it on redial
        self.ensure_conn()?;
        match self.call(&Request::OpenSketch(key.clone()))? {
            Response::SketchOpened { handle, info } => {
                if !self.opened.iter().any(|(k, _)| k.same_identity(key)) {
                    self.opened.push((key.clone(), handle));
                }
                Ok(info)
            }
            other => Err(Self::remote_err(other)),
        }
    }

    /// The current connection's handle for `key`, opening it if needed.
    /// Deliberately retry-free: callers invoke it inside a
    /// [`with_retry`](Self::with_retry) iteration.
    fn handle_for(&mut self, key: &StoreKey) -> Result<u32> {
        self.ensure_conn()?;
        if let Some((_, h)) = self.opened.iter().find(|(k, _)| k.same_identity(key)) {
            return Ok(*h);
        }
        self.open_once(key)?;
        self.opened
            .iter()
            .find(|(k, _)| k.same_identity(key))
            .map(|(_, h)| *h)
            .ok_or_else(|| Error::Pipeline("open succeeded but recorded no handle".into()))
    }

    /// Execute one query against the sketch stored under `key`, at the
    /// key's sticky pin if one is set (else the server's latest
    /// generation). Without a pin the frame goes out at its operation's
    /// minimum protocol version, so an upgraded client keeps talking to
    /// old servers. Retries under the policy.
    pub fn query(&mut self, key: &StoreKey, query: &QueryRequest) -> Result<QueryResponse> {
        if self.pin_for(key).is_some() {
            return self.query_at(key, query, None).map(|(resp, _)| resp);
        }
        self.with_retry(|c| c.query_once(key, query, 0, false)).map(|(resp, _)| resp)
    }

    /// Execute one query with an explicit generation pin (`None` falls
    /// back to the key's sticky pin, then to latest), returning the
    /// answer plus the generation it was answered at. The frame always
    /// goes out at v3 — even unpinned — so the answered-at tag survives
    /// the wire. Survives redials: each retry iteration re-opens the
    /// handle and re-sends with the same pin, so a reconnect never
    /// silently moves a pinned reader to latest.
    pub fn query_at(
        &mut self,
        key: &StoreKey,
        query: &QueryRequest,
        pin: Option<u64>,
    ) -> Result<(QueryResponse, u64)> {
        let pin = pin.or_else(|| self.pin_for(key)).unwrap_or(0);
        self.with_retry(|c| c.query_once(key, query, pin, true))
    }

    fn query_once(
        &mut self,
        key: &StoreKey,
        query: &QueryRequest,
        pin: u64,
        generation_aware: bool,
    ) -> Result<(QueryResponse, u64)> {
        let handle = self.handle_for(key)?;
        // sampled requests carry their trace id on the wire (forcing a
        // v5 frame) and log the round trip as a client-side span tree
        let trace_id = trace::sample();
        let active = match trace_id {
            0 => None,
            id => Some(trace::ActiveTrace::begin(id)),
        };
        let req = Request::Query { handle, pin, trace: trace_id, query: query.clone() };
        let out = {
            let resp = if generation_aware {
                let id = self.send_at(&req, 3)?;
                self.recv(id)?
            } else {
                self.call(&req)?
            };
            match resp {
                Response::Answer { generation, answer } => Ok((answer, generation)),
                other => Err(Self::remote_err(other)),
            }
        };
        if let Some(active) = active {
            active.record_with(
                0,
                "client_send",
                active.origin(),
                Instant::now(),
                vec![("addr".into(), self.addr.to_string())],
            );
            trace::finish(&active);
        }
        out
    }

    /// Latest published generation of the sketch under `key` (0 for
    /// frozen sketches). With `min_gen > 0` the server parks the request
    /// up to `timeout_ms` waiting for the chain to reach it, returning
    /// whatever generation is current when it answers.
    pub fn poll_generation(
        &mut self,
        key: &StoreKey,
        min_gen: u64,
        timeout_ms: u32,
    ) -> Result<u64> {
        self.with_retry(|c| {
            let handle = c.handle_for(key)?;
            match c.call(&Request::GenPoll { handle, min_gen, timeout_ms })? {
                Response::Generation(g) => Ok(g),
                other => Err(Self::remote_err(other)),
            }
        })
    }

    /// Pipeline a batch: requests are written ahead of the responses
    /// being read, so the whole batch costs ~one round trip instead of
    /// `queries.len()`. In-flight requests are capped at
    /// `PIPELINE_WINDOW` (8) — the client drains a response before sending
    /// past the window, so outstanding data stays bounded and a batch of
    /// large answers cannot mutually wedge both ends on full socket
    /// buffers. Per-query failures come back as `Err` entries without
    /// aborting the batch. Only the handle acquisition retries: once
    /// frames are in flight, a mid-batch redial could silently re-answer
    /// at a different generation, so batch transport errors surface to
    /// the caller instead.
    pub fn pipeline(
        &mut self,
        key: &StoreKey,
        queries: Vec<QueryRequest>,
    ) -> Result<Vec<Result<QueryResponse>>> {
        // the whole batch answers at one pin (the key's sticky pin, or
        // latest) — matching the local batched path, where a batch sees a
        // single snapshot
        let pin = self.pin_for(key).unwrap_or(0);
        let handle = self.with_retry(|c| c.handle_for(key))?;
        let mut ids = VecDeque::with_capacity(PIPELINE_WINDOW);
        let mut out = Vec::with_capacity(queries.len());
        let collect = |resp: Response| match resp {
            Response::Answer { answer, .. } => Ok(answer),
            other => Err(Self::remote_err(other)),
        };
        for q in queries {
            if ids.len() >= PIPELINE_WINDOW {
                let id = ids.pop_front().expect("window non-empty");
                let resp = self.recv(id)?;
                out.push(collect(resp));
            }
            // per-request sampling: a sampled entry gets its server-side
            // span tree; the batch itself adds no client-side spans
            let req = Request::Query { handle, pin, trace: trace::sample(), query: q };
            ids.push_back(self.send(&req)?);
        }
        for id in ids {
            let resp = self.recv(id)?;
            out.push(collect(resp));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_deterministic_and_bounded() {
        let policy = RetryPolicy::default();
        let mut a = Rng::new(policy.jitter_seed);
        let mut b = Rng::new(policy.jitter_seed);
        let da: Vec<Duration> = (0..8).map(|i| policy.delay_for(i, 0, &mut a)).collect();
        let db: Vec<Duration> = (0..8).map(|i| policy.delay_for(i, 0, &mut b)).collect();
        assert_eq!(da, db, "same seed must replay the same delay schedule");
        for (i, d) in da.iter().enumerate() {
            assert!(*d <= policy.max_backoff, "retry {i} overshoots the cap: {d:?}");
        }
        // full jitter keeps at least half the exponential delay
        assert!(da[0] >= policy.base_backoff / 2, "first delay too small: {:?}", da[0]);
    }

    #[test]
    fn server_hint_floors_the_delay() {
        let policy = RetryPolicy::default();
        let mut rng = Rng::new(1);
        let d = policy.delay_for(0, 2_000_000, &mut rng);
        assert!(d >= Duration::from_secs(2), "hint ignored: {d:?}");
    }

    #[test]
    fn zero_backoff_policy_sleeps_only_on_hint() {
        let policy = RetryPolicy {
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
            ..RetryPolicy::default()
        };
        let mut rng = Rng::new(2);
        assert_eq!(policy.delay_for(3, 0, &mut rng), Duration::ZERO);
        assert_eq!(policy.delay_for(3, 750, &mut rng), Duration::from_micros(750));
    }
}
