//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments; each binary declares its options and gets `--help` text for
//! free.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw args (excluding argv[0]). `flag_names` lists boolean
    /// options that take no value.
    pub fn parse(raw: impl IntoIterator<Item = String>, flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| Error::invalid(format!("--{body} expects a value")))?;
                    out.opts.insert(body.to_string(), v);
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env(flag_names: &[&str]) -> Result<Args> {
        Args::parse(std::env::args().skip(1), flag_names)
    }

    /// Option lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// Option with default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Typed option lookup.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| Error::invalid(format!("--{key}: cannot parse {v:?}"))),
        }
    }

    /// Typed option with default.
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        Ok(self.get_parse(key)?.unwrap_or(default))
    }

    /// Boolean flag presence.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(sv(&["cmd", "--s", "1000", "--out=reports", "--verbose", "extra"]),
                            &["verbose"]).unwrap();
        assert_eq!(a.positional(), &["cmd".to_string(), "extra".to_string()]);
        assert_eq!(a.get("s"), Some("1000"));
        assert_eq!(a.get("out"), Some("reports"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(sv(&["--s", "123", "--eps", "0.5"]), &[]).unwrap();
        assert_eq!(a.get_parse_or::<usize>("s", 0).unwrap(), 123);
        assert_eq!(a.get_parse_or::<f64>("eps", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_parse_or::<usize>("missing", 7).unwrap(), 7);
        assert!(a.get_parse::<usize>("eps").is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(sv(&["--s"]), &[]).is_err());
    }
}
