//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! AOT `manifest.json`, bench result rows, and experiment reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (kept as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::Parse(format!("trailing bytes at {}", p.i)));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array elements (empty slice for non-arrays).
    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            _ => &[],
        }
    }

    /// Number accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer accessor (rejects non-integral numbers).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: number.
pub fn num(x: f64) -> Json {
    Json::Num(x)
}

/// Convenience: string.
pub fn s(x: impl Into<String>) -> Json {
    Json::Str(x.into())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Parse(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Parse(format!("unexpected {other:?} at byte {}", self.i))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Parse(format!("bad literal at byte {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Parse(format!("bad number {text:?}: {e}")))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Parse("unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| Error::Parse("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::Parse("bad \\u escape".into()))?,
                                16,
                            )
                            .map_err(|_| Error::Parse("bad \\u escape".into()))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => {
                            return Err(Error::Parse(format!("bad escape {other:?}")));
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: find the char boundary.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| Error::Parse("invalid utf-8".into()))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(Error::Parse(format!("bad array sep {other:?}"))),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(Error::Parse(format!("bad object sep {other:?}"))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"version": 1, "entries": [{"op": "gram", "rows": 2048,
            "inputs": [{"dims": [2048, 32], "dtype": "float32"}]}],
            "tuple_output": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let e = &v.get("entries").unwrap().items()[0];
        assert_eq!(e.get("op").unwrap().as_str(), Some("gram"));
        assert_eq!(
            e.get("inputs").unwrap().items()[0].get("dims").unwrap().items()[1].as_usize(),
            Some(32)
        );
        // serialize + reparse is stable
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn escapes_and_numbers() {
        let v = Json::parse(r#"{"s": "a\"b\\c\nd", "x": -1.5e3, "b": false, "z": null}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a\"b\\c\nd"));
        assert_eq!(v.get("x").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("z"), Some(&Json::Null));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] trailing").is_err());
    }
}
