//! Small self-contained utilities.
//!
//! The image's offline crate registry only carries the `xla` dependency
//! tree, so the usual ecosystem crates (`rand`, `serde`, `clap`, `log`
//! facade impls) are replaced by the minimal implementations here — see
//! DESIGN.md §4.

pub mod args;
pub mod bytes;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;

pub use bytes::SharedBytes;

/// Round `x` up to the next multiple of `to` (`to > 0`).
pub fn round_up(x: usize, to: usize) -> usize {
    debug_assert!(to > 0);
    x.div_ceil(to) * to
}

/// Human-readable byte count.
pub fn human_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Logarithmically spaced integer grid in `[lo, hi]` with `count` points,
/// deduplicated and sorted — used for the Figure-1 sample-budget sweeps.
pub fn log_space(lo: usize, hi: usize, count: usize) -> Vec<usize> {
    assert!(lo >= 1 && hi >= lo && count >= 1);
    let (llo, lhi) = ((lo as f64).ln(), (hi as f64).ln());
    let mut out: Vec<usize> = (0..count)
        .map(|i| {
            let t = if count == 1 { 0.0 } else { i as f64 / (count - 1) as f64 };
            (llo + t * (lhi - llo)).exp().round() as usize
        })
        .collect();
    out.sort_unstable();
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_works() {
        assert_eq!(round_up(0, 256), 0);
        assert_eq!(round_up(1, 256), 256);
        assert_eq!(round_up(256, 256), 256);
        assert_eq!(round_up(257, 256), 512);
    }

    #[test]
    fn log_space_endpoints_and_monotone() {
        let g = log_space(10, 100_000, 12);
        assert_eq!(*g.first().unwrap(), 10);
        assert_eq!(*g.last().unwrap(), 100_000);
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn human_bytes_scales() {
        assert_eq!(human_bytes(17), "17 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
