//! Shared immutable byte buffers for zero-copy payload handling.
//!
//! [`SharedBytes`] is a cheaply clonable (`Arc`-backed) view over an
//! immutable byte buffer — a `Vec<u8>`, or, with the `mmap` cargo
//! feature, a memory-mapped file. Slicing is O(1) and shares the owner,
//! so a multi-megabyte `.msk` payload can be handed to every
//! `ServableSketch` clone and worker thread without ever being copied.

use std::fmt;
use std::ops::{Deref, Range};
use std::sync::Arc;

/// An immutable, reference-counted byte buffer view. Cloning and
/// [`SharedBytes::slice`] are O(1): both share the underlying owner
/// (a `Vec<u8>`, a memory map, …) instead of copying bytes.
#[derive(Clone)]
pub struct SharedBytes {
    owner: Arc<dyn AsRef<[u8]> + Send + Sync>,
    off: usize,
    len: usize,
}

impl SharedBytes {
    /// Wrap any owned byte container (`Vec<u8>`, `Box<[u8]>`, a memory
    /// map, …) without copying it.
    pub fn from_owner<T: AsRef<[u8]> + Send + Sync + 'static>(owner: T) -> SharedBytes {
        let len = owner.as_ref().len();
        SharedBytes { owner: Arc::new(owner), off: 0, len }
    }

    /// O(1) subview sharing the same owner. Panics when `range` is out
    /// of bounds, exactly like slice indexing.
    pub fn slice(&self, range: Range<usize>) -> SharedBytes {
        assert!(
            range.start <= range.end && range.end <= self.len,
            "SharedBytes::slice {range:?} out of bounds (len {})",
            self.len
        );
        SharedBytes {
            owner: Arc::clone(&self.owner),
            off: self.off + range.start,
            len: range.end - range.start,
        }
    }

    /// Byte length of this view.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether this view is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Default for SharedBytes {
    fn default() -> Self {
        SharedBytes::from_owner(Vec::<u8>::new())
    }
}

impl Deref for SharedBytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &(*self.owner).as_ref()[self.off..self.off + self.len]
    }
}

impl AsRef<[u8]> for SharedBytes {
    fn as_ref(&self) -> &[u8] {
        self
    }
}

impl From<Vec<u8>> for SharedBytes {
    fn from(v: Vec<u8>) -> SharedBytes {
        SharedBytes::from_owner(v)
    }
}

impl From<&[u8]> for SharedBytes {
    fn from(v: &[u8]) -> SharedBytes {
        SharedBytes::from_owner(v.to_vec())
    }
}

impl PartialEq for SharedBytes {
    fn eq(&self, other: &SharedBytes) -> bool {
        self[..] == other[..]
    }
}

impl Eq for SharedBytes {}

// Debug cannot be derived: the owner is a `dyn` trait object.
impl fmt::Debug for SharedBytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "SharedBytes({} B)", self.len)
    }
}

/// Memory-mapped read-only file support (the `mmap` cargo feature).
///
/// Declared against the platform libc directly — the build image has no
/// crates.io access, and every Unix target this crate builds on links
/// libc anyway. Gated to 64-bit Unix targets, where `off_t` is 64 bits
/// and the `offset: i64` declaration below matches the C ABI; elsewhere
/// (or without the feature) the store falls back to a buffered read
/// into one shared allocation.
#[cfg(all(feature = "mmap", target_family = "unix", target_pointer_width = "64"))]
pub mod mmap {
    use std::ffi::c_void;
    use std::fs::File;
    use std::io;
    use std::os::unix::io::AsRawFd;
    use std::ptr::NonNull;

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> i32;
    }

    /// A read-only, privately mapped file; unmapped on drop. Implements
    /// `AsRef<[u8]>`, so it plugs straight into
    /// [`SharedBytes::from_owner`](super::SharedBytes::from_owner).
    pub struct MappedFile {
        ptr: NonNull<u8>,
        len: usize,
    }

    // SAFETY: the mapping is read-only (PROT_READ) and never remapped
    // after construction, so concurrent access from any thread only ever
    // observes the same immutable bytes; the raw pointer is exclusively
    // owned and unmapped once, on drop.
    unsafe impl Send for MappedFile {}
    unsafe impl Sync for MappedFile {}

    impl AsRef<[u8]> for MappedFile {
        fn as_ref(&self) -> &[u8] {
            // SAFETY: ptr/len describe one live PROT_READ mapping owned
            // by self; the mapping outlives every borrow of self.
            unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
        }
    }

    impl Drop for MappedFile {
        fn drop(&mut self) {
            // SAFETY: exactly the region mmap returned.
            unsafe {
                munmap(self.ptr.as_ptr() as *mut c_void, self.len);
            }
        }
    }

    /// Map `file` read-only in its entirety. Errors on empty files (a
    /// zero-length mmap is invalid) and on any mapping failure — callers
    /// fall back to a buffered read.
    pub fn map_readonly(file: &File) -> io::Result<MappedFile> {
        let len = file.metadata()?.len();
        if len == 0 || len > usize::MAX as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "mmap: empty or oversized file",
            ));
        }
        let len = len as usize;
        // SAFETY: plain PROT_READ/MAP_PRIVATE mapping of a file we hold
        // open; the result is validated before use.
        let ptr = unsafe {
            mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
        };
        if ptr as isize == -1 || ptr.is_null() {
            return Err(io::Error::last_os_error());
        }
        Ok(MappedFile { ptr: NonNull::new(ptr as *mut u8).expect("mmap non-null"), len })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_and_clone_share_without_copying() {
        let b = SharedBytes::from(vec![1u8, 2, 3, 4, 5]);
        let s = b.slice(1..4);
        assert_eq!(&s[..], &[2, 3, 4]);
        assert_eq!(s.len(), 3);
        let s2 = s.slice(1..3);
        assert_eq!(&s2[..], &[3, 4]);
        let c = s2.clone();
        assert_eq!(c, s2);
        assert_eq!(&b[..], &[1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_and_equality() {
        let e = SharedBytes::default();
        assert!(e.is_empty());
        assert_eq!(e, SharedBytes::from(Vec::new()));
        let a = SharedBytes::from(vec![7u8, 8]);
        let b = SharedBytes::from(vec![7u8, 8]);
        let c = SharedBytes::from(vec![7u8, 9]);
        assert_eq!(a, b);
        assert_ne!(a, c);
        // equality is by content, not by owner identity or offset
        let whole = SharedBytes::from(vec![0u8, 7, 8]);
        assert_eq!(whole.slice(1..3), a);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn out_of_bounds_slice_panics() {
        SharedBytes::from(vec![1u8, 2]).slice(0..3);
    }

    #[cfg(all(feature = "mmap", target_family = "unix", target_pointer_width = "64"))]
    #[test]
    fn mmap_reads_file_contents() {
        let path = std::env::temp_dir()
            .join(format!("matsketch_mmap_test_{}", std::process::id()));
        std::fs::write(&path, b"hello mapping").unwrap();
        let f = std::fs::File::open(&path).unwrap();
        let map = mmap::map_readonly(&f).unwrap();
        let shared = SharedBytes::from_owner(map);
        assert_eq!(&shared[..], b"hello mapping");
        assert_eq!(shared.slice(6..13), SharedBytes::from(&b"mapping"[..]));
        let _ = std::fs::remove_file(&path);
    }
}
