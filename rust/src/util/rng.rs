//! Deterministic pseudo-random number generation.
//!
//! `rand` is not available in the offline registry, so this module provides
//! the generators the framework needs: a SplitMix64 seeder, a
//! xoshiro256++ core generator, unbiased bounded integers (Lemire), and the
//! standard continuous transforms (uniform, normal via Box–Muller,
//! exponential). All experiment code takes explicit seeds so every figure
//! and table is reproducible bit-for-bit.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_cache: Option<f64>,
}

impl Rng {
    /// Seeded construction (SplitMix64-expanded, never all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_cache: None }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` (53-bit resolution).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `(0, 1]` — safe as a `ln()` argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn u64_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.u64_below(bound as u64) as usize
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal variate (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_cache.take() {
            return z;
        }
        let u1 = self.f64_open();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_cache = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Exponential variate with rate 1.
    #[inline]
    pub fn exp(&mut self) -> f64 {
        -self.f64_open().ln()
    }

    /// Geometric number of failures before first success, `p ∈ (0, 1]`.
    /// Returns `u64::MAX` for p so small the draw overflows.
    #[inline]
    pub fn geometric(&mut self, p: f64) -> u64 {
        debug_assert!(p > 0.0 && p <= 1.0);
        if p >= 1.0 {
            return 0;
        }
        let g = (self.f64_open().ln() / (1.0 - p).ln()).floor();
        if g >= u64::MAX as f64 {
            u64::MAX
        } else {
            g as u64
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_with_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn bounded_integers_unbiased() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 7];
        let n = 140_000;
        for _ in 0..n {
            counts[r.u64_below(7) as usize] += 1;
        }
        let expect = n as f64 / 7.0;
        for c in counts {
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.01, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var={m2}");
    }

    #[test]
    fn geometric_mean_matches() {
        let mut r = Rng::new(5);
        let p = 0.05;
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.geometric(p) as f64;
        }
        let mean = sum / n as f64;
        let expect = (1.0 - p) / p;
        assert!((mean - expect).abs() / expect < 0.05, "mean={mean} expect={expect}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..1000).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(v, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
