//! Leveled stderr logger with wall-clock-relative timestamps.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Errors only.
    Error = 0,
    /// + warnings.
    Warn = 1,
    /// + progress info (default).
    Info = 2,
    /// + per-step detail.
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

/// Set global verbosity.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current verbosity.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        3 => Level::Debug,
        _ => Level::Info,
    }
}

/// Emit a message at `lvl` (used through the macros below).
pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if lvl <= level() {
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{t:9.3}s {tag}] {args}");
    }
}

/// Log at info level.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*))
    };
}

/// Log at warn level.
#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at debug level.
#[macro_export]
macro_rules! debug_log {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Info);
        assert_eq!(level(), Level::Info);
    }
}
