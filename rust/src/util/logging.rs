//! Leveled stderr logger with wall-clock-relative timestamps.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Errors only.
    Error = 0,
    /// + warnings.
    Warn = 1,
    /// + progress info (default).
    Info = 2,
    /// + per-step detail.
    Debug = 3,
}

impl Level {
    /// Parse a CLI / environment spelling (`error|warn|info|debug`,
    /// case-insensitive; `warning` and `warn` both accepted).
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    /// Canonical lowercase name (the `parse` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

/// Set global verbosity.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current verbosity. The decode is exhaustive over the values
/// [`set_level`] can store, so set/get round-trips for every level;
/// out-of-range bytes (impossible via the public API) fall back to the
/// `Info` default.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Info,
    }
}

/// Emit a message at `lvl` (used through the macros below).
pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if lvl <= level() {
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{t:9.3}s {tag}] {args}");
    }
}

/// Log at info level.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*))
    };
}

/// Log at warn level.
#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at debug level.
#[macro_export]
macro_rules! debug_log {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        // every level must survive a set/get round-trip; the decode
        // used to reach Info only through the wildcard arm, so nothing
        // pinned the stored discriminants to the decoded levels.
        // Restore the default afterwards — LEVEL is process-global and
        // other tests log.
        for lvl in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            set_level(lvl);
            assert_eq!(level(), lvl, "round-trip of {lvl:?}");
        }
        set_level(Level::Info);
        assert_eq!(level(), Level::Info);
    }

    #[test]
    fn parse_accepts_cli_spellings() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("Info"), Some(Level::Info));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("verbose"), None);
        for lvl in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::parse(lvl.name()), Some(lvl), "name round-trip");
        }
    }
}
