//! Online and batch summary statistics (Welford accumulation, quantiles)
//! used by the bench harness and the pipeline metrics.

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 for an empty accumulator).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n−1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Minimum observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Batch quantile (linear interpolation); `q ∈ [0, 1]`. Works on a copy;
/// see [`quantiles_in_place`] for the allocation-free form.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    quantiles(xs, &[q])[0]
}

/// Several quantiles of one sample (the latency-histogram path:
/// p50/p95/p99 over thousands of per-query timings). Each `q ∈ [0, 1]`,
/// linear interpolation. Works on a copy of `xs`; callers that own their
/// sample (and can tolerate it being permuted) should use
/// [`quantiles_in_place`], which allocates nothing.
pub fn quantiles(xs: &[f64], qs: &[f64]) -> Vec<f64> {
    let mut scratch = xs.to_vec();
    quantiles_in_place(&mut scratch, qs)
}

/// [`quantiles`] over a caller-owned buffer: selects only the needed
/// order statistics with `select_nth_unstable` (expected O(n) total,
/// no sort, no allocation beyond the tiny index list) and leaves `xs`
/// permuted. This is what the load-generator report path uses — the
/// latency buffer it already owns doubles as the scratch space.
pub fn quantiles_in_place(xs: &mut [f64], qs: &[f64]) -> Vec<f64> {
    assert!(!xs.is_empty());
    let n = xs.len();
    let pos_of = |q: f64| q.clamp(0.0, 1.0) * (n - 1) as f64;
    // the order statistics the interpolation reads: floor + ceil per q
    let mut idxs: Vec<usize> = Vec::with_capacity(qs.len() * 2);
    for &q in qs {
        let pos = pos_of(q);
        idxs.push(pos.floor() as usize);
        idxs.push(pos.ceil() as usize);
    }
    idxs.sort_unstable();
    idxs.dedup();
    // ascending multi-select: after selecting order statistic i, every
    // element left of i is ≤ xs[i], so the next (larger) selection can
    // run on the tail alone and each selected slot is final
    let cmp = |a: &f64, b: &f64| a.partial_cmp(b).unwrap();
    let mut start = 0usize;
    for &i in &idxs {
        xs[start..].select_nth_unstable_by(i - start, cmp);
        start = i + 1;
        if start >= n {
            break;
        }
    }
    qs.iter()
        .map(|&q| {
            let pos = pos_of(q);
            let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
            if lo == hi {
                xs[lo]
            } else {
                let t = pos - lo as f64;
                xs[lo] * (1.0 - t) + xs[hi] * t
            }
        })
        .collect()
}

/// Quantile of a bucketed histogram (`q ∈ [0, 1]`): `counts[i]`
/// observations fell in the half-open value range `edges[i] = (lo, hi)`.
/// Finds the bucket holding the `q`-th observation by cumulative count
/// and interpolates linearly inside it — the extraction path for the
/// telemetry histograms in [`crate::obs`], whose log₂ buckets bound the
/// relative error of any interior quantile by 2×. An all-empty histogram
/// has no observations to rank, so it is `None` — not an interpolated
/// edge value that would read as a real (and misleading) latency.
pub fn histogram_quantile(counts: &[u64], edges: &[(f64, f64)], q: f64) -> Option<f64> {
    assert_eq!(counts.len(), edges.len());
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return None;
    }
    // rank of the target observation, 1-based so q=0 lands on the first
    // observation and q=1 on the last
    let target = 1.0 + q.clamp(0.0, 1.0) * (total - 1) as f64;
    let mut cum = 0u64;
    for (&c, &(lo, hi)) in counts.iter().zip(edges.iter()) {
        if c == 0 {
            continue;
        }
        if (cum + c) as f64 >= target {
            let frac = (target - cum as f64) / c as f64; // ∈ (0, 1]
            return Some(lo + frac * (hi - lo));
        }
        cum += c;
    }
    // numerically unreachable; the last non-empty bucket's upper bound
    edges
        .iter()
        .zip(counts.iter())
        .filter(|(_, &c)| c > 0)
        .map(|(&(_, hi), _)| hi)
        .next_back()
}

/// Median absolute deviation — the bench harness's robust spread measure.
pub fn mad(xs: &[f64]) -> f64 {
    let med = quantile(xs, 0.5);
    let mut dev: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    quantiles_in_place(&mut dev, &[0.5])[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let batch_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.variance() - batch_var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn quantiles() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantiles_match_one_at_a_time() {
        let xs = [5.0, 3.0, 1.0, 2.0, 4.0, 9.0];
        let qs = [0.0, 0.5, 0.95, 0.99, 1.0];
        let batch = quantiles(&xs, &qs);
        for (q, got) in qs.iter().zip(&batch) {
            assert_eq!(*got, quantile(&xs, *q), "q={q}");
        }
    }

    #[test]
    fn selection_quantiles_match_full_sort_reference() {
        // the select_nth path must agree exactly with a sort-and-index
        // reference, on random data, duplicate-heavy data, and repeated
        // / unsorted q lists; the in-place form reuses one scratch buffer
        let mut rng = crate::util::rng::Rng::new(0x9A);
        let qs = [0.99, 0.0, 0.5, 0.5, 0.95, 1.0, 0.25];
        let mut scratch: Vec<f64> = Vec::new();
        for case in 0..20 {
            let n = 1 + (case * 37) % 500;
            let xs: Vec<f64> = (0..n)
                .map(|_| {
                    if case % 3 == 0 {
                        rng.u64_below(7) as f64 // heavy ties
                    } else {
                        rng.normal() * 100.0
                    }
                })
                .collect();
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let reference: Vec<f64> = qs
                .iter()
                .map(|&q| {
                    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
                    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
                    let t = pos - lo as f64;
                    sorted[lo] * (1.0 - t) + sorted[hi] * t
                })
                .collect();
            assert_eq!(quantiles(&xs, &qs), reference, "case {case}");
            // scratch-reusing in-place form: same answers, no per-call
            // allocation of the sample
            scratch.clear();
            scratch.extend_from_slice(&xs);
            assert_eq!(quantiles_in_place(&mut scratch, &qs), reference, "case {case}");
        }
    }

    #[test]
    fn histogram_quantile_interpolates_within_buckets() {
        // 10 obs in [1, 2), 85 in [2, 4), 5 in [4, 8)
        let counts = [10u64, 85, 5];
        let edges = [(1.0, 2.0), (2.0, 4.0), (4.0, 8.0)];
        let p50 = histogram_quantile(&counts, &edges, 0.5).unwrap();
        assert!((2.0..4.0).contains(&p50), "p50 = {p50}");
        let p99 = histogram_quantile(&counts, &edges, 0.99).unwrap();
        assert!((4.0..=8.0).contains(&p99), "p99 = {p99}");
        // q=0 is the first observation, q=1 the last
        assert!(histogram_quantile(&counts, &edges, 0.0).unwrap() >= 1.0);
        assert!(histogram_quantile(&counts, &edges, 1.0).unwrap() <= 8.0);
        // monotone in q
        let qs = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0];
        let vals: Vec<f64> =
            qs.iter().map(|&q| histogram_quantile(&counts, &edges, q).unwrap()).collect();
        assert!(vals.windows(2).all(|w| w[0] <= w[1]), "{vals:?}");
    }

    #[test]
    fn histogram_quantile_empty_is_none() {
        // no observations: every quantile is None, never an edge value
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(histogram_quantile(&[0, 0, 0], &[(0.0, 1.0), (1.0, 2.0), (2.0, 4.0)], q), None);
            assert_eq!(histogram_quantile(&[], &[], q), None);
        }
    }

    #[test]
    fn histogram_quantile_single_bucket_stays_inside_it() {
        // all mass in one interior bucket: every quantile interpolates
        // within its bounds and the extremes touch them
        let counts = [0u64, 7, 0];
        let edges = [(0.0, 1.0), (1.0, 2.0), (2.0, 4.0)];
        for q in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let v = histogram_quantile(&counts, &edges, q).unwrap();
            assert!((1.0..=2.0).contains(&v), "q={q} v={v}");
        }
        assert!(histogram_quantile(&counts, &edges, 0.0).unwrap() > 1.0);
        assert_eq!(histogram_quantile(&counts, &edges, 1.0), Some(2.0));
    }

    #[test]
    fn histogram_quantile_saturated_top_bucket() {
        // everything lands in the open-ended last bucket (the obs
        // registry's overflow bucket): quantiles stay within its bounds
        let counts = [0u64, 0, 12];
        let edges = [(0.0, 1.0), (1.0, 2.0), (2.0, 4.0)];
        for q in [0.0, 0.5, 1.0] {
            let v = histogram_quantile(&counts, &edges, q).unwrap();
            assert!((2.0..=4.0).contains(&v), "q={q} v={v}");
        }
        assert_eq!(histogram_quantile(&counts, &edges, 1.0), Some(4.0));
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
    }
}
