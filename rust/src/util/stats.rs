//! Online and batch summary statistics (Welford accumulation, quantiles)
//! used by the bench harness and the pipeline metrics.

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 for an empty accumulator).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n−1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Minimum observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Batch quantile (linear interpolation); `q ∈ [0, 1]`. Sorts a copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
    if lo == hi {
        v[lo]
    } else {
        let t = pos - lo as f64;
        v[lo] * (1.0 - t) + v[hi] * t
    }
}

/// Several quantiles of one sample with a single sort (the latency
/// histogram path: p50/p95/p99 over thousands of per-query timings).
/// Each `q ∈ [0, 1]`, linear interpolation, matching [`quantile`].
pub fn quantiles(xs: &[f64], qs: &[f64]) -> Vec<f64> {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    qs.iter()
        .map(|q| {
            let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
            let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
            if lo == hi {
                v[lo]
            } else {
                let t = pos - lo as f64;
                v[lo] * (1.0 - t) + v[hi] * t
            }
        })
        .collect()
}

/// Median absolute deviation — the bench harness's robust spread measure.
pub fn mad(xs: &[f64]) -> f64 {
    let med = quantile(xs, 0.5);
    let dev: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    quantile(&dev, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let batch_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.variance() - batch_var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn quantiles() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantiles_match_one_at_a_time() {
        let xs = [5.0, 3.0, 1.0, 2.0, 4.0, 9.0];
        let qs = [0.0, 0.5, 0.95, 0.99, 1.0];
        let batch = quantiles(&xs, &qs);
        for (q, got) in qs.iter().zip(&batch) {
            assert_eq!(*got, quantile(&xs, *q), "q={q}");
        }
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
    }
}
