//! Live sketches: an RCU-style generation chain serving queries while the
//! entry stream is still arriving.
//!
//! The paper's headline property is O(1)-per-nonzero sketching of streams
//! presented in arbitrary order — yet everything the serving stack answers
//! was frozen at build time. This module closes that gap:
//!
//! * **Foreground reads** execute against an immutable
//!   [`Arc<ServableSketch>`] snapshot. Publication of a new generation is
//!   a single pointer swap under a briefly-held lock (the payload is
//!   [`crate::util::SharedBytes`], so snapshots clone in O(1)); readers
//!   never block on ingest, and a query — including every window of a
//!   row-parallel split — runs entirely on the snapshot it started on
//!   ([`QueryServer::submit_on`]).
//! * **Background ingest** appends entries through [`LiveSketch::push`].
//!   On an epoch tick (every [`LiveConfig::epoch_entries`] entries, or an
//!   explicit [`LiveSketch::flush`]) the writer publishes generation
//!   `g+1`: it rebuilds the sketch of the *entire prefix* received so far
//!   through the deterministic offline engine
//!   ([`crate::engine::build_sketcher`] with [`SketchMode::Offline`] and
//!   the chain's plan seed). Because the build is a pure function of
//!   `(prefix, plan)`, **a generation served live is bit-identical to the
//!   offline sketch built from the same entry prefix with the same
//!   seed** — the acceptance bar the integration suite pins for every
//!   Figure-1 distribution, locally and over the wire. (A statistical
//!   delta-fold through [`crate::engine::fold`] would be exchangeable but
//!   not bit-identical: the alias draw depends on the prefix stats and the
//!   plan-seed RNG stream, so exactness here means exact recomputation,
//!   kept off the read path.)
//! * **Generations are retained** in a bounded ring
//!   ([`LiveConfig::retain`]) so pinned reads ("query at generation g")
//!   have a validity window. A pin ahead of the chain or behind the ring
//!   is a typed [`Error::Generation`] — remote servers map it onto the
//!   wire's `generation` fault without dropping the connection.
//!
//! Generation 0 is an empty placeholder snapshot (all queries answer
//! zeros / empty slices); real generations start at 1 with the first
//! publish. [`LiveReader`] is the cheap cloneable read handle the API
//! backends ([`crate::api::LocalClient`]) and the network front
//! ([`crate::net::NetServer`]) attach; [`LiveSketch`] is the single
//! writer. Freshness bookkeeping (publish lag per epoch) feeds the
//! `eval::serving` live tables.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::api::{QueryRequest, QueryResponse, SketchInfo};
use crate::distributions::MatrixStats;
use crate::engine::{build_sketcher, PipelineConfig, SketchMode, Sketcher};
use crate::error::{Error, Result};
use crate::obs::trace::{self, SpanCtx};
use crate::obs::{self, Counter, Gauge, Hist};
use crate::sketch::{Sketch, SketchPlan};
use crate::sparse::Entry;

use super::server::{QueryServer, ServableSketch};

/// Tuning knobs of a live generation chain.
#[derive(Clone, Debug)]
pub struct LiveConfig {
    /// Publish a new generation once this many entries arrived since the
    /// last publish. 0 disables the automatic tick — only
    /// [`LiveSketch::flush`] publishes.
    pub epoch_entries: usize,
    /// How many recent generations stay pinnable (≥ 1). Older snapshots
    /// retire; pinned queries against them get a typed
    /// [`Error::Generation`].
    pub retain: usize,
    /// Worker threads of the chain's query pool.
    pub workers: usize,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig { epoch_entries: 4096, retain: 4, workers: 2 }
    }
}

/// The retained tail of the generation chain plus freshness bookkeeping.
struct Chain {
    /// Recent snapshots, ascending generation (back = latest).
    snapshots: VecDeque<Arc<ServableSketch>>,
    /// Publish lag of each published epoch, in seconds: publish instant
    /// minus the first push of the epoch.
    lags: Vec<f64>,
}

/// State shared between the writer and every reader.
struct LiveShared {
    plan: SketchPlan,
    m: usize,
    n: usize,
    retain: usize,
    epoch_entries: usize,
    chain: Mutex<Chain>,
    /// Latest published generation (0 = the empty placeholder).
    generation: AtomicU64,
    /// Notified under `chain` on every publish.
    advance: Condvar,
    /// The pool every retained generation answers on.
    server: QueryServer,
}

fn chain_lock(shared: &LiveShared) -> Result<std::sync::MutexGuard<'_, Chain>> {
    shared
        .chain
        .lock()
        .map_err(|_| Error::Pipeline("live chain lock poisoned".into()))
}

/// The single-writer ingest handle of a live chain. Create with
/// [`LiveSketch::start`], hand [`LiveReader`]s (from
/// [`LiveSketch::reader`]) to every query path, and drive the stream
/// through [`push`](LiveSketch::push) / [`flush`](LiveSketch::flush) from
/// the ingest thread.
pub struct LiveSketch {
    inner: Arc<LiveShared>,
    /// The full prefix in stream order — each publish rebuilds from it.
    prefix: Vec<Entry>,
    /// Entries since the last publish.
    pending: usize,
    /// First push instant of the pending epoch (freshness lag origin).
    epoch_t0: Option<Instant>,
}

impl LiveSketch {
    /// Start a live chain for an `m × n` stream sketched under `plan`.
    /// Generation 0 (an empty snapshot) is served immediately.
    pub fn start(m: usize, n: usize, plan: &SketchPlan, cfg: &LiveConfig) -> Result<LiveSketch> {
        if plan.s == 0 {
            return Err(Error::invalid("sample budget must be positive"));
        }
        let empty = Sketch {
            m,
            n,
            s: plan.s,
            entries: Vec::new(),
            row_scale: None,
            method: plan.kind.name(),
        };
        let gen0 = Arc::new(ServableSketch::from_sketch(&empty)?);
        let server = QueryServer::start(Arc::clone(&gen0), cfg.workers);
        let mut snapshots = VecDeque::with_capacity(cfg.retain.max(1) + 1);
        snapshots.push_back(gen0);
        let inner = Arc::new(LiveShared {
            plan: plan.clone(),
            m,
            n,
            retain: cfg.retain.max(1),
            epoch_entries: cfg.epoch_entries,
            chain: Mutex::new(Chain { snapshots, lags: Vec::new() }),
            generation: AtomicU64::new(0),
            advance: Condvar::new(),
            server,
        });
        Ok(LiveSketch { inner, prefix: Vec::new(), pending: 0, epoch_t0: None })
    }

    /// A cheap cloneable read handle onto the chain.
    pub fn reader(&self) -> LiveReader {
        LiveReader { inner: Arc::clone(&self.inner) }
    }

    /// `(m, n)` of the sketched stream.
    pub fn shape(&self) -> (usize, usize) {
        (self.inner.m, self.inner.n)
    }

    /// Entries ingested so far (the prefix length).
    pub fn ingested(&self) -> usize {
        self.prefix.len()
    }

    /// Append a batch of stream entries (any order, any batching).
    /// Publishes a new generation when the epoch tick fires, returning
    /// its number; rejects out-of-shape coordinates up front.
    pub fn push(&mut self, batch: &[Entry]) -> Result<Option<u64>> {
        for e in batch {
            if (e.row as usize) >= self.inner.m || (e.col as usize) >= self.inner.n {
                return Err(Error::shape(format!(
                    "stream entry ({}, {}) outside {}x{}",
                    e.row, e.col, self.inner.m, self.inner.n
                )));
            }
        }
        if batch.is_empty() {
            return Ok(None);
        }
        if self.pending == 0 {
            self.epoch_t0 = Some(Instant::now());
        }
        self.prefix.extend_from_slice(batch);
        self.pending += batch.len();
        if self.inner.epoch_entries > 0 && self.pending >= self.inner.epoch_entries {
            return self.publish().map(Some);
        }
        Ok(None)
    }

    /// Force a publish of everything pushed so far. A no-op (returning
    /// the current generation) when nothing arrived since the last one.
    pub fn flush(&mut self) -> Result<u64> {
        if self.pending == 0 {
            return Ok(self.inner.generation.load(Ordering::Acquire));
        }
        self.publish()
    }

    /// Build and publish the next generation from the full prefix. The
    /// rebuild runs entirely off the read path — the chain lock is taken
    /// only for the final snapshot swap. A sampled publish records its
    /// own span tree (`live_publish` → `rebuild`, `swap`).
    fn publish(&mut self) -> Result<u64> {
        let reg = obs::global();
        let active = match trace::sample() {
            0 => None,
            id => Some(trace::ActiveTrace::begin(id)),
        };
        let root = active.as_ref().map(|a| {
            let mut s = a.span(0, "live_publish");
            s.note("entries", self.prefix.len().to_string());
            s
        });
        let t_build = (reg.enabled() || root.is_some()).then(Instant::now);
        let mut stats = MatrixStats::new(self.inner.m, self.inner.n);
        for e in &self.prefix {
            stats.push(e);
        }
        let mut sketcher = build_sketcher(
            SketchMode::Offline,
            &stats,
            &self.inner.plan,
            &PipelineConfig::default(),
        )?;
        sketcher.ingest(&self.prefix)?;
        let (sketch, _) = sketcher.finalize()?;
        let g = self.inner.generation.load(Ordering::Acquire) + 1;
        let snap = Arc::new(ServableSketch::from_sketch(&sketch)?.with_generation(g));
        if let Some(t0) = t_build {
            if reg.enabled() {
                reg.record_duration(Hist::LivePublishUs, t0.elapsed());
            }
            if let Some(root) = &root {
                root.ctx().record("rebuild", t0, Instant::now());
            }
        }
        let lag = self.epoch_t0.take().map_or(0.0, |t| t.elapsed().as_secs_f64());
        {
            let swap_span = root.as_ref().map(|r| r.ctx().span("swap"));
            let mut chain = chain_lock(&self.inner)?;
            chain.snapshots.push_back(snap);
            while chain.snapshots.len() > self.inner.retain {
                chain.snapshots.pop_front();
            }
            chain.lags.push(lag);
            self.inner.generation.store(g, Ordering::Release);
            self.inner.advance.notify_all();
            drop(swap_span);
        }
        reg.inc(Counter::LivePublish);
        reg.gauge_set(Gauge::LiveGeneration, g);
        reg.record(Hist::LiveLagUs, (lag * 1e6) as u64);
        if let Some(root) = root {
            root.finish();
        }
        if let Some(active) = active {
            trace::finish(&active);
        }
        self.pending = 0;
        Ok(g)
    }
}

/// A cloneable read handle onto a live chain: snapshot access, pinned and
/// unpinned queries, and generation-advance waits. Every backend
/// (in-process or remote) serves a live sketch through one of these.
#[derive(Clone)]
pub struct LiveReader {
    inner: Arc<LiveShared>,
}

impl LiveReader {
    /// Latest published generation.
    pub fn generation(&self) -> u64 {
        self.inner.generation.load(Ordering::Acquire)
    }

    /// `(m, n)` of the sketched stream.
    pub fn shape(&self) -> (usize, usize) {
        (self.inner.m, self.inner.n)
    }

    /// The chain's sketch plan.
    pub fn plan(&self) -> &SketchPlan {
        &self.inner.plan
    }

    /// The latest snapshot (O(1): one lock + one `Arc` clone).
    pub fn snapshot(&self) -> Result<Arc<ServableSketch>> {
        let chain = chain_lock(&self.inner)?;
        chain
            .snapshots
            .back()
            .cloned()
            .ok_or_else(|| Error::Pipeline("live chain holds no snapshot".into()))
    }

    /// The snapshot a pin selects: `None` (or `Some(latest)`) is the
    /// newest; an explicit older generation must still be inside the
    /// retained ring. A pin ahead of the chain or already retired is a
    /// typed [`Error::Generation`].
    pub fn snapshot_at(&self, pin: Option<u64>) -> Result<Arc<ServableSketch>> {
        let Some(g) = pin else { return self.snapshot() };
        let reg = obs::global();
        let chain = chain_lock(&self.inner)?;
        let latest = self.inner.generation.load(Ordering::Acquire);
        if g > latest {
            reg.inc(Counter::LivePinMiss);
            return Err(Error::Generation(format!(
                "generation {g} not yet published (latest is {latest})"
            )));
        }
        match chain.snapshots.iter().find(|s| s.generation() == g) {
            Some(snap) => {
                reg.inc(Counter::LivePinHit);
                Ok(Arc::clone(snap))
            }
            None => {
                reg.inc(Counter::LivePinMiss);
                let oldest = chain.snapshots.front().map_or(latest, |s| s.generation());
                Err(Error::Generation(format!(
                    "generation {g} retired (retained window is {oldest}..={latest})"
                )))
            }
        }
    }

    /// Answer one request on the snapshot the pin selects, reporting the
    /// generation it was answered at. The whole request — including every
    /// window of a row-parallel split — runs on that one snapshot, so a
    /// concurrent publish never tears an answer.
    pub fn answer_at(
        &self,
        pin: Option<u64>,
        request: &QueryRequest,
    ) -> Result<(QueryResponse, u64)> {
        self.answer_at_traced(pin, request, None)
    }

    /// [`Self::answer_at`] carrying a trace context: pool stages (queue
    /// wait, execution / split windows, reduction) become child spans.
    pub fn answer_at_traced(
        &self,
        pin: Option<u64>,
        request: &QueryRequest,
        ctx: Option<SpanCtx>,
    ) -> Result<(QueryResponse, u64)> {
        let snap = self.snapshot_at(pin)?;
        let g = snap.generation();
        let resp = self.inner.server.submit_on_traced(snap, request.clone(), ctx).wait()?;
        Ok((resp, g))
    }

    /// Answer a batch on **one** snapshot (the pin's, or the latest at
    /// submission): every request in the batch sees the same generation
    /// even while publishes land concurrently. Per-request failures come
    /// back as their `Err` entries.
    pub fn answer_batch_at(
        &self,
        pin: Option<u64>,
        requests: Vec<QueryRequest>,
    ) -> Result<(Vec<Result<QueryResponse>>, u64)> {
        let snap = self.snapshot_at(pin)?;
        let g = snap.generation();
        let pending: Vec<_> = requests
            .into_iter()
            .map(|q| self.inner.server.submit_on(Arc::clone(&snap), q))
            .collect();
        Ok((pending.into_iter().map(|p| p.wait()).collect(), g))
    }

    /// Block until the chain reaches `min_gen` (or `timeout` passes);
    /// returns the generation current at return, which may still be
    /// below `min_gen` on timeout. A wait that actually blocks may be
    /// sampled into a one-span `pin_wait` trace.
    pub fn wait_for(&self, min_gen: u64, timeout: Duration) -> Result<u64> {
        let deadline = Instant::now() + timeout;
        let mut chain = chain_lock(&self.inner)?;
        let mut pin_wait: Option<(Arc<trace::ActiveTrace>, Instant)> = None;
        let g = loop {
            let g = self.inner.generation.load(Ordering::Acquire);
            if g >= min_gen {
                break g;
            }
            let now = Instant::now();
            if now >= deadline {
                break g;
            }
            if pin_wait.is_none() {
                match trace::sample() {
                    0 => {}
                    id => pin_wait = Some((trace::ActiveTrace::begin_at(id, now), now)),
                }
            }
            chain = self
                .inner
                .advance
                .wait_timeout(chain, deadline - now)
                .map_err(|_| Error::Pipeline("live chain lock poisoned".into()))?
                .0;
        };
        drop(chain);
        if let Some((active, t0)) = pin_wait {
            active.record_with(
                0,
                "pin_wait",
                t0,
                Instant::now(),
                vec![("min_gen".into(), min_gen.to_string())],
            );
            trace::finish(&active);
        }
        Ok(g)
    }

    /// Identity of the chain as a servable sketch, under `dataset`.
    pub fn info(&self, dataset: &str) -> Result<SketchInfo> {
        let snap = self.snapshot()?;
        Ok(SketchInfo {
            dataset: dataset.to_string(),
            method: snap.method.clone(),
            s: self.inner.plan.s,
            seed: self.inner.plan.seed,
            m: self.inner.m as u64,
            n: self.inner.n as u64,
            compact: snap.enc.compact,
        })
    }

    /// Publish lag of every epoch so far, in seconds (publish instant
    /// minus the epoch's first push) — the freshness metric the live
    /// serving tables report.
    pub fn freshness_lags(&self) -> Result<Vec<f64>> {
        Ok(chain_lock(&self.inner)?.lags.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::DistributionKind;
    use crate::util::rng::Rng;

    fn entries(m: usize, n: usize, count: usize, seed: u64) -> Vec<Entry> {
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|_| {
                Entry::new(
                    rng.usize_below(m) as u32,
                    rng.usize_below(n) as u32,
                    rng.normal() as f32 + 1.5,
                )
            })
            .collect()
    }

    fn plan() -> SketchPlan {
        SketchPlan::new(DistributionKind::Bernstein, 300).with_seed(7)
    }

    #[test]
    fn generations_advance_on_epoch_tick_and_flush() {
        let cfg = LiveConfig { epoch_entries: 100, retain: 3, workers: 2 };
        let mut live = LiveSketch::start(16, 64, &plan(), &cfg).unwrap();
        let reader = live.reader();
        assert_eq!(reader.generation(), 0);

        let es = entries(16, 64, 250, 1);
        assert_eq!(live.push(&es[..99]).unwrap(), None);
        assert_eq!(live.push(&es[99..100]).unwrap(), Some(1));
        assert_eq!(live.push(&es[100..250]).unwrap(), Some(2));
        assert_eq!(reader.generation(), 2);
        // nothing pending: flush is a no-op
        assert_eq!(live.flush().unwrap(), 2);
        assert_eq!(live.push(&es[..10]).unwrap(), None);
        assert_eq!(live.flush().unwrap(), 3);
        assert_eq!(reader.freshness_lags().unwrap().len(), 3);
    }

    #[test]
    fn published_generation_is_bit_identical_to_offline_prefix_sketch() {
        let cfg = LiveConfig { epoch_entries: 0, retain: 2, workers: 1 };
        let p = plan();
        let mut live = LiveSketch::start(16, 64, &p, &cfg).unwrap();
        let es = entries(16, 64, 400, 2);
        live.push(&es[..300]).unwrap();
        live.flush().unwrap();
        // offline reference over the same prefix, same plan
        let mut stats = MatrixStats::new(16, 64);
        for e in &es[..300] {
            stats.push(e);
        }
        let mut sk =
            build_sketcher(SketchMode::Offline, &stats, &p, &PipelineConfig::default())
                .unwrap();
        sk.ingest(&es[..300]).unwrap();
        let (reference, _) = sk.finalize().unwrap();
        let want = crate::sketch::encode_sketch(&reference).unwrap();
        let snap = live.reader().snapshot().unwrap();
        assert_eq!(snap.generation(), 1);
        assert_eq!(&*snap.enc.bytes, &*want.bytes, "live generation != offline prefix");
    }

    #[test]
    fn pins_respect_the_retained_window() {
        let cfg = LiveConfig { epoch_entries: 0, retain: 2, workers: 1 };
        let mut live = LiveSketch::start(8, 32, &plan(), &cfg).unwrap();
        let reader = live.reader();
        let es = entries(8, 32, 300, 3);
        for chunk in es.chunks(100) {
            live.push(chunk).unwrap();
            live.flush().unwrap();
        }
        assert_eq!(reader.generation(), 3);
        // retained: 2 and 3; retired: 0 and 1; future: 4
        assert_eq!(reader.snapshot_at(Some(2)).unwrap().generation(), 2);
        assert_eq!(reader.snapshot_at(Some(3)).unwrap().generation(), 3);
        assert_eq!(reader.snapshot_at(None).unwrap().generation(), 3);
        let retired = reader.snapshot_at(Some(1)).unwrap_err();
        assert!(matches!(retired, Error::Generation(_)), "{retired}");
        let future = reader.snapshot_at(Some(4)).unwrap_err();
        assert!(matches!(future, Error::Generation(_)), "{future}");
    }

    #[test]
    fn answers_report_their_generation_and_empty_gen0_serves_zeros() {
        let cfg = LiveConfig { epoch_entries: 0, retain: 4, workers: 2 };
        let mut live = LiveSketch::start(8, 32, &plan(), &cfg).unwrap();
        let reader = live.reader();
        let x = vec![1.0; 32];
        let (resp, g) = reader.answer_at(None, &QueryRequest::Matvec(x.clone())).unwrap();
        assert_eq!(g, 0);
        match resp {
            QueryResponse::Vector(y) => assert!(y.iter().all(|&v| v == 0.0)),
            other => panic!("unexpected response {other:?}"),
        }
        live.push(&entries(8, 32, 200, 4)).unwrap();
        live.flush().unwrap();
        let (_, g) = reader.answer_at(None, &QueryRequest::Matvec(x.clone())).unwrap();
        assert_eq!(g, 1);
        // a pinned answer on the retained gen 0 still works
        let (resp0, g0) = reader.answer_at(Some(0), &QueryRequest::Matvec(x)).unwrap();
        assert_eq!(g0, 0);
        assert!(matches!(resp0, QueryResponse::Vector(_)));
    }

    #[test]
    fn wait_for_observes_publishes_from_another_thread() {
        let cfg = LiveConfig { epoch_entries: 50, retain: 4, workers: 1 };
        let mut live = LiveSketch::start(8, 32, &plan(), &cfg).unwrap();
        let reader = live.reader();
        let es = entries(8, 32, 200, 5);
        let t = std::thread::spawn(move || {
            for chunk in es.chunks(50) {
                live.push(chunk).unwrap();
            }
            live.ingested()
        });
        let g = reader.wait_for(4, Duration::from_secs(20)).unwrap();
        assert!(g >= 4, "observed generation {g}");
        assert_eq!(t.join().unwrap(), 200);
        // timeout path: generation 100 never arrives
        let g = reader.wait_for(100, Duration::from_millis(20)).unwrap();
        assert!(g < 100);
    }
}
