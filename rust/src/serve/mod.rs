//! The serving layer: persist sketches, answer queries against them.
//!
//! Building the sketch is half the paper's story; the payoff is *serving*
//! approximate matrix queries from the compressed sketch instead of from
//! `A` (cf. §1's disc-size argument, and the downstream-use framing in
//! BKK20 / fast sketched matrix multiplication). This module holds the
//! serving machinery; the **query surface callers use is
//! [`crate::api::SketchClient`]**, whose local backend wraps the types
//! here:
//!
//! * [`store`] — a versioned on-disk container (magic / header / FNV-1a
//!   checksum, written via [`crate::sketch::bitio`]) plus [`SketchStore`],
//!   a directory keyed by `(dataset, distribution, budget s, seed)` so
//!   repeated runs reuse cached sketches instead of re-sketching.
//! * [`query`] — matvec (`B·x`, `Bᵀ·x`, batched multi-x SpMM), row/column
//!   slices, and top-k heaviest entries executed *directly on the Elias-γ
//!   compressed payload* via [`crate::sketch::encode::SketchCursor`]
//!   (streaming decode, no full [`crate::sketch::Sketch`]
//!   materialization). Only the one-shot forms are public (for
//!   benchmarks); the header-cached / index-seeking / decoded-reference
//!   variants are crate-internal execution plans picked by
//!   [`ServableSketch::answer`].
//! * [`server`] — [`QueryServer`]: one immutable compressed sketch shared
//!   across worker threads answering batched
//!   [`crate::api::QueryRequest`]s over per-job reply channels. Large
//!   matvec / batched-matvec / top-k requests are **row-parallel**: the
//!   per-row offset index splits one query into contiguous windows
//!   across the pool, reduced in window order so answers stay
//!   bit-identical to the sequential scan.
//!
//! * [`live`] — [`LiveSketch`] / [`LiveReader`]: an RCU-style generation
//!   chain serving queries *while the stream is still arriving* — an
//!   ingest writer publishes immutable snapshot generations by atomic
//!   swap (each one bit-identical to the offline sketch of the same entry
//!   prefix), readers pin a generation or follow the latest, and recent
//!   generations stay pinnable in a bounded ring.
//!
//! CLI entry points: `matsketch sketch` writes into the store,
//! `matsketch query` answers one query from it (locally or against a
//! remote server), and `matsketch serve-bench` measures concurrent-reader
//! throughput into the eval report (see `eval::serving`). Remote traffic
//! goes through the network front ([`crate::net`]): `matsketch serve`
//! exposes this layer over TCP and `matsketch net-bench` load-tests it.

pub mod live;
pub mod query;
pub mod server;
pub mod store;

pub use live::{LiveConfig, LiveReader, LiveSketch};
pub use query::{col_slice, matvec, matvec_batch, matvec_t, rank_cmp, row_slice, top_k};
pub use server::{Pending, QueryServer, ServableSketch, ServerStats};
pub use store::{
    coo_fingerprint, read_header, Fingerprinter, SketchStore, StoreEntryInfo, StoreKey,
    StoredSketch,
};
