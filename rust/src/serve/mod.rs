//! The serving layer: persist sketches, answer queries against them.
//!
//! Building the sketch is half the paper's story; the payoff is *serving*
//! approximate matrix queries from the compressed sketch instead of from
//! `A` (cf. §1's disc-size argument, and the downstream-use framing in
//! BKK20 / fast sketched matrix multiplication). This module turns the
//! repo from a sketch builder into a sketch service:
//!
//! * [`store`] — a versioned on-disk container (magic / header / FNV-1a
//!   checksum, written via [`crate::sketch::bitio`]) plus [`SketchStore`],
//!   a directory keyed by `(dataset, distribution, budget s, seed)` so
//!   repeated runs reuse cached sketches instead of re-sketching.
//! * [`query`] — matvec (`B·x`, `Bᵀ·x`), row/column slices, and top-k
//!   heaviest entries executed *directly on the Elias-γ compressed
//!   payload* via [`crate::sketch::encode::SketchCursor`] (streaming
//!   decode, no full [`crate::sketch::Sketch`] materialization), with
//!   decoded-path twins for cross-checking.
//! * [`server`] — [`QueryServer`]: one immutable compressed sketch shared
//!   across worker threads answering batched [`Query`] requests.
//!
//! CLI entry points: `matsketch sketch` writes into the store,
//! `matsketch query` answers one query from it, and
//! `matsketch serve-bench` measures concurrent-reader throughput into the
//! eval report (see `eval::serving`). Remote traffic goes through the
//! network front ([`crate::net`]): `matsketch serve` exposes this layer
//! over TCP and `matsketch net-bench` load-tests it.

pub mod query;
pub mod server;
pub mod store;

pub use query::{
    col_slice, col_slice_h, decoded_matvec, decoded_matvec_t, decoded_top_k, matvec, matvec_h,
    matvec_t, matvec_t_h, row_slice, row_slice_h, row_slice_indexed, top_k, top_k_h,
};
pub use server::{Pending, Query, QueryOutcome, QueryServer, ServableSketch, ServerStats};
pub use store::{
    coo_fingerprint, read_header, Fingerprinter, SketchStore, StoreEntryInfo, StoreKey,
    StoredSketch,
};
