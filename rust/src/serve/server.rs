//! The multi-threaded serving core: one immutable loaded sketch shared
//! across worker threads answering batched query requests.
//!
//! A [`QueryServer`] owns `W` workers pulling tasks off a shared queue;
//! each submitted request carries its own reply channel, so callers
//! submit (optionally in batches), keep working, and [`Pending::wait`]
//! when they need the answer. The sketch stays in its compressed form for
//! the whole server lifetime — workers answer straight off the Elias-γ
//! payload via [`super::query`], so serving memory is the compressed
//! size, not the decoded one.
//!
//! ## Row-parallel queries
//!
//! On sketches with at least [`QueryServer::DEFAULT_SPLIT_MIN_GROUPS`]
//! occupied rows, a single matvec / batched-matvec / top-k request is
//! **split across the pool**: the per-row offset index is partitioned
//! into `W` contiguous windows, each worker decodes one window
//! ([`crate::sketch::SketchCursor::row_range`]) into a partial result,
//! and the last finisher reduces the partials **in window order** —
//! per-row f64 accumulation order is exactly the sequential scan's, so
//! the combined answer is bit-identical to a one-thread answer (pinned
//! in `tests/integration_serve.rs` for every Figure-1 distribution).
//! `Bᵀ·x` and column slices stay sequential (their accumulations cross
//! rows), and row slices already seek through the index.
//!
//! Callers do not drive this type directly any more: the public query
//! surface is [`crate::api::SketchClient`], whose in-process backend
//! ([`crate::api::LocalClient`]) and network front ([`crate::net`]) both
//! dispatch onto these pools.
//!
//! Every submit/dequeue/execute step records into the process-global
//! telemetry registry ([`crate::obs`]): queue-wait vs per-op execute
//! latency histograms, whole-vs-sharded split decision counters, and
//! per-window times of split requests. Sampled requests additionally
//! carry a trace context ([`crate::obs::trace::SpanCtx`]) through the
//! queue — queue wait, execution, each split window, and the in-order
//! reduction become child spans of the request's span tree.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::api::{QueryRequest, QueryResponse};
use crate::error::{Error, Result};
use crate::obs::trace::SpanCtx;
use crate::obs::{self, Counter, Hist};
use crate::sketch::{
    encode_sketch, row_group_index_h, EncodedSketch, PayloadHeader, Sketch, SketchEntry,
};

use super::query;
use super::store::StoredSketch;

/// An immutable, shareable loaded sketch: what a [`QueryServer`] serves.
///
/// Loading parses the payload header (the O(m) row-scale table — ROADMAP
/// flags re-reading it per query as dominating row/top-k latency on tall
/// matrices) and materializes the per-row seek index **once**; every
/// query after that reuses both, so serving cost is the query itself, not
/// repeated header decodes.
#[derive(Clone, Debug)]
pub struct ServableSketch {
    /// The compressed payload queries execute against.
    pub enc: EncodedSketch,
    /// Distribution name (provenance, reporting).
    pub method: String,
    /// Header parsed once at load time (row scales behind an `Arc`).
    header: PayloadHeader,
    /// `(row id, payload bit offset)` seek index, ascending.
    row_index: Vec<(u32, u64)>,
    /// Which generation of a live chain this snapshot is. Frozen
    /// store-loaded sketches are generation 0; [`super::live`] tags each
    /// published snapshot with its epoch counter.
    generation: u64,
}

impl ServableSketch {
    /// Wrap an already-encoded sketch: parse its header and build the
    /// row seek index once, up front. Fails on a corrupt payload —
    /// loudly, at load time, not mid-query.
    pub fn new(enc: EncodedSketch, method: impl Into<String>) -> Result<ServableSketch> {
        let header = PayloadHeader::parse(&enc)?;
        let row_index = row_group_index_h(&enc, &header)?;
        Ok(ServableSketch { enc, method: method.into(), header, row_index, generation: 0 })
    }

    /// Encode and wrap an in-memory sketch.
    pub fn from_sketch(sk: &Sketch) -> Result<ServableSketch> {
        Self::new(encode_sketch(sk)?, sk.method.clone())
    }

    /// Wrap a sketch read back from the store, reusing the persisted
    /// row index when the container carries one (format v2).
    pub fn from_stored(stored: StoredSketch) -> Result<ServableSketch> {
        let header = PayloadHeader::parse(&stored.enc)?;
        let row_index = match stored.row_index {
            Some(index) => index,
            None => row_group_index_h(&stored.enc, &header)?,
        };
        Ok(ServableSketch {
            enc: stored.enc,
            method: stored.method,
            header,
            row_index,
            generation: 0,
        })
    }

    /// Tag this snapshot with a live-chain generation (builder style).
    pub fn with_generation(mut self, generation: u64) -> ServableSketch {
        self.generation = generation;
        self
    }

    /// The live-chain generation this snapshot belongs to (0 for frozen
    /// store-loaded sketches).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// `(m, n)` of the served matrix sketch.
    pub fn shape(&self) -> (usize, usize) {
        (self.enc.m, self.enc.n)
    }

    /// The payload header parsed at load time.
    pub fn header(&self) -> &PayloadHeader {
        &self.header
    }

    /// The per-row seek index built (or loaded) at load time.
    pub fn row_index(&self) -> &[(u32, u64)] {
        &self.row_index
    }

    /// Answer one request synchronously (the worker body; also usable
    /// directly for single-threaded callers and cross-checks). This is
    /// where the execution plan is selected: row slices seek through the
    /// index, batched matvecs share one payload pass, everything else
    /// streams from the cached header.
    pub fn answer(&self, q: &QueryRequest) -> Result<QueryResponse> {
        Ok(match q {
            QueryRequest::Matvec(x) => {
                QueryResponse::Vector(query::matvec_h(&self.enc, &self.header, x)?)
            }
            QueryRequest::MatvecT(x) => {
                QueryResponse::Vector(query::matvec_t_h(&self.enc, &self.header, x)?)
            }
            QueryRequest::MatvecBatch(xs) => {
                QueryResponse::Vectors(query::matvec_batch_h(&self.enc, &self.header, xs)?)
            }
            QueryRequest::Row(i) => QueryResponse::Entries(query::row_slice_indexed(
                &self.enc,
                &self.header,
                &self.row_index,
                *i,
            )?),
            QueryRequest::Col(j) => {
                QueryResponse::Entries(query::col_slice_h(&self.enc, &self.header, *j)?)
            }
            QueryRequest::TopK(k) => {
                QueryResponse::Entries(query::top_k_h(&self.enc, &self.header, *k)?)
            }
        })
    }
}

/// One unit of worker work: a whole request, or one window of a
/// row-parallel split.
/// Every task carries the snapshot it must answer against: under a live
/// generation chain the pool's "current" sketch can be swapped mid-query,
/// and a request — including every window of a row-parallel split — must
/// execute entirely on the snapshot it was submitted on.
enum Task {
    /// One request answered sequentially, with its private reply channel.
    Whole {
        sketch: Arc<ServableSketch>,
        request: QueryRequest,
        reply: SyncSender<Result<QueryResponse>>,
        /// Submit-time stamp for the queue-wait histogram; `None` when
        /// both the telemetry registry and the request's trace are off
        /// (no clock reads at all).
        enqueued: Option<Instant>,
        /// Trace context of a sampled request; spans nest under it.
        ctx: Option<SpanCtx>,
    },
    /// One contiguous row-group window of a split request (the snapshot
    /// and trace context ride on the shared plan).
    Shard { plan: Arc<SplitPlan>, chunk: usize, enqueued: Option<Instant> },
}

/// Which execute-latency histogram a request records into.
fn exec_hist(q: &QueryRequest) -> Hist {
    match q {
        QueryRequest::Matvec(_) => Hist::ExecMatvecUs,
        QueryRequest::MatvecT(_) => Hist::ExecMatvecTUs,
        QueryRequest::MatvecBatch(_) => Hist::ExecBatchUs,
        QueryRequest::Row(_) => Hist::ExecRowUs,
        QueryRequest::Col(_) => Hist::ExecColUs,
        QueryRequest::TopK(_) => Hist::ExecTopKUs,
    }
}

/// Which operator a row-parallel split runs. Only row-separable
/// operators split: matvec and batched matvec (each output row is one
/// row group's private sum) and top-k (a strict total order, so
/// window-local winners merge exactly).
enum SplitOp {
    Matvec(Vec<f64>),
    MatvecBatch(Vec<Vec<f64>>),
    TopK(usize),
}

/// One window's partial result.
enum Partial {
    /// Per-group sums, window order ([`query::matvec_groups`]).
    Sums(Vec<f64>),
    /// Per-vector per-group sums ([`query::matvec_batch_groups`]).
    SumsBatch(Vec<Vec<f64>>),
    /// Window-local top-k ([`query::top_k_groups`]).
    TopK(Vec<SketchEntry>),
}

/// Collected window partials of one split request, indexed by chunk.
type PartialSlots = Vec<Option<Result<Partial>>>;

/// Shared state of one split request: the operator, the row-group
/// windows, the collected partials, and the reply channel. The last
/// worker to finish its window performs the reduction — partials are
/// combined **in window order**, never completion order, so the answer
/// is deterministic and bit-identical to the sequential scan.
struct SplitPlan {
    /// The snapshot every window decodes — pinned at submit time so no
    /// shard ever straddles a generation swap.
    sketch: Arc<ServableSketch>,
    op: SplitOp,
    /// Contiguous `[lo, hi)` windows into the row-group index, ascending.
    ranges: Vec<(usize, usize)>,
    partials: Mutex<PartialSlots>,
    remaining: AtomicUsize,
    reply: SyncSender<Result<QueryResponse>>,
    /// Trace context of a sampled request; window/reduce spans nest here.
    ctx: Option<SpanCtx>,
    /// Whether a worker already recorded the shared queue-wait span (the
    /// first dequeuer wins; per-shard waits still hit the histogram).
    queue_span_done: AtomicBool,
}

impl SplitPlan {
    /// Decode and accumulate one window.
    fn run_chunk(&self, chunk: usize) -> Result<Partial> {
        let sk = &*self.sketch;
        let (lo, hi) = self.ranges[chunk];
        let (enc, header, index) = (&sk.enc, sk.header(), sk.row_index());
        Ok(match &self.op {
            SplitOp::Matvec(x) => {
                Partial::Sums(query::matvec_groups(enc, header, index, lo, hi, x)?)
            }
            SplitOp::MatvecBatch(xs) => {
                Partial::SumsBatch(query::matvec_batch_groups(enc, header, index, lo, hi, xs)?)
            }
            SplitOp::TopK(k) => {
                Partial::TopK(query::top_k_groups(enc, header, index, lo, hi, *k)?)
            }
        })
    }

    /// Record `chunk`'s partial; the last finisher reduces and replies.
    /// Returns `true` iff this call completed (and answered) the request.
    fn complete(&self, chunk: usize, result: Result<Partial>) -> bool {
        {
            // a poisoned lock means a sibling worker panicked mid-query;
            // dropping the plan without replying surfaces it at wait()
            let Ok(mut partials) = self.partials.lock() else { return false };
            partials[chunk] = Some(result);
        }
        if self.remaining.fetch_sub(1, Ordering::AcqRel) != 1 {
            return false;
        }
        let taken = match self.partials.lock() {
            Ok(mut p) => std::mem::take(&mut *p),
            Err(_) => return false,
        };
        let started = self.ctx.as_ref().map(|_| Instant::now());
        let out = self.reduce(taken);
        if let (Some(ctx), Some(t0)) = (&self.ctx, started) {
            ctx.record("reduce", t0, Instant::now());
        }
        let _ = self.reply.send(out);
        true
    }

    /// Combine the window partials in window order.
    fn reduce(&self, partials: PartialSlots) -> Result<QueryResponse> {
        let sk = &*self.sketch;
        // deterministic error reporting: the lowest window's error wins,
        // independent of which worker finished first
        let mut parts = Vec::with_capacity(partials.len());
        for p in partials {
            match p {
                Some(Ok(part)) => parts.push(part),
                Some(Err(e)) => return Err(e),
                None => {
                    return Err(Error::Pipeline("split query lost a window partial".into()))
                }
            }
        }
        let index = sk.row_index();
        let m = sk.header().m;
        let mismatch = || Error::Pipeline("split query partial kind mismatch".into());
        Ok(match &self.op {
            SplitOp::Matvec(_) => {
                let mut y = vec![0.0f64; m];
                for (&(lo, _), part) in self.ranges.iter().zip(parts) {
                    let Partial::Sums(sums) = part else { return Err(mismatch()) };
                    for (off, s) in sums.into_iter().enumerate() {
                        y[index[lo + off].0 as usize] = s;
                    }
                }
                QueryResponse::Vector(y)
            }
            SplitOp::MatvecBatch(xs) => {
                let mut ys = vec![vec![0.0f64; m]; xs.len()];
                for (&(lo, _), part) in self.ranges.iter().zip(parts) {
                    let Partial::SumsBatch(sb) = part else { return Err(mismatch()) };
                    for (y, sums) in ys.iter_mut().zip(sb) {
                        for (off, s) in sums.into_iter().enumerate() {
                            y[index[lo + off].0 as usize] = s;
                        }
                    }
                }
                QueryResponse::Vectors(ys)
            }
            SplitOp::TopK(k) => {
                let mut all: Vec<SketchEntry> = Vec::new();
                for part in parts {
                    let Partial::TopK(es) = part else { return Err(mismatch()) };
                    all.extend(es);
                }
                all.sort_by(query::rank_cmp);
                all.truncate(*k);
                QueryResponse::Entries(all)
            }
        })
    }
}

/// Handle to one submitted request's eventual answer.
pub struct Pending {
    rx: Receiver<Result<QueryResponse>>,
}

impl Pending {
    /// Block until the worker answers.
    pub fn wait(self) -> Result<QueryResponse> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(Error::Pipeline(
                "query worker dropped the reply channel".into(),
            )),
        }
    }
}

/// Per-run serving counters, returned by [`QueryServer::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// Queries answered by each worker.
    pub served_per_worker: Vec<u64>,
}

impl ServerStats {
    /// Total queries answered.
    pub fn total(&self) -> u64 {
        self.served_per_worker.iter().sum()
    }
}

/// A pool of worker threads answering requests against one shared
/// compressed sketch, splitting large row-separable queries across the
/// pool (see the module docs).
pub struct QueryServer {
    sketch: Arc<ServableSketch>,
    tx: Sender<Task>,
    handles: Vec<JoinHandle<u64>>,
    split_min_groups: usize,
}

impl QueryServer {
    /// Default minimum occupied row groups before a single query is
    /// split across the pool. Below this the whole-payload decode is so
    /// cheap that the fork/reduce coordination costs more than it saves
    /// (and concurrent requests already keep every worker busy); above
    /// it, one tall-matrix matvec scales with the worker count.
    pub const DEFAULT_SPLIT_MIN_GROUPS: usize = 512;

    /// Spawn `workers` (min 1) threads serving `sketch`, splitting
    /// row-separable queries once the sketch has at least
    /// [`Self::DEFAULT_SPLIT_MIN_GROUPS`] occupied rows.
    pub fn start(sketch: Arc<ServableSketch>, workers: usize) -> QueryServer {
        Self::start_with(sketch, workers, Self::DEFAULT_SPLIT_MIN_GROUPS)
    }

    /// [`Self::start`] with an explicit split threshold: requests are
    /// row-parallelized only when the sketch has ≥ `split_min_groups`
    /// occupied rows (and the pool has ≥ 2 workers). Tests pin
    /// parallel-vs-sequential bit-equality with a threshold of 1.
    pub fn start_with(
        sketch: Arc<ServableSketch>,
        workers: usize,
        split_min_groups: usize,
    ) -> QueryServer {
        let workers = workers.max(1);
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = Arc::clone(&rx);
            handles.push(std::thread::spawn(move || -> u64 {
                let mut served = 0u64;
                loop {
                    // hold the queue lock only for the dequeue, not the
                    // (possibly long) answer computation
                    let task = match rx.lock() {
                        Ok(guard) => guard.recv(),
                        Err(_) => break,
                    };
                    let Ok(task) = task else { break };
                    let reg = obs::global();
                    match task {
                        Task::Whole { sketch, request, reply, enqueued, ctx } => {
                            if let Some(t0) = enqueued {
                                if reg.enabled() {
                                    reg.record_duration(Hist::QueueWaitUs, t0.elapsed());
                                }
                                if let Some(ctx) = &ctx {
                                    ctx.record("queue_wait", t0, Instant::now());
                                }
                            }
                            let started =
                                (reg.enabled() || ctx.is_some()).then(Instant::now);
                            let out = sketch.answer(&request);
                            if let Some(t0) = started {
                                if reg.enabled() {
                                    reg.record_duration(exec_hist(&request), t0.elapsed());
                                }
                                if let Some(ctx) = &ctx {
                                    ctx.record("exec", t0, Instant::now());
                                }
                            }
                            // a caller that dropped its Pending is fine
                            let _ = reply.send(out);
                            served += 1;
                        }
                        Task::Shard { plan, chunk, enqueued } => {
                            if let Some(t0) = enqueued {
                                if reg.enabled() {
                                    reg.record_duration(Hist::QueueWaitUs, t0.elapsed());
                                }
                                // one shared queue-wait span per split
                                // request: the first dequeuer records it
                                if let Some(ctx) = &plan.ctx {
                                    if !plan.queue_span_done.swap(true, Ordering::Relaxed) {
                                        ctx.record("queue_wait", t0, Instant::now());
                                    }
                                }
                            }
                            let started =
                                (reg.enabled() || plan.ctx.is_some()).then(Instant::now);
                            let out = plan.run_chunk(chunk);
                            if let Some(t0) = started {
                                if reg.enabled() {
                                    reg.record_duration(Hist::SplitWindowUs, t0.elapsed());
                                }
                                if let Some(ctx) = &plan.ctx {
                                    ctx.trace.record_with(
                                        ctx.parent,
                                        "split_window",
                                        t0,
                                        Instant::now(),
                                        vec![("window".into(), chunk.to_string())],
                                    );
                                }
                            }
                            if plan.complete(chunk, out) {
                                // a split request counts once, credited
                                // to the worker that reduced it
                                served += 1;
                            }
                        }
                    }
                }
                served
            }));
        }
        QueryServer { sketch, tx, handles, split_min_groups }
    }

    /// The served sketch.
    pub fn sketch(&self) -> &Arc<ServableSketch> {
        &self.sketch
    }

    /// Worker count.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Enqueue one request against the pool's default sketch; returns
    /// immediately with a wait handle. Large row-separable requests are
    /// sharded across the pool here.
    pub fn submit(&self, request: QueryRequest) -> Pending {
        self.submit_on(Arc::clone(&self.sketch), request)
    }

    /// [`Self::submit`] carrying a trace context: queue wait, execution
    /// (or each split window plus the reduction) become child spans.
    pub fn submit_traced(&self, request: QueryRequest, ctx: Option<SpanCtx>) -> Pending {
        self.submit_on_traced(Arc::clone(&self.sketch), request, ctx)
    }

    /// Enqueue one request pinned to an explicit snapshot. The request —
    /// including every window of a row-parallel split — executes entirely
    /// on `sketch`, so a live generation swap never tears an in-flight
    /// answer. The snapshot need not be the pool's default sketch (a live
    /// chain submits retained generations through the same pool).
    pub fn submit_on(&self, sketch: Arc<ServableSketch>, request: QueryRequest) -> Pending {
        self.submit_on_traced(sketch, request, None)
    }

    /// [`Self::submit_on`] carrying a trace context (see
    /// [`Self::submit_traced`]).
    pub fn submit_on_traced(
        &self,
        sketch: Arc<ServableSketch>,
        request: QueryRequest,
        ctx: Option<SpanCtx>,
    ) -> Pending {
        let reg = obs::global();
        let (reply, rx) = sync_channel(1);
        let enqueued = (reg.enabled() || ctx.is_some()).then(Instant::now);
        // if every worker is gone the Pending surfaces it at wait()
        if let Some((request, ctx)) = self.try_split(&sketch, request, &reply, enqueued, ctx)
        {
            reg.inc(Counter::SplitWhole);
            let _ = self.tx.send(Task::Whole { sketch, request, reply, enqueued, ctx });
        } else {
            reg.inc(Counter::SplitSharded);
        }
        Pending { rx }
    }

    /// Shard a splittable request across the pool, enqueuing one window
    /// task per chunk; hands the request back when it should run whole
    /// (unsplittable op, trivial/invalid shapes — the sequential path
    /// produces the canonical error — or a sketch below the threshold).
    fn try_split(
        &self,
        sketch: &Arc<ServableSketch>,
        request: QueryRequest,
        reply: &SyncSender<Result<QueryResponse>>,
        enqueued: Option<Instant>,
        ctx: Option<SpanCtx>,
    ) -> Option<(QueryRequest, Option<SpanCtx>)> {
        let workers = self.handles.len();
        let groups = sketch.row_index().len();
        if workers < 2 || groups < self.split_min_groups.max(2) {
            return Some((request, ctx));
        }
        let n = sketch.header().n;
        let op = match request {
            QueryRequest::Matvec(x) if x.len() == n => SplitOp::Matvec(x),
            QueryRequest::MatvecBatch(xs)
                if !xs.is_empty() && xs.iter().all(|x| x.len() == n) =>
            {
                SplitOp::MatvecBatch(xs)
            }
            QueryRequest::TopK(k) if k > 0 => SplitOp::TopK(k),
            other => return Some((other, ctx)),
        };
        let chunks = workers.min(groups);
        let ranges: Vec<(usize, usize)> = (0..chunks)
            .map(|c| (groups * c / chunks, groups * (c + 1) / chunks))
            .collect();
        let plan = Arc::new(SplitPlan {
            sketch: Arc::clone(sketch),
            op,
            ranges,
            partials: Mutex::new((0..chunks).map(|_| None).collect()),
            remaining: AtomicUsize::new(chunks),
            reply: reply.clone(),
            ctx,
            queue_span_done: AtomicBool::new(false),
        });
        for chunk in 0..chunks {
            let _ = self.tx.send(Task::Shard { plan: Arc::clone(&plan), chunk, enqueued });
        }
        None
    }

    /// Enqueue a batch; answers can be awaited in any order.
    pub fn submit_batch(&self, requests: Vec<QueryRequest>) -> Vec<Pending> {
        requests.into_iter().map(|q| self.submit(q)).collect()
    }

    /// Close the queue, join every worker, and report serving stats.
    pub fn shutdown(self) -> ServerStats {
        drop(self.tx);
        let served_per_worker: Vec<u64> = self
            .handles
            .into_iter()
            .map(|h| h.join().unwrap_or(0))
            .collect();
        ServerStats { served_per_worker }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::DistributionKind;
    use crate::sketch::{sketch_offline, SketchPlan};
    use crate::sparse::Coo;
    use crate::util::rng::Rng;

    fn servable() -> ServableSketch {
        let mut rng = Rng::new(11);
        let mut coo = Coo::new(10, 64);
        for i in 0..10u32 {
            for _ in 0..12 {
                coo.push(i, rng.usize_below(64) as u32, rng.normal() as f32 + 1.5);
            }
        }
        let a = coo.to_csr();
        let sk =
            sketch_offline(&a, &SketchPlan::new(DistributionKind::Bernstein, 400)).unwrap();
        ServableSketch::from_sketch(&sk).unwrap()
    }

    #[test]
    fn concurrent_answers_match_direct_answers() {
        let sk = Arc::new(servable());
        let (m, n) = sk.shape();
        let server = QueryServer::start(Arc::clone(&sk), 4);
        assert_eq!(server.workers(), 4);

        let mut rng = Rng::new(5);
        let requests: Vec<QueryRequest> = (0..24usize)
            .map(|i| match i % 5 {
                0 => QueryRequest::Matvec((0..n).map(|_| rng.normal()).collect()),
                1 => QueryRequest::MatvecT((0..m).map(|_| rng.normal()).collect()),
                2 => QueryRequest::MatvecBatch(
                    (0..3).map(|_| (0..n).map(|_| rng.normal()).collect()).collect(),
                ),
                3 => QueryRequest::Row((i % m) as u32),
                _ => QueryRequest::TopK(5),
            })
            .collect();
        let pending = server.submit_batch(requests.clone());
        for (q, p) in requests.iter().zip(pending) {
            let got = p.wait().unwrap();
            let want = sk.answer(q).unwrap();
            assert_eq!(got, want);
        }
        let stats = server.shutdown();
        assert_eq!(stats.total(), 24);
        assert_eq!(stats.served_per_worker.len(), 4);
    }

    #[test]
    fn batched_matvec_answer_matches_independent_answers() {
        let sk = servable();
        let (_, n) = sk.shape();
        let mut rng = Rng::new(9);
        let xs: Vec<Vec<f64>> =
            (0..4).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let QueryResponse::Vectors(ys) =
            sk.answer(&QueryRequest::MatvecBatch(xs.clone())).unwrap()
        else {
            panic!("batch answer is not Vectors");
        };
        for (x, y) in xs.into_iter().zip(ys) {
            assert_eq!(
                sk.answer(&QueryRequest::Matvec(x)).unwrap(),
                QueryResponse::Vector(y)
            );
        }
    }

    #[test]
    fn bad_query_surfaces_as_error_not_poison() {
        let sk = Arc::new(servable());
        let server = QueryServer::start(Arc::clone(&sk), 2);
        // wrong-length x: the error comes back on the reply channel and
        // the server keeps serving afterwards
        assert!(server.submit(QueryRequest::Matvec(vec![1.0; 3])).wait().is_err());
        let ok = server.submit(QueryRequest::TopK(3)).wait().unwrap();
        match ok {
            QueryResponse::Entries(es) => assert_eq!(es.len(), 3),
            other => panic!("unexpected outcome {other:?}"),
        }
        server.shutdown();
    }

    #[test]
    fn split_answers_match_sequential_bitwise() {
        let sk = Arc::new(servable());
        let (m, n) = sk.shape();
        // threshold 1: every splittable request shards across the pool
        let server = QueryServer::start_with(Arc::clone(&sk), 4, 1);
        let mut rng = Rng::new(31);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let xs: Vec<Vec<f64>> =
            (0..3).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let requests = [
            QueryRequest::Matvec(x.clone()),
            QueryRequest::MatvecBatch(xs),
            QueryRequest::TopK(5),
            QueryRequest::MatvecT((0..m).map(|_| 0.5).collect()),
            QueryRequest::Row(3),
        ];
        for q in requests {
            let got = server.submit(q.clone()).wait().unwrap();
            let want = sk.answer(&q).unwrap();
            assert_eq!(got, want);
            if let (QueryResponse::Vector(a), Ok(QueryResponse::Vector(b))) =
                (&got, sk.answer(&q))
            {
                for (va, vb) in a.iter().zip(&b) {
                    assert_eq!(va.to_bits(), vb.to_bits(), "not bit-identical");
                }
            }
        }
        // wrong-shape / trivial requests fall back to the sequential
        // path and keep its canonical behavior
        assert!(server.submit(QueryRequest::Matvec(vec![0.0; n + 1])).wait().is_err());
        match server.submit(QueryRequest::MatvecBatch(Vec::new())).wait().unwrap() {
            QueryResponse::Vectors(vs) => assert!(vs.is_empty()),
            other => panic!("unexpected outcome {other:?}"),
        }
        match server.submit(QueryRequest::TopK(0)).wait().unwrap() {
            QueryResponse::Entries(es) => assert!(es.is_empty()),
            other => panic!("unexpected outcome {other:?}"),
        }
        server.shutdown();
    }

    #[test]
    fn split_queries_count_once_in_stats() {
        let sk = Arc::new(servable());
        let (_, n) = sk.shape();
        let server = QueryServer::start_with(Arc::clone(&sk), 3, 1);
        let pending = server.submit_batch(vec![QueryRequest::Matvec(vec![0.25; n]); 10]);
        for p in pending {
            p.wait().unwrap();
        }
        let stats = server.shutdown();
        assert_eq!(stats.total(), 10, "a split request must count once");
        assert_eq!(stats.served_per_worker.len(), 3);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let sk = Arc::new(servable());
        let server = QueryServer::start(sk, 0);
        assert_eq!(server.workers(), 1);
        server.submit(QueryRequest::TopK(1)).wait().unwrap();
        assert_eq!(server.shutdown().total(), 1);
    }
}
