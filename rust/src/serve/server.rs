//! The multi-threaded serving core: one immutable loaded sketch shared
//! across worker threads answering batched query requests.
//!
//! A [`QueryServer`] owns `W` workers pulling [`QueryRequest`] jobs off a
//! shared queue; each job carries its own reply channel, so callers
//! submit (optionally in batches), keep working, and [`Pending::wait`]
//! when they need the answer. The sketch stays in its compressed form for
//! the whole server lifetime — workers answer straight off the Elias-γ
//! payload via [`super::query`], so serving memory is the compressed
//! size, not the decoded one.
//!
//! Callers do not drive this type directly any more: the public query
//! surface is [`crate::api::SketchClient`], whose in-process backend
//! ([`crate::api::LocalClient`]) and network front ([`crate::net`]) both
//! dispatch onto these pools.

use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::api::{QueryRequest, QueryResponse};
use crate::error::{Error, Result};
use crate::sketch::{
    encode_sketch, row_group_index_h, EncodedSketch, PayloadHeader, Sketch,
};

use super::query;
use super::store::StoredSketch;

/// An immutable, shareable loaded sketch: what a [`QueryServer`] serves.
///
/// Loading parses the payload header (the O(m) row-scale table — ROADMAP
/// flags re-reading it per query as dominating row/top-k latency on tall
/// matrices) and materializes the per-row seek index **once**; every
/// query after that reuses both, so serving cost is the query itself, not
/// repeated header decodes.
#[derive(Clone, Debug)]
pub struct ServableSketch {
    /// The compressed payload queries execute against.
    pub enc: EncodedSketch,
    /// Distribution name (provenance, reporting).
    pub method: String,
    /// Header parsed once at load time (row scales behind an `Arc`).
    header: PayloadHeader,
    /// `(row id, payload bit offset)` seek index, ascending.
    row_index: Vec<(u32, u64)>,
}

impl ServableSketch {
    /// Wrap an already-encoded sketch: parse its header and build the
    /// row seek index once, up front. Fails on a corrupt payload —
    /// loudly, at load time, not mid-query.
    pub fn new(enc: EncodedSketch, method: impl Into<String>) -> Result<ServableSketch> {
        let header = PayloadHeader::parse(&enc)?;
        let row_index = row_group_index_h(&enc, &header)?;
        Ok(ServableSketch { enc, method: method.into(), header, row_index })
    }

    /// Encode and wrap an in-memory sketch.
    pub fn from_sketch(sk: &Sketch) -> Result<ServableSketch> {
        Self::new(encode_sketch(sk)?, sk.method.clone())
    }

    /// Wrap a sketch read back from the store, reusing the persisted
    /// row index when the container carries one (format v2).
    pub fn from_stored(stored: StoredSketch) -> Result<ServableSketch> {
        let header = PayloadHeader::parse(&stored.enc)?;
        let row_index = match stored.row_index {
            Some(index) => index,
            None => row_group_index_h(&stored.enc, &header)?,
        };
        Ok(ServableSketch {
            enc: stored.enc,
            method: stored.method,
            header,
            row_index,
        })
    }

    /// `(m, n)` of the served matrix sketch.
    pub fn shape(&self) -> (usize, usize) {
        (self.enc.m, self.enc.n)
    }

    /// The payload header parsed at load time.
    pub fn header(&self) -> &PayloadHeader {
        &self.header
    }

    /// The per-row seek index built (or loaded) at load time.
    pub fn row_index(&self) -> &[(u32, u64)] {
        &self.row_index
    }

    /// Answer one request synchronously (the worker body; also usable
    /// directly for single-threaded callers and cross-checks). This is
    /// where the execution plan is selected: row slices seek through the
    /// index, batched matvecs share one payload pass, everything else
    /// streams from the cached header.
    pub fn answer(&self, q: &QueryRequest) -> Result<QueryResponse> {
        Ok(match q {
            QueryRequest::Matvec(x) => {
                QueryResponse::Vector(query::matvec_h(&self.enc, &self.header, x)?)
            }
            QueryRequest::MatvecT(x) => {
                QueryResponse::Vector(query::matvec_t_h(&self.enc, &self.header, x)?)
            }
            QueryRequest::MatvecBatch(xs) => {
                QueryResponse::Vectors(query::matvec_batch_h(&self.enc, &self.header, xs)?)
            }
            QueryRequest::Row(i) => QueryResponse::Entries(query::row_slice_indexed(
                &self.enc,
                &self.header,
                &self.row_index,
                *i,
            )?),
            QueryRequest::Col(j) => {
                QueryResponse::Entries(query::col_slice_h(&self.enc, &self.header, *j)?)
            }
            QueryRequest::TopK(k) => {
                QueryResponse::Entries(query::top_k_h(&self.enc, &self.header, *k)?)
            }
        })
    }
}

/// One in-flight job: the request plus its private reply channel.
struct Job {
    request: QueryRequest,
    reply: SyncSender<Result<QueryResponse>>,
}

/// Handle to one submitted request's eventual answer.
pub struct Pending {
    rx: Receiver<Result<QueryResponse>>,
}

impl Pending {
    /// Block until the worker answers.
    pub fn wait(self) -> Result<QueryResponse> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(Error::Pipeline(
                "query worker dropped the reply channel".into(),
            )),
        }
    }
}

/// Per-run serving counters, returned by [`QueryServer::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// Queries answered by each worker.
    pub served_per_worker: Vec<u64>,
}

impl ServerStats {
    /// Total queries answered.
    pub fn total(&self) -> u64 {
        self.served_per_worker.iter().sum()
    }
}

/// A pool of worker threads answering requests against one shared
/// compressed sketch.
pub struct QueryServer {
    sketch: Arc<ServableSketch>,
    tx: Sender<Job>,
    handles: Vec<JoinHandle<u64>>,
}

impl QueryServer {
    /// Spawn `workers` (min 1) threads serving `sketch`.
    pub fn start(sketch: Arc<ServableSketch>, workers: usize) -> QueryServer {
        let workers = workers.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = Arc::clone(&rx);
            let sk = Arc::clone(&sketch);
            handles.push(std::thread::spawn(move || -> u64 {
                let mut served = 0u64;
                loop {
                    // hold the queue lock only for the dequeue, not the
                    // (possibly long) answer computation
                    let job = match rx.lock() {
                        Ok(guard) => guard.recv(),
                        Err(_) => break,
                    };
                    let Ok(job) = job else { break };
                    let out = sk.answer(&job.request);
                    // a caller that dropped its Pending is fine to ignore
                    let _ = job.reply.send(out);
                    served += 1;
                }
                served
            }));
        }
        QueryServer { sketch, tx, handles }
    }

    /// The served sketch.
    pub fn sketch(&self) -> &Arc<ServableSketch> {
        &self.sketch
    }

    /// Worker count.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Enqueue one request; returns immediately with a wait handle.
    pub fn submit(&self, request: QueryRequest) -> Pending {
        let (reply, rx) = sync_channel(1);
        // if every worker is gone the Pending surfaces it at wait()
        let _ = self.tx.send(Job { request, reply });
        Pending { rx }
    }

    /// Enqueue a batch; answers can be awaited in any order.
    pub fn submit_batch(&self, requests: Vec<QueryRequest>) -> Vec<Pending> {
        requests.into_iter().map(|q| self.submit(q)).collect()
    }

    /// Close the queue, join every worker, and report serving stats.
    pub fn shutdown(self) -> ServerStats {
        drop(self.tx);
        let served_per_worker: Vec<u64> = self
            .handles
            .into_iter()
            .map(|h| h.join().unwrap_or(0))
            .collect();
        ServerStats { served_per_worker }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::DistributionKind;
    use crate::sketch::{sketch_offline, SketchPlan};
    use crate::sparse::Coo;
    use crate::util::rng::Rng;

    fn servable() -> ServableSketch {
        let mut rng = Rng::new(11);
        let mut coo = Coo::new(10, 64);
        for i in 0..10u32 {
            for _ in 0..12 {
                coo.push(i, rng.usize_below(64) as u32, rng.normal() as f32 + 1.5);
            }
        }
        let a = coo.to_csr();
        let sk =
            sketch_offline(&a, &SketchPlan::new(DistributionKind::Bernstein, 400)).unwrap();
        ServableSketch::from_sketch(&sk).unwrap()
    }

    #[test]
    fn concurrent_answers_match_direct_answers() {
        let sk = Arc::new(servable());
        let (m, n) = sk.shape();
        let server = QueryServer::start(Arc::clone(&sk), 4);
        assert_eq!(server.workers(), 4);

        let mut rng = Rng::new(5);
        let requests: Vec<QueryRequest> = (0..24usize)
            .map(|i| match i % 5 {
                0 => QueryRequest::Matvec((0..n).map(|_| rng.normal()).collect()),
                1 => QueryRequest::MatvecT((0..m).map(|_| rng.normal()).collect()),
                2 => QueryRequest::MatvecBatch(
                    (0..3).map(|_| (0..n).map(|_| rng.normal()).collect()).collect(),
                ),
                3 => QueryRequest::Row((i % m) as u32),
                _ => QueryRequest::TopK(5),
            })
            .collect();
        let pending = server.submit_batch(requests.clone());
        for (q, p) in requests.iter().zip(pending) {
            let got = p.wait().unwrap();
            let want = sk.answer(q).unwrap();
            assert_eq!(got, want);
        }
        let stats = server.shutdown();
        assert_eq!(stats.total(), 24);
        assert_eq!(stats.served_per_worker.len(), 4);
    }

    #[test]
    fn batched_matvec_answer_matches_independent_answers() {
        let sk = servable();
        let (_, n) = sk.shape();
        let mut rng = Rng::new(9);
        let xs: Vec<Vec<f64>> =
            (0..4).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let QueryResponse::Vectors(ys) =
            sk.answer(&QueryRequest::MatvecBatch(xs.clone())).unwrap()
        else {
            panic!("batch answer is not Vectors");
        };
        for (x, y) in xs.into_iter().zip(ys) {
            assert_eq!(
                sk.answer(&QueryRequest::Matvec(x)).unwrap(),
                QueryResponse::Vector(y)
            );
        }
    }

    #[test]
    fn bad_query_surfaces_as_error_not_poison() {
        let sk = Arc::new(servable());
        let server = QueryServer::start(Arc::clone(&sk), 2);
        // wrong-length x: the error comes back on the reply channel and
        // the server keeps serving afterwards
        assert!(server.submit(QueryRequest::Matvec(vec![1.0; 3])).wait().is_err());
        let ok = server.submit(QueryRequest::TopK(3)).wait().unwrap();
        match ok {
            QueryResponse::Entries(es) => assert_eq!(es.len(), 3),
            other => panic!("unexpected outcome {other:?}"),
        }
        server.shutdown();
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let sk = Arc::new(servable());
        let server = QueryServer::start(sk, 0);
        assert_eq!(server.workers(), 1);
        server.submit(QueryRequest::TopK(1)).wait().unwrap();
        assert_eq!(server.shutdown().total(), 1);
    }
}
