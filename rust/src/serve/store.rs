//! The persistent sketch store: a versioned on-disk container around
//! [`EncodedSketch`], keyed by `(dataset, distribution, budget s, seed)`.
//!
//! ## File format (version 2; version-1 files remain readable)
//!
//! Everything is written MSB-first through [`crate::sketch::bitio`]; every
//! header field is a whole number of bytes, so the payload starts
//! byte-aligned:
//!
//! | field          | size     | contents                                  |
//! |----------------|----------|-------------------------------------------|
//! | magic          | 4 B      | `"MSKS"`                                  |
//! | version        | 2 B      | format version (currently 2)              |
//! | flags          | 2 B      | bit 0: compact payload form; bit 1: a     |
//! |                |          | per-row offset index follows the payload  |
//! | dataset length | 2 B      | byte length of the dataset label          |
//! | dataset        | ≤64 KiB  | dataset label (UTF-8)                     |
//! | method length  | 2 B      | byte length of the method name            |
//! | method         | ≤64 KiB  | distribution name (UTF-8)                 |
//! | m              | 4 B      | rows                                      |
//! | n              | 4 B      | columns                                   |
//! | s              | 8 B      | sample budget                             |
//! | seed           | 8 B      | RNG seed of the sketching run             |
//! | fingerprint    | 8 B      | FNV-1a 64 of the *input matrix* entry     |
//! |                |          | stream (0 = unknown); v2 only             |
//! | header bits    | 8 B      | payload codec header size in bits         |
//! | body bits      | 8 B      | payload codec body size in bits           |
//! | payload bytes  | 8 B      | payload length in bytes                   |
//! | index bytes    | 8 B      | row-index section length (0 = none); v2   |
//! | checksum       | 8 B      | FNV-1a 64 over header + payload + index   |
//! | payload        | variable | the [`EncodedSketch`] bit stream          |
//! | row index      | variable | entry count (4 B), then per occupied row  |
//! |                |          | its id (4 B) + payload bit offset (8 B)   |
//!
//! The **fingerprint** ties a store entry to the exact input matrix it was
//! sketched from: a cache lookup whose key carries a different (non-zero)
//! fingerprint is *stale* — the input regenerated under the same label —
//! and reads back as a miss so callers rebuild, instead of relying on
//! mtime + shape heuristics alone. The **row index** (flags bit 1) gives
//! [`crate::sketch::SketchCursor::row_group_at`] an O(1) seek to any
//! row's entries on the compressed path.
//!
//! The checksum covers every byte before it *and* the payload and index,
//! so a flipped bit in any header field (identity, shape, budget, flags)
//! is caught, not just payload damage. The container records the *full*
//! [`StoreKey`] identity — dataset, method, `s`, seed, fingerprint — and
//! [`SketchStore::get`] validates it against the requested key, so even a
//! file-name collision (two labels sanitizing to the same name) is
//! detected at read time instead of silently serving the wrong sketch.
//!
//! A reader rejects bad magic, unknown versions, any size mismatch between
//! the declared and actual payload (truncated *or* padded files), and
//! checksum mismatches — a stored sketch either round-trips bit-identically
//! or fails loudly, never silently serves corrupt data.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::sketch::bitio::{BitReader, BitWriter};
use crate::sketch::{encode_sketch, row_group_index, EncodedSketch, Sketch};
use crate::sparse::Entry;
use crate::util::SharedBytes;

/// File magic: "MSKS" (matsketch sketch store).
pub const STORE_MAGIC: [u8; 4] = *b"MSKS";

/// Current container format version.
pub const STORE_VERSION: u16 = 2;

/// Oldest container version still readable.
pub const STORE_VERSION_MIN: u16 = 1;

/// Flags bit 0: the payload uses the compact (row-scale) form.
pub const FLAG_COMPACT: u16 = 1;

/// Flags bit 1: a per-row offset index follows the payload.
pub const FLAG_ROW_INDEX: u16 = 1 << 1;

/// Extension used for store files.
pub const STORE_EXT: &str = "msk";

/// FNV-1a 64-bit initial state.
const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;

/// Fold `bytes` into a running FNV-1a 64 state (chainable across
/// non-contiguous regions, e.g. header then payload).
pub fn fnv1a64_extend(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// FNV-1a 64-bit checksum (dependency-free, stable across platforms).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_extend(FNV_OFFSET, bytes)
}

/// Incremental FNV-1a 64 over a stream of matrix entries — the content
/// fingerprint recorded in [`StoreKey`] / the `.msk` header. Entries hash
/// as `(row, col, value-bits)` big-endian, so the fingerprint is stable
/// across platforms and entry-stream implementations; it is
/// order-sensitive, matching the deterministic order of dataset
/// generators and triplet files. `finish` never returns 0 (the "unknown"
/// sentinel).
#[derive(Clone, Debug)]
pub struct Fingerprinter {
    h: u64,
}

impl Default for Fingerprinter {
    fn default() -> Self {
        Self::new()
    }
}

impl Fingerprinter {
    /// Fresh accumulator.
    pub fn new() -> Fingerprinter {
        Fingerprinter { h: FNV_OFFSET }
    }

    /// Fold one entry into the fingerprint (12 bytes: row, col, val
    /// bits, each big-endian — extended in field order, so the digest
    /// matches hashing the concatenated buffer).
    pub fn push(&mut self, e: &Entry) {
        let h = fnv1a64_extend(self.h, &e.row.to_be_bytes());
        let h = fnv1a64_extend(h, &e.col.to_be_bytes());
        self.h = fnv1a64_extend(h, &e.val.to_bits().to_be_bytes());
    }

    /// The fingerprint; remapped away from the 0 sentinel.
    pub fn finish(&self) -> u64 {
        if self.h == 0 {
            1
        } else {
            self.h
        }
    }
}

/// Fingerprint of an in-memory COO matrix (its entry list in order).
pub fn coo_fingerprint(coo: &crate::sparse::Coo) -> u64 {
    let mut fp = Fingerprinter::new();
    for e in &coo.entries {
        fp.push(e);
    }
    fp.finish()
}

/// Identity of a stored sketch: the inputs that make a sketching run
/// reproducible. Two runs with equal keys produce statistically identical
/// sketches, so the store can serve the cached one.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreKey {
    /// Dataset label (e.g. a [`crate::datasets::DatasetId`] name or an
    /// input file stem).
    pub dataset: String,
    /// Distribution name ([`crate::distributions::DistributionKind::name`]).
    pub method: String,
    /// Sample budget `s`.
    pub s: u64,
    /// RNG seed of the sketching run.
    pub seed: u64,
    /// Content fingerprint of the input matrix ([`Fingerprinter`]);
    /// 0 = unknown. Not part of the file name — a fingerprint change under
    /// the same label means the cached entry is *stale*, not distinct.
    pub fingerprint: u64,
}

impl StoreKey {
    /// Build a key with an unknown (unchecked) input fingerprint.
    pub fn new(dataset: &str, method: &str, s: u64, seed: u64) -> StoreKey {
        StoreKey {
            dataset: dataset.to_string(),
            method: method.to_string(),
            s,
            seed,
            fingerprint: 0,
        }
    }

    /// Attach the input matrix's content fingerprint.
    pub fn with_fingerprint(mut self, fingerprint: u64) -> StoreKey {
        self.fingerprint = fingerprint;
        self
    }

    /// Whether two keys name the same sketch identity (dataset, method,
    /// `s`, seed) — the fields the file name is derived from. Fingerprints
    /// are deliberately excluded: a mismatch there means *stale*, which
    /// [`SketchStore::get`] turns into a rebuild, not a collision error.
    pub fn same_identity(&self, other: &StoreKey) -> bool {
        self.dataset == other.dataset
            && self.method == other.method
            && self.s == other.s
            && self.seed == other.seed
    }

    /// Deterministic file name: sanitized components joined with `__`.
    pub fn file_name(&self) -> String {
        format!(
            "{}__{}__s{}__seed{}.{STORE_EXT}",
            sanitize(&self.dataset),
            sanitize(&self.method),
            self.s,
            self.seed
        )
    }
}

/// Lower-case a label and replace every non-alphanumeric run with one `-`
/// so method names like `"L2 trim 0.1"` become safe file-name components.
fn sanitize(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut pending_dash = false;
    for c in s.chars() {
        if c.is_ascii_alphanumeric() {
            if pending_dash && !out.is_empty() {
                out.push('-');
            }
            pending_dash = false;
            out.push(c.to_ascii_lowercase());
        } else {
            pending_dash = true;
        }
    }
    if out.is_empty() {
        out.push('x');
    }
    out
}

/// A sketch read back from the store, with the identity recorded at
/// write time.
#[derive(Clone, Debug)]
pub struct StoredSketch {
    /// The encoded payload, bit-identical to what was written.
    pub enc: EncodedSketch,
    /// Dataset label recorded at write time.
    pub dataset: String,
    /// Distribution name recorded at write time.
    pub method: String,
    /// Sketching seed recorded at write time.
    pub seed: u64,
    /// Input-matrix content fingerprint recorded at write time (0 for
    /// version-1 files, which predate fingerprints).
    pub fingerprint: u64,
    /// Per-row `(row id, payload bit offset)` seek index, ascending in
    /// row id (absent for version-1 files).
    pub row_index: Option<Vec<(u32, u64)>>,
}

impl StoredSketch {
    /// The key this entry was written under.
    pub fn key(&self) -> StoreKey {
        StoreKey::new(&self.dataset, &self.method, self.enc.s, self.seed)
            .with_fingerprint(self.fingerprint)
    }
}

fn put_str(w: &mut BitWriter, label: &str, what: &str) -> Result<()> {
    let bytes = label.as_bytes();
    if bytes.len() > u16::MAX as usize {
        return Err(Error::invalid(format!("{what} longer than 64 KiB")));
    }
    w.put_bits(bytes.len() as u64, 16);
    for &b in bytes {
        w.put_bits(b as u64, 8);
    }
    Ok(())
}

/// Serialize an encoded sketch plus its [`StoreKey`] identity into the
/// container format (version 2: fingerprint field + per-row seek index).
pub fn encode_container(enc: &EncodedSketch, key: &StoreKey) -> Result<Vec<u8>> {
    if enc.m > u32::MAX as usize || enc.n > u32::MAX as usize {
        return Err(Error::invalid("sketch dimensions exceed u32"));
    }
    // one payload walk up front: the row-group seek index
    let index = row_group_index(enc)?;
    let index_bytes = {
        let mut iw = BitWriter::new();
        iw.put_bits(index.len() as u64, 32);
        for &(row, off) in &index {
            iw.put_bits(row as u64, 32);
            iw.put_bits(off, 64);
        }
        iw.finish()
    };

    let mut w = BitWriter::new();
    for b in STORE_MAGIC {
        w.put_bits(b as u64, 8);
    }
    w.put_bits(STORE_VERSION as u64, 16);
    let mut flags: u16 = if enc.compact { FLAG_COMPACT } else { 0 };
    flags |= FLAG_ROW_INDEX;
    w.put_bits(flags as u64, 16);
    put_str(&mut w, &key.dataset, "dataset label")?;
    put_str(&mut w, &key.method, "method name")?;
    w.put_bits(enc.m as u64, 32);
    w.put_bits(enc.n as u64, 32);
    w.put_bits(enc.s, 64);
    w.put_bits(key.seed, 64);
    w.put_bits(key.fingerprint, 64);
    w.put_bits(enc.header_bits as u64, 64);
    w.put_bits(enc.body_bits as u64, 64);
    w.put_bits(enc.bytes.len() as u64, 64);
    w.put_bits(index_bytes.len() as u64, 64);
    let mut out = w.finish();
    // checksum covers every header byte so far plus the payload and index
    let sum = fnv1a64_extend(fnv1a64_extend(fnv1a64(&out), &enc.bytes), &index_bytes);
    out.extend_from_slice(&sum.to_be_bytes());
    out.extend_from_slice(&enc.bytes);
    out.extend_from_slice(&index_bytes);
    Ok(out)
}

/// Every container-header field, plus where the header ends — shared by
/// the full reader ([`decode_container`]) and the header-only one
/// ([`read_header`]).
struct RawHeader {
    dataset: String,
    method: String,
    m: usize,
    n: usize,
    s: u64,
    seed: u64,
    fingerprint: u64,
    header_bits: usize,
    body_bits: usize,
    payload_len: usize,
    index_len: usize,
    checksum: u64,
    compact: bool,
    has_index: bool,
    /// Byte length of the header (fields through the checksum).
    header_bytes: usize,
}

/// Parse the container header (magic through checksum) from the front of
/// `data`; `data` may be just a file prefix.
fn parse_container_header(data: &[u8]) -> Result<RawHeader> {
    let err = |what: &str| Error::Parse(format!("sketch store: {what}"));
    let mut r = BitReader::new(data);
    for want in STORE_MAGIC {
        let got = r.get_bits(8).ok_or_else(|| err("truncated header"))?;
        if got != want as u64 {
            return Err(err("bad magic (not a sketch store file)"));
        }
    }
    let version = r.get_bits(16).ok_or_else(|| err("truncated header"))?;
    if !(STORE_VERSION_MIN as u64..=STORE_VERSION as u64).contains(&version) {
        return Err(Error::Parse(format!(
            "sketch store: unsupported version {version} \
             (expected {STORE_VERSION_MIN}..={STORE_VERSION})"
        )));
    }
    let flags = r.get_bits(16).ok_or_else(|| err("truncated header"))?;
    let compact = flags & FLAG_COMPACT as u64 != 0;
    let has_index = version >= 2 && flags & FLAG_ROW_INDEX as u64 != 0;
    let dataset = get_str(&mut r, "dataset label")?;
    let method = get_str(&mut r, "method name")?;
    let m = r.get_bits(32).ok_or_else(|| err("truncated header"))? as usize;
    let n = r.get_bits(32).ok_or_else(|| err("truncated header"))? as usize;
    let s = r.get_bits(64).ok_or_else(|| err("truncated header"))?;
    let seed = r.get_bits(64).ok_or_else(|| err("truncated header"))?;
    let fingerprint = if version >= 2 {
        r.get_bits(64).ok_or_else(|| err("truncated header"))?
    } else {
        0
    };
    let header_bits = r.get_bits(64).ok_or_else(|| err("truncated header"))? as usize;
    let body_bits = r.get_bits(64).ok_or_else(|| err("truncated header"))? as usize;
    let payload_len = r.get_bits(64).ok_or_else(|| err("truncated header"))? as usize;
    let index_len = if version >= 2 {
        r.get_bits(64).ok_or_else(|| err("truncated header"))? as usize
    } else {
        0
    };
    let checksum = r.get_bits(64).ok_or_else(|| err("truncated header"))?;
    debug_assert_eq!(r.bit_pos() % 8, 0, "store header must stay byte-aligned");
    Ok(RawHeader {
        dataset,
        method,
        m,
        n,
        s,
        seed,
        fingerprint,
        header_bits,
        body_bits,
        payload_len,
        index_len,
        checksum,
        compact,
        has_index,
        header_bytes: r.bit_pos() / 8,
    })
}

/// Parse a store container back into its encoded sketch. Reads container
/// versions 1 (no fingerprint / row index) and 2. Rejects bad magic,
/// unknown versions, truncated or padded files, and checksum mismatches.
///
/// Copies the payload into a fresh buffer; [`decode_container_shared`]
/// is the zero-copy form the store's read path uses.
pub fn decode_container(data: &[u8]) -> Result<StoredSketch> {
    decode_container_shared(&SharedBytes::from(data))
}

/// [`decode_container`] over a shared buffer: the returned sketch's
/// payload is an O(1) [`SharedBytes::slice`] of `data` — no copy, so a
/// loaded (or memory-mapped) `.msk` file is aliased by every clone of
/// the servable sketch instead of being duplicated per open.
pub fn decode_container_shared(data: &SharedBytes) -> Result<StoredSketch> {
    let err = |what: &str| Error::Parse(format!("sketch store: {what}"));
    let h = parse_container_header(data)?;
    let declared = h
        .payload_len
        .checked_add(h.index_len)
        .ok_or_else(|| err("declared section lengths overflow"))?;
    let actual = data.len().saturating_sub(h.header_bytes);
    if actual < declared {
        return Err(err("truncated payload"));
    }
    if actual > declared {
        return Err(err("trailing bytes after payload"));
    }
    let payload = data.slice(h.header_bytes..h.header_bytes + h.payload_len);
    let index_bytes = data
        .get(h.header_bytes + h.payload_len..)
        .ok_or_else(|| err("truncated index section"))?;
    // the stored sum covers all header bytes before the checksum field
    // plus the payload and (v2) the index section
    let covered = h
        .header_bytes
        .checked_sub(8)
        .and_then(|n| data.get(..n))
        .ok_or_else(|| err("header too short for checksum"))?;
    let got_sum = fnv1a64_extend(fnv1a64_extend(fnv1a64(covered), &payload), index_bytes);
    if got_sum != h.checksum {
        return Err(Error::Parse(format!(
            "sketch store: checksum mismatch (stored {:#018x}, computed {got_sum:#018x})",
            h.checksum
        )));
    }
    let row_index = if h.has_index {
        Some(parse_row_index(index_bytes, h.payload_len, h.m)?)
    } else {
        None
    };
    Ok(StoredSketch {
        enc: EncodedSketch {
            m: h.m,
            n: h.n,
            s: h.s,
            header_bits: h.header_bits,
            body_bits: h.body_bits,
            bytes: payload,
            compact: h.compact,
        },
        dataset: h.dataset,
        method: h.method,
        seed: h.seed,
        fingerprint: h.fingerprint,
        row_index,
    })
}

/// Identity + shape of a store entry, read from its header alone.
#[derive(Clone, Debug)]
pub struct StoreEntryInfo {
    /// Dataset label recorded at write time.
    pub dataset: String,
    /// Distribution name recorded at write time.
    pub method: String,
    /// Sample budget.
    pub s: u64,
    /// Sketching seed.
    pub seed: u64,
    /// Input content fingerprint (0 for v1 entries).
    pub fingerprint: u64,
    /// Rows.
    pub m: usize,
    /// Columns.
    pub n: usize,
    /// Whether the payload uses the compact row-scale form.
    pub compact: bool,
}

/// Largest possible container header: fixed fields plus two 64 KiB
/// labels.
const MAX_HEADER_BYTES: usize = 4 + 2 + 2 + 2 * (2 + u16::MAX as usize) + 4 + 4 + 8 * 8;

/// Read one entry's identity + shape from its header alone — no payload
/// I/O, allocation, or checksumming, so listing a store of multi-GB
/// entries stays O(header bytes) per file. Serving still goes through
/// the fully validated [`read_encoded`] path.
pub fn read_header(path: &Path) -> Result<StoreEntryInfo> {
    use std::io::Read;
    let mut prefix = Vec::new();
    fs::File::open(path)?
        .take(MAX_HEADER_BYTES as u64)
        .read_to_end(&mut prefix)?;
    let h = parse_container_header(&prefix)?;
    Ok(StoreEntryInfo {
        dataset: h.dataset,
        method: h.method,
        s: h.s,
        seed: h.seed,
        fingerprint: h.fingerprint,
        m: h.m,
        n: h.n,
        compact: h.compact,
    })
}

/// Parse the row-index section: entry count, then ascending
/// `(row, bit offset)` pairs pointing into the payload.
fn parse_row_index(bytes: &[u8], payload_len: usize, m: usize) -> Result<Vec<(u32, u64)>> {
    let err = |what: &str| Error::Parse(format!("sketch store: row index: {what}"));
    let mut r = BitReader::new(bytes);
    let count = r.get_bits(32).ok_or_else(|| err("truncated"))? as usize;
    if bytes.len() != 4 + count * 12 {
        return Err(err("length disagrees with entry count"));
    }
    let payload_bits = (payload_len as u64).saturating_mul(8);
    let mut out = Vec::with_capacity(count);
    let mut prev_row: Option<u32> = None;
    for _ in 0..count {
        let row = r.get_bits(32).ok_or_else(|| err("truncated"))? as u32;
        let off = r.get_bits(64).ok_or_else(|| err("truncated"))?;
        if row as usize >= m {
            return Err(err("row id outside the sketch"));
        }
        if prev_row.is_some_and(|p| p >= row) {
            return Err(err("row ids not strictly ascending"));
        }
        if off >= payload_bits {
            return Err(err("bit offset outside the payload"));
        }
        prev_row = Some(row);
        out.push((row, off));
    }
    Ok(out)
}

fn get_str(r: &mut BitReader<'_>, what: &str) -> Result<String> {
    let err = |msg: String| Error::Parse(format!("sketch store: {msg}"));
    let len = r
        .get_bits(16)
        .ok_or_else(|| err("truncated header".into()))? as usize;
    let mut bytes = Vec::with_capacity(len);
    for _ in 0..len {
        bytes.push(r.get_bits(8).ok_or_else(|| err("truncated header".into()))? as u8);
    }
    String::from_utf8(bytes).map_err(|_| err(format!("{what} is not valid UTF-8")))
}

/// Write one encoded sketch to `path` in the container format,
/// atomically: a writer-unique sibling temp file is written, fsync'd,
/// then renamed over the target, and the parent directory is fsync'd
/// so the rename itself survives a crash. A crash (or an injected
/// chaos fault, [`crate::net::chaos::install_store_fault`]) at *any*
/// byte offset leaves the store entry either old or new, never torn —
/// the kill-at-every-offset test below walks the whole file proving
/// it. An interrupted write's orphaned temp is deliberately left
/// behind for the [`SketchStore::open`] startup sweep.
pub fn write_encoded(path: &Path, enc: &EncodedSketch, key: &StoreKey) -> Result<()> {
    use std::io::Write as _;
    use std::sync::atomic::{AtomicU64, Ordering};
    static WRITE_SEQ: AtomicU64 = AtomicU64::new(0);
    let data = encode_container(enc, key)?;
    let seq = WRITE_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = path.with_extension(format!(
        "{STORE_EXT}.tmp-{}-{seq}",
        std::process::id()
    ));
    if let Some(cap) = crate::net::chaos::store_write_cap(data.len() as u64) {
        // an injected crash: put exactly `cap` bytes in the temp file,
        // leave it orphaned, and fail the write with the same error
        // kind a dying disk would produce
        let mut f = fs::File::create(&tmp)?;
        let head = data.get(..cap as usize).unwrap_or(&data);
        f.write_all(head)?;
        f.sync_all()?;
        return Err(Error::Io(io::Error::other(format!(
            "chaos: store write killed at byte {cap} of {}",
            data.len()
        ))));
    }
    let mut f = fs::File::create(&tmp)?;
    f.write_all(&data)?;
    // data must be durable before the rename can make it visible
    f.sync_all()?;
    drop(f);
    fs::rename(&tmp, path)?;
    // best-effort directory fsync: makes the rename durable; some
    // filesystems refuse to sync a directory handle, which is not a
    // reason to fail a write that is already atomic
    if let Some(parent) = path.parent() {
        if let Ok(d) = fs::File::open(parent) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Read one encoded sketch back from `path`. The payload of the result
/// aliases one shared load of the file (memory-mapped when built with
/// the `mmap` feature, a single buffered read otherwise) — opening a
/// sketch never copies its payload again after the load.
pub fn read_encoded(path: &Path) -> Result<StoredSketch> {
    crate::obs::global().inc(crate::obs::Counter::StoreLoad);
    decode_container_shared(&load_container_bytes(path)?)
}

/// Load a `.msk` file into one shared buffer: zero-copy `mmap` when the
/// feature is enabled (falling back to a read if the map fails, e.g. on
/// an empty file or an mmap-less filesystem), a plain buffered read
/// into a single shared allocation otherwise.
fn load_container_bytes(path: &Path) -> Result<SharedBytes> {
    #[cfg(all(feature = "mmap", target_family = "unix", target_pointer_width = "64"))]
    {
        if let Ok(file) = fs::File::open(path) {
            if let Ok(map) = crate::util::bytes::mmap::map_readonly(&file) {
                return Ok(SharedBytes::from_owner(map));
            }
        }
    }
    Ok(SharedBytes::from(fs::read(path)?))
}

/// A directory of stored sketches, one file per [`StoreKey`].
#[derive(Clone, Debug)]
pub struct SketchStore {
    dir: PathBuf,
}

impl SketchStore {
    /// Open (creating if necessary) a store rooted at `dir`, sweeping
    /// out any `*.msk.tmp-*` temp files a crashed writer left behind.
    /// The sweep is safe against live writers in *this* process — a
    /// write holds its temp only between create and rename, and the
    /// store is opened before serving starts — and temp names embed
    /// the writer's pid, so a crashed writer's orphans are exactly the
    /// files no one will ever rename.
    pub fn open(dir: impl Into<PathBuf>) -> Result<SketchStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        let mut swept = 0u64;
        for de in fs::read_dir(&dir)? {
            let p = de?.path();
            let is_tmp = p
                .extension()
                .and_then(|e| e.to_str())
                .is_some_and(|e| e.starts_with("tmp-"));
            if is_tmp && fs::remove_file(&p).is_ok() {
                swept += 1;
            }
        }
        if swept > 0 {
            crate::obs::global().add(crate::obs::Counter::StoreTmpSwept, swept);
            crate::info!("sketch store: swept {swept} orphaned temp file(s) from {}", dir.display());
        }
        Ok(SketchStore { dir })
    }

    /// Store root.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// File path a key maps to.
    pub fn path_for(&self, key: &StoreKey) -> PathBuf {
        self.dir.join(key.file_name())
    }

    /// Whether a sketch for `key` is present (without validating it).
    pub fn contains(&self, key: &StoreKey) -> bool {
        self.path_for(key).is_file()
    }

    /// Persist an encoded sketch under `key`; returns the file path.
    pub fn put(&self, key: &StoreKey, enc: &EncodedSketch) -> Result<PathBuf> {
        let path = self.path_for(key);
        write_encoded(&path, enc, key)?;
        Ok(path)
    }

    /// Load the sketch stored under `key`. `Ok(None)` when absent **or
    /// stale** (both the key and the entry carry non-zero input
    /// fingerprints and they disagree — the input matrix changed under
    /// the same label, so callers should rebuild and overwrite); `Err`
    /// when present but corrupt or recorded under a *different* identity
    /// — two labels can sanitize to the same file name, and serving the
    /// wrong sketch silently is never acceptable.
    pub fn get(&self, key: &StoreKey) -> Result<Option<StoredSketch>> {
        let path = self.path_for(key);
        if !path.is_file() {
            return Ok(None);
        }
        let stored = read_encoded(&path)?;
        let recorded = stored.key();
        if !recorded.same_identity(key) {
            return Err(Error::Parse(format!(
                "sketch store: {} holds ({}, {}, s={}, seed={}) but ({}, {}, s={}, seed={}) \
                 was requested (file-name collision?)",
                path.display(),
                recorded.dataset,
                recorded.method,
                recorded.s,
                recorded.seed,
                key.dataset,
                key.method,
                key.s,
                key.seed,
            )));
        }
        if key.fingerprint != 0
            && recorded.fingerprint != 0
            && key.fingerprint != recorded.fingerprint
        {
            crate::info!(
                "sketch store: {} is stale (input fingerprint {:#018x} != stored {:#018x}); \
                 treating as a miss",
                path.display(),
                key.fingerprint,
                recorded.fingerprint
            );
            return Ok(None);
        }
        Ok(Some(stored))
    }

    /// Cache lookup with build-on-miss: returns the encoded sketch and
    /// whether it came from the store (`true`) or was freshly built and
    /// persisted (`false`). This is what lets repeated CLI / eval runs at
    /// the same `(dataset, method, s, seed)` skip re-sketching entirely.
    pub fn get_or_build(
        &self,
        key: &StoreKey,
        build: impl FnOnce() -> Result<Sketch>,
    ) -> Result<(EncodedSketch, bool)> {
        if let Some(stored) = self.get(key)? {
            return Ok((stored.enc, true));
        }
        let sketch = build()?;
        let enc = encode_sketch(&sketch)?;
        self.put(key, &enc)?;
        Ok((enc, false))
    }

    /// Keys' file names currently present (for listing / debugging).
    pub fn entries(&self) -> Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        for de in fs::read_dir(&self.dir)? {
            let p = de?.path();
            if p.extension().and_then(|e| e.to_str()) == Some(STORE_EXT) {
                out.push(p);
            }
        }
        out.sort();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::DistributionKind;
    use crate::sketch::{decode_sketch, sketch_offline, SketchPlan};
    use crate::sparse::Coo;
    use crate::util::rng::Rng;

    fn tmp_store(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("matsketch_store_{tag}_{}", std::process::id()))
    }

    fn toy_encoded(kind: DistributionKind, seed: u64) -> (EncodedSketch, String) {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(16, 256);
        for i in 0..16u32 {
            for _ in 0..20 {
                coo.push(i, rng.usize_below(256) as u32, rng.normal() as f32 + 0.5);
            }
        }
        let a = coo.to_csr();
        let sk = sketch_offline(&a, &SketchPlan::new(kind, 800).with_seed(seed)).unwrap();
        (encode_sketch(&sk).unwrap(), sk.method)
    }

    #[test]
    fn container_roundtrip_bit_identical() {
        for kind in [DistributionKind::Bernstein, DistributionKind::L2] {
            let (enc, method) = toy_encoded(kind, 3);
            let key = StoreKey::new("toy", &method, enc.s, 3).with_fingerprint(0xF00D);
            let data = encode_container(&enc, &key).unwrap();
            let back = decode_container(&data).unwrap();
            assert_eq!(back.enc.bytes, enc.bytes, "{method}: payload changed");
            assert_eq!(back.enc.m, enc.m);
            assert_eq!(back.enc.n, enc.n);
            assert_eq!(back.enc.s, enc.s);
            assert_eq!(back.enc.header_bits, enc.header_bits);
            assert_eq!(back.enc.body_bits, enc.body_bits);
            assert_eq!(back.enc.compact, enc.compact);
            assert_eq!(back.fingerprint, 0xF00D);
            assert_eq!(back.key(), key);
            // the appended seek index round-trips exactly
            assert_eq!(
                back.row_index.as_deref(),
                Some(row_group_index(&enc).unwrap().as_slice()),
                "{method}: row index changed"
            );
            // decoded sketches agree entry-for-entry
            let a = decode_sketch(&enc, &method).unwrap();
            let b = decode_sketch(&back.enc, &back.method).unwrap();
            assert_eq!(a.entries, b.entries);
        }
    }

    #[test]
    fn container_rejects_corruption() {
        let (enc, method) = toy_encoded(DistributionKind::Bernstein, 4);
        let key = StoreKey::new("toy", &method, enc.s, 4);
        let good = encode_container(&enc, &key).unwrap();

        // flipped payload byte (well past the header) -> checksum mismatch
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        let e = decode_container(&bad).unwrap_err().to_string();
        assert!(e.contains("checksum"), "{e}");

        // flipped header field byte (the last byte of the `s` field,
        // located from the front of the header) -> checksum mismatch too
        let s_off = 4 + 2 + 2 + (2 + "toy".len()) + (2 + method.len()) + 4 + 4;
        let mut hbad = good.clone();
        hbad[s_off + 7] ^= 0x01;
        let e = decode_container(&hbad).unwrap_err().to_string();
        assert!(e.contains("checksum"), "{e}");

        // truncated payload
        let e = decode_container(&good[..good.len() - 3]).unwrap_err().to_string();
        assert!(e.contains("truncated"), "{e}");

        // padded payload
        let mut padded = good.clone();
        padded.push(0);
        let e = decode_container(&padded).unwrap_err().to_string();
        assert!(e.contains("trailing"), "{e}");

        // bad magic
        let mut wrong = good.clone();
        wrong[0] = b'X';
        let e = decode_container(&wrong).unwrap_err().to_string();
        assert!(e.contains("magic"), "{e}");

        // unsupported version
        let mut vers = good;
        vers[5] = 0xEE;
        let e = decode_container(&vers).unwrap_err().to_string();
        assert!(e.contains("version"), "{e}");
    }

    /// Hand-build a version-1 container (no fingerprint, no index) for the
    /// given payload — the pre-PR-3 writer, kept verbatim so old store
    /// files provably stay readable.
    fn encode_container_v1(enc: &EncodedSketch, key: &StoreKey) -> Vec<u8> {
        let mut w = BitWriter::new();
        for b in STORE_MAGIC {
            w.put_bits(b as u64, 8);
        }
        w.put_bits(1, 16); // version 1
        w.put_bits(enc.compact as u64, 16);
        put_str(&mut w, &key.dataset, "dataset label").unwrap();
        put_str(&mut w, &key.method, "method name").unwrap();
        w.put_bits(enc.m as u64, 32);
        w.put_bits(enc.n as u64, 32);
        w.put_bits(enc.s, 64);
        w.put_bits(key.seed, 64);
        w.put_bits(enc.header_bits as u64, 64);
        w.put_bits(enc.body_bits as u64, 64);
        w.put_bits(enc.bytes.len() as u64, 64);
        let mut out = w.finish();
        let sum = fnv1a64_extend(fnv1a64(&out), &enc.bytes);
        out.extend_from_slice(&sum.to_be_bytes());
        out.extend_from_slice(&enc.bytes);
        out
    }

    #[test]
    fn version1_files_remain_readable() {
        let (enc, method) = toy_encoded(DistributionKind::Bernstein, 6);
        let key = StoreKey::new("legacy", &method, enc.s, 6);
        let v1 = encode_container_v1(&enc, &key);
        let back = decode_container(&v1).unwrap();
        assert_eq!(back.enc.bytes, enc.bytes);
        assert_eq!(back.fingerprint, 0, "v1 predates fingerprints");
        assert!(back.row_index.is_none(), "v1 has no seek index");
        assert_eq!(back.key(), key);
        // a v1 entry on disk serves through the store like any other
        let dir = tmp_store("v1compat");
        let _ = std::fs::remove_dir_all(&dir);
        let store = SketchStore::open(&dir).unwrap();
        std::fs::write(store.path_for(&key), &v1).unwrap();
        let got = store.get(&key).unwrap().unwrap();
        assert_eq!(got.enc.bytes, enc.bytes);
        // even when the caller now knows the input fingerprint: a stored
        // fingerprint of 0 is "unknown", not "mismatched"
        let fp_key = key.clone().with_fingerprint(0xABCD);
        assert!(store.get(&fp_key).unwrap().is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_mismatch_reads_as_stale_miss() {
        let dir = tmp_store("stale");
        let _ = std::fs::remove_dir_all(&dir);
        let store = SketchStore::open(&dir).unwrap();
        let (enc, method) = toy_encoded(DistributionKind::Bernstein, 7);
        let written = StoreKey::new("toy", &method, enc.s, 7).with_fingerprint(0x1111);
        store.put(&written, &enc).unwrap();

        // same fingerprint -> hit; unknown fingerprint -> hit
        assert!(store.get(&written).unwrap().is_some());
        let unknown = written.clone().with_fingerprint(0);
        assert!(store.get(&unknown).unwrap().is_some());

        // different fingerprint -> stale miss (not an error), and a
        // rebuild through get_or_build overwrites the stale entry
        let changed = written.clone().with_fingerprint(0x2222);
        assert!(store.get(&changed).unwrap().is_none());
        let (_, hit) = store
            .get_or_build(&changed, || {
                let mut rng = Rng::new(99);
                let mut coo = Coo::new(16, 256);
                for i in 0..16u32 {
                    for _ in 0..20 {
                        coo.push(i, rng.usize_below(256) as u32, rng.normal() as f32 + 0.5);
                    }
                }
                let a = coo.to_csr();
                sketch_offline(
                    &a,
                    &SketchPlan::new(DistributionKind::Bernstein, enc.s).with_seed(7),
                )
            })
            .unwrap();
        assert!(!hit, "stale entry must rebuild");
        assert_eq!(
            store.get(&changed).unwrap().unwrap().fingerprint,
            0x2222,
            "rebuild must overwrite the stale fingerprint"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_header_reads_identity_without_payload_validation() {
        let dir = tmp_store("hdr");
        let _ = std::fs::remove_dir_all(&dir);
        let store = SketchStore::open(&dir).unwrap();
        let (enc, method) = toy_encoded(DistributionKind::Bernstein, 8);
        let key = StoreKey::new("toy", &method, enc.s, 8).with_fingerprint(0xFEED);
        let path = store.put(&key, &enc).unwrap();
        let info = read_header(&path).unwrap();
        assert_eq!(info.dataset, "toy");
        assert_eq!(info.method, method);
        assert_eq!((info.m, info.n, info.s), (enc.m, enc.n, enc.s));
        assert_eq!(info.fingerprint, 0xFEED);
        assert_eq!(info.compact, enc.compact);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprinter_is_order_sensitive_and_stable() {
        let a = Entry { row: 1, col: 2, val: 3.5 };
        let b = Entry { row: 2, col: 1, val: 3.5 };
        let fp = |es: &[Entry]| {
            let mut f = Fingerprinter::new();
            for e in es {
                f.push(e);
            }
            f.finish()
        };
        assert_eq!(fp(&[a, b]), fp(&[a, b]));
        assert_ne!(fp(&[a, b]), fp(&[b, a]));
        assert_ne!(fp(&[a]), fp(&[a, b]));
        assert_ne!(fp(&[a]), 0, "0 is reserved for unknown");
    }

    #[test]
    fn store_put_get_and_cache() {
        let dir = tmp_store("putget");
        let _ = std::fs::remove_dir_all(&dir);
        let store = SketchStore::open(&dir).unwrap();
        let key = StoreKey::new("toy", "Bernstein", 800, 3);
        assert!(!store.contains(&key));
        assert!(store.get(&key).unwrap().is_none());

        let (enc, _) = toy_encoded(DistributionKind::Bernstein, 3);
        store.put(&key, &enc).unwrap();
        assert!(store.contains(&key));
        let back = store.get(&key).unwrap().unwrap();
        assert_eq!(back.enc.bytes, enc.bytes);
        assert_eq!(back.dataset, "toy");
        assert_eq!(back.method, "Bernstein");
        assert_eq!(back.seed, 3);
        assert_eq!(store.entries().unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn file_name_collision_is_detected_not_served() {
        // "Data.v2" and "data-v2" sanitize to the same file name; the
        // recorded identity must reject the mismatched read.
        let dir = tmp_store("collision");
        let _ = std::fs::remove_dir_all(&dir);
        let store = SketchStore::open(&dir).unwrap();
        let (enc, _) = toy_encoded(DistributionKind::Bernstein, 5);
        let written = StoreKey::new("Data.v2", "Bernstein", enc.s, 5);
        let requested = StoreKey::new("data-v2", "Bernstein", enc.s, 5);
        assert_eq!(written.file_name(), requested.file_name());
        store.put(&written, &enc).unwrap();
        assert!(store.get(&written).unwrap().is_some());
        let e = store.get(&requested).unwrap_err().to_string();
        assert!(e.contains("collision"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_file_names_are_sanitized_and_distinct() {
        let a = StoreKey::new("enron", "L2 trim 0.1", 1000, 0);
        let b = StoreKey::new("enron", "L2 trim 0.01", 1000, 0);
        assert_eq!(a.file_name(), "enron__l2-trim-0-1__s1000__seed0.msk");
        assert_ne!(a.file_name(), b.file_name());
        // different budgets / seeds also separate
        assert_ne!(
            StoreKey::new("x", "L1", 10, 0).file_name(),
            StoreKey::new("x", "L1", 10, 1).file_name()
        );
    }

    #[test]
    fn fnv_vectors() {
        // standard FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xAF63_DC4C_8601_EC8C);
    }

    #[test]
    fn kill_at_every_offset_leaves_old_or_new_never_corrupt() {
        use crate::net::chaos::{
            clear_store_fault, install_store_fault, StoreFault, STORE_FAULT_TEST_LOCK,
        };
        let _guard = STORE_FAULT_TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        clear_store_fault();

        let dir = tmp_store("killat");
        let _ = std::fs::remove_dir_all(&dir);
        let store = SketchStore::open(&dir).unwrap();
        // a deliberately tiny sketch keeps the container a few hundred
        // bytes, so walking literally every byte offset stays fast
        let tiny = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut coo = Coo::new(4, 32);
            for i in 0..4u32 {
                for _ in 0..4 {
                    coo.push(i, rng.usize_below(32) as u32, rng.normal() as f32 + 0.5);
                }
            }
            let a = coo.to_csr();
            let sk = sketch_offline(
                &a,
                &SketchPlan::new(DistributionKind::Bernstein, 40).with_seed(seed),
            )
            .unwrap();
            (encode_sketch(&sk).unwrap(), sk.method)
        };
        let (old_enc, method) = tiny(11);
        let (new_enc, _) = tiny(12);
        let key = StoreKey::new("durable", &method, old_enc.s, 11);
        store.put(&key, &old_enc).unwrap();
        let len = encode_container(&new_enc, &key).unwrap().len();

        let offsets: Vec<u64> = (0..len as u64).collect();
        for &offset in &offsets {
            install_store_fault(StoreFault::KillAt(offset));
            let err = store.put(&key, &new_enc).unwrap_err();
            assert!(
                err.to_string().contains("chaos"),
                "offset {offset}: write must fail with the injected error, got {err}"
            );
            // the interrupted write must be invisible: the old sketch
            // still reads back bit-identically
            let back = store.get(&key).unwrap().expect("old entry must survive");
            assert_eq!(back.enc.bytes, old_enc.bytes, "offset {offset}: old entry torn");
        }
        clear_store_fault();

        // the orphaned temps are invisible to entries() ...
        assert_eq!(store.entries().unwrap().len(), 1);
        let orphans = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|de| {
                de.as_ref().unwrap().path().extension().and_then(|e| e.to_str())
                    != Some(STORE_EXT)
            })
            .count();
        assert_eq!(orphans, offsets.len(), "each killed write leaves one temp");

        // ... and a fresh open sweeps them all
        let store = SketchStore::open(&dir).unwrap();
        let left = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(left, 1, "sweep must remove every orphaned temp");

        // with the fault cleared the write goes through and replaces
        // the entry atomically
        store.put(&key, &new_enc).unwrap();
        let back = store.get(&key).unwrap().unwrap();
        assert_eq!(back.enc.bytes, new_enc.bytes);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn probabilistic_store_faults_replay_deterministically() {
        use crate::net::chaos::{
            clear_store_fault, install_store_fault, StoreFault, STORE_FAULT_TEST_LOCK,
        };
        let _guard = STORE_FAULT_TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());

        let dir = tmp_store("chaosfail");
        let _ = std::fs::remove_dir_all(&dir);
        let (enc, method) = toy_encoded(DistributionKind::Bernstein, 13);
        let run = || {
            clear_store_fault();
            let store = SketchStore::open(&dir).unwrap();
            install_store_fault(StoreFault::Fail { seed: 5, p: 0.5, writes: 0 });
            let outcomes: Vec<bool> = (0..16)
                .map(|i| {
                    let key = StoreKey::new("flaky", &method, enc.s, i);
                    store.put(&key, &enc).is_ok()
                })
                .collect();
            clear_store_fault();
            outcomes
        };
        let first = run();
        let _ = std::fs::remove_dir_all(&dir);
        let second = run();
        assert_eq!(first, second, "the same seed must fail the same writes");
        assert!(first.iter().any(|&ok| ok), "p=0.5 must pass some writes");
        assert!(first.iter().any(|&ok| !ok), "p=0.5 must fail some writes");
        clear_store_fault();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
