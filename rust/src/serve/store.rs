//! The persistent sketch store: a versioned on-disk container around
//! [`EncodedSketch`], keyed by `(dataset, distribution, budget s, seed)`.
//!
//! ## File format (version 1)
//!
//! Everything is written MSB-first through [`crate::sketch::bitio`]; every
//! header field is a whole number of bytes, so the payload starts
//! byte-aligned:
//!
//! | field          | size     | contents                                  |
//! |----------------|----------|-------------------------------------------|
//! | magic          | 4 B      | `"MSKS"`                                  |
//! | version        | 2 B      | format version (currently 1)              |
//! | flags          | 2 B      | bit 0: compact (row-scale) payload form   |
//! | dataset length | 2 B      | byte length of the dataset label          |
//! | dataset        | ≤64 KiB  | dataset label (UTF-8)                     |
//! | method length  | 2 B      | byte length of the method name            |
//! | method         | ≤64 KiB  | distribution name (UTF-8)                 |
//! | m              | 4 B      | rows                                      |
//! | n              | 4 B      | columns                                   |
//! | s              | 8 B      | sample budget                             |
//! | seed           | 8 B      | RNG seed of the sketching run             |
//! | header bits    | 8 B      | payload codec header size in bits         |
//! | body bits      | 8 B      | payload codec body size in bits           |
//! | payload bytes  | 8 B      | payload length in bytes                   |
//! | checksum       | 8 B      | FNV-1a 64 over header fields + payload    |
//! | payload        | variable | the [`EncodedSketch`] bit stream          |
//!
//! The checksum covers every byte before it *and* the payload, so a
//! flipped bit in any header field (identity, shape, budget, flags) is
//! caught, not just payload damage. The container records the *full*
//! [`StoreKey`] identity — dataset, method, `s`, seed — and
//! [`SketchStore::get`] validates it against the requested key, so even a
//! file-name collision (two labels sanitizing to the same name) is
//! detected at read time instead of silently serving the wrong sketch.
//!
//! A reader rejects bad magic, unknown versions, any size mismatch between
//! the declared and actual payload (truncated *or* padded files), and
//! checksum mismatches — a stored sketch either round-trips bit-identically
//! or fails loudly, never silently serves corrupt data.

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::sketch::bitio::{BitReader, BitWriter};
use crate::sketch::{encode_sketch, EncodedSketch, Sketch};

/// File magic: "MSKS" (matsketch sketch store).
pub const STORE_MAGIC: [u8; 4] = *b"MSKS";

/// Current container format version.
pub const STORE_VERSION: u16 = 1;

/// Extension used for store files.
pub const STORE_EXT: &str = "msk";

/// FNV-1a 64-bit initial state.
const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;

/// Fold `bytes` into a running FNV-1a 64 state (chainable across
/// non-contiguous regions, e.g. header then payload).
pub fn fnv1a64_extend(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// FNV-1a 64-bit checksum (dependency-free, stable across platforms).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_extend(FNV_OFFSET, bytes)
}

/// Identity of a stored sketch: the inputs that make a sketching run
/// reproducible. Two runs with equal keys produce statistically identical
/// sketches, so the store can serve the cached one.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreKey {
    /// Dataset label (e.g. a [`crate::datasets::DatasetId`] name or an
    /// input file stem).
    pub dataset: String,
    /// Distribution name ([`crate::distributions::DistributionKind::name`]).
    pub method: String,
    /// Sample budget `s`.
    pub s: u64,
    /// RNG seed of the sketching run.
    pub seed: u64,
}

impl StoreKey {
    /// Build a key.
    pub fn new(dataset: &str, method: &str, s: u64, seed: u64) -> StoreKey {
        StoreKey {
            dataset: dataset.to_string(),
            method: method.to_string(),
            s,
            seed,
        }
    }

    /// Deterministic file name: sanitized components joined with `__`.
    pub fn file_name(&self) -> String {
        format!(
            "{}__{}__s{}__seed{}.{STORE_EXT}",
            sanitize(&self.dataset),
            sanitize(&self.method),
            self.s,
            self.seed
        )
    }
}

/// Lower-case a label and replace every non-alphanumeric run with one `-`
/// so method names like `"L2 trim 0.1"` become safe file-name components.
fn sanitize(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut pending_dash = false;
    for c in s.chars() {
        if c.is_ascii_alphanumeric() {
            if pending_dash && !out.is_empty() {
                out.push('-');
            }
            pending_dash = false;
            out.push(c.to_ascii_lowercase());
        } else {
            pending_dash = true;
        }
    }
    if out.is_empty() {
        out.push('x');
    }
    out
}

/// A sketch read back from the store, with the identity recorded at
/// write time.
#[derive(Clone, Debug)]
pub struct StoredSketch {
    /// The encoded payload, bit-identical to what was written.
    pub enc: EncodedSketch,
    /// Dataset label recorded at write time.
    pub dataset: String,
    /// Distribution name recorded at write time.
    pub method: String,
    /// Sketching seed recorded at write time.
    pub seed: u64,
}

impl StoredSketch {
    /// The key this entry was written under.
    pub fn key(&self) -> StoreKey {
        StoreKey::new(&self.dataset, &self.method, self.enc.s, self.seed)
    }
}

fn put_str(w: &mut BitWriter, label: &str, what: &str) -> Result<()> {
    let bytes = label.as_bytes();
    if bytes.len() > u16::MAX as usize {
        return Err(Error::invalid(format!("{what} longer than 64 KiB")));
    }
    w.put_bits(bytes.len() as u64, 16);
    for &b in bytes {
        w.put_bits(b as u64, 8);
    }
    Ok(())
}

/// Serialize an encoded sketch plus its [`StoreKey`] identity into the
/// container format.
pub fn encode_container(enc: &EncodedSketch, key: &StoreKey) -> Result<Vec<u8>> {
    if enc.m > u32::MAX as usize || enc.n > u32::MAX as usize {
        return Err(Error::invalid("sketch dimensions exceed u32"));
    }
    let mut w = BitWriter::new();
    for b in STORE_MAGIC {
        w.put_bits(b as u64, 8);
    }
    w.put_bits(STORE_VERSION as u64, 16);
    let flags: u16 = enc.compact as u16;
    w.put_bits(flags as u64, 16);
    put_str(&mut w, &key.dataset, "dataset label")?;
    put_str(&mut w, &key.method, "method name")?;
    w.put_bits(enc.m as u64, 32);
    w.put_bits(enc.n as u64, 32);
    w.put_bits(enc.s, 64);
    w.put_bits(key.seed, 64);
    w.put_bits(enc.header_bits as u64, 64);
    w.put_bits(enc.body_bits as u64, 64);
    w.put_bits(enc.bytes.len() as u64, 64);
    let mut out = w.finish();
    // checksum covers every header byte so far plus the payload
    let sum = fnv1a64_extend(fnv1a64(&out), &enc.bytes);
    out.extend_from_slice(&sum.to_be_bytes());
    out.extend_from_slice(&enc.bytes);
    Ok(out)
}

/// Parse a store container back into its encoded sketch. Rejects bad
/// magic, unknown versions, truncated or padded files, and checksum
/// mismatches.
pub fn decode_container(data: &[u8]) -> Result<StoredSketch> {
    let err = |what: &str| Error::Parse(format!("sketch store: {what}"));
    let mut r = BitReader::new(data);
    for want in STORE_MAGIC {
        let got = r.get_bits(8).ok_or_else(|| err("truncated header"))?;
        if got != want as u64 {
            return Err(err("bad magic (not a sketch store file)"));
        }
    }
    let version = r.get_bits(16).ok_or_else(|| err("truncated header"))?;
    if version != STORE_VERSION as u64 {
        return Err(Error::Parse(format!(
            "sketch store: unsupported version {version} (expected {STORE_VERSION})"
        )));
    }
    let flags = r.get_bits(16).ok_or_else(|| err("truncated header"))?;
    let compact = flags & 1 == 1;
    let dataset = get_str(&mut r, "dataset label")?;
    let method = get_str(&mut r, "method name")?;
    let m = r.get_bits(32).ok_or_else(|| err("truncated header"))? as usize;
    let n = r.get_bits(32).ok_or_else(|| err("truncated header"))? as usize;
    let s = r.get_bits(64).ok_or_else(|| err("truncated header"))?;
    let seed = r.get_bits(64).ok_or_else(|| err("truncated header"))?;
    let header_bits = r.get_bits(64).ok_or_else(|| err("truncated header"))? as usize;
    let body_bits = r.get_bits(64).ok_or_else(|| err("truncated header"))? as usize;
    let payload_len = r.get_bits(64).ok_or_else(|| err("truncated header"))? as usize;
    let checksum = r.get_bits(64).ok_or_else(|| err("truncated header"))?;

    debug_assert_eq!(r.bit_pos() % 8, 0, "store header must stay byte-aligned");
    let header_bytes = r.bit_pos() / 8;
    let actual = data.len().saturating_sub(header_bytes);
    if actual < payload_len {
        return Err(err("truncated payload"));
    }
    if actual > payload_len {
        return Err(err("trailing bytes after payload"));
    }
    let payload = data[header_bytes..].to_vec();
    // the stored sum covers all header bytes before the checksum field
    // plus the payload
    let covered = &data[..header_bytes - 8];
    let got_sum = fnv1a64_extend(fnv1a64(covered), &payload);
    if got_sum != checksum {
        return Err(Error::Parse(format!(
            "sketch store: checksum mismatch (stored {checksum:#018x}, computed {got_sum:#018x})"
        )));
    }
    Ok(StoredSketch {
        enc: EncodedSketch {
            m,
            n,
            s,
            header_bits,
            body_bits,
            bytes: payload,
            compact,
        },
        dataset,
        method,
        seed,
    })
}

fn get_str(r: &mut BitReader<'_>, what: &str) -> Result<String> {
    let err = |msg: String| Error::Parse(format!("sketch store: {msg}"));
    let len = r
        .get_bits(16)
        .ok_or_else(|| err("truncated header".into()))? as usize;
    let mut bytes = Vec::with_capacity(len);
    for _ in 0..len {
        bytes.push(r.get_bits(8).ok_or_else(|| err("truncated header".into()))? as u8);
    }
    String::from_utf8(bytes).map_err(|_| err(format!("{what} is not valid UTF-8")))
}

/// Write one encoded sketch to `path` in the container format (through a
/// writer-unique sibling temp file + rename, so neither a crashed writer
/// nor two concurrent writers of the same key can leave a half-written
/// store entry behind).
pub fn write_encoded(path: &Path, enc: &EncodedSketch, key: &StoreKey) -> Result<()> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static WRITE_SEQ: AtomicU64 = AtomicU64::new(0);
    let data = encode_container(enc, key)?;
    let seq = WRITE_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = path.with_extension(format!(
        "{STORE_EXT}.tmp-{}-{seq}",
        std::process::id()
    ));
    fs::write(&tmp, &data)?;
    fs::rename(&tmp, path)?;
    Ok(())
}

/// Read one encoded sketch back from `path`.
pub fn read_encoded(path: &Path) -> Result<StoredSketch> {
    let data = fs::read(path)?;
    decode_container(&data)
}

/// A directory of stored sketches, one file per [`StoreKey`].
#[derive(Clone, Debug)]
pub struct SketchStore {
    dir: PathBuf,
}

impl SketchStore {
    /// Open (creating if necessary) a store rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<SketchStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        Ok(SketchStore { dir })
    }

    /// Store root.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// File path a key maps to.
    pub fn path_for(&self, key: &StoreKey) -> PathBuf {
        self.dir.join(key.file_name())
    }

    /// Whether a sketch for `key` is present (without validating it).
    pub fn contains(&self, key: &StoreKey) -> bool {
        self.path_for(key).is_file()
    }

    /// Persist an encoded sketch under `key`; returns the file path.
    pub fn put(&self, key: &StoreKey, enc: &EncodedSketch) -> Result<PathBuf> {
        let path = self.path_for(key);
        write_encoded(&path, enc, key)?;
        Ok(path)
    }

    /// Load the sketch stored under `key`. `Ok(None)` when absent; `Err`
    /// when present but corrupt or recorded under a *different* identity
    /// — two labels can sanitize to the same file name, and serving the
    /// wrong sketch silently is never acceptable.
    pub fn get(&self, key: &StoreKey) -> Result<Option<StoredSketch>> {
        let path = self.path_for(key);
        if !path.is_file() {
            return Ok(None);
        }
        let stored = read_encoded(&path)?;
        let recorded = stored.key();
        if recorded != *key {
            return Err(Error::Parse(format!(
                "sketch store: {} holds ({}, {}, s={}, seed={}) but ({}, {}, s={}, seed={}) \
                 was requested (file-name collision?)",
                path.display(),
                recorded.dataset,
                recorded.method,
                recorded.s,
                recorded.seed,
                key.dataset,
                key.method,
                key.s,
                key.seed,
            )));
        }
        Ok(Some(stored))
    }

    /// Cache lookup with build-on-miss: returns the encoded sketch and
    /// whether it came from the store (`true`) or was freshly built and
    /// persisted (`false`). This is what lets repeated CLI / eval runs at
    /// the same `(dataset, method, s, seed)` skip re-sketching entirely.
    pub fn get_or_build(
        &self,
        key: &StoreKey,
        build: impl FnOnce() -> Result<Sketch>,
    ) -> Result<(EncodedSketch, bool)> {
        if let Some(stored) = self.get(key)? {
            return Ok((stored.enc, true));
        }
        let sketch = build()?;
        let enc = encode_sketch(&sketch)?;
        self.put(key, &enc)?;
        Ok((enc, false))
    }

    /// Keys' file names currently present (for listing / debugging).
    pub fn entries(&self) -> Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        for de in fs::read_dir(&self.dir)? {
            let p = de?.path();
            if p.extension().and_then(|e| e.to_str()) == Some(STORE_EXT) {
                out.push(p);
            }
        }
        out.sort();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::DistributionKind;
    use crate::sketch::{decode_sketch, sketch_offline, SketchPlan};
    use crate::sparse::Coo;
    use crate::util::rng::Rng;

    fn tmp_store(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("matsketch_store_{tag}_{}", std::process::id()))
    }

    fn toy_encoded(kind: DistributionKind, seed: u64) -> (EncodedSketch, String) {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(16, 256);
        for i in 0..16u32 {
            for _ in 0..20 {
                coo.push(i, rng.usize_below(256) as u32, rng.normal() as f32 + 0.5);
            }
        }
        let a = coo.to_csr();
        let sk = sketch_offline(&a, &SketchPlan::new(kind, 800).with_seed(seed)).unwrap();
        (encode_sketch(&sk).unwrap(), sk.method)
    }

    #[test]
    fn container_roundtrip_bit_identical() {
        for kind in [DistributionKind::Bernstein, DistributionKind::L2] {
            let (enc, method) = toy_encoded(kind, 3);
            let key = StoreKey::new("toy", &method, enc.s, 3);
            let data = encode_container(&enc, &key).unwrap();
            let back = decode_container(&data).unwrap();
            assert_eq!(back.enc.bytes, enc.bytes, "{method}: payload changed");
            assert_eq!(back.enc.m, enc.m);
            assert_eq!(back.enc.n, enc.n);
            assert_eq!(back.enc.s, enc.s);
            assert_eq!(back.enc.header_bits, enc.header_bits);
            assert_eq!(back.enc.body_bits, enc.body_bits);
            assert_eq!(back.enc.compact, enc.compact);
            assert_eq!(back.key(), key);
            // decoded sketches agree entry-for-entry
            let a = decode_sketch(&enc, &method).unwrap();
            let b = decode_sketch(&back.enc, &back.method).unwrap();
            assert_eq!(a.entries, b.entries);
        }
    }

    #[test]
    fn container_rejects_corruption() {
        let (enc, method) = toy_encoded(DistributionKind::Bernstein, 4);
        let key = StoreKey::new("toy", &method, enc.s, 4);
        let good = encode_container(&enc, &key).unwrap();
        let header_len = good.len() - enc.bytes.len();

        // flipped payload byte -> checksum mismatch
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        let e = decode_container(&bad).unwrap_err().to_string();
        assert!(e.contains("checksum"), "{e}");

        // flipped header field byte (the last byte of the `s` field, 41
        // bytes before the end of the header) -> checksum mismatch too
        let mut hbad = good.clone();
        hbad[header_len - 41] ^= 0x01;
        let e = decode_container(&hbad).unwrap_err().to_string();
        assert!(e.contains("checksum"), "{e}");

        // truncated payload
        let e = decode_container(&good[..good.len() - 3]).unwrap_err().to_string();
        assert!(e.contains("truncated"), "{e}");

        // padded payload
        let mut padded = good.clone();
        padded.push(0);
        let e = decode_container(&padded).unwrap_err().to_string();
        assert!(e.contains("trailing"), "{e}");

        // bad magic
        let mut wrong = good.clone();
        wrong[0] = b'X';
        let e = decode_container(&wrong).unwrap_err().to_string();
        assert!(e.contains("magic"), "{e}");

        // unsupported version
        let mut vers = good;
        vers[5] = 0xEE;
        let e = decode_container(&vers).unwrap_err().to_string();
        assert!(e.contains("version"), "{e}");
    }

    #[test]
    fn store_put_get_and_cache() {
        let dir = tmp_store("putget");
        let _ = std::fs::remove_dir_all(&dir);
        let store = SketchStore::open(&dir).unwrap();
        let key = StoreKey::new("toy", "Bernstein", 800, 3);
        assert!(!store.contains(&key));
        assert!(store.get(&key).unwrap().is_none());

        let (enc, _) = toy_encoded(DistributionKind::Bernstein, 3);
        store.put(&key, &enc).unwrap();
        assert!(store.contains(&key));
        let back = store.get(&key).unwrap().unwrap();
        assert_eq!(back.enc.bytes, enc.bytes);
        assert_eq!(back.dataset, "toy");
        assert_eq!(back.method, "Bernstein");
        assert_eq!(back.seed, 3);
        assert_eq!(store.entries().unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn file_name_collision_is_detected_not_served() {
        // "Data.v2" and "data-v2" sanitize to the same file name; the
        // recorded identity must reject the mismatched read.
        let dir = tmp_store("collision");
        let _ = std::fs::remove_dir_all(&dir);
        let store = SketchStore::open(&dir).unwrap();
        let (enc, _) = toy_encoded(DistributionKind::Bernstein, 5);
        let written = StoreKey::new("Data.v2", "Bernstein", enc.s, 5);
        let requested = StoreKey::new("data-v2", "Bernstein", enc.s, 5);
        assert_eq!(written.file_name(), requested.file_name());
        store.put(&written, &enc).unwrap();
        assert!(store.get(&written).unwrap().is_some());
        let e = store.get(&requested).unwrap_err().to_string();
        assert!(e.contains("collision"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_file_names_are_sanitized_and_distinct() {
        let a = StoreKey::new("enron", "L2 trim 0.1", 1000, 0);
        let b = StoreKey::new("enron", "L2 trim 0.01", 1000, 0);
        assert_eq!(a.file_name(), "enron__l2-trim-0-1__s1000__seed0.msk");
        assert_ne!(a.file_name(), b.file_name());
        // different budgets / seeds also separate
        assert_ne!(
            StoreKey::new("x", "L1", 10, 0).file_name(),
            StoreKey::new("x", "L1", 10, 1).file_name()
        );
    }

    #[test]
    fn fnv_vectors() {
        // standard FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xAF63_DC4C_8601_EC8C);
    }
}
