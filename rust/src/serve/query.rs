//! Query execution directly on the Elias-γ compressed sketch.
//!
//! Every operator streams the payload through
//! [`crate::sketch::encode::SketchCursor`] — one pass, O(1) decode state,
//! no full [`Sketch`] materialization. The crate-internal `decoded_*`
//! twins run the same f64 accumulation over a decoded [`Sketch`]'s entry
//! list (which the cursor produces in the same row-major order), so the
//! two paths agree exactly and cross-check each other in unit tests.
//!
//! Only the one-shot forms (`matvec`, `matvec_batch`, …) are public, and
//! they exist for benchmarks and low-level callers; everything above this
//! module goes through [`crate::api::SketchClient`], which picks the
//! execution plan (cached payload header, per-row offset index, streaming
//! scan) internally. The header-cached `*_h` forms, the index-seeking row
//! slice, and the decoded twins are `pub(crate)` execution plans, not API.

use std::cmp::Ordering;

use crate::error::{Error, Result};
use crate::sketch::encode::SketchCursor;
use crate::sketch::{EncodedSketch, PayloadHeader, Sketch, SketchEntry};

/// `y = B·x` computed off the compressed payload (`x` length n, `y`
/// length m).
pub fn matvec(enc: &EncodedSketch, x: &[f64]) -> Result<Vec<f64>> {
    matvec_h(enc, &PayloadHeader::parse(enc)?, x)
}

/// `matvec` with a pre-parsed payload header.
pub(crate) fn matvec_h(
    enc: &EncodedSketch,
    header: &PayloadHeader,
    x: &[f64],
) -> Result<Vec<f64>> {
    let (m, n) = (header.m, header.n);
    if x.len() != n {
        return Err(Error::shape(format!(
            "matvec: x has {} entries, B has {n} columns",
            x.len()
        )));
    }
    let mut cur = SketchCursor::with_header(enc, header);
    let mut y = vec![0.0f64; m];
    while let Some(e) = cur.next_entry()? {
        check_bounds(&e, m, n)?;
        y[e.row as usize] += e.value * x[e.col as usize];
    }
    Ok(y)
}

/// `y = Bᵀ·x` computed off the compressed payload (`x` length m, `y`
/// length n).
pub fn matvec_t(enc: &EncodedSketch, x: &[f64]) -> Result<Vec<f64>> {
    matvec_t_h(enc, &PayloadHeader::parse(enc)?, x)
}

/// `matvec_t` with a pre-parsed payload header.
pub(crate) fn matvec_t_h(
    enc: &EncodedSketch,
    header: &PayloadHeader,
    x: &[f64],
) -> Result<Vec<f64>> {
    let (m, n) = (header.m, header.n);
    if x.len() != m {
        return Err(Error::shape(format!(
            "matvec_t: x has {} entries, B has {m} rows",
            x.len()
        )));
    }
    let mut cur = SketchCursor::with_header(enc, header);
    let mut y = vec![0.0f64; n];
    while let Some(e) = cur.next_entry()? {
        check_bounds(&e, m, n)?;
        y[e.col as usize] += e.value * x[e.row as usize];
    }
    Ok(y)
}

/// `Y = B·X` for a batch of right-hand sides (each length n), executed
/// in **one pass** over the compressed payload: every decoded entry
/// updates all k accumulators, so the Elias-γ decode cost is paid once
/// for the whole batch instead of once per right-hand side.
///
/// Each output vector is bit-identical to the corresponding independent
/// [`matvec`] call — the per-vector f64 accumulation order is the same
/// row-major entry sequence.
pub fn matvec_batch(enc: &EncodedSketch, xs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
    matvec_batch_h(enc, &PayloadHeader::parse(enc)?, xs)
}

/// `matvec_batch` with a pre-parsed payload header.
pub(crate) fn matvec_batch_h(
    enc: &EncodedSketch,
    header: &PayloadHeader,
    xs: &[Vec<f64>],
) -> Result<Vec<Vec<f64>>> {
    let (m, n) = (header.m, header.n);
    for (i, x) in xs.iter().enumerate() {
        if x.len() != n {
            return Err(Error::shape(format!(
                "matvec_batch: x[{i}] has {} entries, B has {n} columns",
                x.len()
            )));
        }
    }
    if xs.is_empty() {
        return Ok(Vec::new());
    }
    let mut ys = vec![vec![0.0f64; m]; xs.len()];
    let mut cur = SketchCursor::with_header(enc, header);
    while let Some(e) = cur.next_entry()? {
        check_bounds(&e, m, n)?;
        let (r, c) = (e.row as usize, e.col as usize);
        for (y, x) in ys.iter_mut().zip(xs) {
            y[r] += e.value * x[c];
        }
    }
    Ok(ys)
}

/// All entries of row `i`, in column order. Stops decoding as soon as the
/// row-major stream passes row `i`.
pub fn row_slice(enc: &EncodedSketch, i: u32) -> Result<Vec<SketchEntry>> {
    row_slice_h(enc, &PayloadHeader::parse(enc)?, i)
}

/// `row_slice` with a pre-parsed payload header (still a scan from the
/// front; the index-seeking plan below does the O(1) seek).
pub(crate) fn row_slice_h(
    enc: &EncodedSketch,
    header: &PayloadHeader,
    i: u32,
) -> Result<Vec<SketchEntry>> {
    if i as usize >= header.m {
        return Err(Error::shape(format!("row {i} outside {} rows", header.m)));
    }
    let mut cur = SketchCursor::with_header(enc, header);
    let mut out = Vec::new();
    while let Some(e) = cur.next_entry()? {
        if e.row > i {
            break;
        }
        if e.row == i {
            out.push(e);
        }
    }
    Ok(out)
}

/// `row_slice` through the store's per-row offset index
/// (`(row id, payload bit offset)` pairs, ascending): binary-search the
/// row, seek straight to its group, decode only that group. Produces
/// exactly the scan result — an index entry pointing at the wrong group
/// is detected and reported, never silently served.
pub(crate) fn row_slice_indexed(
    enc: &EncodedSketch,
    header: &PayloadHeader,
    index: &[(u32, u64)],
    i: u32,
) -> Result<Vec<SketchEntry>> {
    if i as usize >= header.m {
        return Err(Error::shape(format!("row {i} outside {} rows", header.m)));
    }
    let pos = match index.binary_search_by_key(&i, |&(row, _)| row) {
        // a valid row with no sampled entries: the empty slice
        Err(_) => return Ok(Vec::new()),
        Ok(pos) => pos,
    };
    let prev_row = if pos == 0 { 0 } else { index[pos - 1].0 };
    let mut cur = SketchCursor::row_group_at(enc, header, index[pos].1, prev_row);
    let mut out = Vec::new();
    while let Some(e) = cur.next_entry()? {
        if e.row != i {
            return Err(Error::Parse(format!(
                "row index for row {i} points at a group of row {}",
                e.row
            )));
        }
        out.push(e);
    }
    Ok(out)
}

/// Walk the row-group window `index[lo..hi]`, calling
/// `f(group ordinal within the window, entry)` for every entry in
/// stream order — the shared group-transition tracking (and over-decode
/// guard) behind both split-matvec executors.
fn walk_groups(
    enc: &EncodedSketch,
    header: &PayloadHeader,
    index: &[(u32, u64)],
    lo: usize,
    hi: usize,
    mut f: impl FnMut(usize, SketchEntry),
) -> Result<()> {
    let (m, n) = (header.m, header.n);
    let mut cur = SketchCursor::row_range(enc, header, index, lo, hi);
    let mut ord = 0usize;
    let mut last_row = u32::MAX;
    while let Some(e) = cur.next_entry()? {
        check_bounds(&e, m, n)?;
        if e.row != last_row {
            if last_row != u32::MAX {
                ord += 1;
            }
            last_row = e.row;
            if ord >= hi - lo {
                return Err(Error::Parse(
                    "row window decoded more groups than its index range".into(),
                ));
            }
        }
        f(ord, e);
    }
    Ok(())
}

/// Per-row-group partial matvec over the contiguous window
/// `index[lo..hi]`: returns one f64 sum per group, in window order. Each
/// group's sum is accumulated over its entries in stream order — exactly
/// the contribution the sequential [`matvec`] scan writes into
/// `y[group row]` — so scattering the partials of disjoint windows back
/// by group row reproduces the sequential answer **bit-identically**.
pub(crate) fn matvec_groups(
    enc: &EncodedSketch,
    header: &PayloadHeader,
    index: &[(u32, u64)],
    lo: usize,
    hi: usize,
    x: &[f64],
) -> Result<Vec<f64>> {
    if x.len() != header.n {
        return Err(Error::shape(format!(
            "matvec: x has {} entries, B has {} columns",
            x.len(),
            header.n
        )));
    }
    let mut sums = vec![0.0f64; hi - lo];
    walk_groups(enc, header, index, lo, hi, |ord, e| {
        sums[ord] += e.value * x[e.col as usize];
    })?;
    Ok(sums)
}

/// Batched form of [`matvec_groups`]: one pass over the window, one
/// per-group sum row per right-hand side (`out[vector][group]`). Each
/// (vector, group) accumulation order matches [`matvec_batch`]'s exactly.
pub(crate) fn matvec_batch_groups(
    enc: &EncodedSketch,
    header: &PayloadHeader,
    index: &[(u32, u64)],
    lo: usize,
    hi: usize,
    xs: &[Vec<f64>],
) -> Result<Vec<Vec<f64>>> {
    for (i, x) in xs.iter().enumerate() {
        if x.len() != header.n {
            return Err(Error::shape(format!(
                "matvec_batch: x[{i}] has {} entries, B has {} columns",
                x.len(),
                header.n
            )));
        }
    }
    let mut sums = vec![vec![0.0f64; hi - lo]; xs.len()];
    walk_groups(enc, header, index, lo, hi, |ord, e| {
        let c = e.col as usize;
        for (s, x) in sums.iter_mut().zip(xs) {
            s[ord] += e.value * x[c];
        }
    })?;
    Ok(sums)
}

/// Window-local top-k over `index[lo..hi]` under [`rank_cmp`]. Because
/// the ranking is a strict total order (coordinates are unique), merging
/// the window-local top-k lists of a disjoint cover and re-truncating
/// reproduces the global [`top_k`] answer element-for-element.
pub(crate) fn top_k_groups(
    enc: &EncodedSketch,
    header: &PayloadHeader,
    index: &[(u32, u64)],
    lo: usize,
    hi: usize,
    k: usize,
) -> Result<Vec<SketchEntry>> {
    let mut cur = SketchCursor::row_range(enc, header, index, lo, hi);
    top_k_cursor(&mut cur, k)
}

/// All entries of column `j`, in row order (full payload scan).
pub fn col_slice(enc: &EncodedSketch, j: u32) -> Result<Vec<SketchEntry>> {
    col_slice_h(enc, &PayloadHeader::parse(enc)?, j)
}

/// `col_slice` with a pre-parsed payload header.
pub(crate) fn col_slice_h(
    enc: &EncodedSketch,
    header: &PayloadHeader,
    j: u32,
) -> Result<Vec<SketchEntry>> {
    if j as usize >= header.n {
        return Err(Error::shape(format!("column {j} outside {} columns", header.n)));
    }
    let mut cur = SketchCursor::with_header(enc, header);
    let mut out = Vec::new();
    while let Some(e) = cur.next_entry()? {
        if e.col == j {
            out.push(e);
        }
    }
    Ok(out)
}

/// Deterministic heaviness order: larger `|value|` first, ties broken by
/// `(row, col)` ascending. Entries have unique coordinates, so this is a
/// strict total order and the compressed / decoded top-k paths agree
/// element-for-element.
pub fn rank_cmp(a: &SketchEntry, b: &SketchEntry) -> Ordering {
    b.value
        .abs()
        .partial_cmp(&a.value.abs())
        .unwrap_or(Ordering::Equal)
        .then_with(|| (a.row, a.col).cmp(&(b.row, b.col)))
}

/// The `k` heaviest entries by `|value|`, heaviest first, computed with a
/// k-bounded selection buffer over the streaming decode.
pub fn top_k(enc: &EncodedSketch, k: usize) -> Result<Vec<SketchEntry>> {
    top_k_h(enc, &PayloadHeader::parse(enc)?, k)
}

/// `top_k` with a pre-parsed payload header.
pub(crate) fn top_k_h(
    enc: &EncodedSketch,
    header: &PayloadHeader,
    k: usize,
) -> Result<Vec<SketchEntry>> {
    let mut cur = SketchCursor::with_header(enc, header);
    top_k_cursor(&mut cur, k)
}

/// The k-bounded selection body shared by the full-payload and
/// row-window top-k plans: drain `cur`, keeping the `k` heaviest entries
/// under [`rank_cmp`], heaviest first.
fn top_k_cursor(cur: &mut SketchCursor<'_>, k: usize) -> Result<Vec<SketchEntry>> {
    if k == 0 {
        return Ok(Vec::new());
    }
    // cap the eager allocation: a user-supplied k may far exceed the
    // sketch's entry count, and the buffer grows on demand anyway
    let mut top: Vec<SketchEntry> = Vec::with_capacity(k.min(1024) + 1);
    while let Some(e) = cur.next_entry()? {
        if top.len() == k {
            let lightest = top.last().expect("top non-empty when len == k");
            if rank_cmp(lightest, &e) != Ordering::Greater {
                continue;
            }
        }
        let pos = top.partition_point(|t| rank_cmp(t, &e) == Ordering::Less);
        top.insert(pos, e);
        if top.len() > k {
            top.pop();
        }
    }
    Ok(top)
}

/// Reference matvec over a decoded sketch: identical f64 accumulation
/// order to [`matvec`] (the entry list is row-major, exactly the cursor's
/// emission order).
pub(crate) fn decoded_matvec(sk: &Sketch, x: &[f64]) -> Result<Vec<f64>> {
    if x.len() != sk.n {
        return Err(Error::shape(format!(
            "decoded_matvec: x has {} entries, B has {} columns",
            x.len(),
            sk.n
        )));
    }
    let mut y = vec![0.0f64; sk.m];
    for e in &sk.entries {
        y[e.row as usize] += e.value * x[e.col as usize];
    }
    Ok(y)
}

/// Reference transposed matvec over a decoded sketch (see
/// `decoded_matvec`).
pub(crate) fn decoded_matvec_t(sk: &Sketch, x: &[f64]) -> Result<Vec<f64>> {
    if x.len() != sk.m {
        return Err(Error::shape(format!(
            "decoded_matvec_t: x has {} entries, B has {} rows",
            x.len(),
            sk.m
        )));
    }
    let mut y = vec![0.0f64; sk.n];
    for e in &sk.entries {
        y[e.col as usize] += e.value * x[e.row as usize];
    }
    Ok(y)
}

/// Reference top-k over a decoded sketch: full sort under [`rank_cmp`].
pub(crate) fn decoded_top_k(sk: &Sketch, k: usize) -> Vec<SketchEntry> {
    let mut all = sk.entries.clone();
    all.sort_by(rank_cmp);
    all.truncate(k);
    all
}

#[inline]
fn check_bounds(e: &SketchEntry, m: usize, n: usize) -> Result<()> {
    if (e.row as usize) >= m || (e.col as usize) >= n {
        return Err(Error::Parse(format!(
            "sketch payload entry ({}, {}) outside {m}x{n}",
            e.row, e.col
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::DistributionKind;
    use crate::sketch::{decode_sketch, encode_sketch, sketch_offline, SketchPlan};
    use crate::sparse::Coo;
    use crate::util::rng::Rng;

    fn toy(kind: DistributionKind) -> (EncodedSketch, Sketch) {
        let mut rng = Rng::new(7);
        let mut coo = Coo::new(12, 90);
        for i in 0..12u32 {
            for _ in 0..15 {
                coo.push(i, rng.usize_below(90) as u32, rng.normal() as f32 + 1.0);
            }
        }
        let a = coo.to_csr();
        let sk = sketch_offline(&a, &SketchPlan::new(kind, 500).with_seed(1)).unwrap();
        let enc = encode_sketch(&sk).unwrap();
        let dec = decode_sketch(&enc, &sk.method).unwrap();
        (enc, dec)
    }

    #[test]
    fn compressed_matvec_matches_decoded_exactly() {
        for kind in [DistributionKind::Bernstein, DistributionKind::L2] {
            let (enc, dec) = toy(kind);
            let mut rng = Rng::new(42);
            let x: Vec<f64> = (0..dec.n).map(|_| rng.normal()).collect();
            let xt: Vec<f64> = (0..dec.m).map(|_| rng.normal()).collect();
            assert_eq!(matvec(&enc, &x).unwrap(), decoded_matvec(&dec, &x).unwrap());
            assert_eq!(
                matvec_t(&enc, &xt).unwrap(),
                decoded_matvec_t(&dec, &xt).unwrap()
            );
        }
    }

    #[test]
    fn batched_matvec_matches_independent_matvecs_bitwise() {
        for kind in [DistributionKind::Bernstein, DistributionKind::L2] {
            let (enc, dec) = toy(kind);
            let mut rng = Rng::new(91);
            let xs: Vec<Vec<f64>> = (0..5)
                .map(|_| (0..dec.n).map(|_| rng.normal()).collect())
                .collect();
            let ys = matvec_batch(&enc, &xs).unwrap();
            assert_eq!(ys.len(), xs.len());
            for (x, y) in xs.iter().zip(&ys) {
                let want = matvec(&enc, x).unwrap();
                assert_eq!(y.len(), want.len());
                for (a, b) in y.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{kind:?}");
                }
            }
        }
    }

    #[test]
    fn batched_matvec_edge_cases() {
        let (enc, dec) = toy(DistributionKind::Bernstein);
        // empty batch: empty answer, no decode
        assert!(matvec_batch(&enc, &[]).unwrap().is_empty());
        // any shape-mismatched member rejects the whole batch
        let good = vec![0.5f64; dec.n];
        let bad = vec![0.5f64; dec.n + 1];
        assert!(matvec_batch(&enc, &[good.clone(), bad]).is_err());
        // k = 1 equals the single-vector path bitwise
        let ys = matvec_batch(&enc, std::slice::from_ref(&good)).unwrap();
        assert_eq!(ys[0], matvec(&enc, &good).unwrap());
    }

    #[test]
    fn slices_match_decoded_filter() {
        let (enc, dec) = toy(DistributionKind::Bernstein);
        for i in [0u32, 5, 11] {
            let want: Vec<SketchEntry> =
                dec.entries.iter().copied().filter(|e| e.row == i).collect();
            assert_eq!(row_slice(&enc, i).unwrap(), want, "row {i}");
        }
        let j = dec.entries[0].col;
        let want: Vec<SketchEntry> = dec.entries.iter().copied().filter(|e| e.col == j).collect();
        assert_eq!(col_slice(&enc, j).unwrap(), want);
        assert!(row_slice(&enc, 1_000).is_err());
        assert!(col_slice(&enc, 100_000).is_err());
    }

    #[test]
    fn indexed_row_slice_matches_scan_for_every_row() {
        for kind in [DistributionKind::Bernstein, DistributionKind::L2] {
            let (enc, dec) = toy(kind);
            let header = PayloadHeader::parse(&enc).unwrap();
            let index = crate::sketch::row_group_index(&enc).unwrap();
            for i in 0..dec.m as u32 {
                assert_eq!(
                    row_slice_indexed(&enc, &header, &index, i).unwrap(),
                    row_slice(&enc, i).unwrap(),
                    "{kind:?} row {i}"
                );
            }
            assert!(row_slice_indexed(&enc, &header, &index, dec.m as u32).is_err());
        }
    }

    #[test]
    fn header_variants_match_one_shot_forms() {
        let (enc, dec) = toy(DistributionKind::Bernstein);
        let header = PayloadHeader::parse(&enc).unwrap();
        let mut rng = Rng::new(8);
        let x: Vec<f64> = (0..dec.n).map(|_| rng.normal()).collect();
        let xt: Vec<f64> = (0..dec.m).map(|_| rng.normal()).collect();
        assert_eq!(matvec(&enc, &x).unwrap(), matvec_h(&enc, &header, &x).unwrap());
        assert_eq!(
            matvec_t(&enc, &xt).unwrap(),
            matvec_t_h(&enc, &header, &xt).unwrap()
        );
        assert_eq!(top_k(&enc, 9).unwrap(), top_k_h(&enc, &header, 9).unwrap());
        let j = dec.entries[0].col;
        assert_eq!(col_slice(&enc, j).unwrap(), col_slice_h(&enc, &header, j).unwrap());
    }

    #[test]
    fn top_k_matches_full_sort_and_is_ordered() {
        let (enc, dec) = toy(DistributionKind::Bernstein);
        for k in [0usize, 1, 7, 50, 10_000] {
            let got = top_k(&enc, k).unwrap();
            let want = decoded_top_k(&dec, k);
            assert_eq!(got, want, "k={k}");
            assert!(
                got.windows(2).all(|w| rank_cmp(&w[0], &w[1]) == Ordering::Less),
                "k={k}: not strictly ordered"
            );
        }
    }

    #[test]
    fn group_partials_reassemble_sequential_answers_bitwise() {
        for kind in [DistributionKind::Bernstein, DistributionKind::L2] {
            let (enc, dec) = toy(kind);
            let header = PayloadHeader::parse(&enc).unwrap();
            let index = crate::sketch::row_group_index(&enc).unwrap();
            let g = index.len();
            let mut rng = Rng::new(17);
            let x: Vec<f64> = (0..dec.n).map(|_| rng.normal()).collect();

            // matvec: scatter per-group partial sums of any contiguous
            // cover back by group row == the sequential scan, bitwise
            let want = matvec(&enc, &x).unwrap();
            for chunks in [1usize, 2, 3, g] {
                let mut y = vec![0.0f64; dec.m];
                let mut lo = 0usize;
                for c in 0..chunks {
                    let hi = (g * (c + 1)) / chunks;
                    let sums = matvec_groups(&enc, &header, &index, lo, hi, &x).unwrap();
                    assert_eq!(sums.len(), hi - lo);
                    for (off, s) in sums.iter().enumerate() {
                        y[index[lo + off].0 as usize] = *s;
                    }
                    lo = hi;
                }
                for (a, b) in y.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{kind:?} chunks={chunks}");
                }
            }

            // top-k: merging window-local top-k lists re-truncated under
            // rank_cmp equals the global answer element-for-element
            let want_k = top_k(&enc, 7).unwrap();
            let mid = g / 2;
            let mut cand = top_k_groups(&enc, &header, &index, 0, mid, 7).unwrap();
            cand.extend(top_k_groups(&enc, &header, &index, mid, g, 7).unwrap());
            cand.sort_by(rank_cmp);
            cand.truncate(7);
            assert_eq!(cand, want_k, "{kind:?}");

            // batched matvec partials
            let xs: Vec<Vec<f64>> = (0..3)
                .map(|_| (0..dec.n).map(|_| rng.normal()).collect())
                .collect();
            let want_b = matvec_batch(&enc, &xs).unwrap();
            let sums = matvec_batch_groups(&enc, &header, &index, 0, g, &xs).unwrap();
            assert_eq!(sums.len(), xs.len());
            for (v, wv) in sums.iter().zip(&want_b) {
                for (off, s) in v.iter().enumerate() {
                    assert_eq!(
                        s.to_bits(),
                        wv[index[off].0 as usize].to_bits(),
                        "{kind:?} batch partial"
                    );
                }
            }
        }
    }

    #[test]
    fn shape_mismatches_rejected() {
        let (enc, dec) = toy(DistributionKind::L2);
        assert!(matvec(&enc, &vec![0.0; dec.n + 1]).is_err());
        assert!(matvec_t(&enc, &vec![0.0; dec.m + 1]).is_err());
    }
}
