//! The lock-free registry core: fixed metric ids over `AtomicU64` cells.
//!
//! Metrics are a closed enum rather than a string-keyed map so the
//! record path is a single array index + relaxed `fetch_add` — no
//! hashing, no locks, no allocation. Names only materialize when a
//! [`MetricsSnapshot`] is taken.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Duration;

use super::snapshot::MetricsSnapshot;

/// Buckets per latency histogram: bucket 0 is the value 0, bucket
/// `i ≥ 1` covers `[2^(i-1), 2^i)`, and the last bucket is open-ended
/// (same idiom as [`crate::engine::metrics::SPILL_DEPTH_BUCKETS`]).
/// 32 buckets cover `[1, 2^30)` exactly — for microsecond latencies
/// that is everything below ~18 minutes.
pub const HIST_BUCKETS: usize = 32;

/// Histogram bucket index for an observed value.
#[inline]
pub fn hist_bucket(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        let b = (u64::BITS - v.leading_zeros()) as usize; // floor(log2)+1
        b.min(HIST_BUCKETS - 1)
    }
}

/// `[lo, hi)` value range of bucket `i` as `f64` (for interpolation).
/// Bucket 0 is `[0, 1)`; the open-ended last bucket is capped at twice
/// its lower bound so quantile interpolation stays finite.
pub fn hist_bucket_bounds(i: usize) -> (f64, f64) {
    assert!(i < HIST_BUCKETS);
    if i == 0 {
        (0.0, 1.0)
    } else {
        let lo = (1u64 << (i - 1)) as f64;
        (lo, lo * 2.0)
    }
}

macro_rules! metric_ids {
    ($(#[$doc:meta])* $name:ident { $($(#[$vdoc:meta])* $var:ident => $label:literal,)+ }) => {
        $(#[$doc])*
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        pub enum $name {
            $($(#[$vdoc])* $var,)+
        }

        impl $name {
            /// Every id, index-aligned with the registry's cell array.
            pub const ALL: &'static [$name] = &[$($name::$var,)+];

            /// Number of ids.
            pub const COUNT: usize = $name::ALL.len();

            /// Stable metric name — the snapshot / wire / report key.
            pub fn name(self) -> &'static str {
                match self {
                    $($name::$var => $label,)+
                }
            }
        }
    };
}

metric_ids! {
    /// Monotone event counters.
    Counter {
        /// `Ping` requests served.
        ReqPing => "req_ping",
        /// `ListSketches` requests served.
        ReqList => "req_list",
        /// `OpenSketch` requests served.
        ReqOpen => "req_open",
        /// `Shutdown` requests served.
        ReqShutdown => "req_shutdown",
        /// `Matvec` query requests served.
        ReqMatvec => "req_matvec",
        /// `MatvecT` query requests served.
        ReqMatvecT => "req_matvec_t",
        /// `Row` query requests served.
        ReqRow => "req_row",
        /// `Col` query requests served.
        ReqCol => "req_col",
        /// `TopK` query requests served.
        ReqTopK => "req_top_k",
        /// `MatvecBatch` query requests served.
        ReqMatvecBatch => "req_matvec_batch",
        /// `GenPoll` requests served.
        ReqGenPoll => "req_gen_poll",
        /// `Stats` requests served.
        ReqStats => "req_stats",
        /// `TraceDump` requests served.
        ReqTraceDump => "req_trace_dump",
        /// Process uptime in µs, materialized at snapshot time. Kept as
        /// a counter (not a gauge) so a scrape-to-scrape `diff` yields
        /// the interval length — the denominator of derived rates.
        UptimeUs => "uptime_us",
        /// Wire bytes read (headers + payloads).
        NetBytesIn => "net_bytes_in",
        /// Wire bytes written (headers + payloads).
        NetBytesOut => "net_bytes_out",
        /// Connections accepted.
        NetConnAccepted => "net_conn_accepted",
        /// Connections closed (either side).
        NetConnClosed => "net_conn_closed",
        /// Faults answered with `ErrCode::Malformed`.
        FaultMalformed => "fault_malformed",
        /// Faults answered with `ErrCode::BadVersion`.
        FaultBadVersion => "fault_bad_version",
        /// Faults answered with `ErrCode::Oversized`.
        FaultOversized => "fault_oversized",
        /// Faults answered with `ErrCode::UnknownOpcode`.
        FaultUnknownOpcode => "fault_unknown_opcode",
        /// Faults answered with `ErrCode::BadHandle`.
        FaultBadHandle => "fault_bad_handle",
        /// Faults answered with `ErrCode::Store`.
        FaultStore => "fault_store",
        /// Faults answered with `ErrCode::Query`.
        FaultQuery => "fault_query",
        /// Faults answered with `ErrCode::Busy`.
        FaultBusy => "fault_busy",
        /// Faults answered with `ErrCode::ShuttingDown`.
        FaultShuttingDown => "fault_shutting_down",
        /// Faults answered with `ErrCode::Generation`.
        FaultGeneration => "fault_generation",
        /// Open-sketch cache hits (api::local + net::server caches).
        OpenCacheHit => "open_cache_hit",
        /// Open-sketch cache misses (entry loaded from the store).
        OpenCacheMiss => "open_cache_miss",
        /// Open-sketch cache evictions (stale fingerprint).
        OpenCacheEvict => "open_cache_evict",
        /// Sketch payloads loaded from disk by the store.
        StoreLoad => "store_load",
        /// Queries executed whole on one worker.
        SplitWhole => "split_whole",
        /// Queries split row-parallel across the pool.
        SplitSharded => "split_sharded",
        /// Live `snapshot_at` pins resolved from the retained ring.
        LivePinHit => "live_pin_hit",
        /// Live `snapshot_at` pins older than the retained ring.
        LivePinMiss => "live_pin_miss",
        /// Live generations published.
        LivePublish => "live_publish",
        /// Faults answered with `ErrCode::Overloaded` (load shed).
        FaultOverloaded => "fault_overloaded",
        /// Faults answered with `ErrCode::Timeout` (slow read/write).
        FaultTimeout => "fault_timeout",
        /// Client-side request retries (any cause: I/O, wire corruption,
        /// overload backoff, version renegotiation).
        ClientRetry => "client_retry",
        /// Client-side requests abandoned because a per-request deadline
        /// expired before a retry could be attempted.
        ClientDeadline => "client_deadline",
        /// Faults deliberately injected by an active chaos `FaultPlan`.
        ChaosInjected => "chaos_injected",
        /// Orphaned `.msk.tmp-*` files removed by the store startup sweep.
        StoreTmpSwept => "store_tmp_swept",
    }
}

metric_ids! {
    /// Instantaneous values (set/adjusted, not summed over time).
    Gauge {
        /// Currently open TCP connections.
        NetConnections => "net_connections",
        /// Process start time as Unix milliseconds (set once at registry
        /// creation; 0 only if the system clock predates the epoch).
        ProcessStartMs => "process_start_unix_ms",
        /// Latest published live generation.
        LiveGeneration => "live_generation",
    }
}

metric_ids! {
    /// Log₂-bucketed histograms; recorded values are microseconds.
    Hist {
        /// Whole request handling time in `net::server` (decode → reply
        /// encoded), any opcode.
        NetRequestUs => "net_request_us",
        /// Time a query waited in the `QueryServer` channel before a
        /// worker picked it up.
        QueueWaitUs => "queue_wait_us",
        /// Matvec execute time (on-worker, excludes queue wait).
        ExecMatvecUs => "exec_matvec_us",
        /// Transposed-matvec execute time.
        ExecMatvecTUs => "exec_matvec_t_us",
        /// Row-slice execute time.
        ExecRowUs => "exec_row_us",
        /// Column-slice execute time.
        ExecColUs => "exec_col_us",
        /// Top-k execute time.
        ExecTopKUs => "exec_top_k_us",
        /// Batched-matvec execute time.
        ExecBatchUs => "exec_batch_us",
        /// Per-window execute time of row-parallel split chunks.
        SplitWindowUs => "split_window_us",
        /// Live epoch publish (prefix rebuild + swap) duration.
        LivePublishUs => "live_publish_us",
        /// Live freshness lag (ingest → queryable) per publish.
        LiveLagUs => "live_lag_us",
    }
}

/// The registry: one `AtomicU64` cell per counter / gauge / histogram
/// bucket. All record-path operations are `Ordering::Relaxed`; cells are
/// only ever added to (counters, buckets) or stored (gauges), so a
/// snapshot is a plain relaxed read sweep — totals are exact once the
/// recording threads are quiescent, and monotone under concurrency.
pub struct MetricsRegistry {
    enabled: AtomicBool,
    /// Registry creation instant: the origin of the `uptime_us` counter
    /// materialized at snapshot time (immutable — `reset` keeps it).
    started: std::time::Instant,
    counters: Vec<AtomicU64>,
    gauges: Vec<AtomicU64>,
    hists: Vec<[AtomicU64; HIST_BUCKETS]>,
}

fn zeroed(n: usize) -> Vec<AtomicU64> {
    (0..n).map(|_| AtomicU64::new(0)).collect()
}

impl MetricsRegistry {
    /// A fresh, enabled registry (tests and benches; servers use
    /// [`global()`]).
    pub fn new() -> Self {
        let r = Self {
            enabled: AtomicBool::new(true),
            started: std::time::Instant::now(),
            counters: zeroed(Counter::COUNT),
            gauges: zeroed(Gauge::COUNT),
            hists: (0..Hist::COUNT)
                .map(|_| std::array::from_fn(|_| AtomicU64::new(0)))
                .collect(),
        };
        let start_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map_or(0, |d| d.as_millis().min(u64::MAX as u128) as u64);
        r.gauge_set(Gauge::ProcessStartMs, start_ms);
        r
    }

    /// A registry that drops every event — the no-op baseline for the
    /// instrumentation-overhead bench.
    pub fn disabled() -> Self {
        let r = Self::new();
        r.set_enabled(false);
        r
    }

    /// Whether events are being recorded. Call sites that need a clock
    /// read should gate `Instant::now()` on this so the disabled mode is
    /// a true no-op.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Turn recording on or off (e.g. the overhead bench).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Add 1 to a counter.
    #[inline]
    pub fn inc(&self, c: Counter) {
        self.add(c, 1);
    }

    /// Add `n` to a counter.
    #[inline]
    pub fn add(&self, c: Counter, n: u64) {
        if self.enabled() {
            self.counters[c as usize].fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Set a gauge to an absolute value.
    #[inline]
    pub fn gauge_set(&self, g: Gauge, v: u64) {
        if self.enabled() {
            self.gauges[g as usize].store(v, Ordering::Relaxed);
        }
    }

    /// Adjust a gauge by a signed delta (two's-complement wrap, so a
    /// matched inc/dec pair nets to zero).
    #[inline]
    pub fn gauge_add(&self, g: Gauge, delta: i64) {
        if self.enabled() {
            self.gauges[g as usize].fetch_add(delta as u64, Ordering::Relaxed);
        }
    }

    /// Record one observation into a histogram.
    #[inline]
    pub fn record(&self, h: Hist, v: u64) {
        if self.enabled() {
            self.hists[h as usize][hist_bucket(v)].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a duration (saturating microseconds).
    #[inline]
    pub fn record_duration(&self, h: Hist, d: Duration) {
        self.record(h, d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Plain-data copy of every cell (relaxed read sweep).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = MetricsSnapshot::default();
        for &c in Counter::ALL {
            let v = if c == Counter::UptimeUs {
                // materialized on read: monotone like every counter, so
                // the scrape-to-scrape diff is the interval length
                self.started.elapsed().as_micros().min(u64::MAX as u128) as u64
            } else {
                self.counters[c as usize].load(Ordering::Relaxed)
            };
            snap.counters.push((c.name().to_string(), v));
        }
        for &g in Gauge::ALL {
            let v = self.gauges[g as usize].load(Ordering::Relaxed);
            snap.gauges.push((g.name().to_string(), v));
        }
        for &h in Hist::ALL {
            let buckets: Vec<u64> = self.hists[h as usize]
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect();
            snap.hists.push((h.name().to_string(), buckets));
        }
        snap
    }

    /// Zero every cell (tests and the overhead bench; servers never
    /// reset — scrapers diff snapshots instead).
    pub fn reset(&self) {
        for c in &self.counters {
            c.store(0, Ordering::Relaxed);
        }
        for g in &self.gauges {
            g.store(0, Ordering::Relaxed);
        }
        for h in &self.hists {
            for b in h {
                b.store(0, Ordering::Relaxed);
            }
        }
    }
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// The process-global registry every serving layer records into.
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_match_documented_scheme() {
        // bucket 0 is the value 0; bucket i ≥ 1 covers [2^(i-1), 2^i)
        assert_eq!(hist_bucket(0), 0);
        assert_eq!(hist_bucket(1), 1);
        for i in 1..HIST_BUCKETS - 1 {
            let lo = 1u64 << (i - 1);
            let hi = 1u64 << i;
            assert_eq!(hist_bucket(lo), i, "lower edge of bucket {i}");
            assert_eq!(hist_bucket(hi - 1), i, "upper edge of bucket {i}");
            assert_eq!(hist_bucket(hi), i + 1, "first value past bucket {i}");
        }
        // the last bucket is open-ended
        let last_lo = 1u64 << (HIST_BUCKETS - 2);
        assert_eq!(hist_bucket(last_lo), HIST_BUCKETS - 1);
        assert_eq!(hist_bucket(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn bucket_bounds_align_with_bucket_fn() {
        for i in 0..HIST_BUCKETS {
            let (lo, hi) = hist_bucket_bounds(i);
            assert!(lo < hi);
            assert_eq!(hist_bucket(lo as u64), i);
            if i < HIST_BUCKETS - 1 {
                assert_eq!(hist_bucket(hi as u64 - 1), i);
            }
        }
    }

    #[test]
    fn concurrent_recording_loses_no_counts() {
        // 8 threads × 10k events each into the same counter + histogram:
        // the relaxed fetch_adds must not lose a single event.
        const THREADS: usize = 8;
        const PER_THREAD: u64 = 10_000;
        let reg = MetricsRegistry::new();
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let reg = &reg;
                s.spawn(move || {
                    for i in 0..PER_THREAD {
                        reg.inc(Counter::ReqMatvec);
                        reg.record(Hist::ExecMatvecUs, t as u64 * PER_THREAD + i);
                        reg.gauge_add(Gauge::NetConnections, 1);
                        reg.gauge_add(Gauge::NetConnections, -1);
                    }
                });
            }
        });
        let snap = reg.snapshot();
        let total = THREADS as u64 * PER_THREAD;
        assert_eq!(snap.counter("req_matvec"), total);
        assert_eq!(snap.hist_count("exec_matvec_us"), total);
        assert_eq!(snap.gauge("net_connections"), 0);
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let reg = MetricsRegistry::disabled();
        reg.inc(Counter::ReqPing);
        reg.record(Hist::NetRequestUs, 42);
        reg.gauge_set(Gauge::LiveGeneration, 9);
        let snap = reg.snapshot();
        assert_eq!(snap.counter("req_ping"), 0);
        assert_eq!(snap.hist_count("net_request_us"), 0);
        assert_eq!(snap.gauge("live_generation"), 0);
    }

    #[test]
    fn uptime_and_start_time_materialize_in_snapshots() {
        let reg = MetricsRegistry::new();
        assert!(reg.snapshot().gauge("process_start_unix_ms") > 0);
        std::thread::sleep(Duration::from_millis(2));
        let a = reg.snapshot();
        assert!(a.counter("uptime_us") >= 2_000, "{}", a.counter("uptime_us"));
        std::thread::sleep(Duration::from_millis(2));
        let b = reg.snapshot();
        assert!(b.counter("uptime_us") > a.counter("uptime_us"), "uptime is monotone");
        // a scrape-to-scrape diff carries the interval, not the total
        let d = b.diff(&a);
        assert!(d.counter("uptime_us") < a.counter("uptime_us") + b.counter("uptime_us"));
        assert!(d.counter("uptime_us") >= 2_000);
    }

    #[test]
    fn metric_names_are_unique() {
        let mut names: Vec<&str> = Counter::ALL.iter().map(|c| c.name()).collect();
        names.extend(Gauge::ALL.iter().map(|g| g.name()));
        names.extend(Hist::ALL.iter().map(|h| h.name()));
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate metric name");
    }
}
