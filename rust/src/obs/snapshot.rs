//! Plain-data snapshots: merge, diff, quantiles, and the versioned
//! byte encoding the `Stats` wire opcode ships.
//!
//! Snapshots are **name-keyed**, not id-keyed: a v4 client scraping a
//! newer server that grew extra metrics simply sees extra names, and a
//! newer client scraping an older server sees fewer — no renegotiation.

use crate::error::{Error, Result};
use crate::util::stats::histogram_quantile;

use super::registry::{hist_bucket_bounds, HIST_BUCKETS};

/// Version tag leading the byte encoding. Bump when the layout changes;
/// decoders reject newer tags rather than misreading them.
pub const SNAPSHOT_VERSION: u16 = 1;

/// A point-in-time copy of a [`super::MetricsRegistry`].
///
/// Counters and histogram buckets are monotone, so `later.diff(earlier)`
/// isolates exactly the events between two scrapes; `merge` sums two
/// snapshots (e.g. across processes in a future sharded deployment).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// `(name, value)` monotone counters.
    pub counters: Vec<(String, u64)>,
    /// `(name, value)` instantaneous gauges.
    pub gauges: Vec<(String, u64)>,
    /// `(name, buckets)` log₂ histograms (see
    /// [`super::registry::hist_bucket`]).
    pub hists: Vec<(String, Vec<u64>)>,
}

fn lookup(list: &[(String, u64)], name: &str) -> u64 {
    list.iter().find(|(n, _)| n == name).map_or(0, |(_, v)| *v)
}

impl MetricsSnapshot {
    /// Counter value by name (0 if absent).
    pub fn counter(&self, name: &str) -> u64 {
        lookup(&self.counters, name)
    }

    /// Gauge value by name (0 if absent).
    pub fn gauge(&self, name: &str) -> u64 {
        lookup(&self.gauges, name)
    }

    /// Histogram buckets by name.
    pub fn hist(&self, name: &str) -> Option<&[u64]> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, b)| b.as_slice())
    }

    /// Total observations recorded into a histogram.
    pub fn hist_count(&self, name: &str) -> u64 {
        self.hist(name).map_or(0, |b| b.iter().sum())
    }

    /// Quantile of a histogram (`q ∈ [0, 1]`), linearly interpolated
    /// inside the winning log₂ bucket via
    /// [`crate::util::stats::histogram_quantile`]. 0 for an empty or
    /// absent histogram (the quantile itself is `None` there — this
    /// table-facing wrapper flattens that to 0).
    pub fn hist_quantile(&self, name: &str, q: f64) -> f64 {
        let Some(buckets) = self.hist(name) else { return 0.0 };
        let edges: Vec<(f64, f64)> =
            (0..buckets.len().min(HIST_BUCKETS)).map(hist_bucket_bounds).collect();
        let head = buckets.get(..edges.len()).unwrap_or(buckets);
        histogram_quantile(head, &edges, q).unwrap_or(0.0)
    }

    /// True when no counter, gauge, or bucket is non-zero.
    pub fn is_empty(&self) -> bool {
        self.counters.iter().all(|(_, v)| *v == 0)
            && self.gauges.iter().all(|(_, v)| *v == 0)
            && self.hists.iter().all(|(_, b)| b.iter().all(|&c| c == 0))
    }

    /// Events recorded between `earlier` and `self`: counters and
    /// histogram buckets subtract (saturating, so a restarted server
    /// yields zeros instead of garbage); gauges keep `self`'s value
    /// (they are instantaneous, not cumulative).
    pub fn diff(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        let mut out = self.clone();
        for (name, v) in &mut out.counters {
            *v = v.saturating_sub(earlier.counter(name));
        }
        for (name, buckets) in &mut out.hists {
            if let Some(prev) = earlier.hist(name) {
                for (b, p) in buckets.iter_mut().zip(prev.iter()) {
                    *b = b.saturating_sub(*p);
                }
            }
        }
        out
    }

    /// Sum `other` into `self`: counters and buckets add; gauges add too
    /// (the merged view of N processes has the summed connection count).
    /// Names present in only one side are kept as-is / appended.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        for (name, v) in &other.counters {
            match self.counters.iter_mut().find(|(n, _)| n == name) {
                Some((_, mine)) => *mine += v,
                None => self.counters.push((name.clone(), *v)),
            }
        }
        for (name, v) in &other.gauges {
            match self.gauges.iter_mut().find(|(n, _)| n == name) {
                Some((_, mine)) => *mine += v,
                None => self.gauges.push((name.clone(), *v)),
            }
        }
        for (name, buckets) in &other.hists {
            match self.hists.iter_mut().find(|(n, _)| n == name) {
                Some((_, mine)) => {
                    if mine.len() < buckets.len() {
                        mine.resize(buckets.len(), 0);
                    }
                    for (m, b) in mine.iter_mut().zip(buckets.iter()) {
                        *m += b;
                    }
                }
                None => self.hists.push((name.clone(), buckets.clone())),
            }
        }
    }

    /// Versioned byte encoding (big-endian, length-prefixed names —
    /// the same conventions as the wire protocol).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(1024);
        out.extend_from_slice(&SNAPSHOT_VERSION.to_be_bytes());
        put_scalar_section(&mut out, &self.counters);
        put_scalar_section(&mut out, &self.gauges);
        out.extend_from_slice(&(self.hists.len() as u32).to_be_bytes());
        for (name, buckets) in &self.hists {
            put_name(&mut out, name);
            out.extend_from_slice(&(buckets.len() as u32).to_be_bytes());
            for b in buckets {
                out.extend_from_slice(&b.to_be_bytes());
            }
        }
        out
    }

    /// Decode [`Self::encode`] output; rejects unknown versions and
    /// truncated or oversized payloads with [`Error::Parse`].
    pub fn decode(bytes: &[u8]) -> Result<MetricsSnapshot> {
        let mut rd = Cursor { b: bytes, i: 0 };
        let version = rd.u16()?;
        if version == 0 || version > SNAPSHOT_VERSION {
            return Err(Error::Parse(format!("unknown metrics snapshot version {version}")));
        }
        let counters = get_scalar_section(&mut rd)?;
        let gauges = get_scalar_section(&mut rd)?;
        let nh = rd.count(8)?;
        let mut hists = Vec::with_capacity(nh.min(1024));
        for _ in 0..nh {
            let name = rd.name()?;
            let nb = rd.count(8)?;
            let mut buckets = Vec::with_capacity(nb.min(1024));
            for _ in 0..nb {
                buckets.push(rd.u64()?);
            }
            hists.push((name, buckets));
        }
        rd.done()?;
        Ok(MetricsSnapshot { counters, gauges, hists })
    }
}

fn put_name(out: &mut Vec<u8>, name: &str) {
    debug_assert!(name.len() <= u16::MAX as usize);
    out.extend_from_slice(&(name.len() as u16).to_be_bytes());
    out.extend_from_slice(name.as_bytes());
}

fn put_scalar_section(out: &mut Vec<u8>, list: &[(String, u64)]) {
    out.extend_from_slice(&(list.len() as u32).to_be_bytes());
    for (name, v) in list {
        put_name(out, name);
        out.extend_from_slice(&v.to_be_bytes());
    }
}

fn get_scalar_section(rd: &mut Cursor<'_>) -> Result<Vec<(String, u64)>> {
    let n = rd.count(8)?;
    let mut list = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        let name = rd.name()?;
        let v = rd.u64()?;
        list.push((name, v));
    }
    Ok(list)
}

/// Bounds-checked decode cursor (the snapshot-local twin of the wire
/// reader; kept here so `obs` stays a leaf module).
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        let s = self
            .b
            .get(self.i..self.i.saturating_add(n))
            .ok_or_else(|| Error::Parse("truncated metrics snapshot".into()))?;
        self.i += n;
        Ok(s)
    }

    /// [`Cursor::take`], as a fixed-size array (for `from_be_bytes`).
    fn take_arr<const N: usize>(&mut self) -> Result<[u8; N]> {
        self.take(N)?
            .try_into()
            .map_err(|_| Error::Parse("truncated metrics snapshot".into()))
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_be_bytes(self.take_arr()?))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_be_bytes(self.take_arr()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_be_bytes(self.take_arr()?))
    }

    /// Element count whose remaining payload must hold at least
    /// `count · elem_bytes` bytes (pre-allocation guard).
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_bytes) > self.b.len() - self.i {
            return Err(Error::Parse(format!("metrics snapshot count {n} exceeds payload")));
        }
        Ok(n)
    }

    fn name(&mut self) -> Result<String> {
        let len = self.u16()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::Parse("metrics name is not UTF-8".into()))
    }

    fn done(&self) -> Result<()> {
        if self.i != self.b.len() {
            return Err(Error::Parse(format!(
                "metrics snapshot has {} trailing bytes",
                self.b.len() - self.i
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::registry::{Counter, Hist, MetricsRegistry};
    use super::*;

    fn sample_snapshot() -> MetricsSnapshot {
        let reg = MetricsRegistry::new();
        reg.add(Counter::ReqMatvec, 17);
        reg.add(Counter::FaultQuery, 3);
        reg.gauge_set(super::super::Gauge::LiveGeneration, 5);
        for v in [0u64, 1, 2, 3, 700, 65_000] {
            reg.record(Hist::ExecMatvecUs, v);
        }
        reg.snapshot()
    }

    #[test]
    fn encode_decode_roundtrips() {
        let snap = sample_snapshot();
        let bytes = snap.encode();
        let back = MetricsSnapshot::decode(&bytes).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn decode_rejects_bad_input() {
        let snap = sample_snapshot();
        let bytes = snap.encode();
        // truncation anywhere fails cleanly
        assert!(MetricsSnapshot::decode(&bytes[..bytes.len() - 1]).is_err());
        assert!(MetricsSnapshot::decode(&bytes[..1]).is_err());
        // trailing garbage is rejected
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(MetricsSnapshot::decode(&padded).is_err());
        // future versions are rejected, not misread
        let mut future = bytes;
        future[0..2].copy_from_slice(&(SNAPSHOT_VERSION + 1).to_be_bytes());
        assert!(MetricsSnapshot::decode(&future).is_err());
    }

    #[test]
    fn merged_snapshot_equals_sum_of_parts() {
        let a = sample_snapshot();
        let b = sample_snapshot();
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.counter("req_matvec"), a.counter("req_matvec") * 2);
        assert_eq!(merged.hist_count("exec_matvec_us"), a.hist_count("exec_matvec_us") * 2);
        let (ab, bb, mb) = (
            a.hist("exec_matvec_us").unwrap(),
            b.hist("exec_matvec_us").unwrap(),
            merged.hist("exec_matvec_us").unwrap(),
        );
        for (i, ((m, a), b)) in mb.iter().zip(ab.iter()).zip(bb.iter()).enumerate() {
            assert_eq!(*m, a + b, "bucket {i}");
        }
        // names unique to one side are preserved
        let mut lonely = MetricsSnapshot::default();
        lonely.counters.push(("only_here".into(), 7));
        merged.merge(&lonely);
        assert_eq!(merged.counter("only_here"), 7);
    }

    #[test]
    fn diff_isolates_the_delta() {
        let reg = MetricsRegistry::new();
        reg.add(Counter::ReqRow, 4);
        reg.record(Hist::ExecRowUs, 10);
        let before = reg.snapshot();
        reg.add(Counter::ReqRow, 6);
        reg.record(Hist::ExecRowUs, 10);
        reg.record(Hist::ExecRowUs, 1000);
        let after = reg.snapshot();
        let d = after.diff(&before);
        assert_eq!(d.counter("req_row"), 6);
        assert_eq!(d.hist_count("exec_row_us"), 2);
        // a "restarted server" (later scrape below earlier) saturates to 0
        let d2 = before.diff(&after);
        assert_eq!(d2.counter("req_row"), 0);
    }

    #[test]
    fn quantiles_come_from_bucket_interpolation() {
        let reg = MetricsRegistry::new();
        // 100 observations all in bucket [64, 128)
        for _ in 0..100 {
            reg.record(Hist::NetRequestUs, 100);
        }
        let snap = reg.snapshot();
        let p50 = snap.hist_quantile("net_request_us", 0.5);
        assert!((64.0..128.0).contains(&p50), "p50 = {p50}");
        assert!(snap.hist_quantile("net_request_us", 0.0) >= 64.0);
        assert!(snap.hist_quantile("net_request_us", 1.0) <= 128.0);
        // empty histogram → 0
        assert_eq!(snap.hist_quantile("exec_col_us", 0.99), 0.0);
        assert_eq!(snap.hist_quantile("no_such_hist", 0.5), 0.0);
    }
}
