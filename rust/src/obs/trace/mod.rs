//! Request-scoped tracing: span timelines beside the metrics registry.
//!
//! The metrics registry ([`super::registry`]) answers "how is the server
//! doing on aggregate"; this module answers "where did *this* query spend
//! its time" — client send, frame decode, queue wait, each row-parallel
//! split window, the in-order reduction, the reply write — as one span
//! tree per sampled request. Like the registry it is std-only and gated
//! on a global enable flag, with a sampling knob
//! ([`set_trace_one_in_n`]) so production-rate traffic traces a subset.
//!
//! ## Span model
//!
//! A **trace** is identified by a nonzero `u64` generated at the request
//! origin ([`sample`]) and carried across the wire on protocol-v5 query
//! frames ([`crate::net::wire`]), so the client-side and server-side
//! views of one request share an id. Within a trace, **spans** are
//! `(id, parent, name, start_us, end_us, key=value notes)` records with
//! microsecond offsets from the trace's monotonic origin instant —
//! wall-clock free, so a span tree is meaningful even when the clock
//! steps. Span id 0 is reserved ("no parent"); the root span has
//! `parent == 0`.
//!
//! Recording happens into an [`ActiveTrace`] (an `Arc` shared across the
//! worker threads a request fans out over); [`finish`] freezes it into a
//! plain-data [`TraceRecord`] and retires it into the global collector:
//! a fixed-capacity ring of recent traces plus a slow-query ring that
//! retains (and warn-logs, via [`crate::util::logging`]) any trace whose
//! root span exceeded [`slow_us`]. The `TraceDump` wire opcode and
//! `matsketch trace` read the rings back; [`render`] draws the indented
//! timelines.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::warn_log;

/// Version tag of the [`encode_traces`] byte layout (carried inside the
/// payload, so the trace format can evolve without a wire-protocol bump).
pub const TRACE_VERSION: u16 = 1;

/// Completed traces retained in the recent ring.
pub const TRACE_RING_CAP: usize = 256;

/// Slow traces retained verbatim past recent-ring eviction.
pub const SLOW_RING_CAP: usize = 64;

/// One recorded span: a named `[start_us, end_us)` interval (offsets
/// from the trace origin) with a parent link and key=value annotations.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    /// Span id, unique within the trace (≥ 1).
    pub id: u32,
    /// Parent span id; 0 marks the root.
    pub parent: u32,
    /// Stage name (`"request"`, `"queue_wait"`, `"split_window"`, …).
    pub name: String,
    /// Start offset from the trace origin, µs.
    pub start_us: u64,
    /// End offset from the trace origin, µs.
    pub end_us: u64,
    /// Free-form `key=value` annotations (op kind, window index, …).
    pub notes: Vec<(String, String)>,
}

impl SpanRecord {
    /// Span duration, µs.
    pub fn duration_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }
}

/// One completed trace: its id plus every span recorded under it.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct TraceRecord {
    /// The wire-propagated trace id (nonzero).
    pub trace: u64,
    /// Spans in recording order.
    pub spans: Vec<SpanRecord>,
}

impl TraceRecord {
    /// The root span (`parent == 0`), if one was recorded.
    pub fn root(&self) -> Option<&SpanRecord> {
        self.spans.iter().find(|s| s.parent == 0)
    }

    /// Root duration in µs (0 when no root span exists).
    pub fn root_duration_us(&self) -> u64 {
        self.root().map_or(0, SpanRecord::duration_us)
    }

    /// Direct children of `parent`, by start offset.
    pub fn children(&self, parent: u32) -> Vec<&SpanRecord> {
        let mut out: Vec<&SpanRecord> =
            self.spans.iter().filter(|s| s.parent == parent && s.id != parent).collect();
        out.sort_by_key(|s| (s.start_us, s.id));
        out
    }
}

/// A trace being recorded: shared (`Arc`) across every thread one
/// request touches. Span offsets are measured from `t0`, the monotonic
/// origin fixed at [`ActiveTrace::begin_at`].
pub struct ActiveTrace {
    trace: u64,
    t0: Instant,
    next: AtomicU32,
    spans: Mutex<Vec<SpanRecord>>,
}

impl ActiveTrace {
    /// Open a trace with origin "now".
    pub fn begin(trace: u64) -> Arc<ActiveTrace> {
        Self::begin_at(trace, Instant::now())
    }

    /// Open a trace with an explicit origin instant (the server uses the
    /// frame-header read instant so the root span covers the whole
    /// request, not just the part after decode).
    pub fn begin_at(trace: u64, t0: Instant) -> Arc<ActiveTrace> {
        Arc::new(ActiveTrace {
            trace,
            t0,
            next: AtomicU32::new(0),
            spans: Mutex::new(Vec::new()),
        })
    }

    /// The trace id.
    pub fn trace(&self) -> u64 {
        self.trace
    }

    /// The monotonic origin every span offset is measured from.
    pub fn origin(&self) -> Instant {
        self.t0
    }

    fn offset_us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.t0).as_micros().min(u64::MAX as u128) as u64
    }

    fn next_id(&self) -> u32 {
        self.next.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Start a live span under `parent` (0 = root), clocked from "now".
    pub fn span(self: &Arc<Self>, parent: u32, name: &str) -> Span {
        self.span_at(parent, name, Instant::now())
    }

    /// Start a live span with an explicit start instant.
    pub fn span_at(self: &Arc<Self>, parent: u32, name: &str, start: Instant) -> Span {
        Span {
            trace: Arc::clone(self),
            id: self.next_id(),
            parent,
            name: name.to_string(),
            start,
            notes: Vec::new(),
        }
    }

    /// Record a completed interval retroactively (e.g. queue wait, known
    /// only once a worker dequeues). Returns the new span's id.
    pub fn record(&self, parent: u32, name: &str, start: Instant, end: Instant) -> u32 {
        self.record_with(parent, name, start, end, Vec::new())
    }

    /// [`ActiveTrace::record`] with annotations.
    pub fn record_with(
        &self,
        parent: u32,
        name: &str,
        start: Instant,
        end: Instant,
        notes: Vec<(String, String)>,
    ) -> u32 {
        let id = self.next_id();
        let rec = SpanRecord {
            id,
            parent,
            name: name.to_string(),
            start_us: self.offset_us(start),
            end_us: self.offset_us(end),
            notes,
        };
        if let Ok(mut spans) = self.spans.lock() {
            spans.push(rec);
        }
        id
    }

    /// Freeze the spans recorded so far into a plain-data record. Spans
    /// still open (unfinished [`Span`] guards) are not included.
    fn freeze(&self) -> TraceRecord {
        let spans = self.spans.lock().map(|mut s| std::mem::take(&mut *s)).unwrap_or_default();
        TraceRecord { trace: self.trace, spans }
    }
}

/// A live span guard: records its interval into the owning
/// [`ActiveTrace`] when finished (or dropped).
pub struct Span {
    trace: Arc<ActiveTrace>,
    id: u32,
    parent: u32,
    name: String,
    start: Instant,
    notes: Vec<(String, String)>,
}

impl Span {
    /// This span's id (the parent id for spans nested under it).
    pub fn id(&self) -> u32 {
        self.id
    }

    /// Attach a `key=value` annotation.
    pub fn note(&mut self, key: &str, value: impl Into<String>) {
        self.notes.push((key.to_string(), value.into()));
    }

    /// A propagation context whose children nest under this span.
    pub fn ctx(&self) -> SpanCtx {
        SpanCtx { trace: Arc::clone(&self.trace), parent: self.id }
    }

    /// End the span now (recording happens in `Drop`, so an early return
    /// still closes it; `finish` just makes the end explicit).
    pub fn finish(self) {}
}

impl Drop for Span {
    fn drop(&mut self) {
        let rec = SpanRecord {
            id: self.id,
            parent: self.parent,
            name: std::mem::take(&mut self.name),
            start_us: self.trace.offset_us(self.start),
            end_us: self.trace.offset_us(Instant::now()),
            notes: std::mem::take(&mut self.notes),
        };
        if let Ok(mut spans) = self.trace.spans.lock() {
            spans.push(rec);
        }
    }
}

/// The propagation context threaded through queue tasks and backends: the
/// shared trace plus the span id new children nest under.
#[derive(Clone)]
pub struct SpanCtx {
    /// The trace being recorded.
    pub trace: Arc<ActiveTrace>,
    /// Parent span id for spans opened through this context.
    pub parent: u32,
}

impl SpanCtx {
    /// Open a child span.
    pub fn span(&self, name: &str) -> Span {
        self.trace.span(self.parent, name)
    }

    /// Record a completed child interval retroactively.
    pub fn record(&self, name: &str, start: Instant, end: Instant) -> u32 {
        self.trace.record(self.parent, name, start, end)
    }
}

/// The process-global retention side: sampling state plus the completed
/// and slow-trace rings. Servers use [`global()`]; tests can own one.
pub struct TraceCollector {
    enabled: AtomicBool,
    one_in_n: AtomicU64,
    slow_us: AtomicU64,
    tick: AtomicU64,
    next_trace: AtomicU64,
    ring: Mutex<VecDeque<TraceRecord>>,
    slow: Mutex<VecDeque<TraceRecord>>,
}

impl TraceCollector {
    /// A fresh collector: enabled, sampling 1-in-64, 100 ms slow bar.
    pub fn new() -> TraceCollector {
        TraceCollector {
            enabled: AtomicBool::new(true),
            one_in_n: AtomicU64::new(64),
            slow_us: AtomicU64::new(100_000),
            tick: AtomicU64::new(0),
            next_trace: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
            slow: Mutex::new(VecDeque::new()),
        }
    }

    /// Whether traces are being sampled at all.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Turn tracing on or off (the overhead bench's baseline).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Current sampling rate: one trace per `n` sampled requests.
    pub fn one_in_n(&self) -> u64 {
        self.one_in_n.load(Ordering::Relaxed)
    }

    /// Sample one request in `n` (clamped ≥ 1; 1 traces everything).
    pub fn set_one_in_n(&self, n: u64) {
        self.one_in_n.store(n.max(1), Ordering::Relaxed);
    }

    /// Slow-query threshold in µs applied to the root span (0 disables
    /// the slow log).
    pub fn slow_us(&self) -> u64 {
        self.slow_us.load(Ordering::Relaxed)
    }

    /// Set the slow-query threshold.
    pub fn set_slow_us(&self, v: u64) {
        self.slow_us.store(v, Ordering::Relaxed);
    }

    /// Origin-side sampling decision: a fresh nonzero trace id for one in
    /// [`one_in_n`](Self::one_in_n) calls while enabled, 0 otherwise.
    /// The fast path is one relaxed load (disabled) or two relaxed RMWs.
    #[inline]
    pub fn sample(&self) -> u64 {
        if !self.enabled() {
            return 0;
        }
        let n = self.one_in_n();
        if n > 1 && self.tick.fetch_add(1, Ordering::Relaxed) % n != 0 {
            return 0;
        }
        self.next_trace.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Retire a trace: freeze its spans, append to the recent ring, and
    /// when the root exceeded [`slow_us`](Self::slow_us) retain a copy in
    /// the slow ring and log it at warn.
    pub fn finish(&self, active: &ActiveTrace) {
        let rec = active.freeze();
        if rec.spans.is_empty() {
            return;
        }
        let slow_bar = self.slow_us();
        let dur = rec.root_duration_us();
        if slow_bar > 0 && dur >= slow_bar {
            let root = rec.root().map(|r| r.name.clone()).unwrap_or_default();
            warn_log!(
                "slow query: trace {:016x} root {root:?} took {dur} µs (bar {slow_bar} µs, \
                 {} spans)",
                rec.trace,
                rec.spans.len()
            );
            if let Ok(mut slow) = self.slow.lock() {
                slow.push_back(rec.clone());
                while slow.len() > SLOW_RING_CAP {
                    slow.pop_front();
                }
            }
        }
        if let Ok(mut ring) = self.ring.lock() {
            ring.push_back(rec);
            while ring.len() > TRACE_RING_CAP {
                ring.pop_front();
            }
        }
    }

    /// The `n` retained traces with the longest root spans (slow ring
    /// first, deduplicated), longest first.
    pub fn dump_slowest(&self, n: usize) -> Vec<TraceRecord> {
        let mut out: Vec<TraceRecord> = Vec::new();
        let mut push = |r: &TraceRecord| {
            let key = (r.trace, r.root_duration_us(), r.spans.len());
            if !out.iter().any(|o| (o.trace, o.root_duration_us(), o.spans.len()) == key) {
                out.push(r.clone());
            }
        };
        if let Ok(slow) = self.slow.lock() {
            slow.iter().for_each(&mut push);
        }
        if let Ok(ring) = self.ring.lock() {
            ring.iter().for_each(&mut push);
        }
        out.sort_by(|a, b| b.root_duration_us().cmp(&a.root_duration_us()));
        out.truncate(n);
        out
    }

    /// Every retained record of trace `id` — one request can leave
    /// several views (the client-side send trace and the server-side
    /// request trace share the id when both ends live in one process).
    pub fn dump_by_id(&self, id: u64) -> Vec<TraceRecord> {
        let mut out: Vec<TraceRecord> = Vec::new();
        let mut push = |r: &TraceRecord| {
            if r.trace == id && !out.contains(r) {
                out.push(r.clone());
            }
        };
        if let Ok(ring) = self.ring.lock() {
            ring.iter().for_each(&mut push);
        }
        if let Ok(slow) = self.slow.lock() {
            slow.iter().for_each(&mut push);
        }
        out
    }

    /// Drop every retained trace (tests, benches).
    pub fn clear(&self) {
        if let Ok(mut ring) = self.ring.lock() {
            ring.clear();
        }
        if let Ok(mut slow) = self.slow.lock() {
            slow.clear();
        }
    }
}

impl Default for TraceCollector {
    fn default() -> Self {
        Self::new()
    }
}

/// The process-global collector every serving layer samples from and
/// retires into.
pub fn global() -> &'static TraceCollector {
    static GLOBAL: OnceLock<TraceCollector> = OnceLock::new();
    GLOBAL.get_or_init(TraceCollector::new)
}

/// Whether the global collector is sampling (`false` short-circuits every
/// tracing call site to a single relaxed load, mirroring the metrics
/// registry's enable gate).
#[inline]
pub fn tracing_enabled() -> bool {
    global().enabled()
}

/// Enable / disable global tracing.
pub fn set_tracing_enabled(on: bool) {
    global().set_enabled(on);
}

/// Set the global sampling rate (trace one request in `n`; min 1).
pub fn set_trace_one_in_n(n: u64) {
    global().set_one_in_n(n);
}

/// Set the global slow-query threshold in µs (0 disables the slow log).
pub fn set_slow_us(v: u64) {
    global().set_slow_us(v);
}

/// Global origin-side sampling decision (see [`TraceCollector::sample`]).
#[inline]
pub fn sample() -> u64 {
    global().sample()
}

/// Retire a trace into the global collector.
pub fn finish(active: &ActiveTrace) {
    global().finish(active);
}

/// The globally retained traces with the longest roots.
pub fn dump_slowest(n: usize) -> Vec<TraceRecord> {
    global().dump_slowest(n)
}

/// Every globally retained record of one trace id.
pub fn dump_by_id(id: u64) -> Vec<TraceRecord> {
    global().dump_by_id(id)
}

// ---------------------------------------------------------------------
// Byte encoding (the `TraceDump` wire payload)
// ---------------------------------------------------------------------

fn put_str(out: &mut Vec<u8>, s: &str) {
    let len = s.len().min(u16::MAX as usize);
    out.extend_from_slice(&(len as u16).to_le_bytes());
    out.extend_from_slice(&s.as_bytes()[..len]);
}

/// Serialize traces into the versioned byte layout ships over the wire.
pub fn encode_traces(traces: &[TraceRecord]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&TRACE_VERSION.to_le_bytes());
    out.extend_from_slice(&(traces.len() as u32).to_le_bytes());
    for t in traces {
        out.extend_from_slice(&t.trace.to_le_bytes());
        out.extend_from_slice(&(t.spans.len() as u32).to_le_bytes());
        for s in &t.spans {
            out.extend_from_slice(&s.id.to_le_bytes());
            out.extend_from_slice(&s.parent.to_le_bytes());
            put_str(&mut out, &s.name);
            out.extend_from_slice(&s.start_us.to_le_bytes());
            out.extend_from_slice(&s.end_us.to_le_bytes());
            let notes = s.notes.len().min(u16::MAX as usize);
            out.extend_from_slice(&(notes as u16).to_le_bytes());
            for (k, v) in s.notes.iter().take(notes) {
                put_str(&mut out, k);
                put_str(&mut out, v);
            }
        }
    }
    out
}

/// Bounds-checked little cursor over an encoded trace payload (same
/// idiom as the snapshot codec: every length is validated against the
/// remaining bytes before any allocation).
struct Rd<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() - self.i < n {
            return Err(Error::Parse("trace payload truncated".into()));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("2 bytes")))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    /// An element count, validated against the bytes actually left so a
    /// hostile count cannot force a huge allocation.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_bytes) > self.b.len() - self.i {
            return Err(Error::Parse("trace payload count exceeds payload".into()));
        }
        Ok(n)
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u16()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::Parse("trace payload holds non-UTF-8 text".into()))
    }

    fn done(&self) -> Result<()> {
        if self.i != self.b.len() {
            return Err(Error::Parse("trace payload has trailing bytes".into()));
        }
        Ok(())
    }
}

/// Parse an encoded trace payload. Rejects unknown versions, truncation,
/// hostile counts, and trailing bytes with typed parse errors.
pub fn decode_traces(bytes: &[u8]) -> Result<Vec<TraceRecord>> {
    let mut rd = Rd { b: bytes, i: 0 };
    let version = rd.u16()?;
    if version == 0 || version > TRACE_VERSION {
        return Err(Error::Parse(format!("unsupported trace payload version {version}")));
    }
    // minimum bytes per trace: id (8) + span count (4)
    let traces = rd.count(12)?;
    let mut out = Vec::with_capacity(traces);
    for _ in 0..traces {
        let trace = rd.u64()?;
        // minimum bytes per span: id + parent + name len + times + notes
        let spans = rd.count(4 + 4 + 2 + 8 + 8 + 2)?;
        let mut t = TraceRecord { trace, spans: Vec::with_capacity(spans) };
        for _ in 0..spans {
            let id = rd.u32()?;
            let parent = rd.u32()?;
            let name = rd.str()?;
            let start_us = rd.u64()?;
            let end_us = rd.u64()?;
            let notes = rd.u16()? as usize;
            let mut ns = Vec::with_capacity(notes.min(64));
            for _ in 0..notes {
                let k = rd.str()?;
                let v = rd.str()?;
                ns.push((k, v));
            }
            t.spans.push(SpanRecord { id, parent, name, start_us, end_us, notes: ns });
        }
        out.push(t);
    }
    rd.done()?;
    Ok(out)
}

// ---------------------------------------------------------------------
// Rendering (the `matsketch trace` timelines)
// ---------------------------------------------------------------------

fn render_span(t: &TraceRecord, s: &SpanRecord, depth: usize, out: &mut String) {
    use std::fmt::Write as _;
    let indent = "  ".repeat(depth);
    let notes: String = s
        .notes
        .iter()
        .map(|(k, v)| format!("  {k}={v}"))
        .collect();
    let _ = writeln!(
        out,
        "{indent}[{:>8} ..{:>8}] {:<14} {:>8} µs{notes}",
        s.start_us,
        s.end_us,
        s.name,
        s.duration_us()
    );
    for child in t.children(s.id) {
        render_span(t, child, depth + 1, out);
    }
}

/// Render span trees as indented timelines (one block per record).
pub fn render(traces: &[TraceRecord]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for t in traces {
        let _ = writeln!(
            out,
            "trace {:016x} · {} spans · root {} µs",
            t.trace,
            t.spans.len(),
            t.root_duration_us()
        );
        for root in t.children(0) {
            render_span(t, root, 1, &mut out);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn record(trace: u64, root_us: u64) -> TraceRecord {
        TraceRecord {
            trace,
            spans: vec![
                SpanRecord {
                    id: 1,
                    parent: 0,
                    name: "request".into(),
                    start_us: 0,
                    end_us: root_us,
                    notes: vec![("op".into(), "matvec".into())],
                },
                SpanRecord {
                    id: 2,
                    parent: 1,
                    name: "queue_wait".into(),
                    start_us: 1,
                    end_us: 3,
                    notes: Vec::new(),
                },
            ],
        }
    }

    #[test]
    fn sampling_respects_enable_flag_and_rate() {
        let c = TraceCollector::new();
        c.set_enabled(false);
        assert_eq!(c.sample(), 0);
        c.set_enabled(true);
        c.set_one_in_n(4);
        let sampled = (0..40).filter(|_| c.sample() != 0).count();
        assert_eq!(sampled, 10, "1-in-4 sampling over 40 requests");
        c.set_one_in_n(1);
        // trace ids are distinct and never zero
        let a = c.sample();
        let b = c.sample();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
        // a zero knob clamps to 1 instead of disabling by accident
        c.set_one_in_n(0);
        assert_eq!(c.one_in_n(), 1);
    }

    #[test]
    fn spans_nest_and_freeze_into_a_tree() {
        let active = ActiveTrace::begin(7);
        let mut root = active.span(0, "request");
        root.note("op", "matvec");
        let root_id = root.id();
        {
            let child = active.span(root_id, "exec");
            std::thread::sleep(Duration::from_millis(1));
            child.finish();
        }
        let t_mid = Instant::now();
        active.record(root_id, "queue_wait", active.origin(), t_mid);
        root.finish();

        let c = TraceCollector::new();
        c.finish(&active);
        let dump = c.dump_by_id(7);
        assert_eq!(dump.len(), 1);
        let t = &dump[0];
        assert_eq!(t.spans.len(), 3);
        let root = t.root().expect("root span");
        assert_eq!(root.name, "request");
        assert_eq!(root.notes, vec![("op".to_string(), "matvec".to_string())]);
        let kids = t.children(root.id);
        let names: Vec<&str> = kids.iter().map(|s| s.name.as_str()).collect();
        assert!(names.contains(&"exec") && names.contains(&"queue_wait"), "{names:?}");
        assert!(root.duration_us() >= 1000, "root {} µs", root.duration_us());
        // finishing again is a no-op (spans were drained)
        c.finish(&active);
        assert_eq!(c.dump_by_id(7).len(), 1);
    }

    #[test]
    fn rings_bound_retention_and_keep_slow_traces() {
        let c = TraceCollector::new();
        c.set_slow_us(1_000);
        // one slow trace, then enough fast ones to evict it from the ring
        let slow = ActiveTrace::begin(1);
        let t0 = slow.origin();
        slow.record(0, "request", t0, t0 + Duration::from_millis(50));
        c.finish(&slow);
        for i in 0..(TRACE_RING_CAP as u64 + 8) {
            let fast = ActiveTrace::begin(100 + i);
            let t0 = fast.origin();
            fast.record(0, "request", t0, t0 + Duration::from_micros(10));
            c.finish(&fast);
        }
        // the slow trace survived eviction via the slow ring
        let slowest = c.dump_slowest(3);
        assert_eq!(slowest[0].trace, 1);
        assert_eq!(slowest[0].root_duration_us(), 50_000);
        assert!(slowest.len() > 1 && slowest[1].root_duration_us() <= 50_000);
        assert_eq!(c.dump_by_id(1).len(), 1);
        c.clear();
        assert!(c.dump_slowest(3).is_empty());
    }

    #[test]
    fn encode_decode_roundtrips_and_rejects_corruption() {
        let traces = vec![record(0xAB, 1234), record(0xCD, 99)];
        let bytes = encode_traces(&traces);
        assert_eq!(decode_traces(&bytes).unwrap(), traces);
        // empty set round-trips too
        assert!(decode_traces(&encode_traces(&[])).unwrap().is_empty());

        // truncation, bad version, hostile count, trailing bytes
        assert!(decode_traces(&bytes[..bytes.len() - 1]).is_err());
        let mut bad = bytes.clone();
        bad[0] = 0xFF;
        bad[1] = 0xFF;
        assert!(decode_traces(&bad).is_err());
        let mut hostile = encode_traces(&[]);
        hostile[2] = 0xFF;
        hostile[3] = 0xFF;
        assert!(decode_traces(&hostile).is_err());
        let mut trailing = bytes;
        trailing.push(0);
        assert!(decode_traces(&trailing).is_err());
    }

    #[test]
    fn render_draws_an_indented_timeline() {
        let out = render(&[record(0x2A, 1234)]);
        assert!(out.contains("trace 000000000000002a"), "{out}");
        assert!(out.contains("request"), "{out}");
        assert!(out.contains("op=matvec"), "{out}");
        let req_line = out.lines().find(|l| l.contains("request")).unwrap();
        let queue_line = out.lines().find(|l| l.contains("queue_wait")).unwrap();
        let lead = |l: &str| l.len() - l.trim_start().len();
        assert!(lead(queue_line) > lead(req_line), "children indent deeper");
    }
}
