//! Server-wide telemetry: a process-global, lock-free metrics registry.
//!
//! The paper's pitch is cost discipline — O(1) work per streamed
//! non-zero — and the serving stack built on top of it (PRs 2–6) should
//! be observable without betraying that spirit. This module provides:
//!
//! * [`MetricsRegistry`] — a fixed set of [`Counter`]s, [`Gauge`]s, and
//!   log₂-bucketed latency [`Hist`]ograms backed by plain `AtomicU64`
//!   cells. Recording an event is **one relaxed `fetch_add`** (plus one
//!   relaxed load of the enable flag); there are no locks, no hashing,
//!   and no allocation anywhere on the record path.
//! * [`MetricsSnapshot`] — a plain-data, name-keyed copy of the registry
//!   that merges, diffs, and extracts p50/p95/p99 from the histogram
//!   buckets (via [`crate::util::stats::histogram_quantile`]), and
//!   round-trips through a versioned byte encoding so the `Stats` wire
//!   opcode can ship it to remote scrapers.
//! * [`global()`] — the process-global registry every serving layer
//!   records into: `net::server` (per-opcode counts, bytes, faults by
//!   code, connection gauge), `serve::server` (queue-wait vs execute
//!   split, per-op execute histograms, whole-vs-sharded decisions),
//!   the open-sketch caches (`api::local` + `net::server`), and
//!   `serve::live` (publish duration, generation, freshness lag,
//!   retained-pin hits).
//!
//! Beside the aggregate registry, [`trace`] adds request-scoped span
//! timelines: a sampled query carries a wire-propagated trace id
//! (protocol v5) and every serving stage — frame decode, queue wait,
//! split windows, reduction, reply write — records a span; completed
//! traces retire into bounded rings with a slow-query log, read back via
//! the `TraceDump` opcode / `matsketch trace`.
//!
//! Scrape it three ways: the `Stats` wire opcode
//! ([`crate::net::Request::Stats`]), the `matsketch stats --addr` CLI,
//! or [`crate::eval::report::server_metrics_table`] which renders a
//! snapshot (usually a before/after diff from a bench run) into
//! `reports/server_metrics.{csv,md}`.
//!
//! The histogram bucketing is the same idiom as
//! [`crate::engine::metrics::SPILL_DEPTH_BUCKETS`]: bucket 0 holds the
//! value 0, bucket `i ≥ 1` covers `[2^(i-1), 2^i)`, and the last bucket
//! is open-ended.

pub mod registry;
pub mod snapshot;
pub mod trace;

pub use registry::{
    global, hist_bucket, hist_bucket_bounds, Counter, Gauge, Hist, MetricsRegistry, HIST_BUCKETS,
};
pub use snapshot::{MetricsSnapshot, SNAPSHOT_VERSION};
pub use trace::{SpanCtx, SpanRecord, TraceRecord, TRACE_VERSION};
