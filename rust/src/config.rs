//! Experiment configuration: a small `key = value` / `[section]` config
//! format (TOML subset — serde/toml are unavailable offline) used by the
//! CLI to parametrize datasets, budgets and sweeps.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};

/// Parsed configuration: `section.key → value` strings with typed getters.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Parse(format!("config line {}: expected key = value", lineno + 1))
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, v.trim().trim_matches('"').to_string());
        }
        Ok(Config { values })
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Config> {
        Config::parse(&std::fs::read_to_string(path)?)
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default.
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Parse(format!("config {key}: cannot parse {v:?}"))),
        }
    }

    /// Keys under a section prefix.
    pub fn section_keys(&self, section: &str) -> Vec<&str> {
        let prefix = format!("{section}.");
        self.values
            .keys()
            .filter(|k| k.starts_with(&prefix))
            .map(|k| k.as_str())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let cfg = Config::parse(
            "top = 1\n# comment\n[sweep]\nbudgets = \"1e3,1e4\" # inline\nseed = 7\n",
        )
        .unwrap();
        assert_eq!(cfg.get("top"), Some("1"));
        assert_eq!(cfg.get("sweep.budgets"), Some("1e3,1e4"));
        assert_eq!(cfg.get_parse_or::<u64>("sweep.seed", 0).unwrap(), 7);
        assert_eq!(cfg.section_keys("sweep").len(), 2);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("not a kv line").is_err());
    }

    #[test]
    fn typed_defaults() {
        let cfg = Config::parse("").unwrap();
        assert_eq!(cfg.get_parse_or::<usize>("missing", 5).unwrap(), 5);
    }
}
