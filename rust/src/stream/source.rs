//! Concrete entry-stream sources.

use std::fs::File;
use std::io::{BufReader, Read};
use std::path::Path;

use super::EntryStream;
use crate::error::{Error, Result};
use crate::sparse::{Coo, Entry};
use crate::util::rng::Rng;

/// In-memory stream over a COO's entries, in stored order.
pub struct VecStream {
    m: usize,
    n: usize,
    entries: std::vec::IntoIter<Entry>,
}

impl VecStream {
    /// Stream a COO matrix (consumes a copy of the entries).
    pub fn new(coo: &Coo) -> VecStream {
        VecStream { m: coo.m, n: coo.n, entries: coo.entries.clone().into_iter() }
    }
}

impl EntryStream for VecStream {
    fn shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }
    fn next_entry(&mut self) -> Result<Option<Entry>> {
        Ok(self.entries.next())
    }
    fn size_hint(&self) -> Option<usize> {
        Some(self.entries.len())
    }
}

/// In-memory stream in a seeded *random* order — models the paper's
/// "non-zeros presented in arbitrary order".
pub struct ShuffledStream {
    inner: VecStream,
}

impl ShuffledStream {
    /// Shuffle the COO's entries with the given seed and stream them.
    pub fn new(coo: &Coo, seed: u64) -> ShuffledStream {
        let mut entries = coo.entries.clone();
        Rng::new(seed).shuffle(&mut entries);
        ShuffledStream {
            inner: VecStream { m: coo.m, n: coo.n, entries: entries.into_iter() },
        }
    }
}

impl EntryStream for ShuffledStream {
    fn shape(&self) -> (usize, usize) {
        self.inner.shape()
    }
    fn next_entry(&mut self) -> Result<Option<Entry>> {
        self.inner.next_entry()
    }
    fn size_hint(&self) -> Option<usize> {
        self.inner.size_hint()
    }
}

/// Header bytes of the binary triplet format: magic + m + n + nnz.
const HEADER_BYTES: u64 = 8 + 8 + 8 + 8;
/// Bytes per `(u32 row, u32 col, f32 val)` record.
const RECORD_BYTES: u64 = 12;

/// Streaming reader over the binary triplet file format
/// (`sparse::io::write_binary`) — entries never fully materialize in
/// memory, matching the "durable storage, random access prohibitive" mode.
///
/// The header `nnz` is validated against the file's payload length at
/// open, and a short read mid-stream surfaces as [`Error::Parse`] instead
/// of a silent early end-of-stream.
pub struct FileStream {
    m: usize,
    n: usize,
    remaining: usize,
    reader: BufReader<File>,
}

impl FileStream {
    /// Open a binary triplet file. For regular files the payload length
    /// is validated against the header's `nnz` (`header + nnz · 12`
    /// bytes) up front, so a truncated or padded file never masquerades
    /// as a clean stream; non-regular inputs (FIFOs, device files) have
    /// no meaningful length and rely on the per-record truncation check
    /// in [`EntryStream::next_entry`].
    pub fn open(path: &Path) -> Result<FileStream> {
        let file = File::open(path)?;
        let meta = file.metadata()?;
        let file_len = meta.len();
        let mut reader = BufReader::new(file);
        let mut magic = [0u8; 8];
        reader.read_exact(&mut magic)?;
        if &magic != b"MSKTRP01" {
            return Err(Error::Parse("bad magic".into()));
        }
        let mut b = [0u8; 8];
        reader.read_exact(&mut b)?;
        let m = u64::from_le_bytes(b) as usize;
        reader.read_exact(&mut b)?;
        let n = u64::from_le_bytes(b) as usize;
        reader.read_exact(&mut b)?;
        let nnz = u64::from_le_bytes(b) as usize;
        let expect_len = (nnz as u64)
            .checked_mul(RECORD_BYTES)
            .and_then(|payload| payload.checked_add(HEADER_BYTES))
            .ok_or_else(|| {
                Error::Parse(format!("triplet header nnz={nnz} overflows the format"))
            })?;
        if meta.is_file() && file_len != expect_len {
            return Err(Error::Parse(format!(
                "triplet file length mismatch: header says nnz={nnz} \
                 ({expect_len} bytes expected), file is {file_len} bytes"
            )));
        }
        Ok(FileStream { m, n, remaining: nnz, reader })
    }
}

impl EntryStream for FileStream {
    fn shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }
    fn next_entry(&mut self) -> Result<Option<Entry>> {
        if self.remaining == 0 {
            return Ok(None);
        }
        let mut rec = [0u8; 12];
        if let Err(e) = self.reader.read_exact(&mut rec) {
            // Surface truncation as a parse error — never a clean EOF.
            let missing = self.remaining;
            self.remaining = 0;
            return Err(Error::Parse(format!(
                "truncated triplet stream: {missing} records still expected ({e})"
            )));
        }
        self.remaining -= 1;
        Ok(Some(Entry::new(
            u32::from_le_bytes(rec[0..4].try_into().unwrap()),
            u32::from_le_bytes(rec[4..8].try_into().unwrap()),
            f32::from_le_bytes(rec[8..12].try_into().unwrap()),
        )))
    }
    fn size_hint(&self) -> Option<usize> {
        Some(self.remaining)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::io::write_binary;

    fn sample() -> Coo {
        let mut coo = Coo::new(3, 4);
        for (i, j, v) in [(0u32, 1u32, 1.0f32), (1, 0, -2.0), (2, 3, 0.5)] {
            coo.push(i, j, v);
        }
        coo
    }

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn vec_stream_yields_all() {
        let coo = sample();
        let mut s = VecStream::new(&coo);
        assert_eq!(s.shape(), (3, 4));
        assert_eq!(s.size_hint(), Some(3));
        let mut count = 0;
        while s.next_entry().unwrap().is_some() {
            count += 1;
        }
        assert_eq!(count, 3);
    }

    #[test]
    fn shuffled_stream_is_permutation() {
        let mut coo = Coo::new(1, 1000);
        for j in 0..1000u32 {
            coo.push(0, j, j as f32 + 1.0);
        }
        let mut s = ShuffledStream::new(&coo, 42);
        let mut cols: Vec<u32> = Vec::new();
        while let Some(e) = s.next_entry().unwrap() {
            cols.push(e.col);
        }
        assert_ne!(cols, (0..1000).collect::<Vec<_>>());
        cols.sort_unstable();
        assert_eq!(cols, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn file_stream_roundtrip() {
        let dir = tmp_dir("matsketch_stream_test");
        let path = dir.join("s.bin");
        let coo = sample();
        write_binary(&coo, &path).unwrap();
        let mut s = FileStream::open(&path).unwrap();
        assert_eq!(s.shape(), (3, 4));
        let mut got = Vec::new();
        while let Some(e) = s.next_entry().unwrap() {
            got.push(e);
        }
        assert_eq!(got, coo.entries);
    }

    #[test]
    fn open_rejects_truncated_payload() {
        // header claims 3 records but the payload holds fewer bytes
        let dir = tmp_dir("matsketch_stream_test_trunc_open");
        let path = dir.join("short.bin");
        write_binary(&sample(), &path).unwrap();
        let full = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - 5).unwrap();
        drop(f);
        let err = FileStream::open(&path).unwrap_err();
        assert!(
            err.to_string().contains("length mismatch"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn open_rejects_overflowing_header_nnz() {
        // a hostile header whose nnz·12 overflows u64 must be a parse
        // error, not an arithmetic panic
        let dir = tmp_dir("matsketch_stream_test_overflow");
        let path = dir.join("evil.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"MSKTRP01");
        bytes.extend_from_slice(&3u64.to_le_bytes());
        bytes.extend_from_slice(&4u64.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = FileStream::open(&path).unwrap_err();
        assert!(err.to_string().contains("overflow"), "unexpected error: {err}");
    }

    #[test]
    fn open_rejects_trailing_garbage() {
        let dir = tmp_dir("matsketch_stream_test_pad");
        let path = dir.join("padded.bin");
        write_binary(&sample(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0u8; 7]); // not a whole record
        std::fs::write(&path, &bytes).unwrap();
        assert!(FileStream::open(&path).is_err());
    }

    #[test]
    fn truncation_mid_stream_is_a_parse_error_not_eof() {
        // Regression for the silent-EOF bug: a file truncated *after* open
        // (or any short read) must surface as Error::Parse, not Ok(None).
        let dir = tmp_dir("matsketch_stream_test_trunc_read");
        let path = dir.join("cut.bin");
        // larger than FileStream's internal read buffer, so truncation
        // past the buffered prefix is actually observed
        let mut coo = Coo::new(10, 2000);
        for j in 0..2000u32 {
            coo.push(j % 10, j, 1.0 + j as f32);
        }
        write_binary(&coo, &path).unwrap();
        let mut s = FileStream::open(&path).unwrap();
        // cut the file mid-record once the stream is already open
        let full = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - (RECORD_BYTES + 3)).unwrap();
        drop(f);
        let mut saw_err = false;
        let mut yielded = 0usize;
        loop {
            match s.next_entry() {
                Ok(Some(_)) => yielded += 1,
                Ok(None) => break,
                Err(e) => {
                    assert!(
                        e.to_string().contains("truncated triplet stream"),
                        "unexpected error: {e}"
                    );
                    saw_err = true;
                    break;
                }
            }
        }
        assert!(saw_err, "truncated stream ended cleanly after {yielded} entries");
        assert!(yielded < coo.nnz());
        // after the error the stream stays terminated
        assert!(matches!(s.next_entry(), Ok(None)));
    }
}
