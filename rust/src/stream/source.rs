//! Concrete entry-stream sources.

use std::fs::File;
use std::io::{BufReader, Read};
use std::path::Path;

use super::EntryStream;
use crate::error::{Error, Result};
use crate::sparse::{Coo, Entry};
use crate::util::rng::Rng;

/// In-memory stream over a COO's entries, in stored order.
pub struct VecStream {
    m: usize,
    n: usize,
    entries: std::vec::IntoIter<Entry>,
}

impl VecStream {
    /// Stream a COO matrix (consumes a copy of the entries).
    pub fn new(coo: &Coo) -> VecStream {
        VecStream { m: coo.m, n: coo.n, entries: coo.entries.clone().into_iter() }
    }
}

impl EntryStream for VecStream {
    fn shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }
    fn next_entry(&mut self) -> Option<Entry> {
        self.entries.next()
    }
    fn size_hint(&self) -> Option<usize> {
        Some(self.entries.len())
    }
}

/// In-memory stream in a seeded *random* order — models the paper's
/// "non-zeros presented in arbitrary order".
pub struct ShuffledStream {
    inner: VecStream,
}

impl ShuffledStream {
    /// Shuffle the COO's entries with the given seed and stream them.
    pub fn new(coo: &Coo, seed: u64) -> ShuffledStream {
        let mut entries = coo.entries.clone();
        Rng::new(seed).shuffle(&mut entries);
        ShuffledStream {
            inner: VecStream { m: coo.m, n: coo.n, entries: entries.into_iter() },
        }
    }
}

impl EntryStream for ShuffledStream {
    fn shape(&self) -> (usize, usize) {
        self.inner.shape()
    }
    fn next_entry(&mut self) -> Option<Entry> {
        self.inner.next_entry()
    }
    fn size_hint(&self) -> Option<usize> {
        self.inner.size_hint()
    }
}

/// Streaming reader over the binary triplet file format
/// (`sparse::io::write_binary`) — entries never fully materialize in
/// memory, matching the "durable storage, random access prohibitive" mode.
pub struct FileStream {
    m: usize,
    n: usize,
    remaining: usize,
    reader: BufReader<File>,
}

impl FileStream {
    /// Open a binary triplet file.
    pub fn open(path: &Path) -> Result<FileStream> {
        let mut reader = BufReader::new(File::open(path)?);
        let mut magic = [0u8; 8];
        reader.read_exact(&mut magic)?;
        if &magic != b"MSKTRP01" {
            return Err(Error::Parse("bad magic".into()));
        }
        let mut b = [0u8; 8];
        reader.read_exact(&mut b)?;
        let m = u64::from_le_bytes(b) as usize;
        reader.read_exact(&mut b)?;
        let n = u64::from_le_bytes(b) as usize;
        reader.read_exact(&mut b)?;
        let nnz = u64::from_le_bytes(b) as usize;
        Ok(FileStream { m, n, remaining: nnz, reader })
    }
}

impl EntryStream for FileStream {
    fn shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }
    fn next_entry(&mut self) -> Option<Entry> {
        if self.remaining == 0 {
            return None;
        }
        let mut rec = [0u8; 12];
        if self.reader.read_exact(&mut rec).is_err() {
            self.remaining = 0;
            return None;
        }
        self.remaining -= 1;
        Some(Entry::new(
            u32::from_le_bytes(rec[0..4].try_into().unwrap()),
            u32::from_le_bytes(rec[4..8].try_into().unwrap()),
            f32::from_le_bytes(rec[8..12].try_into().unwrap()),
        ))
    }
    fn size_hint(&self) -> Option<usize> {
        Some(self.remaining)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::io::write_binary;

    fn sample() -> Coo {
        let mut coo = Coo::new(3, 4);
        for (i, j, v) in [(0u32, 1u32, 1.0f32), (1, 0, -2.0), (2, 3, 0.5)] {
            coo.push(i, j, v);
        }
        coo
    }

    #[test]
    fn vec_stream_yields_all() {
        let coo = sample();
        let mut s = VecStream::new(&coo);
        assert_eq!(s.shape(), (3, 4));
        assert_eq!(s.size_hint(), Some(3));
        let mut count = 0;
        while s.next_entry().is_some() {
            count += 1;
        }
        assert_eq!(count, 3);
    }

    #[test]
    fn shuffled_stream_is_permutation() {
        let mut coo = Coo::new(1, 1000);
        for j in 0..1000u32 {
            coo.push(0, j, j as f32 + 1.0);
        }
        let mut s = ShuffledStream::new(&coo, 42);
        let mut cols: Vec<u32> = Vec::new();
        while let Some(e) = s.next_entry() {
            cols.push(e.col);
        }
        assert_ne!(cols, (0..1000).collect::<Vec<_>>());
        cols.sort_unstable();
        assert_eq!(cols, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn file_stream_roundtrip() {
        let dir = std::env::temp_dir().join("matsketch_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.bin");
        let coo = sample();
        write_binary(&coo, &path).unwrap();
        let mut s = FileStream::open(&path).unwrap();
        assert_eq!(s.shape(), (3, 4));
        let mut got = Vec::new();
        while let Some(e) = s.next_entry() {
            got.push(e);
        }
        assert_eq!(got, coo.entries);
    }
}
