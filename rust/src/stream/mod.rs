//! Entry streams: the arbitrary-order sources the coordinator ingests.
//!
//! The paper's model presents non-zeros one at a time in arbitrary order;
//! [`EntryStream`] abstracts the source (in-memory, shuffled, file-backed)
//! so the pipeline code is identical for all of them.

pub mod source;

pub use source::{FileStream, ShuffledStream, VecStream};

use crate::sparse::Entry;

/// A finite stream of matrix non-zeros with known shape.
pub trait EntryStream {
    /// `(m, n)` of the underlying matrix.
    fn shape(&self) -> (usize, usize);
    /// Next entry, or `None` at end of stream.
    fn next_entry(&mut self) -> Option<Entry>;
    /// Optional size hint (number of remaining entries).
    fn size_hint(&self) -> Option<usize> {
        None
    }
}

impl<S: EntryStream + ?Sized> EntryStream for Box<S> {
    fn shape(&self) -> (usize, usize) {
        (**self).shape()
    }
    fn next_entry(&mut self) -> Option<Entry> {
        (**self).next_entry()
    }
    fn size_hint(&self) -> Option<usize> {
        (**self).size_hint()
    }
}
