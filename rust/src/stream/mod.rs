//! Entry streams: the arbitrary-order sources the engine ingests.
//!
//! The paper's model presents non-zeros one at a time in arbitrary order;
//! [`EntryStream`] abstracts the source (in-memory, shuffled, file-backed)
//! so every [`crate::engine::Sketcher`] mode is identical for all of them.

pub mod source;

pub use source::{FileStream, ShuffledStream, VecStream};

use crate::error::Result;
use crate::sparse::Entry;

/// A finite stream of matrix non-zeros with known shape.
pub trait EntryStream {
    /// `(m, n)` of the underlying matrix.
    fn shape(&self) -> (usize, usize);
    /// Next entry. `Ok(None)` at a clean end of stream; `Err` when the
    /// source is corrupt (e.g. a truncated file) — a short read is never
    /// silently treated as end-of-stream.
    fn next_entry(&mut self) -> Result<Option<Entry>>;
    /// Optional size hint (number of remaining entries).
    fn size_hint(&self) -> Option<usize> {
        None
    }
}

impl<S: EntryStream + ?Sized> EntryStream for Box<S> {
    fn shape(&self) -> (usize, usize) {
        (**self).shape()
    }
    fn next_entry(&mut self) -> Result<Option<Entry>> {
        (**self).next_entry()
    }
    fn size_hint(&self) -> Option<usize> {
        (**self).size_hint()
    }
}
