//! Project static analysis: the `matsketch lint` subcommand.
//!
//! A std-only source analyzer enforcing the invariants this codebase's
//! serving stack depends on but the compiler cannot see:
//!
//! * the **unsafe-audit** discipline around the raw-libc `mmap` FFI,
//! * the **atomics-ordering allowlist** (telemetry is Relaxed-only, the
//!   live-chain RCU publication is Acquire/Release, `SeqCst` is
//!   deny-by-default),
//! * **panic-free decode** paths facing bytes from disk or the wire,
//! * the **wire-discipline** cross-check between `net/wire.rs`'s opcode
//!   table, its test corpus, and the README wire table,
//! * **timed-section gating** per the telemetry overhead contract.
//!
//! The pipeline: [`lexer`] strips comments/strings with a small
//! hand-rolled Rust lexer and marks `#[cfg(test)]` regions, [`lints`]
//! runs the registry over every `.rs` file, [`baseline`] subtracts the
//! checked-in `lint.allow` exceptions (reporting stale entries), and
//! [`report`] emits `reports/lint.{json,md}`. The CLI exits nonzero on
//! any non-baselined finding, which is what the CI `lint` step gates on.
//!
//! Everything is a pure function of file contents, so the self-test
//! fixtures inject violations as in-memory sources and the integration
//! suite asserts the real tree is lint-clean.

pub mod baseline;
pub mod lexer;
pub mod lints;
pub mod report;

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

pub use baseline::AllowEntry;

/// One loaded-and-lexed source file.
#[derive(Debug)]
pub struct SourceFile {
    /// Crate-relative path with `/` separators (e.g. `src/net/wire.rs`).
    pub path: String,
    /// Raw source text.
    pub src: String,
    /// Lexed form (code/comment split, test regions marked).
    pub model: lexer::Model,
}

impl SourceFile {
    /// Lex `src` under crate-relative `path`. Files under `tests/` are
    /// test code in their entirety.
    pub fn new(path: &str, src: &str) -> SourceFile {
        let all_test = path.starts_with("tests/");
        SourceFile {
            path: path.to_string(),
            src: src.to_string(),
            model: lexer::model(src, all_test),
        }
    }
}

/// One lint finding, pointing at `path:line`.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Lint id (one of [`lints::LINT_IDS`]).
    pub lint: &'static str,
    /// Crate-relative path.
    pub path: String,
    /// 1-based line.
    pub line: usize,
    /// What is wrong and what to do instead.
    pub message: String,
    /// The trimmed code text of the offending line (baseline key).
    pub excerpt: String,
}

impl Finding {
    /// `path:line [lint] message` — the CLI output row.
    pub fn render(&self) -> String {
        format!("{}:{} [{}] {}", self.path, self.line, self.lint, self.message)
    }
}

/// Where to find the tree, the baseline, and where to write reports.
#[derive(Debug, Clone)]
pub struct LintConfig {
    /// The cargo crate root (the directory holding `Cargo.toml`).
    pub crate_root: PathBuf,
    /// The repo README carrying the wire table, if present.
    pub readme: Option<PathBuf>,
    /// The `lint.allow` baseline file, if present.
    pub allow: Option<PathBuf>,
}

impl LintConfig {
    /// Locate the project from `start` (usually the working directory):
    /// walk upward to the first directory holding `Cargo.toml` and
    /// `src/`, take the wire-table README from that crate root or its
    /// parent, and the baseline from `src/analysis/lint.allow`.
    pub fn locate(start: &Path) -> Result<LintConfig> {
        let mut dir = start.to_path_buf();
        loop {
            if dir.join("Cargo.toml").is_file() && dir.join("src").is_dir() {
                break;
            }
            // a checkout root holding the crate under `rust/`
            if dir.join("rust/Cargo.toml").is_file() && dir.join("rust/src").is_dir() {
                dir = dir.join("rust");
                break;
            }
            if !dir.pop() {
                return Err(Error::invalid(format!(
                    "no Cargo.toml + src/ found above {}",
                    start.display()
                )));
            }
        }
        let readme = [dir.join("README.md"), dir.join("../README.md")]
            .into_iter()
            .find(|p| p.is_file());
        let allow = Some(dir.join("src/analysis/lint.allow")).filter(|p| p.is_file());
        Ok(LintConfig { crate_root: dir, readme, allow })
    }
}

/// The outcome of one analyzer run.
#[derive(Debug)]
pub struct LintReport {
    /// `.rs` files scanned.
    pub files_scanned: usize,
    /// Non-baselined findings — any entry here fails the run.
    pub findings: Vec<Finding>,
    /// Findings accepted by `lint.allow`.
    pub baselined: Vec<Finding>,
    /// `lint.allow` entries that matched nothing (rot).
    pub stale_allow: Vec<AllowEntry>,
}

impl LintReport {
    /// Whether the tree passes (no non-baselined findings).
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Run the registry over in-memory sources — the hook the self-test
/// fixtures and the integration suite use to inject violations.
pub fn analyze_sources(
    files: &[SourceFile],
    readme: Option<&str>,
    allow: &[AllowEntry],
) -> LintReport {
    let all = lints::run_all(files, readme);
    let (findings, baselined, stale_allow) = baseline::apply(all, allow);
    LintReport { files_scanned: files.len(), findings, baselined, stale_allow }
}

/// Run the analyzer over the tree described by `cfg`.
pub fn run(cfg: &LintConfig) -> Result<LintReport> {
    let mut files = Vec::new();
    for sub in ["src", "tests", "benches", "examples"] {
        let dir = cfg.crate_root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &cfg.crate_root, &mut files)?;
        }
    }
    let readme = match &cfg.readme {
        Some(p) => Some(fs::read_to_string(p)?),
        None => None,
    };
    let allow = match &cfg.allow {
        Some(p) => baseline::parse(&fs::read_to_string(p)?),
        None => Vec::new(),
    };
    Ok(analyze_sources(&files, readme.as_deref(), &allow))
}

/// Recursively collect `.rs` files under `dir` (sorted, deterministic).
fn collect_rs(dir: &Path, root: &Path, out: &mut Vec<SourceFile>) -> Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, root, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(SourceFile::new(&rel, &fs::read_to_string(&path)?));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const BAD_DECODE: &str = "fn f(v: &[u8]) -> u8 {\n    v[0]\n}\n";

    #[test]
    fn analyze_sources_reports_open_findings() {
        let report =
            analyze_sources(&[SourceFile::new("src/net/wire.rs", BAD_DECODE)], None, &[]);
        assert!(!report.clean());
        assert_eq!(report.files_scanned, 1);
        assert_eq!(report.findings.len(), 1);
        let f = &report.findings[0];
        assert_eq!((f.lint, f.line, f.excerpt.as_str()), ("panic-free-decode", 2, "v[0]"));
        assert_eq!(f.render(), format!("src/net/wire.rs:2 [panic-free-decode] {}", f.message));
    }

    #[test]
    fn baseline_accepts_matches_and_reports_rot() {
        let allow = baseline::parse(
            "panic-free-decode\tsrc/net/wire.rs\tv[0]\nunsafe-audit\tsrc/gone.rs\tunsafe {}\n",
        );
        let report =
            analyze_sources(&[SourceFile::new("src/net/wire.rs", BAD_DECODE)], None, &allow);
        assert!(report.clean());
        assert_eq!(report.baselined.len(), 1);
        assert_eq!(report.stale_allow.len(), 1);
        assert_eq!(report.stale_allow[0].line, 2);
    }

    #[test]
    fn tests_dir_files_are_test_code_in_their_entirety() {
        let f = SourceFile::new(
            "tests/integration.rs",
            "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n",
        );
        assert!(analyze_sources(&[f], None, &[]).clean());
    }
}
