//! Lint report emission: `reports/lint.json` (the CI gate artifact) and
//! `reports/lint.md` (the human summary).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use crate::error::Result;
use crate::util::json::Json;

use super::lints::LINT_IDS;
use super::{Finding, LintReport};

fn finding_json(f: &Finding) -> Json {
    let mut m = BTreeMap::new();
    m.insert("lint".into(), Json::Str(f.lint.into()));
    m.insert("path".into(), Json::Str(f.path.clone()));
    m.insert("line".into(), Json::Num(f.line as f64));
    m.insert("message".into(), Json::Str(f.message.clone()));
    m.insert("excerpt".into(), Json::Str(f.excerpt.clone()));
    Json::Obj(m)
}

/// The `lint.json` document. `clean` is the CI gate: zero non-baselined
/// findings.
pub fn to_json(r: &LintReport) -> Json {
    let mut m = BTreeMap::new();
    m.insert("schema".into(), Json::Num(1.0));
    m.insert("files_scanned".into(), Json::Num(r.files_scanned as f64));
    m.insert("clean".into(), Json::Bool(r.findings.is_empty()));
    m.insert("findings".into(), Json::Arr(r.findings.iter().map(finding_json).collect()));
    m.insert(
        "baselined".into(),
        Json::Arr(r.baselined.iter().map(finding_json).collect()),
    );
    m.insert(
        "stale_allow".into(),
        Json::Arr(r.stale_allow.iter().map(|e| Json::Str(e.render())).collect()),
    );
    Json::Obj(m)
}

/// The `lint.md` document.
pub fn to_markdown(r: &LintReport) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "# matsketch lint");
    let _ = writeln!(out);
    let _ = writeln!(
        out,
        "{} file(s) scanned — **{}**, {} finding(s), {} baselined, {} stale \
         baseline entr(ies).",
        r.files_scanned,
        if r.findings.is_empty() { "clean" } else { "FAILING" },
        r.findings.len(),
        r.baselined.len(),
        r.stale_allow.len(),
    );
    let _ = writeln!(out);
    let _ = writeln!(out, "| lint | findings | baselined |");
    let _ = writeln!(out, "|---|---:|---:|");
    for id in LINT_IDS {
        let open = r.findings.iter().filter(|f| f.lint == *id).count();
        let base = r.baselined.iter().filter(|f| f.lint == *id).count();
        let _ = writeln!(out, "| {id} | {open} | {base} |");
    }
    for (title, list) in [("Findings", &r.findings), ("Baselined", &r.baselined)] {
        if list.is_empty() {
            continue;
        }
        let _ = writeln!(out);
        let _ = writeln!(out, "## {title}");
        let _ = writeln!(out);
        for f in list.iter() {
            let _ = writeln!(
                out,
                "- `{}:{}` **{}** — {} (`{}`)",
                f.path, f.line, f.lint, f.message, f.excerpt
            );
        }
    }
    if !r.stale_allow.is_empty() {
        let _ = writeln!(out);
        let _ = writeln!(out, "## Stale `lint.allow` entries");
        let _ = writeln!(out);
        for e in &r.stale_allow {
            let _ = writeln!(out, "- line {}: `{}`", e.line, e.render());
        }
    }
    out
}

/// Write `lint.json` + `lint.md` under `dir` (created if needed).
pub fn write(r: &LintReport, dir: &Path) -> Result<()> {
    fs::create_dir_all(dir)?;
    fs::write(dir.join("lint.json"), to_json(r).to_string())?;
    fs::write(dir.join("lint.md"), to_markdown(r))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> LintReport {
        LintReport {
            files_scanned: 3,
            findings: vec![Finding {
                lint: "timed-gating",
                path: "src/serve/live.rs".to_string(),
                line: 12,
                message: "ungated clock read".to_string(),
                excerpt: "let t = Instant::now();".to_string(),
            }],
            baselined: Vec::new(),
            stale_allow: Vec::new(),
        }
    }

    #[test]
    fn json_carries_the_ci_gate_flag() {
        let doc = to_json(&sample()).to_string();
        assert!(doc.contains("\"clean\":false"));
        assert!(doc.contains("\"files_scanned\":3"));
        assert!(doc.contains("src/serve/live.rs"));
        let clean = LintReport { findings: Vec::new(), ..sample() };
        assert!(to_json(&clean).to_string().contains("\"clean\":true"));
    }

    #[test]
    fn markdown_counts_findings_per_lint() {
        let md = to_markdown(&sample());
        assert!(md.contains("FAILING"));
        assert!(md.contains("| timed-gating | 1 | 0 |"));
        assert!(md.contains("`src/serve/live.rs:12`"));
        let clean = to_markdown(&LintReport { findings: Vec::new(), ..sample() });
        assert!(clean.contains("**clean**"));
    }
}
