//! A small hand-rolled Rust lexer for the project lints.
//!
//! The lints in this module family reason about *source shape* — "is this
//! `unsafe` preceded by a `// SAFETY:` comment", "does this line index a
//! slice" — so the first step is separating what the compiler sees from
//! what the reader sees. [`model`] splits every line of a `.rs` file into
//! its **code** text (string literals blanked to `""`, comments removed)
//! and its **comment** text, and marks the lines belonging to
//! `#[cfg(test)]` regions so lints can exempt test code.
//!
//! This is deliberately not a full Rust lexer: it understands line and
//! (nested) block comments, plain / byte / raw string literals, char
//! literals vs lifetimes, and brace-matched `#[cfg(test)] mod` regions.
//! That subset is enough to make the lints precise on this codebase, and
//! the fixtures in [`super::lints`] pin the corner cases that matter
//! (lifetimes, `r#"…"#`, nested `/* /* */ */`).

/// One source line, split into the compiler-visible and reader-visible
/// halves.
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// The line's code with comments removed and string/char literal
    /// *contents* blanked (delimiters are kept, so `"abc"` becomes `""`
    /// and token adjacency survives).
    pub code: String,
    /// The line's comment text (everything after `//`, `//!`, `///`, or
    /// inside a `/* … */` overlapping this line), concatenated.
    pub comment: String,
    /// Whether the line sits inside a `#[cfg(test)]` region.
    pub in_test: bool,
}

/// The lexed form of one source file.
#[derive(Debug, Default)]
pub struct Model {
    /// One entry per source line, index 0 = line 1.
    pub lines: Vec<Line>,
}

impl Model {
    /// 1-based line access (empty line for out-of-range).
    pub fn line(&self, lineno: usize) -> Option<&Line> {
        lineno.checked_sub(1).and_then(|i| self.lines.get(i))
    }
}

/// Lex `src` into per-line code/comment halves and mark test regions.
/// `all_test` forces every line into the test region (integration-test
/// files, where the whole file is test code).
pub fn model(src: &str, all_test: bool) -> Model {
    let mut lines = split_code_and_comments(src);
    if all_test {
        for l in &mut lines {
            l.in_test = true;
        }
    } else {
        mark_test_regions(&mut lines);
    }
    Model { lines }
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Code,
    /// Inside `/* … */`, with the current nesting depth.
    Block(u32),
    /// Inside a `"…"` or `b"…"` literal.
    Str,
    /// Inside a raw string literal with this many `#`s in its delimiter.
    RawStr(u32),
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Split every line into code and comment text, carrying multi-line
/// string/comment state across lines.
fn split_code_and_comments(src: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut state = State::Code;
    for raw in src.lines() {
        let mut line = Line::default();
        let b: Vec<char> = raw.chars().collect();
        let mut i = 0usize;
        while i < b.len() {
            let c = b[i];
            match state {
                State::Block(depth) => {
                    if c == '*' && b.get(i + 1) == Some(&'/') {
                        i += 2;
                        state = if depth > 1 { State::Block(depth - 1) } else { State::Code };
                    } else if c == '/' && b.get(i + 1) == Some(&'*') {
                        i += 2;
                        state = State::Block(depth + 1);
                    } else {
                        line.comment.push(c);
                        i += 1;
                    }
                }
                State::Str => {
                    if c == '\\' {
                        i += 2; // skip the escaped char (possibly the quote)
                    } else if c == '"' {
                        line.code.push('"');
                        i += 1;
                        state = State::Code;
                    } else {
                        i += 1; // blanked
                    }
                }
                State::RawStr(hashes) => {
                    if c == '"' && closes_raw(&b, i, hashes) {
                        line.code.push('"');
                        i += 1 + hashes as usize;
                        state = State::Code;
                    } else {
                        i += 1; // blanked
                    }
                }
                State::Code => {
                    if c == '/' && b.get(i + 1) == Some(&'/') {
                        // line comment: the rest of the line is comment
                        line.comment.push_str(&raw[byte_offset(raw, i + 2)..]);
                        i = b.len();
                    } else if c == '/' && b.get(i + 1) == Some(&'*') {
                        i += 2;
                        state = State::Block(1);
                    } else if c == '"' {
                        line.code.push('"');
                        i += 1;
                        state = State::Str;
                    } else if c == 'r'
                        && !prev_is_ident(&b, i)
                        && raw_string_hashes(&b, i + 1).is_some()
                    {
                        let hashes = raw_string_hashes(&b, i + 1).unwrap_or(0);
                        line.code.push('"');
                        i += 2 + hashes as usize; // r, #s, opening quote
                        state = State::RawStr(hashes);
                    } else if c == 'b'
                        && !prev_is_ident(&b, i)
                        && b.get(i + 1) == Some(&'"')
                    {
                        line.code.push('"');
                        i += 2;
                        state = State::Str;
                    } else if c == '\'' {
                        // char literal or lifetime
                        if let Some(adv) = char_literal_len(&b, i) {
                            line.code.push('\'');
                            line.code.push('\'');
                            i += adv;
                        } else {
                            // a lifetime: keep it as code verbatim
                            line.code.push(c);
                            i += 1;
                        }
                    } else {
                        line.code.push(c);
                        i += 1;
                    }
                }
            }
        }
        out.push(line);
    }
    out
}

/// Whether `b[i]` (a `"`) is followed by `hashes` `#`s, closing a raw
/// string delimiter.
fn closes_raw(b: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| b.get(i + k) == Some(&'#'))
}

/// If `b[from..]` starts a raw-string delimiter (`#`* then `"`), the
/// number of `#`s; `None` otherwise.
fn raw_string_hashes(b: &[char], from: usize) -> Option<u32> {
    let mut hashes = 0u32;
    let mut j = from;
    while b.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    (b.get(j) == Some(&'"')).then_some(hashes)
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && b.get(i - 1).copied().is_some_and(is_ident)
}

/// If position `i` (at a `'`) starts a char literal, its total length in
/// chars; `None` for a lifetime.
fn char_literal_len(b: &[char], i: usize) -> Option<usize> {
    match b.get(i + 1) {
        // escaped char: consume through the closing quote
        Some('\\') => {
            let mut j = i + 2;
            while j < b.len() && b.get(j) != Some(&'\'') {
                j += 1;
            }
            (j < b.len()).then_some(j - i + 1)
        }
        // plain char `'x'` (and not `'a` the lifetime)
        Some(_) if b.get(i + 2) == Some(&'\'') => Some(3),
        _ => None,
    }
}

/// Convert a char index into a byte offset of `s` (for slicing the raw
/// line when a `//` comment starts mid-line).
fn byte_offset(s: &str, char_idx: usize) -> usize {
    s.char_indices().nth(char_idx).map_or(s.len(), |(b, _)| b)
}

/// Mark the brace-matched region following every `#[cfg(test)]` attribute
/// as test code (the attribute line itself included).
fn mark_test_regions(lines: &mut [Line]) {
    let n = lines.len();
    let mut i = 0usize;
    while i < n {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // find the start of the attributed item and walk its braces
        let mut depth = 0i64;
        let mut opened = false;
        let mut j = i;
        while j < n {
            lines[j].in_test = true;
            for c in lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    // an un-braced attributed item (e.g. `use` or
                    // `mod x;`) ends at its semicolon
                    ';' if !opened && depth == 0 => {
                        opened = true;
                        depth = 0;
                    }
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lex(src: &str) -> Model {
        model(src, false)
    }

    #[test]
    fn splits_line_comments_from_code() {
        let m = lex("let x = 1; // note\n/// doc line\nlet y = 2;");
        assert_eq!(m.lines[0].code, "let x = 1; ");
        assert_eq!(m.lines[0].comment, " note");
        assert_eq!(m.lines[1].code, "");
        assert!(m.lines[1].comment.contains("doc line"));
        assert_eq!(m.lines[2].code, "let y = 2;");
    }

    #[test]
    fn blanks_string_contents_keeping_delimiters() {
        let m = lex(r#"let s = "unwrap() [0] // not a comment"; done();"#);
        assert_eq!(m.lines[0].code, r#"let s = ""; done();"#);
        assert!(m.lines[0].comment.is_empty());
    }

    #[test]
    fn nested_block_comments_resume_code_midline() {
        let m = lex("a /* x /* y */ z */ b");
        assert_eq!(m.lines[0].code, "a  b");
        assert!(m.lines[0].comment.contains('y'));
        // multi-line blocks carry state across lines
        let m = lex("code(); /* open\nstill comment\n*/ more();");
        assert_eq!(m.lines[0].code, "code(); ");
        assert_eq!(m.lines[1].code, "");
        assert_eq!(m.lines[1].comment, "still comment");
        assert_eq!(m.lines[2].code, " more();");
    }

    #[test]
    fn raw_strings_char_literals_and_lifetimes() {
        let m = lex(r##"let r = r#""quoted""#; let c = '\n'; let lt: &'a [u8] = b;"##);
        assert_eq!(m.lines[0].code, r#"let r = ""; let c = ''; let lt: &'a [u8] = b;"#);
    }

    #[test]
    fn byte_strings_are_blanked_like_plain_strings() {
        let m = lex(r#"let b = b"magic[0]"; let ident_rb = not_raw(r);"#);
        assert_eq!(m.lines[0].code, r#"let b = ""; let ident_rb = not_raw(r);"#);
    }

    #[test]
    fn marks_braced_cfg_test_regions() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}";
        let m = model(src, false);
        let flags: Vec<bool> = m.lines.iter().map(|l| l.in_test).collect();
        assert_eq!(flags, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn braceless_cfg_test_item_ends_at_semicolon() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn d() {}";
        let m = model(src, false);
        let flags: Vec<bool> = m.lines.iter().map(|l| l.in_test).collect();
        assert_eq!(flags, vec![true, true, false]);
    }

    #[test]
    fn all_test_forces_every_line() {
        let m = model("fn a() {}\nfn b() {}", true);
        assert!(m.lines.iter().all(|l| l.in_test));
    }

    #[test]
    fn line_accessor_is_one_based() {
        let m = lex("a\nb");
        assert_eq!(m.line(1).map(|l| l.code.as_str()), Some("a"));
        assert_eq!(m.line(2).map(|l| l.code.as_str()), Some("b"));
        assert!(m.line(0).is_none());
        assert!(m.line(3).is_none());
    }
}
