//! The project lint registry.
//!
//! Each lint is a pure function from lexed sources ([`super::lexer`]) to
//! [`Finding`]s. The five initial lints guard invariants this codebase
//! already paid for once:
//!
//! * [`unsafe_audit`] — every `unsafe` block/impl carries an adjacent
//!   `// SAFETY:` justification (the mmap FFI discipline).
//! * [`atomics_ordering`] — per-module allowlist of atomic `Ordering`s:
//!   telemetry stays `Relaxed` (the ≤-one-atomic-op overhead contract),
//!   the live-chain RCU publication stays `Acquire`/`Release`, and
//!   `SeqCst` is deny-by-default everywhere.
//! * [`panic_free_decode`] — `unwrap`/`expect`/panicking macros/direct
//!   slice indexing are forbidden in the decode-path modules that face
//!   hostile bytes (typed faults only).
//! * [`wire_discipline`] — the opcode table in `net/wire.rs` is
//!   cross-checked against its own test corpus, decode version gates,
//!   and the README wire table.
//! * [`timed_gating`] — `Instant::now()` in instrumented serving modules
//!   must be gated (`enabled()` / trace-context presence), preserving
//!   the near-zero disabled-mode overhead.

use super::lexer::Line;
use super::{Finding, SourceFile};

/// Every lint id, in reporting order.
pub const LINT_IDS: &[&str] = &[
    "unsafe-audit",
    "atomics-ordering",
    "panic-free-decode",
    "wire-discipline",
    "timed-gating",
];

/// Run the whole registry over `files`. `readme` is the repo README (the
/// wire-discipline lint checks its wire table); absent, those checks are
/// skipped.
pub fn run_all(files: &[SourceFile], readme: Option<&str>) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        out.extend(unsafe_audit(f));
        out.extend(atomics_ordering(f));
        out.extend(panic_free_decode(f));
        out.extend(timed_gating(f));
    }
    out.extend(wire_discipline(files, readme));
    out.sort_by(|a, b| (&a.path, a.line, a.lint).cmp(&(&b.path, b.line, b.lint)));
    out
}

fn finding(
    lint: &'static str,
    file: &SourceFile,
    lineno: usize,
    message: String,
) -> Finding {
    let excerpt = file
        .model
        .line(lineno)
        .map(|l| {
            let mut e = l.code.trim().to_string();
            if e.is_empty() {
                e = l.comment.trim().to_string();
            }
            e
        })
        .unwrap_or_default();
    Finding { lint, path: file.path.clone(), line: lineno, message, excerpt }
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Whether `hay` contains `word` with non-identifier characters (or the
/// string boundary) on both sides.
fn has_word(hay: &str, word: &str) -> bool {
    find_word(hay, word, 0).is_some()
}

/// Position of the next word-boundary occurrence of `word` at or after
/// `from`.
fn find_word(hay: &str, word: &str, from: usize) -> Option<usize> {
    let mut at = from;
    while let Some(rel) = hay.get(at..).and_then(|h| h.find(word)) {
        let pos = at + rel;
        let before_ok = pos == 0
            || !hay[..pos].chars().next_back().is_some_and(is_ident_char);
        let after_ok = !hay[pos + word.len()..].chars().next().is_some_and(is_ident_char);
        if before_ok && after_ok {
            return Some(pos);
        }
        at = pos + word.len();
    }
    None
}

// ---------------------------------------------------------------------
// unsafe-audit
// ---------------------------------------------------------------------

/// Every non-test `unsafe` block / fn / impl must be immediately preceded
/// by a `// SAFETY:` comment (attribute lines and contiguous runs of
/// `unsafe impl` may sit between the comment and the site).
pub fn unsafe_audit(file: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for (idx, line) in file.model.lines.iter().enumerate() {
        if line.in_test || !has_word(&line.code, "unsafe") {
            continue;
        }
        if !has_safety_comment(&file.model.lines, idx) {
            out.push(finding(
                "unsafe-audit",
                file,
                idx + 1,
                "`unsafe` without an immediately preceding `// SAFETY:` comment".into(),
            ));
        }
    }
    out
}

/// Walk upward from the `unsafe` at line index `idx` looking for the
/// justifying comment: the site's own line counts, blank lines don't
/// break adjacency, contiguous comment-only lines are scanned as one
/// block (`// SAFETY:` may open a multi-line comment), and attributes
/// and earlier `unsafe impl` lines are skipped (one comment may cover a
/// contiguous `Send`/`Sync` pair). Any other code line ends the search.
fn has_safety_comment(lines: &[Line], idx: usize) -> bool {
    if lines[idx].comment.contains("SAFETY:") {
        return true;
    }
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let l = &lines[i];
        let code = l.code.trim();
        if l.comment.contains("SAFETY:") {
            return true;
        }
        let skippable = code.is_empty()
            || code.starts_with("#[")
            || code.starts_with("unsafe impl");
        if !skippable {
            return false;
        }
    }
    false
}

// ---------------------------------------------------------------------
// atomics-ordering
// ---------------------------------------------------------------------

/// The atomic orderings a module is allowed to use. Longest matching
/// path prefix wins; a module using atomics with no entry at all is a
/// finding (add one deliberately). `SeqCst` appears in no entry: it is
/// deny-by-default project-wide.
pub const ORDERING_ALLOWLIST: &[(&str, &[&str])] = &[
    // telemetry: the ≤-one-relaxed-op-per-event overhead contract
    ("src/obs/", &["Relaxed"]),
    ("src/util/logging.rs", &["Relaxed"]),
    // engine progress counters
    ("src/engine/", &["Relaxed"]),
    // RCU generation publication: store-Release / load-Acquire only
    ("src/serve/live.rs", &["Acquire", "Release"]),
    // split-completion latch (AcqRel fetch_sub) + trace dedup flag
    ("src/serve/server.rs", &["Relaxed", "AcqRel"]),
    ("src/serve/store.rs", &["Relaxed"]),
    // shutdown flag (Acquire load / AcqRel swap) + relaxed counters
    ("src/net/server.rs", &["Relaxed", "Acquire", "AcqRel"]),
];

const ATOMIC_ORDERINGS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// Enforce [`ORDERING_ALLOWLIST`] on every non-test `Ordering::…` use in
/// `src/`.
pub fn atomics_ordering(file: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    if !file.path.starts_with("src/") {
        return out;
    }
    let allowed = ORDERING_ALLOWLIST
        .iter()
        .filter(|(prefix, _)| file.path.starts_with(prefix))
        .max_by_key(|(prefix, _)| prefix.len())
        .map(|(_, orders)| *orders);
    for (idx, line) in file.model.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for ord in ATOMIC_ORDERINGS {
            let token = format!("Ordering::{ord}");
            if !has_word(&line.code, &token) {
                continue;
            }
            match allowed {
                None => out.push(finding(
                    "atomics-ordering",
                    file,
                    idx + 1,
                    format!(
                        "module uses atomic `{token}` but has no entry in the \
                         ordering allowlist — add one deliberately"
                    ),
                )),
                Some(orders) if !orders.contains(ord) => out.push(finding(
                    "atomics-ordering",
                    file,
                    idx + 1,
                    format!(
                        "`{token}` not permitted here (allowed: {})",
                        orders.join(", ")
                    ),
                )),
                Some(_) => {}
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// panic-free-decode
// ---------------------------------------------------------------------

/// The modules whose decode paths face bytes from disk or the wire:
/// panicking on hostile input is a denial-of-service, so every fault must
/// be a typed error.
pub const PANIC_FREE_FILES: &[&str] = &[
    "src/net/wire.rs",
    "src/sketch/bitio.rs",
    "src/sketch/encode.rs",
    "src/serve/store.rs",
    "src/obs/snapshot.rs",
];

/// Identifiers that legally precede a `[` without indexing (keywords, so
/// `for x in [..]`, `let [a, b] = …`, `if let [..]` stay clean).
const NON_INDEX_KEYWORDS: &[&str] = &[
    "as", "break", "box", "continue", "dyn", "else", "in", "let", "match", "mut",
    "move", "ref", "return", "static", "where", "while", "yield",
];

/// Forbid `unwrap()` / `expect()` / panicking macros / direct slice
/// indexing in the non-test code of [`PANIC_FREE_FILES`].
pub fn panic_free_decode(file: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    if !PANIC_FREE_FILES.contains(&file.path.as_str()) {
        return out;
    }
    for (idx, line) in file.model.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        for call in ["unwrap", "expect"] {
            let mut at = 0;
            while let Some(pos) = find_word(code, call, at) {
                at = pos + call.len();
                // a call (next char `(`) of the exact method — so
                // `unwrap_or_else` / `expect_err` never match
                if code[at..].starts_with('(') {
                    out.push(finding(
                        "panic-free-decode",
                        file,
                        idx + 1,
                        format!("`.{call}()` in decode-path code — return a typed fault"),
                    ));
                }
            }
        }
        for mac in ["panic", "unreachable", "todo", "unimplemented"] {
            if let Some(pos) = find_word(code, mac, 0) {
                if code[pos + mac.len()..].starts_with('!') {
                    out.push(finding(
                        "panic-free-decode",
                        file,
                        idx + 1,
                        format!("`{mac}!` in decode-path code — return a typed fault"),
                    ));
                }
            }
        }
        for pos in index_expression_positions(code) {
            out.push(finding(
                "panic-free-decode",
                file,
                idx + 1,
                format!(
                    "direct slice index at column {} — use `get`/`get_mut` and \
                     return a typed fault",
                    pos + 1
                ),
            ));
        }
    }
    out
}

/// Positions of `[` tokens that open an index expression: the previous
/// meaningful character ends an indexable expression (identifier, `)`,
/// `]`, or a string literal), excluding keywords, attributes (`#[`), and
/// macro invocations (`vec![…]`).
fn index_expression_positions(code: &str) -> Vec<usize> {
    let chars: Vec<char> = code.chars().collect();
    let mut out = Vec::new();
    for (i, &c) in chars.iter().enumerate() {
        if c != '[' {
            continue;
        }
        let mut j = i;
        while j > 0 && chars[j - 1] == ' ' {
            j -= 1;
        }
        if j == 0 {
            continue;
        }
        let prev = chars[j - 1];
        let indexes = match prev {
            ')' | ']' | '"' => true,
            _ if is_ident_char(prev) => {
                let mut k = j - 1;
                while k > 0 && is_ident_char(chars[k - 1]) {
                    k -= 1;
                }
                if k > 0 && chars[k - 1] == '\'' {
                    // a lifetime: `&'a [u8]` is a slice type, not indexing
                    continue;
                }
                let word: String = chars[k..j].iter().collect();
                !NON_INDEX_KEYWORDS.contains(&word.as_str())
            }
            _ => false,
        };
        if indexes {
            out.push(i);
        }
    }
    out
}

// ---------------------------------------------------------------------
// wire-discipline
// ---------------------------------------------------------------------

/// One opcode parsed out of `net/wire.rs`.
#[derive(Debug)]
struct Opcode {
    name: String,
    hex: String,
    line: usize,
    /// `Some(v)` when a decode arm gates it with `if version >= v`.
    min_version: Option<u32>,
}

/// Cross-check the opcode table in `src/net/wire.rs`:
///
/// * every `const OP_*` is referenced by non-test code (no dead opcodes);
/// * every opcode name appears in the wire test region (the round-trip /
///   malformed-corpus suites must cover it);
/// * every opcode's hex appears as a `` `0xNN` `` row in the README wire
///   table, and a version-gated opcode's row carries its `(vN+` tag.
pub fn wire_discipline(files: &[SourceFile], readme: Option<&str>) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(wire) = files.iter().find(|f| f.path == "src/net/wire.rs") else {
        return out;
    };
    let opcodes = parse_opcodes(wire);
    for op in &opcodes {
        let mut test_ref = false;
        let mut nontest_refs = 0usize;
        for (idx, line) in wire.model.lines.iter().enumerate() {
            if idx + 1 == op.line || !has_word(&line.code, &op.name) {
                continue;
            }
            if line.in_test {
                test_ref = true;
            } else {
                nontest_refs += 1;
            }
        }
        if nontest_refs == 0 {
            out.push(finding(
                "wire-discipline",
                wire,
                op.line,
                format!("opcode `{}` ({}) is never encoded or decoded", op.name, op.hex),
            ));
        }
        if !test_ref {
            out.push(finding(
                "wire-discipline",
                wire,
                op.line,
                format!(
                    "opcode `{}` ({}) is not exercised by the wire test region \
                     (round-trip + malformed corpus)",
                    op.name, op.hex
                ),
            ));
        }
        if let Some(readme) = readme {
            let needle = format!("`{}`", op.hex);
            match readme.find(&needle) {
                None => out.push(finding(
                    "wire-discipline",
                    wire,
                    op.line,
                    format!(
                        "opcode `{}` ({}) has no `{}` row in the README wire table",
                        op.name, op.hex, op.hex
                    ),
                )),
                Some(pos) => {
                    if let Some(v) = op.min_version {
                        let tail = readme[pos..].chars().take(80).collect::<String>();
                        if !tail.contains(&format!("(v{v}+")) {
                            out.push(finding(
                                "wire-discipline",
                                wire,
                                op.line,
                                format!(
                                    "opcode `{}` ({}) is gated on version >= {v} but its \
                                     README row lacks the `(v{v}+)` tag",
                                    op.name, op.hex
                                ),
                            ));
                        }
                    }
                }
            }
        }
    }
    out
}

/// Parse `const OP_*: u8 = 0xNN;` declarations and their decode-side
/// `OP_* if version >= N` gates from the non-test code of `wire.rs`.
fn parse_opcodes(wire: &SourceFile) -> Vec<Opcode> {
    let mut out: Vec<Opcode> = Vec::new();
    for (idx, line) in wire.model.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = line.code.trim();
        if let Some(rest) = code.strip_prefix("const OP_") {
            if let Some((name_tail, value)) = rest.split_once(": u8 = ") {
                let name = format!("OP_{name_tail}");
                let hex = value.trim_end_matches(';').trim().to_string();
                out.push(Opcode { name, hex, line: idx + 1, min_version: None });
            }
        }
        // decode gate: `OP_NAME if version >= N`
        let mut at = 0;
        while let Some(pos) = code[at..].find(" if version >= ") {
            let abs = at + pos;
            at = abs + 1;
            let Some(name_start) = code[..abs].rfind("OP_") else { continue };
            let name: String = code[name_start..abs]
                .chars()
                .take_while(|&c| is_ident_char(c))
                .collect();
            let ver: String = code[abs + " if version >= ".len()..]
                .chars()
                .take_while(char::is_ascii_digit)
                .collect();
            if let (Some(op), Ok(v)) =
                (out.iter_mut().find(|o| o.name == name), ver.parse::<u32>())
            {
                op.min_version = Some(v);
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// timed-gating
// ---------------------------------------------------------------------

/// The serving modules instrumented by the telemetry/tracing layers; the
/// overhead contract says their clock reads must be gated on recording
/// being on.
pub const TIMED_FILES: &[&str] = &[
    "src/net/server.rs",
    "src/serve/server.rs",
    "src/serve/live.rs",
    "src/api/local.rs",
];

/// Evidence that a nearby expression gates the clock read: registry
/// `enabled()`, trace-context presence combinators, or span recording
/// (already inside a trace-gated branch).
const GATE_TOKENS: &[&str] =
    &["enabled", ".then(", ".map(", "unwrap_or_else", ".record", "record_with", "is_some"];

/// `Instant::now()` in [`TIMED_FILES`] must show gating evidence within
/// the surrounding statement (4 lines above through 1 below).
pub fn timed_gating(file: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    if !TIMED_FILES.contains(&file.path.as_str()) {
        return out;
    }
    let lines = &file.model.lines;
    for (idx, line) in lines.iter().enumerate() {
        if line.in_test || !line.code.contains("Instant::now") {
            continue;
        }
        let lo = idx.saturating_sub(4);
        let hi = (idx + 2).min(lines.len());
        let gated = lines[lo..hi]
            .iter()
            .any(|l| GATE_TOKENS.iter().any(|t| l.code.contains(t)));
        if !gated {
            out.push(finding(
                "timed-gating",
                file,
                idx + 1,
                "`Instant::now()` without `enabled()`/trace gating in an \
                 instrumented module (overhead contract)"
                    .into(),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(path: &str, src: &str) -> SourceFile {
        SourceFile::new(path, src)
    }

    // --- unsafe-audit -------------------------------------------------

    #[test]
    fn unsafe_without_safety_comment_is_flagged() {
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let out = unsafe_audit(&file("src/x.rs", src));
        assert_eq!(out.len(), 1);
        assert_eq!((out[0].lint, out[0].line), ("unsafe-audit", 2));
        assert_eq!(out[0].excerpt, "unsafe { *p }");
    }

    #[test]
    fn safety_comment_silences_unsafe() {
        let src = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller keeps p valid\n    \
                   unsafe { *p }\n}\n";
        assert!(unsafe_audit(&file("src/x.rs", src)).is_empty());
    }

    #[test]
    fn one_multiline_safety_comment_covers_a_send_sync_pair() {
        let src = "// SAFETY: immutable after construction,\n// so sharing is sound.\n\
                   unsafe impl Send for X {}\nunsafe impl Sync for X {}\n";
        assert!(unsafe_audit(&file("src/x.rs", src)).is_empty());
    }

    #[test]
    fn intervening_code_breaks_safety_adjacency() {
        let src = "// SAFETY: stale justification\nlet x = 1;\nunsafe { hazard() }\n";
        let out = unsafe_audit(&file("src/x.rs", src));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 3);
    }

    #[test]
    fn test_region_unsafe_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(p: *const u8) -> u8 {\n        \
                   unsafe { *p }\n    }\n}\n";
        assert!(unsafe_audit(&file("src/x.rs", src)).is_empty());
    }

    // --- atomics-ordering ---------------------------------------------

    #[test]
    fn seqcst_is_denied_everywhere() {
        let src = "fn f(c: &AtomicU64) { c.store(1, Ordering::SeqCst); }\n";
        let out = atomics_ordering(&file("src/obs/metrics.rs", src));
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("SeqCst"));
    }

    #[test]
    fn telemetry_keeps_relaxed_and_live_chain_keeps_acquire_release() {
        let relaxed = "fn f(c: &AtomicU64) { c.store(1, Ordering::Relaxed); }\n";
        assert!(atomics_ordering(&file("src/obs/metrics.rs", relaxed)).is_empty());
        let acq = "fn f(a: &AtomicPtr<u8>) { a.load(Ordering::Acquire); }\n";
        assert!(atomics_ordering(&file("src/serve/live.rs", acq)).is_empty());
        let out = atomics_ordering(&file("src/serve/live.rs", relaxed));
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("not permitted"));
    }

    #[test]
    fn module_without_allowlist_entry_is_flagged() {
        let src = "fn f(c: &AtomicU64) { c.load(Ordering::Relaxed); }\n";
        let out = atomics_ordering(&file("src/sketch/fresh.rs", src));
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("no entry"));
    }

    #[test]
    fn atomics_lint_exempts_tests_and_non_src_files() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(a: &AtomicU64) { \
                   a.load(Ordering::SeqCst); }\n}\n";
        assert!(atomics_ordering(&file("src/serve/live.rs", src)).is_empty());
        let bench = "fn f(c: &AtomicU64) { c.store(1, Ordering::SeqCst); }\n";
        assert!(atomics_ordering(&file("benches/b.rs", bench)).is_empty());
    }

    // --- panic-free-decode --------------------------------------------

    #[test]
    fn unwrap_macros_and_indexing_flagged_in_decode_paths() {
        let src = "fn f(v: &[u8]) -> u8 {\n    let x = v.first().unwrap();\n    \
                   if *x > 9 { panic!(\"bad\") }\n    v[0]\n}\n";
        let out = panic_free_decode(&file("src/net/wire.rs", src));
        let lines: Vec<usize> = out.iter().map(|f| f.line).collect();
        assert_eq!(lines, vec![2, 3, 4]);
        assert!(out[2].message.contains("direct slice index"));
    }

    #[test]
    fn non_panicking_lookalikes_stay_clean() {
        let src = "fn f(v: &[u8]) -> u8 {\n    let x = v.first().unwrap_or(&0);\n    \
                   let [a, b] = [*x, 2];\n    for y in [a, b] {\n        let _ = y;\n    }\n    \
                   let s: &[u8] = v;\n    s.first().copied().expect_none_is_fine(a, b)\n}\n";
        assert!(panic_free_decode(&file("src/sketch/bitio.rs", src)).is_empty());
    }

    #[test]
    fn lifetime_slice_types_are_not_indexing() {
        let src = "struct Rd<'a> {\n    buf: &'a [u8],\n}\n";
        assert!(panic_free_decode(&file("src/net/wire.rs", src)).is_empty());
    }

    #[test]
    fn panic_free_scope_is_limited_to_decode_files() {
        let src = "fn f(v: &[u8]) -> u8 { v[0] }\n";
        assert!(panic_free_decode(&file("src/main.rs", src)).is_empty());
    }

    // --- wire-discipline ----------------------------------------------

    fn wire_fixture() -> SourceFile {
        let src = "const OP_PING: u8 = 0x01;\n\
                   const OP_STATS: u8 = 0x14;\n\
                   fn decode(version: u16, op: u8) -> u8 {\n\
                       match op {\n\
                           OP_PING => 1,\n\
                           OP_STATS if version >= 4 => 2,\n\
                           _ => 0,\n\
                       }\n\
                   }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn corpus() -> (u8, u8) { (OP_PING, OP_STATS) }\n\
                   }\n";
        SourceFile::new("src/net/wire.rs", src)
    }

    #[test]
    fn consistent_wire_fixture_is_clean() {
        let readme = "| `0x01` | Ping |\n| `0x14` | Stats (v4+) |\n";
        assert!(wire_discipline(&[wire_fixture()], Some(readme)).is_empty());
        // without a README there is nothing to cross-check against
        assert!(wire_discipline(&[wire_fixture()], None).is_empty());
    }

    #[test]
    fn dead_untested_and_undocumented_opcodes_are_flagged() {
        let f = SourceFile::new("src/net/wire.rs", "const OP_GHOST: u8 = 0x7F;\n");
        let out = wire_discipline(&[f], Some("no wire table here"));
        let msgs: Vec<&str> = out.iter().map(|f| f.message.as_str()).collect();
        assert_eq!(out.len(), 3);
        assert!(msgs.iter().any(|m| m.contains("never encoded")));
        assert!(msgs.iter().any(|m| m.contains("not exercised")));
        assert!(msgs.iter().any(|m| m.contains("README wire table")));
        assert!(out.iter().all(|f| f.line == 1));
    }

    #[test]
    fn version_gated_opcode_requires_readme_tag() {
        let readme = "| `0x01` | Ping |\n| `0x14` | Stats |\n";
        let out = wire_discipline(&[wire_fixture()], Some(readme));
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("(v4+)"));
        assert_eq!(out[0].line, 2);
    }

    // --- timed-gating -------------------------------------------------

    #[test]
    fn ungated_clock_read_in_instrumented_module_is_flagged() {
        let src = "fn f() {\n    let t = Instant::now();\n    work(t);\n}\n";
        let out = timed_gating(&file("src/serve/server.rs", src));
        assert_eq!(out.len(), 1);
        assert_eq!((out[0].lint, out[0].line), ("timed-gating", 2));
    }

    #[test]
    fn enabled_gate_and_uninstrumented_modules_stay_clean() {
        let src = "fn f(reg: &Registry) {\n    if reg.enabled() {\n        \
                   let t = Instant::now();\n        work(t);\n    }\n}\n";
        assert!(timed_gating(&file("src/serve/server.rs", src)).is_empty());
        let other = "fn f() { let t = Instant::now(); work(t); }\n";
        assert!(timed_gating(&file("src/sketch/merge.rs", other)).is_empty());
    }

    // --- registry -----------------------------------------------------

    #[test]
    fn run_all_sorts_findings_by_location() {
        let a = file("src/serve/server.rs", "fn f() {\n    let t = Instant::now();\n}\n");
        let b = file("src/net/wire.rs", "fn f(v: &[u8]) -> u8 { v.first().unwrap() }\n");
        let out = run_all(&[a, b], None);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].path, "src/net/wire.rs");
        assert_eq!(out[1].path, "src/serve/server.rs");
    }
}
