//! The lint baseline (`lint.allow`): a checked-in list of accepted
//! findings.
//!
//! A few findings are legitimate — e.g. the live chain's `GenPoll`
//! deadline arithmetic *is* a functional clock read, not telemetry — and
//! get a baseline entry instead of a code contortion. Entries are keyed
//! by lint id, path, and the finding line's trimmed code text (not its
//! line number, so unrelated edits above the site don't invalidate the
//! baseline). An entry that stops matching anything is reported as
//! **stale** so the file can only shrink back to the truth.
//!
//! Format: one entry per line, tab-separated —
//! `lint-id<TAB>path<TAB>trimmed line text` — with `#` comments and blank
//! lines ignored.

use super::Finding;

/// One parsed `lint.allow` entry.
#[derive(Debug, Clone, PartialEq)]
pub struct AllowEntry {
    /// Lint id (e.g. `timed-gating`).
    pub lint: String,
    /// Crate-relative path (e.g. `src/serve/live.rs`).
    pub path: String,
    /// The trimmed code text of the accepted line.
    pub excerpt: String,
    /// 1-based line in `lint.allow` (for stale reporting).
    pub line: usize,
}

impl AllowEntry {
    /// Whether this entry accepts `f`.
    pub fn matches(&self, f: &Finding) -> bool {
        self.lint == f.lint && self.path == f.path && self.excerpt == f.excerpt
    }

    /// The entry in file format.
    pub fn render(&self) -> String {
        format!("{}\t{}\t{}", self.lint, self.path, self.excerpt)
    }
}

/// Parse a `lint.allow` document. Malformed lines (fewer than three
/// tab-separated fields) are themselves errors, reported as a pseudo
/// entry the caller will list as stale — a broken baseline must never
/// silently widen.
pub fn parse(doc: &str) -> Vec<AllowEntry> {
    let mut out = Vec::new();
    for (idx, raw) in doc.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.splitn(3, '\t');
        let (lint, path, excerpt) = (parts.next(), parts.next(), parts.next());
        out.push(AllowEntry {
            lint: lint.unwrap_or_default().trim().to_string(),
            path: path.unwrap_or_default().trim().to_string(),
            excerpt: excerpt.unwrap_or_default().trim().to_string(),
            line: idx + 1,
        });
    }
    out
}

/// Split `findings` into (non-baselined, baselined) and report the
/// entries that matched nothing as stale.
pub fn apply(
    findings: Vec<Finding>,
    entries: &[AllowEntry],
) -> (Vec<Finding>, Vec<Finding>, Vec<AllowEntry>) {
    let mut open = Vec::new();
    let mut accepted = Vec::new();
    let mut used = vec![false; entries.len()];
    for f in findings {
        match entries.iter().position(|e| e.matches(&f)) {
            Some(i) => {
                used[i] = true;
                accepted.push(f);
            }
            None => open.push(f),
        }
    }
    let stale = entries
        .iter()
        .zip(&used)
        .filter(|(_, &u)| !u)
        .map(|(e, _)| e.clone())
        .collect();
    (open, accepted, stale)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(lint: &'static str, path: &str, excerpt: &str) -> Finding {
        Finding {
            lint,
            path: path.to_string(),
            line: 7,
            message: "msg".to_string(),
            excerpt: excerpt.to_string(),
        }
    }

    #[test]
    fn parse_skips_comments_and_keeps_source_lines() {
        let doc = "# header\n\nlint-a\tsrc/a.rs\tlet x = 1;\n  # indented comment\n\
                   lint-b\tsrc/b.rs\ty();\n";
        let entries = parse(doc);
        assert_eq!(entries.len(), 2);
        assert_eq!((entries[0].lint.as_str(), entries[0].line), ("lint-a", 3));
        assert_eq!((entries[1].excerpt.as_str(), entries[1].line), ("y();", 5));
        assert_eq!(entries[0].render(), "lint-a\tsrc/a.rs\tlet x = 1;");
    }

    #[test]
    fn matching_keys_on_lint_path_and_excerpt_not_line() {
        let e = parse("lint-a\tsrc/a.rs\tlet x = 1;\n").remove(0);
        assert!(e.matches(&finding("lint-a", "src/a.rs", "let x = 1;")));
        assert!(!e.matches(&finding("lint-a", "src/a.rs", "let x = 2;")));
        assert!(!e.matches(&finding("lint-b", "src/a.rs", "let x = 1;")));
        assert!(!e.matches(&finding("lint-a", "src/b.rs", "let x = 1;")));
    }

    #[test]
    fn malformed_entries_never_match_and_surface_as_stale() {
        let entries = parse("no-tabs-on-this-line\n");
        assert_eq!(entries.len(), 1);
        assert!(entries[0].path.is_empty() && entries[0].excerpt.is_empty());
        let (open, accepted, stale) =
            apply(vec![finding("no-tabs-on-this-line", "src/a.rs", "x")], &entries);
        assert_eq!(open.len(), 1);
        assert!(accepted.is_empty());
        assert_eq!(stale.len(), 1);
    }

    #[test]
    fn apply_partitions_findings_and_reports_unused_entries() {
        let entries = parse("lint-a\tsrc/a.rs\tx\nlint-a\tsrc/a.rs\tnever-matches\n");
        let (open, accepted, stale) = apply(
            vec![finding("lint-a", "src/a.rs", "x"), finding("lint-a", "src/b.rs", "x")],
            &entries,
        );
        assert_eq!(open.len(), 1);
        assert_eq!(open[0].path, "src/b.rs");
        assert_eq!(accepted.len(), 1);
        assert_eq!(stale.len(), 1);
        assert_eq!(stale[0].excerpt, "never-matches");
    }
}
