//! matsketch CLI — the L3 leader entrypoint.
//!
//! ```text
//! matsketch tables      [--small] [--seed N] [--out DIR]
//! matsketch fig1        [--small] [--seed N] [--out DIR] [--k K]
//!                       [--points P] [--datasets a,b] [--engine xla|rust]
//! matsketch compress    [--small] [--seed N] [--out DIR]
//! matsketch theory      [--small] [--seed N] [--out DIR]
//! matsketch sketch      --input a.bin --s N [--method NAME] [--workers W]
//!                       [--mode offline|streaming|spilling|sharded]
//!                       [--store DIR] [--force] [--sketch-out FILE]
//! matsketch query       --dataset NAME --s N [--method NAME] [--store DIR]
//!                       [--addr HOST:PORT]
//!                       --op matvec|matvec-t|matvec-batch|row|col|top-k
//!                       [--k K] [--index I] [--x-seed N] [--batch-k K]
//! matsketch serve-bench [--small] [--seed N] [--out DIR] [--store DIR]
//!                       [--readers 1,2,4 | --workers 1,2,4] [--queries Q]
//!                       [--batch-ks 1,4,16] [--datasets a,b]
//! matsketch serve       --addr HOST:PORT [--store DIR] [--workers W]
//!                       [--max-conns N] [--timeout-secs S]
//!                       [--shutdown-after-secs S]
//!                       [--trace-one-in-n N] [--slow-us US]
//!                       [--shed-high-water N] [--chaos SPEC]
//!                       [--ingest a.bin --s N [--method NAME]
//!                        [--epoch-entries E] [--ingest-batch B]]
//! matsketch live-bench  [--seed N] [--out DIR] [--store DIR]
//!                       [--clients 2,4] [--queries Q] [--entries E]
//!                       [--epoch-entries E] [--s N] [--m M] [--n N]
//! matsketch net-bench   [--addr HOST:PORT] [--clients 1,2,8] [--queries Q]
//!                       [--duration-secs S] [--ops matvec,row,top-k]
//!                       [--batch-k K] [--datasets a,b] [--store DIR]
//!                       [--out DIR]
//! matsketch chaos-bench [--clients 2,8] [--queries Q] [--duration-secs S]
//!                       [--ops matvec,row,top-k] [--chaos SPEC]
//!                       [--shed-high-water N] [--datasets a,b]
//!                       [--store DIR] [--out DIR]
//! matsketch stats       --addr HOST:PORT [--json] [--watch SECS]
//! matsketch trace       --addr HOST:PORT [--id N | --slowest N]
//! matsketch lint        [--root DIR] [--out DIR]
//! matsketch gen         --dataset NAME [--seed N] --out a.bin
//! ```
//!
//! Every query path — local store or remote server — goes through one
//! surface: the `SketchClient` trait (`matsketch::api`). `--addr` flips
//! the backend; nothing else about the invocation changes.
//!
//! A global `--log-level error|warn|info|debug` flag (or the
//! `MATSKETCH_LOG` environment variable) sets the logging threshold for
//! any command; `--verbose` stays as shorthand for `--log-level debug`.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use matsketch::analysis;
use matsketch::api::{
    LocalClient, QueryRequest, QueryResponse, RemoteClient, SketchClient, SketchInfo,
};
use matsketch::coordinator::PipelineConfig;
use matsketch::datasets::DatasetId;
use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::engine::{sketch_entry_stream, SketchMode};
use matsketch::error::{Error, Result};
use matsketch::eval::{
    run_compression, run_figure1, run_tables, run_theory, server_metrics_table, Figure1Config,
};
use matsketch::net::{scrape_stats, FaultPlan, LoadOp, NetServer, NetServerConfig};
use matsketch::obs::MetricsSnapshot;
use matsketch::runtime::{default_engine, DenseEngine, RustEngine, XlaEngine};
use matsketch::serve::{Fingerprinter, LiveConfig, LiveSketch, SketchStore, StoreKey};
use matsketch::sketch::{encode_sketch, SketchPlan};
use matsketch::sparse::io as sparse_io;
use matsketch::stream::FileStream;
use matsketch::util::args::Args;
use matsketch::util::human_bytes;
use matsketch::util::json::{self, Json};
use matsketch::util::logging::{set_level, Level};
use matsketch::util::rng::Rng;
use matsketch::{info, warn_log};

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env(&["small", "verbose", "help", "include-ahk06", "force", "json"])?;
    init_log_level(&args)?;
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    if args.flag("help") || cmd == "help" {
        print_help();
        return Ok(());
    }
    let out: PathBuf = PathBuf::from(args.get_or("out", "reports"));
    let seed: u64 = args.get_parse_or("seed", 0)?;
    let small = args.flag("small");

    match cmd {
        "tables" => {
            let rows = run_tables(&out, small, seed)?;
            info!("wrote characteristics + sample-complexity tables for {} matrices", rows.len());
        }
        "fig1" => {
            let engine = pick_engine(args.get("engine"));
            let cfg = Figure1Config {
                k: args.get_parse_or("k", 20)?,
                svd_iters: args.get_parse_or("svd-iters", 8)?,
                budget_points: args.get_parse_or("points", 8)?,
                include_ahk06: args.flag("include-ahk06"),
                seed,
                small,
                ..Default::default()
            };
            let datasets = parse_datasets(args.get("datasets"))?;
            let pts = run_figure1(&out, &cfg, engine.as_ref(), &datasets)?;
            info!("figure1: {} points written to {}", pts.len(), out.display());
        }
        "compress" => {
            let pts = run_compression(&out, small, seed)?;
            info!("compression: {} points", pts.len());
        }
        "theory" => {
            let pts = run_theory(&out, small, seed)?;
            info!("theory: {} points", pts.len());
        }
        "ablate" => {
            let engine = pick_engine(args.get("engine"));
            let pts = matsketch::eval::run_ablation(&out, seed, engine.as_ref())?;
            info!("ablation: {} points -> {}/ablation.*", pts.len(), out.display());
        }
        "gen" => {
            let name = args
                .get("dataset")
                .ok_or_else(|| Error::invalid("gen requires --dataset"))?;
            let id = DatasetId::parse(name)
                .ok_or_else(|| Error::invalid(format!("unknown dataset {name}")))?;
            let coo = if small { id.generate_small(seed) } else { id.generate(seed) };
            let path = PathBuf::from(
                args.get("out")
                    .map(str::to_string)
                    .unwrap_or_else(|| format!("{name}.bin")),
            );
            sparse_io::write_binary(&coo, &path)?;
            info!(
                "{}: {}x{}, nnz={} -> {}",
                name,
                coo.m,
                coo.n,
                coo.nnz(),
                path.display()
            );
        }
        "sketch" => {
            let input = args
                .get("input")
                .ok_or_else(|| Error::invalid("sketch requires --input <triplets.bin>"))?;
            let s: u64 = args
                .get_parse("s")?
                .ok_or_else(|| Error::invalid("sketch requires --s <budget>"))?;
            let kind = parse_method(args.get_or("method", "bernstein"))?;
            let mode_name = args.get_or("mode", "sharded");
            let mode = SketchMode::parse(mode_name)
                .ok_or_else(|| Error::invalid(format!("unknown mode {mode_name}")))?;
            let store = SketchStore::open(args.get_or("store", "sketch-store"))?;

            // pass 1: stats + content fingerprint in one sweep. The
            // fingerprint goes into the store key, so staleness is
            // decided by what the input *contains*, not just mtime.
            let mut st_stream = FileStream::open(Path::new(input))?;
            let (m, n) = {
                use matsketch::stream::EntryStream;
                st_stream.shape()
            };
            let mut stats = MatrixStats::new(m, n);
            let mut fp = Fingerprinter::new();
            {
                use matsketch::stream::EntryStream;
                while let Some(e) = st_stream.next_entry()? {
                    stats.push(&e);
                    fp.push(&e);
                }
            }
            let key = StoreKey::new(&dataset_label(&args, input), &kind.name(), s, seed)
                .with_fingerprint(fp.finish());

            // cache lookup: a repeated run at the same (dataset, method,
            // s, seed) over unchanged input data is served from the
            // store; a changed input reads as a stale miss. --force skips
            // the lookup entirely (also the escape hatch for a corrupt
            // entry). Legacy v1 entries carry no fingerprint, so for them
            // the mtime + shape heuristics still apply.
            let cached = if args.flag("force") { None } else { store.get(&key)? };
            let cached = match cached {
                Some(stored) => {
                    if stored.fingerprint == 0
                        && input_newer_than(input, &store.path_for(&key))
                    {
                        info!("{input} is newer than the stored v1 sketch; re-sketching");
                        None
                    } else if (m, n) != (stored.enc.m, stored.enc.n) {
                        info!(
                            "{input} is {m}x{n} but the stored sketch is {}x{}; re-sketching",
                            stored.enc.m, stored.enc.n
                        );
                        None
                    } else {
                        Some(stored)
                    }
                }
                None => None,
            };
            let enc = match cached {
                Some(stored) => {
                    info!("store hit: {} (skipping re-sketch)", store.path_for(&key).display());
                    if args.get("mode").is_some() {
                        info!(
                            "note: --mode {mode_name} not exercised on a store hit \
                             (sketches are mode-exchangeable); use --force to re-sketch"
                        );
                    }
                    stored.enc
                }
                None => {
                    // pass 2: streaming sketch through the unified engine
                    let plan = SketchPlan::new(kind, s).with_seed(seed);
                    let cfg = PipelineConfig {
                        workers: args.get_parse_or("workers", 0)?,
                        ..Default::default()
                    };
                    let stream = FileStream::open(Path::new(input))?;
                    let (sketch, metrics) =
                        sketch_entry_stream(mode, stream, &stats, &plan, &cfg)?;
                    info!("pipeline: {}", metrics.summary());
                    let enc = encode_sketch(&sketch)?;
                    let path = store.put(&key, &enc)?;
                    info!("stored sketch at {}", path.display());
                    enc
                }
            };
            info!(
                "sketch: {} encoded ({:.2} bits/sample)",
                human_bytes(enc.bytes.len()),
                enc.bits_per_sample()
            );
            if let Some(outp) = args.get("sketch-out") {
                std::fs::write(outp, &enc.bytes)?;
                info!("wrote encoded sketch to {outp}");
            }
        }
        "query" => {
            let dataset = args
                .get("dataset")
                .ok_or_else(|| Error::invalid("query requires --dataset <label>"))?;
            let s: u64 = args
                .get_parse("s")?
                .ok_or_else(|| Error::invalid("query requires --s <budget>"))?;
            let kind = parse_method(args.get_or("method", "bernstein"))?;
            let key = StoreKey::new(dataset, &kind.name(), s, seed);
            // one surface, two backends: --addr targets a live
            // `matsketch serve`, otherwise the local store answers
            let mut client: Box<dyn SketchClient> = match args.get("addr") {
                Some(addr) => Box::new(RemoteClient::connect(addr)?),
                None => Box::new(LocalClient::open_dir(args.get_or("store", "sketch-store"))?),
            };
            let info = client.open(&key)?;
            info!(
                "serving {}x{} sketch, s={} ({}, {})",
                info.m,
                info.n,
                key.s,
                info.method,
                if args.get("addr").is_some() { "remote" } else { "local" }
            );
            let result = run_query(&args, client.as_mut(), &key, &info);
            client.close()?;
            result?;
        }
        "serve-bench" => {
            // --workers is an alias for --readers: the reader counts ARE
            // the per-sketch worker-pool sizes under test (and, on tall
            // sketches, the row-parallel split width per query)
            let readers_spec =
                args.get("workers").unwrap_or_else(|| args.get_or("readers", "1,2,4"));
            let cfg = matsketch::eval::ServeConfig {
                readers: parse_usize_list(readers_spec)?,
                queries: args.get_parse_or("queries", 64)?,
                batch_ks: parse_usize_list(args.get_or("batch-ks", "1,4,16"))?,
                budget_frac: args.get_parse_or("budget-frac", 10)?,
                seed,
                small,
            };
            let datasets = parse_datasets(args.get("datasets"))?;
            let store_dir = PathBuf::from(args.get_or("store", "sketch-store"));
            let pts = matsketch::eval::run_serve_bench(&out, &store_dir, &cfg, &datasets)?;
            for p in &pts {
                info!(
                    "serve-bench: {} readers={} -> {:.1} queries/s",
                    p.dataset, p.readers, p.qps
                );
            }
            info!(
                "serve-bench: {} points -> {}/serving.* + serving_batch.*",
                pts.len(),
                out.display()
            );
        }
        "serve" => {
            let addr = args.get_or("addr", "127.0.0.1:7300");
            let store = SketchStore::open(args.get_or("store", "sketch-store"))?;
            let timeout_secs: f64 = args.get_parse_or("timeout-secs", 60.0)?;
            let timeout = if timeout_secs > 0.0 {
                Some(std::time::Duration::from_secs_f64(timeout_secs))
            } else {
                None
            };
            // --chaos installs a seeded, replayable fault plan (and
            // optionally a store write fault) for resilience drills
            let chaos = match args.get("chaos") {
                Some(spec) => {
                    let (plan, store_fault) = FaultPlan::parse(spec)?;
                    if let Some(f) = store_fault {
                        matsketch::net::chaos::install_store_fault(f);
                    }
                    info!("chaos enabled: {spec}");
                    Some(std::sync::Arc::new(plan))
                }
                None => None,
            };
            let cfg = NetServerConfig {
                workers_per_sketch: args.get_parse_or("workers", 4)?,
                max_connections: args.get_parse_or("max-conns", 64)?,
                read_timeout: timeout,
                write_timeout: timeout,
                shed_high_water: args.get_parse_or("shed-high-water", 0)?,
                chaos,
                ..Default::default()
            };
            // request-tracing knobs: sample one query in N (1 traces
            // everything), retain + warn-log roots slower than --slow-us
            if let Some(n) = args.get_parse::<u64>("trace-one-in-n")? {
                matsketch::obs::trace::set_trace_one_in_n(n);
            }
            if let Some(us) = args.get_parse::<u64>("slow-us")? {
                matsketch::obs::trace::set_slow_us(us);
            }
            let server = NetServer::bind(store, addr, cfg)?;
            // --ingest attaches a live generation chain fed from a
            // triplet file by a background thread: clients query the
            // chain (latest or pinned generation) while it grows
            if let Some(input) = args.get("ingest") {
                let s: u64 = args
                    .get_parse("s")?
                    .ok_or_else(|| Error::invalid("serve --ingest requires --s <budget>"))?;
                let kind = parse_method(args.get_or("method", "bernstein"))?;
                let mut stream = FileStream::open(Path::new(input))?;
                let (m, n) = {
                    use matsketch::stream::EntryStream;
                    stream.shape()
                };
                let plan = SketchPlan::new(kind, s).with_seed(seed);
                let live_cfg = LiveConfig {
                    epoch_entries: args.get_parse_or("epoch-entries", 4096)?,
                    retain: args.get_parse_or("retain", 4)?,
                    workers: args.get_parse_or("workers", 4)?,
                };
                let mut live = LiveSketch::start(m, n, &plan, &live_cfg)?;
                let key = StoreKey::new(&dataset_label(&args, input), &kind.name(), s, seed);
                server.attach_live(&key, live.reader());
                info!(
                    "live chain {}: ingesting {m}x{n} stream from {input} \
                     (epoch every {} entries)",
                    key.file_name(),
                    live_cfg.epoch_entries
                );
                let batch: usize = args.get_parse_or::<usize>("ingest-batch", 1024)?.max(1);
                std::thread::spawn(move || {
                    let mut run = || -> Result<()> {
                        use matsketch::stream::EntryStream;
                        let mut buf = Vec::with_capacity(batch);
                        while let Some(e) = stream.next_entry()? {
                            buf.push(e);
                            if buf.len() >= batch {
                                live.push(&buf)?;
                                buf.clear();
                            }
                        }
                        if !buf.is_empty() {
                            live.push(&buf)?;
                        }
                        let g = live.flush()?;
                        info!(
                            "ingest complete: {} entries, generation {g} live",
                            live.ingested()
                        );
                        Ok(())
                    };
                    if let Err(e) = run() {
                        warn_log!("live ingest stopped: {e}");
                    }
                });
            }
            let local = server.local_addr();
            info!(
                "serving on {local}; stop with the wire Shutdown sentinel \
                 (e.g. `matsketch net-shutdown --addr {local}`)"
            );
            if let Some(secs) = args.get_parse::<f64>("shutdown-after-secs")? {
                // timed self-shutdown (CI smoke / demos): send ourselves
                // the sentinel after the deadline
                std::thread::spawn(move || {
                    std::thread::sleep(std::time::Duration::from_secs_f64(secs.max(0.0)));
                    if let Ok(mut c) = RemoteClient::connect(&local.to_string()) {
                        let _ = c.shutdown_server();
                    }
                });
            }
            let stats = server.wait();
            info!(
                "served {} frames over {} connections ({} faults)",
                stats.frames, stats.connections, stats.faults
            );
        }
        "live-bench" => {
            let cfg = matsketch::eval::LiveBenchConfig {
                m: args.get_parse_or("m", 64)?,
                n: args.get_parse_or("n", 256)?,
                entries: args.get_parse_or("entries", 20_000)?,
                epoch_entries: args.get_parse_or("epoch-entries", 2_048)?,
                s: args.get_parse_or("s", 2_000)?,
                clients: parse_usize_list(args.get_or("clients", "2,4"))?,
                queries_per_client: args.get_parse_or("queries", 64)?,
                seed,
            };
            let store_dir = PathBuf::from(args.get_or("store", "sketch-store"));
            let pts = matsketch::eval::run_live_bench(&out, &store_dir, &cfg)?;
            for p in &pts {
                info!(
                    "live-bench: clients={} -> {:.1} queries/s, {} generations, \
                     lag p95 {:.2} ms",
                    p.clients, p.qps, p.generations, p.lag_p95_ms
                );
            }
            info!("live-bench: {} points -> {}/live_serving.*", pts.len(), out.display());
        }
        "stats" => {
            let addr = args
                .get("addr")
                .ok_or_else(|| Error::invalid("stats requires --addr <HOST:PORT>"))?;
            let json = args.flag("json");
            match args.get_parse::<f64>("watch")? {
                // one-shot scrape
                None => {
                    let snap = scrape_stats(addr)?;
                    if snap.is_empty() && !json {
                        info!("server at {addr} has recorded no metrics yet");
                    }
                    print_stats(&snap, json);
                }
                // --watch SECS: re-scrape on an interval and show only
                // what happened since the previous scrape (counters and
                // buckets diff; gauges stay instantaneous). Runs until
                // interrupted or the server goes away.
                Some(secs) => {
                    let interval = std::time::Duration::from_secs_f64(secs.max(0.1));
                    let mut prev = scrape_stats(addr)?;
                    loop {
                        std::thread::sleep(interval);
                        let snap = scrape_stats(addr)?;
                        print_stats(&snap.diff(&prev), json);
                        prev = snap;
                    }
                }
            }
        }
        "trace" => {
            let addr = args
                .get("addr")
                .ok_or_else(|| Error::invalid("trace requires --addr <HOST:PORT>"))?;
            let mut client = RemoteClient::connect(addr)?;
            // --id fetches one retained trace by its (hex) id; otherwise
            // the N slowest retained roots come back
            let (id, slowest) = match args.get("id") {
                Some(spec) => (parse_trace_id(spec)?, 0),
                None => (0, args.get_parse_or("slowest", 5)?),
            };
            let traces = client.traces(id, slowest)?;
            if traces.is_empty() {
                info!(
                    "no matching traces retained at {addr} (is sampling on? \
                     serve --trace-one-in-n 1 traces every query)"
                );
            }
            print!("{}", matsketch::obs::trace::render(&traces));
        }
        "net-shutdown" => {
            let addr = args.get_or("addr", "127.0.0.1:7300");
            let mut client = RemoteClient::connect(addr)?;
            client.shutdown_server()?;
            info!("server at {addr} acknowledged shutdown");
        }
        "net-bench" => {
            let cfg = matsketch::eval::NetBenchConfig {
                clients: parse_usize_list(args.get_or("clients", "1,2,8"))?,
                queries: args.get_parse_or("queries", 64)?,
                duration_secs: args.get_parse::<f64>("duration-secs")?,
                ops: parse_ops(args.get_or("ops", "matvec,row,top-k"))?,
                top_k: args.get_parse_or("k", 10)?,
                batch_k: args.get_parse_or("batch-k", 4)?,
                budget_frac: args.get_parse_or("budget-frac", 10)?,
                seed,
                small,
                workers: args.get_parse_or("workers", 4)?,
            };
            let datasets = parse_datasets(args.get("datasets"))?;
            let store_dir = PathBuf::from(args.get_or("store", "sketch-store"));
            let pts = matsketch::eval::run_net_bench(
                &out,
                &store_dir,
                args.get("addr"),
                &cfg,
                &datasets,
            )?;
            info!("net-bench: {} points -> {}/net_serving.*", pts.len(), out.display());
        }
        "chaos-bench" => {
            let default_chaos = matsketch::eval::ChaosBenchConfig::default().chaos;
            let cfg = matsketch::eval::ChaosBenchConfig {
                clients: parse_usize_list(args.get_or("clients", "2,8"))?,
                queries: args.get_parse_or("queries", 64)?,
                duration_secs: args.get_parse::<f64>("duration-secs")?,
                ops: parse_ops(args.get_or("ops", "matvec,row,top-k"))?,
                top_k: args.get_parse_or("k", 10)?,
                batch_k: args.get_parse_or("batch-k", 4)?,
                budget_frac: args.get_parse_or("budget-frac", 10)?,
                seed,
                small,
                workers: args.get_parse_or("workers", 2)?,
                chaos: args.get_or("chaos", &default_chaos).to_string(),
                shed_high_water: args.get_parse_or("shed-high-water", 2)?,
            };
            let datasets = parse_datasets(args.get("datasets"))?;
            let store_dir = PathBuf::from(args.get_or("store", "sketch-store"));
            let pts = matsketch::eval::run_chaos_bench(&out, &store_dir, &cfg, &datasets)?;
            info!("chaos-bench: {} points -> {}/chaos_serving.*", pts.len(), out.display());
        }
        "lint" => {
            let start = match args.get("root") {
                Some(r) => PathBuf::from(r),
                None => std::env::current_dir()?,
            };
            let cfg = analysis::LintConfig::locate(&start)?;
            let report = analysis::run(&cfg)?;
            analysis::report::write(&report, &out)?;
            for f in &report.findings {
                println!("{}", f.render());
            }
            for e in &report.stale_allow {
                warn_log!("lint: stale lint.allow entry (line {}): {}", e.line, e.render());
            }
            info!(
                "lint: {} files, {} finding(s), {} baselined, {} stale allow \
                 entr(ies) -> {}/lint.*",
                report.files_scanned,
                report.findings.len(),
                report.baselined.len(),
                report.stale_allow.len(),
                out.display()
            );
            if !report.clean() {
                return Err(Error::invalid(format!(
                    "{} lint finding(s); see {}/lint.md",
                    report.findings.len(),
                    out.display()
                )));
            }
        }
        other => {
            print_help();
            return Err(Error::invalid(format!("unknown command {other}")));
        }
    }
    Ok(())
}

/// Resolve the global log threshold. Precedence: an explicit
/// `--log-level` flag beats the `MATSKETCH_LOG` environment variable
/// beats `--verbose` (debug); otherwise the default level stands. A bad
/// flag value is an error; a bad env value only warns, so a stale shell
/// export cannot make every invocation fail.
fn init_log_level(args: &Args) -> Result<()> {
    if let Some(spec) = args.get("log-level") {
        let level = Level::parse(spec).ok_or_else(|| {
            Error::invalid(format!("unknown --log-level {spec:?} (error|warn|info|debug)"))
        })?;
        set_level(level);
    } else if let Ok(spec) = std::env::var("MATSKETCH_LOG") {
        match Level::parse(&spec) {
            Some(level) => set_level(level),
            None => warn_log!("ignoring MATSKETCH_LOG={spec:?} (expected error|warn|info|debug)"),
        }
    } else if args.flag("verbose") {
        set_level(Level::Debug);
    }
    Ok(())
}

/// Print one stats scrape: the markdown table by default, or a single
/// machine-readable JSON object with `--json`.
fn print_stats(snap: &MetricsSnapshot, json: bool) {
    if json {
        println!("{}", snapshot_json(snap).to_string());
    } else {
        print!("{}", server_metrics_table(snap).to_markdown());
    }
}

/// Lower a telemetry snapshot to JSON: counters and gauges become
/// name→value objects, histograms become name→bucket-count arrays (the
/// log₂-µs bucket layout is fixed; see `obs::registry::hist_bucket`).
fn snapshot_json(snap: &MetricsSnapshot) -> Json {
    let kv = |list: &[(String, u64)]| {
        Json::Obj(list.iter().map(|(n, v)| (n.clone(), json::num(*v as f64))).collect())
    };
    json::obj(vec![
        ("counters", kv(&snap.counters)),
        ("gauges", kv(&snap.gauges)),
        (
            "hists",
            Json::Obj(
                snap.hists
                    .iter()
                    .map(|(n, buckets)| {
                        let arr = buckets.iter().map(|&c| json::num(c as f64)).collect();
                        (n.clone(), Json::Arr(arr))
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Parse a `--id` trace-id argument. Trace ids render as 16-digit hex
/// (`trace::render`, the slow-query warn line), so hex is accepted with
/// or without a `0x` prefix; a plain run of digits parses as decimal.
fn parse_trace_id(spec: &str) -> Result<u64> {
    let bad = || Error::invalid(format!("bad trace id {spec:?} (hex or decimal)"));
    if let Some(hex) = spec.strip_prefix("0x").or_else(|| spec.strip_prefix("0X")) {
        return u64::from_str_radix(hex, 16).map_err(|_| bad());
    }
    if spec.bytes().all(|b| b.is_ascii_digit()) {
        return spec.parse::<u64>().map_err(|_| bad());
    }
    u64::from_str_radix(spec, 16).map_err(|_| bad())
}

/// Whether `input` was modified after the stored sketch at `entry` (when
/// both timestamps are available): a cache hit for a since-regenerated
/// input file must not serve a sketch of the old matrix.
fn input_newer_than(input: &str, entry: &Path) -> bool {
    let mtime = |p: &Path| std::fs::metadata(p).and_then(|m| m.modified()).ok();
    match (mtime(Path::new(input)), mtime(entry)) {
        (Some(a), Some(b)) => a > b,
        _ => false,
    }
}

/// Dataset label for the store key: explicit `--dataset`, else the input
/// file stem.
fn dataset_label(args: &Args, input: &str) -> String {
    if let Some(d) = args.get("dataset") {
        return d.to_string();
    }
    Path::new(input)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("input")
        .to_string()
}

/// Parse a comma-separated load-op mix (e.g. `--ops matvec,row,top-k`).
fn parse_ops(spec: &str) -> Result<Vec<LoadOp>> {
    let mut out = Vec::new();
    for tok in spec.split(',') {
        let t = tok.trim();
        if t.is_empty() {
            continue;
        }
        out.push(
            LoadOp::parse(t)
                .ok_or_else(|| Error::invalid(format!("unknown op {t:?} in mix {spec:?}")))?,
        );
    }
    if out.is_empty() {
        return Err(Error::invalid(format!("empty op mix {spec:?}")));
    }
    Ok(out)
}

/// Parse a comma-separated list of positive integers (e.g. `--readers 1,2,4`).
fn parse_usize_list(spec: &str) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for tok in spec.split(',') {
        let t = tok.trim();
        if t.is_empty() {
            continue;
        }
        out.push(
            t.parse::<usize>()
                .map_err(|_| Error::invalid(format!("bad count {t:?} in list {spec:?}")))?,
        );
    }
    if out.is_empty() {
        return Err(Error::invalid(format!("empty list {spec:?}")));
    }
    Ok(out)
}

/// Build the [`QueryRequest`] for one `query` subcommand invocation.
///
/// Parsing is strict: an option the chosen `--op` does not consume is an
/// error, not silently ignored, and a malformed value errors instead of
/// falling back to a default — so `--op row --idnex 3` or
/// `--op top-k --index 3` can never silently query row 0 / the default k.
fn parse_query_request(args: &Args, op: &str, m: usize, n: usize) -> Result<QueryRequest> {
    let used: &[&str] = match op {
        "matvec" | "matvec-t" => &["x-seed"],
        "matvec-batch" => &["x-seed", "batch-k"],
        "row" | "col" => &["index"],
        "top-k" | "topk" => &["k"],
        other => return Err(Error::invalid(format!("unknown query op {other}"))),
    };
    for opt in ["index", "k", "x-seed", "batch-k"] {
        if args.get(opt).is_some() && !used.contains(&opt) {
            return Err(Error::invalid(format!(
                "--{opt} is not used by --op {op} (it takes --{})",
                used.join(", --")
            )));
        }
    }
    Ok(match op {
        "matvec" | "matvec-t" | "matvec-batch" => {
            // deterministic pseudo-random probe vector (reproducible runs)
            let x_seed: u64 = args.get_parse_or("x-seed", 1)?;
            let len = if op == "matvec-t" { m } else { n };
            let mut rng = Rng::new(x_seed);
            match op {
                "matvec" => QueryRequest::Matvec((0..len).map(|_| rng.normal()).collect()),
                "matvec-t" => QueryRequest::MatvecT((0..len).map(|_| rng.normal()).collect()),
                _ => {
                    let k: usize = args.get_parse_or("batch-k", 4)?;
                    if k == 0 {
                        return Err(Error::invalid("--batch-k must be ≥ 1"));
                    }
                    QueryRequest::MatvecBatch(
                        (0..k)
                            .map(|_| (0..len).map(|_| rng.normal()).collect())
                            .collect(),
                    )
                }
            }
        }
        "row" | "col" => {
            let index: u32 = args.get_parse("index")?.ok_or_else(|| {
                Error::invalid(format!("--op {op} requires an explicit --index <I>"))
            })?;
            if op == "row" {
                QueryRequest::Row(index)
            } else {
                QueryRequest::Col(index)
            }
        }
        _ => QueryRequest::TopK(args.get_parse_or("k", 10)?),
    })
}

/// Execute one `query` subcommand op through the client API (the sketch
/// is already opened; `info` carries its shape) and print the answer.
fn run_query(
    args: &Args,
    client: &mut dyn SketchClient,
    key: &StoreKey,
    info: &SketchInfo,
) -> Result<()> {
    let (m, n) = (info.m as usize, info.n as usize);
    let op = args.get_or("op", "top-k");
    let request = parse_query_request(args, op, m, n)?;
    match client.query(key, &request)? {
        QueryResponse::Vector(y) => print_vector(&y),
        QueryResponse::Vectors(ys) => {
            println!("{} result vectors", ys.len());
            for y in &ys {
                print_vector(y);
            }
        }
        QueryResponse::Entries(es) => {
            println!("{} entries", es.len());
            for e in es.iter().take(20) {
                println!(
                    "  ({}, {})  count={}  value={:.6e}",
                    e.row, e.col, e.count, e.value
                );
            }
            if es.len() > 20 {
                println!("  ... {} more", es.len() - 20);
            }
        }
    }
    Ok(())
}

/// Print a dense result vector: its l2 norm plus the 5 heaviest slots.
fn print_vector(y: &[f64]) {
    let norm = y.iter().map(|v| v * v).sum::<f64>().sqrt();
    let mut heavy: Vec<(usize, f64)> = y.iter().copied().enumerate().collect();
    heavy.sort_by(|a, b| {
        b.1.abs()
            .partial_cmp(&a.1.abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    heavy.truncate(5);
    println!("len={} l2_norm={norm:.6e}", y.len());
    for (i, v) in heavy {
        println!("  y[{i}] = {v:.6e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q_args(raw: &[&str]) -> Args {
        Args::parse(raw.iter().map(|s| s.to_string()), &[]).unwrap()
    }

    #[test]
    fn query_parsing_is_strict() {
        // row/col demand an explicit index — no silent row 0
        let err = parse_query_request(&q_args(&["--op", "row"]), "row", 10, 20).unwrap_err();
        assert!(err.to_string().contains("--index"), "{err}");
        match parse_query_request(&q_args(&["--op", "row", "--index", "3"]), "row", 10, 20) {
            Ok(QueryRequest::Row(3)) => {}
            other => panic!("unexpected {other:?}"),
        }

        // malformed values error instead of falling back to defaults
        assert!(
            parse_query_request(&q_args(&["--index", "zer0"]), "row", 10, 20).is_err()
        );
        assert!(parse_query_request(&q_args(&["--k", "ten"]), "top-k", 10, 20).is_err());

        // options the op does not consume are rejected, not ignored
        let err =
            parse_query_request(&q_args(&["--index", "3"]), "top-k", 10, 20).unwrap_err();
        assert!(err.to_string().contains("not used"), "{err}");
        assert!(parse_query_request(&q_args(&["--k", "5"]), "matvec", 10, 20).is_err());

        // happy paths
        match parse_query_request(&q_args(&["--k", "5"]), "top-k", 10, 20) {
            Ok(QueryRequest::TopK(5)) => {}
            other => panic!("unexpected {other:?}"),
        }
        match parse_query_request(&q_args(&["--batch-k", "3"]), "matvec-batch", 10, 20) {
            Ok(QueryRequest::MatvecBatch(xs)) => {
                assert_eq!(xs.len(), 3);
                assert!(xs.iter().all(|x| x.len() == 20));
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(parse_query_request(&q_args(&["--batch-k", "0"]), "matvec-batch", 10, 20)
            .is_err());
        assert!(parse_query_request(&q_args(&[]), "transpose", 10, 20).is_err());
    }
}

fn pick_engine(name: Option<&str>) -> Box<dyn DenseEngine> {
    match name {
        Some("rust") => Box::new(RustEngine),
        Some("xla") => match XlaEngine::from_dir(Path::new(
            &std::env::var("MATSKETCH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        )) {
            Ok(e) => Box::new(e),
            Err(e) => {
                warn_log!("--engine xla requested but unavailable: {e}; using rust");
                Box::new(RustEngine)
            }
        },
        _ => default_engine(),
    }
}

fn parse_datasets(spec: Option<&str>) -> Result<Vec<DatasetId>> {
    match spec {
        None => Ok(DatasetId::all().to_vec()),
        Some(s) => s
            .split(',')
            .map(|tok| {
                DatasetId::parse(tok.trim())
                    .ok_or_else(|| Error::invalid(format!("unknown dataset {tok}")))
            })
            .collect(),
    }
}

fn parse_method(name: &str) -> Result<DistributionKind> {
    match name.to_ascii_lowercase().as_str() {
        "bernstein" => Ok(DistributionKind::Bernstein),
        "row-l1" | "rowl1" => Ok(DistributionKind::RowL1),
        "l1" => Ok(DistributionKind::L1),
        "l2" => Ok(DistributionKind::L2),
        "l2-trim-0.1" => Ok(DistributionKind::L2Trim(0.1)),
        "l2-trim-0.01" => Ok(DistributionKind::L2Trim(0.01)),
        other => Err(Error::invalid(format!("unknown method {other}"))),
    }
}

fn print_help() {
    println!(
        "matsketch — near-optimal entrywise sampling for data matrices (NIPS'13)

USAGE: matsketch <command> [options]

COMMANDS:
  tables       E1/E4: matrix characteristics + sample-complexity tables
  fig1         E2: Figure-1 quality sweep (all methods x budgets x datasets)
  compress     E3: sketch codec bits/sample + disc-size ratios
  theory       E6: eps5 near-optimality checks
  ablate       E8: row-norm-noise / delta / worker-count ablations
  serve-bench  E9: concurrent + batched query-serving throughput (local client)
  net-bench    E11: remote serving throughput + latency percentiles over TCP
  chaos-bench  E13: goodput, retries, and shed rate under injected faults
  gen          generate a dataset to a binary triplet file
  sketch       stream-sketch a triplet file into the sketch store
  query        answer a matvec / slice / top-k query (local store or --addr)
  serve        serve the sketch store over TCP (wire protocol v6, v1-v5
               accepted); --ingest adds a live ingest-while-serving chain
  live-bench   E12: mixed ingest+query throughput + freshness-lag table
  stats        scrape a running server's telemetry snapshot (per-op
               counts, latency histograms, cache hit rate) as a table,
               JSON blob (--json), or interval diff stream (--watch S)
  trace        fetch retained request traces from a running server and
               render their span timelines (--id N or --slowest N)
  lint         run the project static analyzer (unsafe-audit, atomics
               orderings, panic-free decode, wire discipline, timed-
               section gating) and write reports/lint.{{json,md}}; exits
               nonzero on any non-baselined finding
  net-shutdown send the graceful-shutdown sentinel to a running server

COMMON OPTIONS:
  --out DIR        report/output directory (default: reports)
  --seed N         RNG seed (default 0)
  --small          use reduced-size dataset variants
  --engine xla|rust  dense-compute engine (default: xla if artifacts exist)
  --store DIR      sketch store directory (default: sketch-store)
  --log-level L    logging threshold: error|warn|info|debug (the
                   MATSKETCH_LOG env var is the fallback)
  --verbose        shorthand for --log-level debug

SKETCH OPTIONS:
  --input FILE --s N [--method bernstein|row-l1|l1|l2|l2-trim-0.1]
  [--mode offline|streaming|spilling|sharded] [--workers W]
  [--dataset LABEL] [--force] [--sketch-out FILE]
  The encoded sketch lands in the store keyed by
  (dataset, method, s, seed); a re-run with the same key is a cache hit.

QUERY OPTIONS:
  --dataset LABEL --s N [--method NAME] [--addr HOST:PORT]
  --op matvec|matvec-t|matvec-batch|row|col|top-k
  [--k K] [--index I] [--x-seed N] [--batch-k K]
  Goes through the unified SketchClient API: without --addr the local
  store answers, with --addr a remote server does — same output either
  way. row/col require an explicit --index; options the op does not use
  are rejected.

SERVE-BENCH OPTIONS:
  [--readers 1,2,4] [--queries Q] [--batch-ks 1,4,16] [--budget-frac F]
  [--datasets a,b]
  --workers is accepted as an alias for --readers (the reader counts are
  the per-sketch worker-pool sizes, which also row-parallelize single
  matvec/top-k queries on tall sketches).

SERVE OPTIONS:
  --addr HOST:PORT [--workers W] [--max-conns N] [--timeout-secs S]
  [--shutdown-after-secs S] [--trace-one-in-n N] [--slow-us US]
  [--shed-high-water N] [--chaos SPEC]
  [--ingest a.bin --s N [--method NAME] [--dataset LABEL]
   [--epoch-entries E] [--retain R] [--ingest-batch B]]
  Serves every sketch in the store; clients open by
  (dataset, method, s, seed) and stream matvec / slice / top-k answers.
  With --ingest, a background thread streams the triplet file into a
  live generation chain served alongside the store: a new immutable
  snapshot publishes every --epoch-entries entries (default 4096), and
  v3 clients can pin queries to a generation or poll for a fresher one.
  --shed-high-water N sheds queries past N in flight with a typed
  overloaded fault carrying a retry-after hint (0 = never shed).
  --chaos SPEC injects a seeded, replayable fault schedule, e.g.
  seed=7,disconnect=0.02,partial=0.01,corrupt=0.005,tarpit=0.02:3,
  store=0.1, plus scripted at=CONN:FRAME:KIND[:MS] rules.

LIVE-BENCH OPTIONS:
  [--clients 2,4] [--queries Q] [--entries E] [--epoch-entries E]
  [--s N] [--m M] [--n N]
  Mixed ingest+query load against a live chain: queries/sec + latency
  percentiles measured while the stream arrives, plus freshness-lag
  p50/p95; results land in reports/live_serving.*

NET-BENCH OPTIONS:
  [--addr HOST:PORT] [--clients 1,2,8] [--queries Q] [--duration-secs S]
  [--ops matvec,matvec-t,matvec-batch,row,col,top-k] [--k K] [--batch-k K]
  [--workers W] [--budget-frac F] [--datasets a,b]
  Without --addr the server is self-hosted on an ephemeral loopback port
  over --store; results land in reports/net_serving.* plus a
  server-side telemetry diff in reports/server_metrics.*

CHAOS-BENCH OPTIONS:
  [--clients 2,8] [--queries Q] [--duration-secs S] [--ops ...]
  [--chaos SPEC] [--shed-high-water N] [--budget-frac F] [--datasets a,b]
  Always self-hosted: the load runs against a server with the --chaos
  fault schedule installed and shedding past --shed-high-water queries
  in flight. Reports goodput, client retries, shed count + rate, and
  accepted-work latency percentiles to reports/chaos_serving.*

STATS OPTIONS:
  --addr HOST:PORT [--json] [--watch SECS]
  Pulls the server's obs registry snapshot over the wire (Stats opcode,
  protocol v5) and prints the server_metrics table: per-op request
  counts, qps + bytes/s rates, execute-latency p50/p95/p99 (µs), cache
  hit rate, live freshness-lag buckets. --json emits one machine-readable
  object instead; --watch SECS re-scrapes on an interval and prints only
  what changed since the previous scrape.

TRACE OPTIONS:
  --addr HOST:PORT [--id N | --slowest N]
  Pulls retained request traces (TraceDump opcode, protocol v5) and
  renders each as an indented span timeline with per-span offsets,
  durations, and notes. --id (hex or decimal) fetches one trace;
  --slowest N (default 5) fetches the N slowest retained roots. Traces
  exist only for sampled requests — serve --trace-one-in-n 1 traces
  every query, and roots slower than --slow-us land in the slow log.

LINT OPTIONS:
  [--root DIR] [--out DIR]
  Locates the crate from --root (default: the working directory, walking
  up to the first Cargo.toml + src/), scans src/tests/benches/examples,
  subtracts the src/analysis/lint.allow baseline, and writes
  reports/lint.{{json,md}}. Findings print as path:line [lint] message;
  stale baseline entries are warned about and fail the CI report checks.
"
    );
}
