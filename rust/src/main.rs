//! matsketch CLI — the L3 leader entrypoint.
//!
//! ```text
//! matsketch tables    [--small] [--seed N] [--out DIR]
//! matsketch fig1      [--small] [--seed N] [--out DIR] [--k K]
//!                     [--points P] [--datasets a,b] [--engine xla|rust]
//! matsketch compress  [--small] [--seed N] [--out DIR]
//! matsketch theory    [--small] [--seed N] [--out DIR]
//! matsketch sketch    --input a.bin --s N [--method NAME] [--workers W]
//!                     [--mode offline|streaming|sharded] [--out sketch.bin]
//! matsketch gen       --dataset NAME [--seed N] --out a.bin
//! ```

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use matsketch::coordinator::PipelineConfig;
use matsketch::datasets::DatasetId;
use matsketch::distributions::{DistributionKind, MatrixStats};
use matsketch::engine::{sketch_entry_stream, SketchMode};
use matsketch::error::{Error, Result};
use matsketch::eval::{run_compression, run_figure1, run_tables, run_theory, Figure1Config};
use matsketch::runtime::{default_engine, DenseEngine, RustEngine, XlaEngine};
use matsketch::sketch::{encode_sketch, SketchPlan};
use matsketch::sparse::io as sparse_io;
use matsketch::stream::FileStream;
use matsketch::util::args::Args;
use matsketch::util::human_bytes;
use matsketch::util::logging::{set_level, Level};
use matsketch::{info, warn_log};

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env(&["small", "verbose", "help", "include-ahk06"])?;
    if args.flag("verbose") {
        set_level(Level::Debug);
    }
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    if args.flag("help") || cmd == "help" {
        print_help();
        return Ok(());
    }
    let out: PathBuf = PathBuf::from(args.get_or("out", "reports"));
    let seed: u64 = args.get_parse_or("seed", 0)?;
    let small = args.flag("small");

    match cmd {
        "tables" => {
            let rows = run_tables(&out, small, seed)?;
            info!("wrote characteristics + sample-complexity tables for {} matrices", rows.len());
        }
        "fig1" => {
            let engine = pick_engine(args.get("engine"));
            let cfg = Figure1Config {
                k: args.get_parse_or("k", 20)?,
                svd_iters: args.get_parse_or("svd-iters", 8)?,
                budget_points: args.get_parse_or("points", 8)?,
                include_ahk06: args.flag("include-ahk06"),
                seed,
                small,
                ..Default::default()
            };
            let datasets = parse_datasets(args.get("datasets"))?;
            let pts = run_figure1(&out, &cfg, engine.as_ref(), &datasets)?;
            info!("figure1: {} points written to {}", pts.len(), out.display());
        }
        "compress" => {
            let pts = run_compression(&out, small, seed)?;
            info!("compression: {} points", pts.len());
        }
        "theory" => {
            let pts = run_theory(&out, small, seed)?;
            info!("theory: {} points", pts.len());
        }
        "ablate" => {
            let engine = pick_engine(args.get("engine"));
            let pts = matsketch::eval::run_ablation(&out, seed, engine.as_ref())?;
            info!("ablation: {} points -> {}/ablation.*", pts.len(), out.display());
        }
        "gen" => {
            let name = args
                .get("dataset")
                .ok_or_else(|| Error::invalid("gen requires --dataset"))?;
            let id = DatasetId::parse(name)
                .ok_or_else(|| Error::invalid(format!("unknown dataset {name}")))?;
            let coo = if small { id.generate_small(seed) } else { id.generate(seed) };
            let path = PathBuf::from(
                args.get("out")
                    .map(str::to_string)
                    .unwrap_or_else(|| format!("{name}.bin")),
            );
            sparse_io::write_binary(&coo, &path)?;
            info!(
                "{}: {}x{}, nnz={} -> {}",
                name,
                coo.m,
                coo.n,
                coo.nnz(),
                path.display()
            );
        }
        "sketch" => {
            let input = args
                .get("input")
                .ok_or_else(|| Error::invalid("sketch requires --input <triplets.bin>"))?;
            let s: u64 = args
                .get_parse("s")?
                .ok_or_else(|| Error::invalid("sketch requires --s <budget>"))?;
            let kind = parse_method(args.get_or("method", "bernstein"))?;
            let mode_name = args.get_or("mode", "sharded");
            let mode = SketchMode::parse(mode_name)
                .ok_or_else(|| Error::invalid(format!("unknown mode {mode_name}")))?;
            // pass 1: stats
            let mut st_stream = FileStream::open(Path::new(input))?;
            let (m, n) = {
                use matsketch::stream::EntryStream;
                st_stream.shape()
            };
            let mut stats = MatrixStats::new(m, n);
            {
                use matsketch::stream::EntryStream;
                while let Some(e) = st_stream.next_entry()? {
                    stats.push(&e);
                }
            }
            // pass 2: streaming sketch through the unified engine
            let plan = SketchPlan::new(kind, s).with_seed(seed);
            let cfg = PipelineConfig {
                workers: args.get_parse_or("workers", 0)?,
                ..Default::default()
            };
            let stream = FileStream::open(Path::new(input))?;
            let (sketch, metrics) = sketch_entry_stream(mode, stream, &stats, &plan, &cfg)?;
            info!("pipeline: {}", metrics.summary());
            let enc = encode_sketch(&sketch)?;
            info!(
                "sketch: {} coordinates, {} encoded ({:.2} bits/sample)",
                sketch.nnz(),
                human_bytes(enc.bytes.len()),
                enc.bits_per_sample()
            );
            if let Some(outp) = args.get("sketch-out") {
                std::fs::write(outp, &enc.bytes)?;
                info!("wrote encoded sketch to {outp}");
            }
        }
        other => {
            print_help();
            return Err(Error::invalid(format!("unknown command {other}")));
        }
    }
    Ok(())
}

fn pick_engine(name: Option<&str>) -> Box<dyn DenseEngine> {
    match name {
        Some("rust") => Box::new(RustEngine),
        Some("xla") => match XlaEngine::from_dir(Path::new(
            &std::env::var("MATSKETCH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        )) {
            Ok(e) => Box::new(e),
            Err(e) => {
                warn_log!("--engine xla requested but unavailable: {e}; using rust");
                Box::new(RustEngine)
            }
        },
        _ => default_engine(),
    }
}

fn parse_datasets(spec: Option<&str>) -> Result<Vec<DatasetId>> {
    match spec {
        None => Ok(DatasetId::all().to_vec()),
        Some(s) => s
            .split(',')
            .map(|tok| {
                DatasetId::parse(tok.trim())
                    .ok_or_else(|| Error::invalid(format!("unknown dataset {tok}")))
            })
            .collect(),
    }
}

fn parse_method(name: &str) -> Result<DistributionKind> {
    match name.to_ascii_lowercase().as_str() {
        "bernstein" => Ok(DistributionKind::Bernstein),
        "row-l1" | "rowl1" => Ok(DistributionKind::RowL1),
        "l1" => Ok(DistributionKind::L1),
        "l2" => Ok(DistributionKind::L2),
        "l2-trim-0.1" => Ok(DistributionKind::L2Trim(0.1)),
        "l2-trim-0.01" => Ok(DistributionKind::L2Trim(0.01)),
        other => Err(Error::invalid(format!("unknown method {other}"))),
    }
}

fn print_help() {
    println!(
        "matsketch — near-optimal entrywise sampling for data matrices (NIPS'13)

USAGE: matsketch <command> [options]

COMMANDS:
  tables     E1/E4: matrix characteristics + sample-complexity tables
  fig1       E2: Figure-1 quality sweep (all methods x budgets x datasets)
  compress   E3: sketch codec bits/sample + disc-size ratios
  theory     E6: eps5 near-optimality checks
  ablate     E8: row-norm-noise / delta / worker-count ablations
  gen        generate a dataset to a binary triplet file
  sketch     stream-sketch a triplet file through the full pipeline

COMMON OPTIONS:
  --out DIR        report/output directory (default: reports)
  --seed N         RNG seed (default 0)
  --small          use reduced-size dataset variants
  --engine xla|rust  dense-compute engine (default: xla if artifacts exist)
  --verbose        debug logging

SKETCH OPTIONS:
  --input FILE --s N [--method bernstein|row-l1|l1|l2|l2-trim-0.1]
  [--mode offline|streaming|sharded] [--workers W] [--sketch-out FILE]
"
    );
}
