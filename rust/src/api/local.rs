//! The in-process backend: [`LocalClient`] serves queries straight from
//! a [`SketchStore`] through per-sketch [`QueryServer`] worker pools.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::obs::trace::{self, Span};
use crate::serve::{read_header, LiveReader, QueryServer, ServableSketch, SketchStore, StoreKey};
use crate::warn_log;

use super::{QueryRequest, QueryResponse, SketchClient, SketchInfo};

/// One opened sketch: its worker pool (owning the shared immutable
/// [`ServableSketch`]) plus the identity it was opened under.
struct OpenedSketch {
    key: StoreKey,
    fingerprint: u64,
    server: QueryServer,
    info: SketchInfo,
}

/// The in-process [`SketchClient`]: a [`SketchStore`] plus lazily opened
/// [`QueryServer`] worker pools, one per sketch.
///
/// Execution-plan selection happens *inside* this client (via
/// `ServableSketch::answer`): the payload header is parsed once at open,
/// row slices seek through the per-row offset index, and everything else
/// streams off the cached header. Callers never pick a call form — the
/// header-cached / indexed variants of the query executors are no longer
/// public API.
pub struct LocalClient {
    store: SketchStore,
    workers: usize,
    split_min_groups: usize,
    opened: HashMap<String, OpenedSketch>,
    /// Live chains attached under their key's file name. Checked before
    /// the store on every query, so a live sketch shadows a frozen store
    /// entry of the same identity.
    live: HashMap<String, LiveReader>,
}

impl LocalClient {
    /// Default query workers per opened sketch.
    pub const DEFAULT_WORKERS: usize = 4;

    /// A client over an already-opened store.
    pub fn new(store: SketchStore) -> LocalClient {
        LocalClient {
            store,
            workers: Self::DEFAULT_WORKERS,
            split_min_groups: QueryServer::DEFAULT_SPLIT_MIN_GROUPS,
            opened: HashMap::new(),
            live: HashMap::new(),
        }
    }

    /// A client over the store directory at `dir` (created if absent).
    pub fn open_dir(dir: impl AsRef<Path>) -> Result<LocalClient> {
        Ok(Self::new(SketchStore::open(dir.as_ref())?))
    }

    /// Set the worker-pool size used for sketches opened *after* this
    /// call (min 1).
    pub fn with_workers(mut self, workers: usize) -> LocalClient {
        self.workers = workers.max(1);
        self
    }

    /// Set the minimum occupied row groups before a matvec is
    /// row-parallelized, for sketches opened *after* this call (min 1;
    /// see [`QueryServer::DEFAULT_SPLIT_MIN_GROUPS`]). Lowering it to 1
    /// forces splitting on small sketches — the lever the trace
    /// integration suite uses to pin per-window span trees.
    pub fn with_split_min_groups(mut self, split_min_groups: usize) -> LocalClient {
        self.split_min_groups = split_min_groups.max(1);
        self
    }

    /// The underlying store directory.
    pub fn store_dir(&self) -> &Path {
        self.store.dir()
    }

    /// Attach a live generation chain under `key`: queries for that key
    /// are answered from the chain's published snapshots (latest, or the
    /// pinned generation for [`SketchClient::query_at`]) instead of the
    /// store. Live attachments survive [`SketchClient::close`] — the
    /// chain, not this client, owns the serving pool.
    pub fn attach_live(&mut self, key: &StoreKey, reader: LiveReader) {
        self.live.insert(key.file_name(), reader);
    }

    /// Detach a live chain, returning its reader if one was attached.
    pub fn detach_live(&mut self, key: &StoreKey) -> Option<LiveReader> {
        self.live.remove(&key.file_name())
    }

    /// The opened entry for `key`, loading it from the store on first
    /// use and evicting + reloading when the requested input fingerprint
    /// conflicts with the cached one (a re-sketched input must be picked
    /// up without a restart; fingerprint-less opens keep the cache).
    fn ensure_open(&mut self, key: &StoreKey) -> Result<&OpenedSketch> {
        let file = key.file_name();
        let mut stale = false;
        if let Some(o) = self.opened.get(&file) {
            if !o.key.same_identity(key) {
                return Err(Error::invalid(format!(
                    "open slot {file} holds ({}, {}, s={}, seed={}), not the requested \
                     ({}, {}, s={}, seed={}) (file-name collision?)",
                    o.key.dataset,
                    o.key.method,
                    o.key.s,
                    o.key.seed,
                    key.dataset,
                    key.method,
                    key.s,
                    key.seed,
                )));
            }
            stale =
                key.fingerprint != 0 && o.fingerprint != 0 && key.fingerprint != o.fingerprint;
        }
        let reg = crate::obs::global();
        if stale {
            if let Some(o) = self.opened.remove(&file) {
                reg.inc(crate::obs::Counter::OpenCacheEvict);
                o.server.shutdown();
            }
        }
        if self.opened.contains_key(&file) {
            reg.inc(crate::obs::Counter::OpenCacheHit);
        } else {
            reg.inc(crate::obs::Counter::OpenCacheMiss);
            let stored = self.store.get(key)?.ok_or_else(|| {
                Error::invalid(format!(
                    "no stored sketch {file} under {} (absent or stale) — run \
                     `matsketch sketch` first",
                    self.store.dir().display()
                ))
            })?;
            let fingerprint = stored.fingerprint;
            let sketch = Arc::new(ServableSketch::from_stored(stored)?);
            let (m, n) = sketch.shape();
            let info = SketchInfo {
                dataset: key.dataset.clone(),
                method: key.method.clone(),
                s: key.s,
                seed: key.seed,
                m: m as u64,
                n: n as u64,
                compact: sketch.enc.compact,
            };
            let server = QueryServer::start_with(sketch, self.workers, self.split_min_groups);
            self.opened.insert(
                file.clone(),
                OpenedSketch { key: key.clone(), fingerprint, server, info },
            );
        }
        Ok(self.opened.get(&file).expect("entry just ensured"))
    }
}

/// Begin a sampled local-backend trace: a `request` root matching the
/// shape the net server opens for wire requests, so local and remote
/// span trees compare structurally (same root name, same serve-layer
/// children from the shared worker pool).
fn traced_root(op: &'static str) -> Option<(Arc<trace::ActiveTrace>, Span)> {
    match trace::sample() {
        0 => None,
        id => {
            let active = trace::ActiveTrace::begin(id);
            let mut root = active.span(0, "request");
            root.note("op", op);
            root.note("backend", "local");
            Some((active, root))
        }
    }
}

/// Close a trace opened by [`traced_root`] and hand it to the process
/// collector (retention ring + slow-query log).
fn finish_traced(traced: Option<(Arc<trace::ActiveTrace>, Span)>) {
    if let Some((active, root)) = traced {
        root.finish();
        trace::finish(&active);
    }
}

impl SketchClient for LocalClient {
    fn open(&mut self, key: &StoreKey) -> Result<SketchInfo> {
        if let Some(reader) = self.live.get(&key.file_name()) {
            return reader.info(&key.dataset);
        }
        Ok(self.ensure_open(key)?.info.clone())
    }

    fn list(&mut self) -> Result<Vec<SketchInfo>> {
        let mut out = Vec::new();
        for path in self.store.entries()? {
            match read_header(&path) {
                Ok(h) => out.push(SketchInfo {
                    dataset: h.dataset,
                    method: h.method,
                    s: h.s,
                    seed: h.seed,
                    m: h.m as u64,
                    n: h.n as u64,
                    compact: h.compact,
                }),
                Err(e) => {
                    warn_log!("api: skipping unreadable store entry {}: {e}", path.display())
                }
            }
        }
        // live chains list after the store, in stable (file-name) order
        let mut live: Vec<(&String, &LiveReader)> = self.live.iter().collect();
        live.sort_by(|a, b| a.0.cmp(b.0));
        for (file, reader) in live {
            let dataset = file.split("__").next().unwrap_or(file.as_str());
            out.push(reader.info(dataset)?);
        }
        Ok(out)
    }

    fn query(&mut self, key: &StoreKey, request: &QueryRequest) -> Result<QueryResponse> {
        let traced = traced_root(request.op_name());
        let ctx = traced.as_ref().map(|(_, root)| root.ctx());
        if let Some(reader) = self.live.get(&key.file_name()) {
            let out = reader.answer_at_traced(None, request, ctx).map(|(resp, _)| resp);
            finish_traced(traced);
            return out;
        }
        // span the open-cache path too: a cold open (store read + index
        // build) dominating a trace should be visible, not folded into
        // queue wait
        let open_t0 = ctx.as_ref().map(|_| Instant::now());
        let opened = self.ensure_open(key);
        if let (Some(c), Some(t0)) = (&ctx, open_t0) {
            c.record("open_cache", t0, Instant::now());
        }
        let out = match opened {
            Ok(o) => o.server.submit_traced(request.clone(), ctx).wait(),
            Err(e) => Err(e),
        };
        finish_traced(traced);
        out
    }

    fn query_at(
        &mut self,
        key: &StoreKey,
        request: &QueryRequest,
        pin: Option<u64>,
    ) -> Result<(QueryResponse, u64)> {
        if let Some(reader) = self.live.get(&key.file_name()) {
            return reader.answer_at(pin, request);
        }
        if let Some(g) = pin {
            if g != 0 {
                return Err(Error::Generation(format!(
                    "generation {g} not yet published (latest is 0)"
                )));
            }
        }
        Ok((self.query(key, request)?, 0))
    }

    fn generation(&mut self, key: &StoreKey) -> Result<u64> {
        if let Some(reader) = self.live.get(&key.file_name()) {
            return Ok(reader.generation());
        }
        self.ensure_open(key).map(|_| 0)
    }

    fn query_batch(
        &mut self,
        key: &StoreKey,
        requests: Vec<QueryRequest>,
    ) -> Result<Vec<Result<QueryResponse>>> {
        if let Some(reader) = self.live.get(&key.file_name()) {
            return reader.answer_batch_at(None, requests).map(|(r, _)| r);
        }
        let pending = self.ensure_open(key)?.server.submit_batch(requests);
        Ok(pending.into_iter().map(|p| p.wait()).collect())
    }

    fn close(&mut self) -> Result<()> {
        for (_, o) in self.opened.drain() {
            o.server.shutdown();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::DistributionKind;
    use crate::sketch::{encode_sketch, sketch_offline, SketchPlan};
    use crate::sparse::Coo;
    use crate::util::rng::Rng;

    fn populated_store(dir: &Path) -> (SketchStore, StoreKey) {
        let store = SketchStore::open(dir).unwrap();
        let mut rng = Rng::new(5);
        let mut coo = Coo::new(8, 40);
        for i in 0..8u32 {
            for _ in 0..10 {
                coo.push(i, rng.usize_below(40) as u32, rng.normal() as f32 + 1.0);
            }
        }
        let a = coo.to_csr();
        let sk =
            sketch_offline(&a, &SketchPlan::new(DistributionKind::Bernstein, 300)).unwrap();
        let key = StoreKey::new("toy", &sk.method, 300, 0);
        store.put(&key, &encode_sketch(&sk).unwrap()).unwrap();
        (store, key)
    }

    #[test]
    fn open_query_list_close_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("matsketch_api_local_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (store, key) = populated_store(&dir);
        let mut client = LocalClient::new(store).with_workers(2);

        let info = client.open(&key).unwrap();
        assert_eq!((info.m, info.n), (8, 40));
        assert_eq!(client.list().unwrap().len(), 1);

        // single vs batched matvec: bit-identical
        let x: Vec<f64> = (0..40).map(|i| i as f64 * 0.25 - 3.0).collect();
        let single = client.query(&key, &QueryRequest::Matvec(x.clone())).unwrap();
        let batched = client
            .query(&key, &QueryRequest::MatvecBatch(vec![x.clone(), x]))
            .unwrap();
        match (single, batched) {
            (QueryResponse::Vector(y), QueryResponse::Vectors(ys)) => {
                assert_eq!(ys.len(), 2);
                assert_eq!(ys[0], y);
                assert_eq!(ys[1], ys[0]);
            }
            other => panic!("unexpected outcome {other:?}"),
        }

        // batch errors come back per-entry, not as a batch abort
        let batch = vec![QueryRequest::TopK(3), QueryRequest::Matvec(vec![0.0; 7])];
        let answers = client.query_batch(&key, batch).unwrap();
        assert_eq!(answers.len(), 2);
        assert!(answers[0].is_ok());
        assert!(answers[1].is_err());

        client.close().unwrap();
        // reusable after close: pools are re-acquired lazily
        assert!(client.query(&key, &QueryRequest::TopK(1)).is_ok());
        client.close().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn live_attachment_answers_with_generations() {
        use crate::serve::{LiveConfig, LiveSketch};
        use crate::sparse::Entry;
        let dir = std::env::temp_dir()
            .join(format!("matsketch_api_local_live_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut client = LocalClient::open_dir(&dir).unwrap();

        let plan = SketchPlan::new(DistributionKind::Bernstein, 200).with_seed(3);
        let cfg = LiveConfig { epoch_entries: 0, retain: 4, workers: 1 };
        let mut live = LiveSketch::start(8, 40, &plan, &cfg).unwrap();
        let key = StoreKey::new("liveapi", "Bernstein", 200, 3);
        client.attach_live(&key, live.reader());

        assert_eq!(client.generation(&key).unwrap(), 0);
        let mut rng = Rng::new(4);
        let es: Vec<Entry> = (0..150)
            .map(|_| {
                Entry::new(
                    rng.usize_below(8) as u32,
                    rng.usize_below(40) as u32,
                    rng.normal() as f32 + 1.0,
                )
            })
            .collect();
        live.push(&es).unwrap();
        live.flush().unwrap();

        let x = vec![0.5; 40];
        let (resp, g) = client.query_at(&key, &QueryRequest::Matvec(x), None).unwrap();
        assert_eq!(g, 1);
        assert!(matches!(resp, QueryResponse::Vector(_)));
        assert_eq!(client.generation(&key).unwrap(), 1);
        // a pin ahead of the chain is a typed generation error
        let err = client.query_at(&key, &QueryRequest::TopK(1), Some(9)).unwrap_err();
        assert!(matches!(err, Error::Generation(_)), "{err}");
        // listing includes the live chain
        assert!(client.list().unwrap().iter().any(|i| i.dataset == "liveapi"));
        client.detach_live(&key).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn absent_sketch_is_a_typed_error() {
        let dir = std::env::temp_dir()
            .join(format!("matsketch_api_local_absent_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut client = LocalClient::open_dir(&dir).unwrap();
        let missing = StoreKey::new("nope", "Bernstein", 1, 0);
        let err = client.open(&missing).unwrap_err().to_string();
        assert!(err.contains("no stored sketch"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
