//! The remote backend: [`RemoteClient`] speaks the wire protocol through
//! the pipelining, reconnecting TCP client in [`crate::net`].

use std::net::SocketAddr;
use std::time::Duration;

use crate::error::Result;
use crate::net::{RemoteSketchClient, RetryPolicy};
use crate::serve::StoreKey;

use super::{QueryRequest, QueryResponse, SketchClient, SketchInfo};

/// The remote [`SketchClient`]: one TCP connection to a
/// `matsketch serve` process, with batch pipelining (a `query_batch`
/// costs ~one round trip) and policy-driven retries — bounded attempts,
/// seeded-jitter backoff, retry budget, optional per-request deadline —
/// that redial and re-open handles (at their pinned generations) on
/// broken connections.
///
/// Answers are byte-identical to [`super::LocalClient`] over the same
/// store: the server runs the same execution the local backend does, and
/// f64s travel as IEEE-754 bit patterns.
pub struct RemoteClient {
    inner: RemoteSketchClient,
}

impl RemoteClient {
    /// Resolve `addr` (e.g. `"127.0.0.1:7300"`) and connect with the
    /// default timeout.
    pub fn connect(addr: &str) -> Result<RemoteClient> {
        Ok(RemoteClient { inner: RemoteSketchClient::connect(addr)? })
    }

    /// [`RemoteClient::connect`] with an explicit timeout (`None` =
    /// block forever).
    pub fn connect_with_timeout(addr: &str, timeout: Option<Duration>) -> Result<RemoteClient> {
        Ok(RemoteClient { inner: RemoteSketchClient::connect_with_timeout(addr, timeout)? })
    }

    /// The server address this client dials.
    pub fn addr(&self) -> SocketAddr {
        self.inner.addr()
    }

    /// Replace the retry policy governing idempotent operations
    /// (reseeds the jitter stream and refills the retry budget).
    pub fn set_retry_policy(&mut self, policy: RetryPolicy) {
        self.inner.set_retry_policy(policy);
    }

    /// Set (or with `None` clear) the per-request deadline: the total
    /// wall-clock budget one operation may spend across attempts and
    /// backoff sleeps before failing with
    /// [`Error::Deadline`](crate::error::Error::Deadline).
    pub fn set_deadline(&mut self, deadline: Option<Duration>) {
        self.inner.set_deadline(deadline);
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        self.inner.ping()
    }

    /// Ask the server to shut down gracefully (the wire sentinel).
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.inner.shutdown_server()
    }

    /// Set (or with `None` clear) a sticky generation pin for `key`:
    /// every later query against the key answers at that generation,
    /// surviving the client's one-shot reconnect.
    pub fn set_pin(&mut self, key: &StoreKey, pin: Option<u64>) {
        self.inner.set_pin(key, pin);
    }

    /// Block server-side up to `timeout_ms` until the sketch under `key`
    /// reaches generation `min_gen`, returning the generation current
    /// when the server answers.
    pub fn poll_generation(
        &mut self,
        key: &StoreKey,
        min_gen: u64,
        timeout_ms: u32,
    ) -> Result<u64> {
        self.inner.poll_generation(key, min_gen, timeout_ms)
    }
}

impl SketchClient for RemoteClient {
    fn open(&mut self, key: &StoreKey) -> Result<SketchInfo> {
        self.inner.open(key)
    }

    fn list(&mut self) -> Result<Vec<SketchInfo>> {
        self.inner.list_sketches()
    }

    fn query(&mut self, key: &StoreKey, request: &QueryRequest) -> Result<QueryResponse> {
        self.inner.query(key, request)
    }

    fn query_at(
        &mut self,
        key: &StoreKey,
        request: &QueryRequest,
        pin: Option<u64>,
    ) -> Result<(QueryResponse, u64)> {
        self.inner.query_at(key, request, pin)
    }

    fn generation(&mut self, key: &StoreKey) -> Result<u64> {
        self.inner.poll_generation(key, 0, 0)
    }

    fn stats(&mut self) -> Result<crate::obs::MetricsSnapshot> {
        self.inner.stats()
    }

    fn traces(&mut self, id: u64, slowest: u32) -> Result<Vec<crate::obs::TraceRecord>> {
        self.inner.trace_dump(id, slowest)
    }

    fn query_batch(
        &mut self,
        key: &StoreKey,
        requests: Vec<QueryRequest>,
    ) -> Result<Vec<Result<QueryResponse>>> {
        self.inner.pipeline(key, requests)
    }

    fn close(&mut self) -> Result<()> {
        self.inner.disconnect();
        Ok(())
    }
}
