//! The remote backend: [`RemoteClient`] speaks the wire protocol through
//! the pipelining, reconnecting TCP client in [`crate::net`].

use std::net::SocketAddr;
use std::time::Duration;

use crate::error::Result;
use crate::net::RemoteSketchClient;
use crate::serve::StoreKey;

use super::{QueryRequest, QueryResponse, SketchClient, SketchInfo};

/// The remote [`SketchClient`]: one TCP connection to a
/// `matsketch serve` process, with batch pipelining (a `query_batch`
/// costs ~one round trip) and a one-shot reconnect + handle re-open on
/// broken connections.
///
/// Answers are byte-identical to [`super::LocalClient`] over the same
/// store: the server runs the same execution the local backend does, and
/// f64s travel as IEEE-754 bit patterns.
pub struct RemoteClient {
    inner: RemoteSketchClient,
}

impl RemoteClient {
    /// Resolve `addr` (e.g. `"127.0.0.1:7300"`) and connect with the
    /// default timeout.
    pub fn connect(addr: &str) -> Result<RemoteClient> {
        Ok(RemoteClient { inner: RemoteSketchClient::connect(addr)? })
    }

    /// [`RemoteClient::connect`] with an explicit timeout (`None` =
    /// block forever).
    pub fn connect_with_timeout(addr: &str, timeout: Option<Duration>) -> Result<RemoteClient> {
        Ok(RemoteClient { inner: RemoteSketchClient::connect_with_timeout(addr, timeout)? })
    }

    /// The server address this client dials.
    pub fn addr(&self) -> SocketAddr {
        self.inner.addr()
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        self.inner.ping()
    }

    /// Ask the server to shut down gracefully (the wire sentinel).
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.inner.shutdown_server()
    }
}

impl SketchClient for RemoteClient {
    fn open(&mut self, key: &StoreKey) -> Result<SketchInfo> {
        self.inner.open(key)
    }

    fn list(&mut self) -> Result<Vec<SketchInfo>> {
        self.inner.list_sketches()
    }

    fn query(&mut self, key: &StoreKey, request: &QueryRequest) -> Result<QueryResponse> {
        self.inner.query(key, request)
    }

    fn query_batch(
        &mut self,
        key: &StoreKey,
        requests: Vec<QueryRequest>,
    ) -> Result<Vec<Result<QueryResponse>>> {
        self.inner.pipeline(key, requests)
    }

    fn close(&mut self) -> Result<()> {
        self.inner.disconnect();
        Ok(())
    }
}
