//! The one query surface: [`SketchClient`] over typed requests and
//! responses, implemented by both the in-process and the remote backend.
//!
//! The paper's payoff is that the sketch `B` stands in for `A` in
//! downstream linear algebra, and downstream consumers want a single
//! "multiply / slice / top-k against the sketch" interface — not one per
//! transport. Before this module the repo had three divergent query
//! surfaces: the free functions in `serve::query` (with caller-picked
//! header-cached / indexed / decoded call forms), the method set on
//! `net::client::RemoteSketchClient`, and ad-hoc wiring in the CLI and
//! eval harnesses. This module collapses them into one vocabulary:
//!
//! * [`QueryRequest`] / [`QueryResponse`] — the typed operations and
//!   answers, shared verbatim by the in-process query engine
//!   ([`crate::serve`]), the wire protocol ([`crate::net::wire`]), and
//!   every caller. Includes the batched matvec
//!   ([`QueryRequest::MatvecBatch`]): `k` right-hand sides multiplied in
//!   **one pass** over the compressed payload.
//! * [`SketchClient`] — `open` / `list` / `query` / `query_batch` /
//!   `close`, the whole client API.
//! * [`LocalClient`] — in-process backend: wraps a
//!   [`crate::serve::SketchStore`] and serves each opened sketch from a
//!   [`crate::serve::QueryServer`] worker pool. Execution-plan selection
//!   (cached payload header, per-row offset index, streaming scan) lives
//!   *inside* — callers never pick a call form.
//! * [`RemoteClient`] — the same API over TCP, wrapping the pipelining,
//!   reconnecting wire client.
//!
//! The two backends answer **byte-identically**: every response is
//! produced by the same `ServableSketch::answer` execution, and the wire
//! transports f64s as IEEE-754 bit patterns. The backend-equivalence
//! suite (`rust/tests/integration_api.rs`) drives both through identical
//! request scripts and asserts bit-equality for every request kind.

use crate::error::Result;
use crate::serve::StoreKey;
use crate::sketch::SketchEntry;

mod local;
mod remote;

pub use local::LocalClient;
pub use remote::RemoteClient;

/// One query against an opened sketch — the single request vocabulary
/// shared by the in-process engine, the wire protocol, and every caller.
#[derive(Clone, Debug, PartialEq)]
pub enum QueryRequest {
    /// `y = B·x` (`x` length n).
    Matvec(Vec<f64>),
    /// `y = Bᵀ·x` (`x` length m).
    MatvecT(Vec<f64>),
    /// `Y = B·X` for `k` right-hand sides (each length n), executed in
    /// one pass over the compressed payload. Answer order matches `k`
    /// independent [`QueryRequest::Matvec`] calls bit-for-bit.
    MatvecBatch(Vec<Vec<f64>>),
    /// All entries of one row.
    Row(u32),
    /// All entries of one column.
    Col(u32),
    /// The k heaviest entries by `|value|`.
    TopK(usize),
}

impl QueryRequest {
    /// Stable lower-case operation label (trace span notes, report
    /// rows, per-opcode telemetry tables).
    pub fn op_name(&self) -> &'static str {
        match self {
            QueryRequest::Matvec(_) => "matvec",
            QueryRequest::MatvecT(_) => "matvec_t",
            QueryRequest::MatvecBatch(_) => "matvec_batch",
            QueryRequest::Row(_) => "row",
            QueryRequest::Col(_) => "col",
            QueryRequest::TopK(_) => "topk",
        }
    }
}

/// A query answer.
#[derive(Clone, Debug, PartialEq)]
pub enum QueryResponse {
    /// Dense result vector (matvec family).
    Vector(Vec<f64>),
    /// One dense result vector per batched right-hand side.
    Vectors(Vec<Vec<f64>>),
    /// Entry list (slices, top-k).
    Entries(Vec<SketchEntry>),
}

/// Identity + shape of one served sketch, as listed / opened through a
/// [`SketchClient`] (and carried verbatim over the wire).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SketchInfo {
    /// Dataset label.
    pub dataset: String,
    /// Distribution name.
    pub method: String,
    /// Sample budget `s`.
    pub s: u64,
    /// Sketching seed.
    pub seed: u64,
    /// Rows.
    pub m: u64,
    /// Columns.
    pub n: u64,
    /// Whether the payload uses the compact row-scale form.
    pub compact: bool,
}

/// A boxed client, the form harnesses thread through worker threads.
pub type BoxedSketchClient = Box<dyn SketchClient + Send>;

/// The unified query surface over a sketch backend.
///
/// Implemented by [`LocalClient`] (in-process: sketch store + worker
/// pools) and [`RemoteClient`] (TCP wire protocol). Both answer
/// byte-identically, so harnesses written against
/// `&mut dyn SketchClient` run unchanged — and comparably — on either.
pub trait SketchClient {
    /// Open the sketch stored under `key` for querying; idempotent.
    /// Returns its identity + shape.
    fn open(&mut self, key: &StoreKey) -> Result<SketchInfo>;

    /// Enumerate the sketches this backend can serve.
    fn list(&mut self) -> Result<Vec<SketchInfo>>;

    /// Execute one request against the sketch under `key` (opening it
    /// first if needed).
    fn query(&mut self, key: &StoreKey, request: &QueryRequest) -> Result<QueryResponse>;

    /// Execute one request with an optional **generation pin**, returning
    /// the answer plus the generation it was answered at.
    ///
    /// Live sketches (see [`crate::serve::live`]) answer `pin: None` on
    /// their latest published snapshot and `pin: Some(g)` on retained
    /// generation `g` exactly — a pin ahead of the chain or retired out
    /// of its window is a typed [`crate::error::Error::Generation`].
    /// Frozen store-backed sketches are generation 0 forever; the default
    /// implementation below encodes that, so backends without live chains
    /// keep working unchanged.
    fn query_at(
        &mut self,
        key: &StoreKey,
        request: &QueryRequest,
        pin: Option<u64>,
    ) -> Result<(QueryResponse, u64)> {
        if let Some(g) = pin {
            if g != 0 {
                return Err(crate::error::Error::Generation(format!(
                    "generation {g} not yet published (latest is 0)"
                )));
            }
        }
        Ok((self.query(key, request)?, 0))
    }

    /// Latest published generation of the sketch under `key` (0 for
    /// frozen store-backed sketches, which never advance).
    fn generation(&mut self, key: &StoreKey) -> Result<u64> {
        let _ = key;
        Ok(0)
    }

    /// A snapshot of the serving-side telemetry registry
    /// ([`crate::obs`]): per-opcode request counts, latency histograms,
    /// cache and fault counters. The default implementation reads the
    /// process-global registry — correct for in-process backends, whose
    /// serving side *is* this process; [`RemoteClient`] overrides it to
    /// scrape the server over the wire (`Stats` opcode, protocol v4).
    fn stats(&mut self) -> Result<crate::obs::MetricsSnapshot> {
        Ok(crate::obs::global().snapshot())
    }

    /// Completed request traces (see [`crate::obs::trace`]): the tree(s)
    /// recorded under exact trace `id`, or — with `id == 0` — the
    /// `slowest` N by root duration, slow-query log first. The default
    /// implementation reads the process-global collector — correct for
    /// in-process backends; [`RemoteClient`] overrides it to fetch the
    /// server's retention rings over the wire (`TraceDump`, protocol
    /// v5).
    fn traces(&mut self, id: u64, slowest: u32) -> Result<Vec<crate::obs::TraceRecord>> {
        Ok(if id != 0 {
            crate::obs::trace::dump_by_id(id)
        } else {
            crate::obs::trace::dump_slowest(slowest as usize)
        })
    }

    /// Execute a batch through the backend's batched path (worker-pool
    /// fan-out locally, request pipelining remotely). Requests are taken
    /// by value so submission is zero-copy — benchmarks build the batch
    /// outside the timed window and hand it over whole. One result per
    /// request, in order; a per-request failure comes back as its `Err`
    /// entry without aborting the rest.
    fn query_batch(
        &mut self,
        key: &StoreKey,
        requests: Vec<QueryRequest>,
    ) -> Result<Vec<Result<QueryResponse>>>;

    /// Release backend resources (worker pools, connections). The client
    /// may be reused afterwards; backends re-acquire lazily.
    fn close(&mut self) -> Result<()>;
}
