//! Compressed sparse row matrices — the compute format.
//!
//! SpMM against tall-skinny dense blocks (`A·X`, `Aᵀ·X`) is the Rust-side
//! hot path of the evaluation pipeline (subspace iteration); see
//! EXPERIMENTS.md §Perf for the optimization log.

use super::coo::Coo;
use super::dense::Dense;

/// CSR sparse matrix (f32 values, u32 column indices).
#[derive(Clone, Debug)]
pub struct Csr {
    /// Rows.
    pub m: usize,
    /// Columns.
    pub n: usize,
    /// Row pointers, length `m + 1`.
    pub indptr: Vec<usize>,
    /// Column indices, length nnz.
    pub indices: Vec<u32>,
    /// Values, length nnz.
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from a row-major-sorted, duplicate-free COO.
    pub fn from_sorted_coo(coo: &Coo) -> Csr {
        let mut indptr = vec![0usize; coo.m + 1];
        for e in &coo.entries {
            indptr[e.row as usize + 1] += 1;
        }
        for i in 0..coo.m {
            indptr[i + 1] += indptr[i];
        }
        let mut indices = Vec::with_capacity(coo.nnz());
        let mut values = Vec::with_capacity(coo.nnz());
        for e in &coo.entries {
            indices.push(e.col);
            values.push(e.val);
        }
        Csr { m: coo.m, n: coo.n, indptr, indices, values }
    }

    /// Number of non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Iterate one row's `(col, val)` pairs.
    #[inline]
    pub fn row(&self, i: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        self.indices[lo..hi].iter().copied().zip(self.values[lo..hi].iter().copied())
    }

    /// Back to COO.
    pub fn to_coo(&self) -> Coo {
        let mut out = Coo::new(self.m, self.n);
        for i in 0..self.m {
            for (j, v) in self.row(i) {
                out.push(i as u32, j, v);
            }
        }
        out
    }

    /// Transpose via counting sort — O(nnz + n).
    pub fn transpose(&self) -> Csr {
        let nnz = self.nnz();
        let mut indptr = vec![0usize; self.n + 1];
        for &j in &self.indices {
            indptr[j as usize + 1] += 1;
        }
        for j in 0..self.n {
            indptr[j + 1] += indptr[j];
        }
        let mut indices = vec![0u32; nnz];
        let mut values = vec![0f32; nnz];
        let mut next = indptr.clone();
        for i in 0..self.m {
            for (j, v) in self.row(i) {
                let pos = next[j as usize];
                indices[pos] = i as u32;
                values[pos] = v;
                next[j as usize] += 1;
            }
        }
        Csr { m: self.n, n: self.m, indptr, indices, values }
    }

    /// Dense mat-vec `y = A·x` (`x` length n).
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.m);
        for i in 0..self.m {
            let mut acc = 0.0f32;
            for (j, v) in self.row(i) {
                acc += v * x[j as usize];
            }
            y[i] = acc;
        }
    }

    /// SpMM `Y = A·X` where `X` is a dense `n×k` block; returns `m×k`.
    ///
    /// Row-major X makes the inner loop a contiguous k-wide AXPY — the
    /// compiler auto-vectorizes it (verified in the §Perf pass).
    pub fn spmm(&self, x: &Dense) -> Dense {
        assert_eq!(x.rows, self.n, "spmm: A is {}x{}, X is {}x{}", self.m, self.n, x.rows, x.cols);
        let k = x.cols;
        let mut out = Dense::zeros(self.m, k);
        for i in 0..self.m {
            let dst = &mut out.data[i * k..(i + 1) * k];
            let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
            for idx in lo..hi {
                let j = self.indices[idx] as usize;
                let v = self.values[idx];
                let src = &x.data[j * k..j * k + k];
                for (d, s) in dst.iter_mut().zip(src.iter()) {
                    *d += v * s;
                }
            }
        }
        out
    }

    /// SpMM with the transpose, `Y = Aᵀ·X` where `X` is `m×k`; returns `n×k`.
    ///
    /// Scatter formulation over rows of A avoids materializing Aᵀ.
    pub fn spmm_t(&self, x: &Dense) -> Dense {
        assert_eq!(x.rows, self.m, "spmm_t: A is {}x{}, X is {}x{}", self.m, self.n, x.rows, x.cols);
        let k = x.cols;
        let mut out = Dense::zeros(self.n, k);
        for i in 0..self.m {
            let src = &x.data[i * k..(i + 1) * k];
            let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
            for idx in lo..hi {
                let j = self.indices[idx] as usize;
                let v = self.values[idx];
                let dst = &mut out.data[j * k..j * k + k];
                for (d, s) in dst.iter_mut().zip(src.iter()) {
                    *d += v * s;
                }
            }
        }
        out
    }

    /// Densify a block of rows `[r0, r0+rows) × [c0, c0+cols)` into a
    /// row-major buffer (used to stream dense blocks to the XLA engine).
    pub fn dense_block(&self, r0: usize, rows: usize, c0: usize, cols: usize) -> Dense {
        let mut out = Dense::zeros(rows, cols);
        let r_hi = (r0 + rows).min(self.m);
        for i in r0..r_hi {
            let dst = &mut out.data[(i - r0) * cols..(i - r0 + 1) * cols];
            for (j, v) in self.row(i) {
                let j = j as usize;
                if j >= c0 && j < c0 + cols {
                    dst[j - c0] = v;
                }
            }
        }
        out
    }

    /// Entrywise L1 norm.
    pub fn norm_l1(&self) -> f64 {
        self.values.iter().map(|v| v.abs() as f64).sum()
    }

    /// Frobenius norm.
    pub fn norm_fro(&self) -> f64 {
        self.values.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt()
    }

    /// Per-row L1 norms.
    pub fn row_l1_norms(&self) -> Vec<f64> {
        (0..self.m)
            .map(|i| self.row(i).map(|(_, v)| v.abs() as f64).sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Entry;

    fn sample() -> Csr {
        // [[1, 0, 2], [0, 0, 0], [0, -3, 0.5]]
        Coo::from_entries(
            3,
            3,
            vec![
                Entry::new(0, 0, 1.0),
                Entry::new(0, 2, 2.0),
                Entry::new(2, 1, -3.0),
                Entry::new(2, 2, 0.5),
            ],
        )
        .unwrap()
        .to_csr()
    }

    #[test]
    fn roundtrip_coo() {
        let a = sample();
        let back = a.to_coo().to_csr();
        assert_eq!(a.indptr, back.indptr);
        assert_eq!(a.indices, back.indices);
        assert_eq!(a.values, back.values);
    }

    #[test]
    fn spmv_matches_dense() {
        let a = sample();
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [0.0f32; 3];
        a.spmv(&x, &mut y);
        assert_eq!(y, [7.0, 0.0, -4.5]);
    }

    #[test]
    fn spmm_matches_spmv_per_column() {
        let a = sample();
        let x = Dense::from_rows(&[&[1.0, 4.0], &[2.0, 5.0], &[3.0, 6.0]]);
        let y = a.spmm(&x);
        // column 0 = spmv([1,2,3]); column 1 = spmv([4,5,6])
        assert_eq!(y.get(0, 0), 7.0);
        assert_eq!(y.get(0, 1), 16.0);
        assert_eq!(y.get(2, 0), -4.5);
        assert_eq!(y.get(2, 1), -12.0);
    }

    #[test]
    fn spmm_t_matches_transpose_spmm() {
        let a = sample();
        let x = Dense::from_rows(&[&[1.0, 0.5], &[2.0, -1.0], &[0.0, 2.0]]);
        let y1 = a.spmm_t(&x);
        let y2 = a.transpose().spmm(&x);
        assert_eq!(y1.data, y2.data);
    }

    #[test]
    fn transpose_involution() {
        let a = sample();
        let t2 = a.transpose().transpose();
        assert_eq!(a.indptr, t2.indptr);
        assert_eq!(a.indices, t2.indices);
        assert_eq!(a.values, t2.values);
    }

    #[test]
    fn dense_block_extracts_window() {
        let a = sample();
        let b = a.dense_block(0, 2, 1, 2);
        // rows 0..2, cols 1..3 of [[1,0,2],[0,0,0]] -> [[0,2],[0,0]]
        assert_eq!(b.data, vec![0.0, 2.0, 0.0, 0.0]);
        // out-of-range block rows are zero-padded
        let c = a.dense_block(2, 4, 0, 3);
        assert_eq!(c.get(0, 1), -3.0);
        assert_eq!(c.get(3, 2), 0.0);
    }

    #[test]
    fn norms_match_coo() {
        let a = sample();
        let c = a.to_coo();
        assert!((a.norm_l1() - c.norm_l1()).abs() < 1e-12);
        assert!((a.norm_fro() - c.norm_fro()).abs() < 1e-12);
        assert_eq!(a.row_l1_norms(), vec![3.0, 0.0, 3.5]);
    }
}
