//! Sparse matrix I/O: MatrixMarket coordinate text and a compact binary
//! triplet-stream format (the pipeline's durable-storage interchange).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::coo::{Coo, Entry};
use crate::error::{Error, Result};

/// Write MatrixMarket coordinate format (`%%MatrixMarket matrix coordinate
/// real general`, 1-based indices).
pub fn write_matrix_market(coo: &Coo, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "{} {} {}", coo.m, coo.n, coo.nnz())?;
    for e in &coo.entries {
        writeln!(w, "{} {} {}", e.row + 1, e.col + 1, e.val)?;
    }
    w.flush()?;
    Ok(())
}

/// Read MatrixMarket coordinate format.
pub fn read_matrix_market(path: &Path) -> Result<Coo> {
    let r = BufReader::new(File::open(path)?);
    let mut lines = r.lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::Parse("empty file".into()))??;
    if !header.starts_with("%%MatrixMarket matrix coordinate real") {
        return Err(Error::Parse(format!("unsupported header: {header}")));
    }
    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line?;
        if !line.starts_with('%') && !line.trim().is_empty() {
            size_line = Some(line);
            break;
        }
    }
    let size_line = size_line.ok_or_else(|| Error::Parse("missing size line".into()))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse().map_err(|_| Error::Parse(format!("bad size {t}"))))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        return Err(Error::Parse("size line needs m n nnz".into()));
    }
    let (m, n, nnz) = (dims[0], dims[1], dims[2]);
    let mut entries = Vec::with_capacity(nnz);
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (i, j, v) = (
            it.next().ok_or_else(|| Error::Parse("short row".into()))?,
            it.next().ok_or_else(|| Error::Parse("short row".into()))?,
            it.next().ok_or_else(|| Error::Parse("short row".into()))?,
        );
        let i: usize = i.parse().map_err(|_| Error::Parse(format!("bad row {i}")))?;
        let j: usize = j.parse().map_err(|_| Error::Parse(format!("bad col {j}")))?;
        let v: f32 = v.parse().map_err(|_| Error::Parse(format!("bad val {v}")))?;
        if i == 0 || j == 0 {
            return Err(Error::Parse("MatrixMarket is 1-based".into()));
        }
        entries.push(Entry::new((i - 1) as u32, (j - 1) as u32, v));
    }
    if entries.len() != nnz {
        return Err(Error::Parse(format!("expected {nnz} entries, got {}", entries.len())));
    }
    Coo::from_entries(m, n, entries)
}

const BIN_MAGIC: &[u8; 8] = b"MSKTRP01";

/// Write the binary triplet-stream format: magic, m, n, nnz (LE u64), then
/// packed `(u32 row, u32 col, f32 val)` records.
pub fn write_binary(coo: &Coo, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(coo.m as u64).to_le_bytes())?;
    w.write_all(&(coo.n as u64).to_le_bytes())?;
    w.write_all(&(coo.nnz() as u64).to_le_bytes())?;
    for e in &coo.entries {
        w.write_all(&e.row.to_le_bytes())?;
        w.write_all(&e.col.to_le_bytes())?;
        w.write_all(&e.val.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read the binary triplet-stream format.
pub fn read_binary(path: &Path) -> Result<Coo> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        return Err(Error::Parse("bad magic for binary triplet file".into()));
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let m = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let n = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let nnz = u64::from_le_bytes(u64buf) as usize;
    let mut entries = Vec::with_capacity(nnz);
    let mut rec = [0u8; 12];
    for _ in 0..nnz {
        r.read_exact(&mut rec)?;
        entries.push(Entry::new(
            u32::from_le_bytes(rec[0..4].try_into().unwrap()),
            u32::from_le_bytes(rec[4..8].try_into().unwrap()),
            f32::from_le_bytes(rec[8..12].try_into().unwrap()),
        ));
    }
    Coo::from_entries(m, n, entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Coo {
        Coo::from_entries(
            3,
            5,
            vec![Entry::new(0, 4, 1.25), Entry::new(2, 0, -3.5), Entry::new(1, 1, 0.125)],
        )
        .unwrap()
    }

    #[test]
    fn matrix_market_roundtrip() {
        let dir = std::env::temp_dir().join("matsketch_io_test_mm");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.mtx");
        let a = sample();
        write_matrix_market(&a, &path).unwrap();
        let b = read_matrix_market(&path).unwrap();
        assert_eq!(a.m, b.m);
        assert_eq!(a.n, b.n);
        assert_eq!(a.entries, b.entries);
    }

    #[test]
    fn binary_roundtrip() {
        let dir = std::env::temp_dir().join("matsketch_io_test_bin");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.bin");
        let a = sample();
        write_binary(&a, &path).unwrap();
        let b = read_binary(&path).unwrap();
        assert_eq!(a.entries, b.entries);
        assert_eq!((a.m, a.n), (b.m, b.n));
    }

    #[test]
    fn rejects_bad_files() {
        let dir = std::env::temp_dir().join("matsketch_io_test_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.mtx");
        std::fs::write(&path, "not a matrix").unwrap();
        assert!(read_matrix_market(&path).is_err());
        assert!(read_binary(&path).is_err());
    }
}
