//! Row-major dense matrices — the interface format between the sparse
//! substrate and the XLA runtime (PJRT literals are created directly from
//! the row-major buffer).

/// Row-major dense f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    /// Rows.
    pub rows: usize,
    /// Columns.
    pub cols: usize,
    /// Row-major data, length `rows * cols`.
    pub data: Vec<f32>,
}

impl Dense {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Dense {
        Dense { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// From a row-slice literal (tests/fixtures).
    pub fn from_rows(rows: &[&[f32]]) -> Dense {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Dense { rows: r, cols: c, data }
    }

    /// From parts.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Dense {
        assert_eq!(data.len(), rows * cols);
        Dense { rows, cols, data }
    }

    /// Gaussian random matrix (for subspace-iteration starts).
    pub fn randn(rows: usize, cols: usize, rng: &mut crate::util::rng::Rng) -> Dense {
        let mut d = Dense::zeros(rows, cols);
        for x in &mut d.data {
            *x = rng.normal() as f32;
        }
        d
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Element setter.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Frobenius norm.
    pub fn norm_fro(&self) -> f64 {
        self.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt()
    }

    /// Squared Frobenius norm in f64.
    pub fn norm_fro_sq(&self) -> f64 {
        self.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()
    }

    /// Copy a row window `[r0, r0+rows)` zero-padding past the end —
    /// used to feed fixed-shape XLA blocks.
    pub fn row_window_padded(&self, r0: usize, rows: usize) -> Dense {
        let mut out = Dense::zeros(rows, self.cols);
        let hi = (r0 + rows).min(self.rows);
        if hi > r0 {
            out.data[..(hi - r0) * self.cols]
                .copy_from_slice(&self.data[r0 * self.cols..hi * self.cols]);
        }
        out
    }

    /// Pad (or truncate) the column dimension; extra columns are zero.
    pub fn with_cols(&self, cols: usize) -> Dense {
        let mut out = Dense::zeros(self.rows, cols);
        let c = self.cols.min(cols);
        for i in 0..self.rows {
            out.data[i * cols..i * cols + c]
                .copy_from_slice(&self.data[i * self.cols..i * self.cols + c]);
        }
        out
    }

    /// Transpose (out-of-place).
    pub fn transpose(&self) -> Dense {
        let mut out = Dense::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_accessors() {
        let mut d = Dense::zeros(2, 3);
        d.set(1, 2, 5.0);
        assert_eq!(d.get(1, 2), 5.0);
        assert_eq!(d.row(1), &[0.0, 0.0, 5.0]);
        let e = Dense::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(e.get(1, 0), 3.0);
    }

    #[test]
    fn window_padding() {
        let d = Dense::from_rows(&[&[1.0], &[2.0], &[3.0]]);
        let w = d.row_window_padded(2, 4);
        assert_eq!(w.data, vec![3.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn with_cols_pads_and_truncates() {
        let d = Dense::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(d.with_cols(3).row(0), &[1.0, 2.0, 0.0]);
        assert_eq!(d.with_cols(1).row(1), &[3.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let d = Dense::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(d.transpose().transpose(), d);
        assert_eq!(d.transpose().get(2, 1), 6.0);
    }

    #[test]
    fn norms() {
        let d = Dense::from_rows(&[&[3.0, 4.0]]);
        assert!((d.norm_fro() - 5.0).abs() < 1e-12);
        assert!((d.norm_fro_sq() - 25.0).abs() < 1e-12);
    }
}
