//! Triplet (COO) sparse matrices — the streaming interchange form.

use crate::error::{Error, Result};

/// One non-zero entry of a sparse matrix, as it appears on the stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Entry {
    /// Row index.
    pub row: u32,
    /// Column index.
    pub col: u32,
    /// Value (non-zero).
    pub val: f32,
}

impl Entry {
    /// Construct an entry.
    pub fn new(row: u32, col: u32, val: f32) -> Self {
        Self { row, col, val }
    }
}

/// Coordinate-format sparse matrix.
#[derive(Clone, Debug, Default)]
pub struct Coo {
    /// Number of rows.
    pub m: usize,
    /// Number of columns.
    pub n: usize,
    /// Non-zero entries (arbitrary order unless [`Coo::normalize`]d).
    pub entries: Vec<Entry>,
}

impl Coo {
    /// Empty matrix of the given shape.
    pub fn new(m: usize, n: usize) -> Self {
        Self { m, n, entries: Vec::new() }
    }

    /// From parts, validating indices.
    pub fn from_entries(m: usize, n: usize, entries: Vec<Entry>) -> Result<Self> {
        for e in &entries {
            if e.row as usize >= m || e.col as usize >= n {
                return Err(Error::shape(format!(
                    "entry ({}, {}) outside {}x{}",
                    e.row, e.col, m, n
                )));
            }
        }
        Ok(Self { m, n, entries })
    }

    /// Number of stored entries (duplicates counted separately).
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Push one entry (unchecked shape — hot path).
    #[inline]
    pub fn push(&mut self, row: u32, col: u32, val: f32) {
        self.entries.push(Entry { row, col, val });
    }

    /// Sort row-major and combine duplicate coordinates by summation;
    /// drops entries that cancel to zero.
    pub fn normalize(&mut self) {
        self.entries
            .sort_unstable_by(|a, b| (a.row, a.col).cmp(&(b.row, b.col)));
        let mut out: Vec<Entry> = Vec::with_capacity(self.entries.len());
        for e in self.entries.drain(..) {
            match out.last_mut() {
                Some(last) if last.row == e.row && last.col == e.col => last.val += e.val,
                _ => out.push(e),
            }
        }
        out.retain(|e| e.val != 0.0);
        self.entries = out;
    }

    /// Entrywise L1 norm `‖A‖₁ = Σ|a_ij|`.
    pub fn norm_l1(&self) -> f64 {
        self.entries.iter().map(|e| e.val.abs() as f64).sum()
    }

    /// Frobenius norm.
    pub fn norm_fro(&self) -> f64 {
        self.entries
            .iter()
            .map(|e| (e.val as f64) * (e.val as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// Per-row L1 norms `‖A_(i)‖₁`.
    pub fn row_l1_norms(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.m];
        for e in &self.entries {
            out[e.row as usize] += e.val.abs() as f64;
        }
        out
    }

    /// Per-column L1 norms `‖A^(j)‖₁`.
    pub fn col_l1_norms(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.n];
        for e in &self.entries {
            out[e.col as usize] += e.val.abs() as f64;
        }
        out
    }

    /// Transpose (swaps row/col of every entry).
    pub fn transpose(&self) -> Coo {
        Coo {
            m: self.n,
            n: self.m,
            entries: self
                .entries
                .iter()
                .map(|e| Entry { row: e.col, col: e.row, val: e.val })
                .collect(),
        }
    }

    /// Convert to CSR (normalizes duplicates first).
    pub fn to_csr(&self) -> super::Csr {
        let mut c = self.clone();
        c.normalize();
        super::Csr::from_sorted_coo(&c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Coo {
        Coo::from_entries(
            3,
            4,
            vec![
                Entry::new(0, 0, 1.0),
                Entry::new(2, 3, -2.0),
                Entry::new(1, 1, 0.5),
                Entry::new(0, 0, 1.0), // duplicate
            ],
        )
        .unwrap()
    }

    #[test]
    fn normalize_merges_duplicates() {
        let mut c = sample();
        c.normalize();
        assert_eq!(c.nnz(), 3);
        assert_eq!(c.entries[0], Entry::new(0, 0, 2.0));
    }

    #[test]
    fn normalize_drops_cancelled() {
        let mut c = Coo::new(2, 2);
        c.push(0, 0, 1.0);
        c.push(0, 0, -1.0);
        c.push(1, 1, 3.0);
        c.normalize();
        assert_eq!(c.nnz(), 1);
        assert_eq!(c.entries[0], Entry::new(1, 1, 3.0));
    }

    #[test]
    fn norms() {
        let mut c = sample();
        c.normalize();
        assert!((c.norm_l1() - 4.5).abs() < 1e-12);
        assert!((c.norm_fro() - (4.0f64 + 4.0 + 0.25).sqrt()).abs() < 1e-12);
        assert_eq!(c.row_l1_norms(), vec![2.0, 0.5, 2.0]);
        assert_eq!(c.col_l1_norms(), vec![2.0, 0.5, 0.0, 2.0]);
    }

    #[test]
    fn shape_validation() {
        assert!(Coo::from_entries(2, 2, vec![Entry::new(2, 0, 1.0)]).is_err());
        assert!(Coo::from_entries(2, 2, vec![Entry::new(0, 5, 1.0)]).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let c = sample();
        let t2 = c.transpose().transpose();
        assert_eq!(c.m, t2.m);
        assert_eq!(c.n, t2.n);
        assert_eq!(c.entries.len(), t2.entries.len());
    }
}
