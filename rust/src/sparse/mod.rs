//! Sparse and dense matrix substrate.
//!
//! * [`Coo`] — triplet form, the natural streaming/interchange format.
//! * [`Csr`] — compressed sparse rows, the compute format (SpMV/SpMM).
//! * [`Dense`] — row-major dense blocks fed to the XLA runtime.
//! * [`io`] — MatrixMarket + binary triplet-stream readers/writers.

pub mod coo;
pub mod csr;
pub mod dense;
pub mod io;

pub use coo::{Coo, Entry};
pub use csr::Csr;
pub use dense::Dense;
