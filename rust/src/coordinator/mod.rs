//! L3 coordinator — the streaming sketch pipeline façade.
//!
//! Since the engine unification this module is a compatibility layer over
//! [`crate::engine`]: [`Pipeline`]/[`sketch_stream`] run the **sharded**
//! [`crate::engine::Sketcher`] (leader routes entries by row shard to `W`
//! worker reservoirs over bounded backpressured channels; a deterministic
//! seeded merger composes the shard samples into `s` exact global i.i.d.
//! draws). The merge law:
//!
//! 1. per-shard sample counts `s_w ~ Multinomial(s, W_w/ΣW)` (pre-split
//!    from stats when derivable, else over the *observed* shard weights);
//! 2. for the observed path, a uniformly random `s_w`-subset (multivariate
//!    hypergeometric) of each shard's `s` exchangeable reservoir samples.
//!
//! Both steps preserve the i.i.d. law exactly — see
//! `rust/tests/prop_invariants.rs` for the distributional tests, and
//! `rust/src/engine/` for the mechanics.

pub mod metrics;
pub mod pipeline;

pub use metrics::PipelineMetrics;
pub use pipeline::{sketch_stream, Pipeline, PipelineConfig};

use crate::engine::{self, SketchMode};
use crate::error::Result;
use crate::sketch::{Sketch, SketchPlan};
use crate::sparse::Coo;

/// Convenience: sketch an in-memory matrix through the full streaming
/// pipeline (two passes: stats, then shuffled-order sampling).
pub fn sketch_matrix(a: &Coo, plan: &SketchPlan) -> Result<Sketch> {
    let (sketch, _metrics) =
        engine::sketch_coo(SketchMode::Sharded, a, plan, &PipelineConfig::default())?;
    Ok(sketch)
}
