//! L3 coordinator — the streaming sketch pipeline.
//!
//! A leader thread ingests an arbitrary-order entry stream and routes each
//! non-zero to one of `W` worker threads by row-shard assignment over
//! bounded channels (backpressure). Each worker runs the paper's
//! Appendix-A [`crate::samplers::ParallelReservoir`] with the entry
//! weights of the chosen distribution (O(1) work per non-zero, Theorem
//! 4.2). At end of stream the merger composes the shard samples into `s`
//! exact global i.i.d. draws:
//!
//! 1. per-shard sample counts `s_w ~ Multinomial(s, W_w/ΣW)` over the
//!    *observed* shard weights;
//! 2. a uniformly random `s_w`-subset (multivariate hypergeometric) of
//!    each shard's `s` exchangeable reservoir samples.
//!
//! Both steps preserve the i.i.d. law exactly — see
//! `rust/tests/prop_invariants.rs` for the distributional tests.

pub mod metrics;
pub mod pipeline;

pub use metrics::PipelineMetrics;
pub use pipeline::{sketch_stream, Pipeline, PipelineConfig};

use crate::distributions::MatrixStats;
use crate::error::Result;
use crate::sketch::{Sketch, SketchPlan};
use crate::sparse::Coo;
use crate::stream::ShuffledStream;

/// Convenience: sketch an in-memory matrix through the full streaming
/// pipeline (two passes: stats, then shuffled-order sampling).
pub fn sketch_matrix(a: &Coo, plan: &SketchPlan) -> Result<Sketch> {
    let stats = MatrixStats::from_coo(a);
    let stream = ShuffledStream::new(a, plan.seed ^ 0xD1CE);
    let (sketch, _metrics) = sketch_stream(stream, &stats, plan, &PipelineConfig::default())?;
    Ok(sketch)
}
