//! The leader/worker streaming pipeline.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::time::Instant;

use crate::distributions::{Distribution, MatrixStats};
use crate::error::{Error, Result};
use crate::samplers::{hypergeometric, multinomial_counts, ParallelReservoir};
use crate::sketch::{Sketch, SketchEntry, SketchPlan};
use crate::sparse::Entry;
use crate::stream::EntryStream;
use crate::util::rng::Rng;

use super::metrics::PipelineMetrics;

/// Pipeline tuning knobs.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Worker (shard) count. 0 = auto (available_parallelism − 1, min 1).
    pub workers: usize,
    /// Bounded channel capacity per worker, in batches.
    pub channel_cap: usize,
    /// Entries per batch message (amortizes channel overhead).
    pub batch: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { workers: 0, channel_cap: 64, batch: 4096 }
    }
}

impl PipelineConfig {
    fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        std::thread::available_parallelism()
            .map(|p| p.get().saturating_sub(1).max(1))
            .unwrap_or(1)
    }
}

/// The streaming pipeline object (reusable across runs).
pub struct Pipeline {
    cfg: PipelineConfig,
}

impl Pipeline {
    /// Create with a config.
    pub fn new(cfg: PipelineConfig) -> Pipeline {
        Pipeline { cfg }
    }

    /// Run the full streaming sketch on one entry stream.
    ///
    /// `stats` must describe the same matrix the stream yields (pass 1 of
    /// the two-pass algorithm, or a-priori row-norm estimates — see §3 of
    /// the paper; only row-norm *ratios* matter for Bernstein/Row-L1).
    pub fn run<S: EntryStream>(
        &self,
        mut stream: S,
        stats: &MatrixStats,
        plan: &SketchPlan,
    ) -> Result<(Sketch, PipelineMetrics)> {
        if plan.s == 0 {
            return Err(Error::invalid("sample budget must be positive"));
        }
        let (m, n) = stream.shape();
        if stats.row_l1.len() != m {
            return Err(Error::shape(format!(
                "stats rows {} != stream rows {m}",
                stats.row_l1.len()
            )));
        }
        let dist = Distribution::prepare(plan.kind, stats, plan.s, plan.delta)?;
        let workers = self.cfg.effective_workers();
        let t0 = Instant::now();
        let mut merge_rng = Rng::new(plan.seed ^ 0x4D45_5247);

        // Shard-budget pre-split (§Perf): when per-row weight totals are
        // derivable from the one-pass stats, draw the per-shard sample
        // counts up front and run each worker's reservoir at its own
        // multinomial share s_w — total reservoir work O(s·log N)
        // independent of the worker count. Trimmed distributions fall
        // back to full-budget workers + hypergeometric subset merge.
        // Fibonacci hash + Lemire range reduction (multiply-shift, no
        // integer division on the per-entry hot path).
        let wmax = workers.max(1) as u64;
        let shard_of = move |row: u32| -> usize {
            let h = (row as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            (((h as u128) * (wmax as u128)) >> 64) as usize
        };
        let presplit: Option<(Vec<u64>, Vec<f64>)> =
            dist.row_weight_totals(stats).map(|row_totals| {
                let mut shard_w = vec![0.0f64; workers];
                for (i, &w) in row_totals.iter().enumerate() {
                    shard_w[shard_of(i as u32)] += w;
                }
                let total: f64 = shard_w.iter().sum();
                let counts = multinomial_counts(&mut merge_rng, plan.s, &shard_w);
                let q: Vec<f64> = shard_w.iter().map(|w| w / total).collect();
                (counts, q)
            });

        // --- spawn workers ---
        struct WorkerOut {
            shard: usize,
            samples: Vec<crate::samplers::WeightedSample<Entry>>,
            total_weight: f64,
            sketch_records: u64,
            skipped: u64,
        }
        let mut senders: Vec<SyncSender<Vec<Entry>>> = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx): (SyncSender<Vec<Entry>>, Receiver<Vec<Entry>>) =
                sync_channel(self.cfg.channel_cap);
            senders.push(tx);
            let dist = dist.clone();
            // pre-split: this worker samples only its multinomial share
            let budget = match &presplit {
                Some((counts, _)) => counts[w],
                None => plan.s,
            };
            let seed = plan.seed ^ (0xA5A5_0000 + w as u64);
            handles.push(std::thread::spawn(move || -> WorkerOut {
                let mut res: Option<ParallelReservoir<Entry>> =
                    (budget > 0).then(|| ParallelReservoir::new(budget, seed));
                let mut skipped = 0u64;
                let mut total_weight = 0.0f64;
                for batch in rx.iter() {
                    for e in batch {
                        let wgt = dist.weight(e.row, e.val);
                        if wgt > 0.0 {
                            total_weight += wgt;
                            if let Some(r) = res.as_mut() {
                                r.push(e, wgt);
                            }
                        } else {
                            skipped += 1;
                        }
                    }
                }
                let sketch_records = res.as_ref().map_or(0, |r| r.sketch_len() as u64);
                WorkerOut {
                    shard: w,
                    samples: res.map_or_else(Vec::new, |r| r.finalize()),
                    total_weight,
                    sketch_records,
                    skipped,
                }
            }));
        }

        // --- leader: route entries by row shard ---
        let mut metrics = PipelineMetrics {
            workers,
            ..Default::default()
        };
        let mut batches: Vec<Vec<Entry>> = (0..workers)
            .map(|_| Vec::with_capacity(self.cfg.batch))
            .collect();
        while let Some(e) = stream.next_entry() {
            if (e.row as usize) >= m || (e.col as usize) >= n {
                return Err(Error::shape(format!(
                    "stream entry ({}, {}) outside {m}x{n}",
                    e.row, e.col
                )));
            }
            metrics.ingested += 1;
            // row-based sharding: Fibonacci hash of the row id (must
            // match the shard_of used for the budget pre-split)
            let shard = shard_of(e.row);
            let b = &mut batches[shard];
            b.push(e);
            if b.len() >= self.cfg.batch {
                let full = std::mem::replace(b, Vec::with_capacity(self.cfg.batch));
                send_with_backpressure(&senders[shard], full, &mut metrics);
            }
        }
        for (shard, b) in batches.into_iter().enumerate() {
            if !b.is_empty() {
                send_with_backpressure(&senders[shard], b, &mut metrics);
            }
        }
        drop(senders);

        // --- collect worker outputs ---
        let mut outs: Vec<WorkerOut> = Vec::with_capacity(workers);
        for h in handles {
            outs.push(h.join().map_err(|_| Error::Pipeline("worker panicked".into()))?);
        }
        outs.sort_by_key(|o| o.shard);
        for o in &outs {
            metrics.skipped_zero_weight += o.skipped;
            metrics.sketch_records += o.sketch_records;
            metrics.pre_merge_samples += o.samples.iter().map(|s| s.count).sum::<u64>();
        }

        let total_weight: f64 = outs.iter().map(|o| o.total_weight).sum();
        if total_weight <= 0.0 {
            return Err(Error::Pipeline("stream carried no positive-weight entries".into()));
        }
        let mut entries: Vec<SketchEntry> = Vec::new();
        match &presplit {
            Some((_counts, q)) => {
                // --- merge, pre-split path: every worker already holds
                //     exactly its multinomial share. The effective global
                //     sampling probability of an entry in shard w is
                //     q_w · w_ij / W_w(observed) — exact even when the
                //     stats were rough estimates (§3 one-pass mode).
                for o in &outs {
                    let qw = q[o.shard];
                    if o.total_weight <= 0.0 {
                        continue;
                    }
                    for smp in &o.samples {
                        let e = smp.item;
                        let w = dist.weight(e.row, e.val);
                        let p = qw * w / o.total_weight;
                        entries.push(SketchEntry {
                            row: e.row,
                            col: e.col,
                            count: smp.count as u32,
                            value: smp.count as f64 * e.val as f64 / (plan.s as f64 * p),
                        });
                    }
                }
            }
            None => {
                // --- merge, fallback path: multinomial over *observed*
                //     shard weights, then a uniformly random subset
                //     (hypergeometric chain) of each shard's s samples.
                let shard_weights: Vec<f64> = outs.iter().map(|o| o.total_weight).collect();
                let take = multinomial_counts(&mut merge_rng, plan.s, &shard_weights);
                for (o, &need_total) in outs.iter().zip(take.iter()) {
                    if need_total == 0 {
                        continue;
                    }
                    let have: u64 = o.samples.iter().map(|s| s.count).sum();
                    if have < need_total {
                        return Err(Error::Pipeline(format!(
                            "shard {} holds {have} samples, needs {need_total}",
                            o.shard
                        )));
                    }
                    let mut pop = have;
                    let mut need = need_total;
                    for smp in &o.samples {
                        if need == 0 {
                            break;
                        }
                        let t = hypergeometric(&mut merge_rng, pop, smp.count, need);
                        pop -= smp.count;
                        need -= t;
                        if t > 0 {
                            let e = smp.item;
                            let w = dist.weight(e.row, e.val);
                            let p = w / total_weight; // global probability
                            entries.push(SketchEntry {
                                row: e.row,
                                col: e.col,
                                count: t as u32,
                                value: t as f64 * e.val as f64 / (plan.s as f64 * p),
                            });
                        }
                    }
                }
            }
        }

        let row_scale = dist.rho.as_ref().map(|rho| {
            rho.iter()
                .zip(stats.row_l1.iter())
                .map(|(&r, &z)| if r > 0.0 { z / (plan.s as f64 * r) } else { 0.0 })
                .collect()
        });

        let mut sketch = Sketch {
            m,
            n,
            s: plan.s,
            entries,
            row_scale,
            method: plan.kind.name(),
        };
        sketch.normalize();
        metrics.merged_samples = sketch.entries.iter().map(|e| e.count as u64).sum();
        metrics.wall = t0.elapsed();
        Ok((sketch, metrics))
    }
}

/// Send a batch, accounting blocked time as backpressure.
fn send_with_backpressure(
    tx: &SyncSender<Vec<Entry>>,
    batch: Vec<Entry>,
    metrics: &mut PipelineMetrics,
) {
    match tx.try_send(batch) {
        Ok(()) => {}
        Err(TrySendError::Full(batch)) => {
            let t = Instant::now();
            // blocking send; worker will drain
            let _ = tx.send(batch);
            metrics.backpressure_wait += t.elapsed();
        }
        Err(TrySendError::Disconnected(_)) => {
            // worker ended early (only on panic; surfaced at join)
        }
    }
}

/// One-call façade over [`Pipeline::run`].
pub fn sketch_stream<S: EntryStream>(
    stream: S,
    stats: &MatrixStats,
    plan: &SketchPlan,
    cfg: &PipelineConfig,
) -> Result<(Sketch, PipelineMetrics)> {
    Pipeline::new(cfg.clone()).run(stream, stats, plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::DistributionKind;
    use crate::sparse::Coo;
    use crate::stream::{ShuffledStream, VecStream};

    fn toy(m: usize, n: usize, seed: u64) -> Coo {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(m, n);
        for i in 0..m as u32 {
            for _ in 0..12 {
                coo.push(i, rng.usize_below(n) as u32, rng.normal() as f32 + 2.0);
            }
        }
        coo.normalize();
        coo
    }

    #[test]
    fn total_sample_count_is_s() {
        let a = toy(20, 200, 0);
        let stats = MatrixStats::from_coo(&a);
        let plan = SketchPlan::new(DistributionKind::Bernstein, 777).with_seed(3);
        let (sk, metrics) =
            sketch_stream(VecStream::new(&a), &stats, &plan, &PipelineConfig::default())
                .unwrap();
        assert_eq!(metrics.merged_samples, 777);
        assert_eq!(sk.entries.iter().map(|e| e.count as u64).sum::<u64>(), 777);
        assert_eq!(metrics.ingested, a.nnz() as u64);
    }

    #[test]
    fn stream_order_does_not_matter() {
        // same matrix, different arrival orders → statistically identical
        // sketches; verify expectation over several seeds.
        let a = toy(10, 50, 1);
        let stats = MatrixStats::from_coo(&a);
        let mut sums = [0.0f64; 2];
        for (which, seed0) in [(0usize, 100u64), (1, 900)] {
            for t in 0..40 {
                let plan =
                    SketchPlan::new(DistributionKind::L1, 200).with_seed(seed0 + t);
                let (sk, _) = sketch_stream(
                    ShuffledStream::new(&a, seed0 * 31 + t),
                    &stats,
                    &plan,
                    &PipelineConfig { workers: 3, ..Default::default() },
                )
                .unwrap();
                sums[which] += sk.entries.iter().map(|e| e.value.abs()).sum::<f64>();
            }
        }
        let rel = (sums[0] - sums[1]).abs() / sums[0];
        assert!(rel < 0.1, "order-dependent bias: {sums:?}");
    }

    #[test]
    fn unbiased_through_pipeline() {
        let a = Coo::from_entries(
            2,
            2,
            vec![
                crate::sparse::Entry::new(0, 0, 4.0),
                crate::sparse::Entry::new(0, 1, -1.0),
                crate::sparse::Entry::new(1, 1, 2.0),
            ],
        )
        .unwrap();
        let stats = MatrixStats::from_coo(&a);
        let trials = 1500u64;
        let mut acc = [[0.0f64; 2]; 2];
        for t in 0..trials {
            let plan = SketchPlan::new(DistributionKind::Bernstein, 6).with_seed(t);
            let (sk, _) = sketch_stream(
                ShuffledStream::new(&a, t),
                &stats,
                &plan,
                &PipelineConfig { workers: 2, ..Default::default() },
            )
            .unwrap();
            for e in &sk.entries {
                acc[e.row as usize][e.col as usize] += e.value;
            }
        }
        let want = [[4.0, -1.0], [0.0, 2.0]];
        for i in 0..2 {
            for j in 0..2 {
                let mean = acc[i][j] / trials as f64;
                assert!(
                    (mean - want[i][j]).abs() < 0.3,
                    "({i},{j}): mean={mean} want={}",
                    want[i][j]
                );
            }
        }
    }

    #[test]
    fn single_worker_matches_many_workers_in_distribution() {
        let a = toy(16, 100, 2);
        let stats = MatrixStats::from_coo(&a);
        let mut totals = [0.0f64; 2];
        for (which, workers) in [(0usize, 1usize), (1, 4)] {
            for t in 0..30 {
                let plan = SketchPlan::new(DistributionKind::RowL1, 400).with_seed(t);
                let (sk, _) = sketch_stream(
                    VecStream::new(&a),
                    &stats,
                    &plan,
                    &PipelineConfig { workers, ..Default::default() },
                )
                .unwrap();
                totals[which] += sk.nnz() as f64;
            }
        }
        let rel = (totals[0] - totals[1]).abs() / totals[0];
        assert!(rel < 0.05, "worker-count bias in distinct-coordinate counts: {totals:?}");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = toy(5, 10, 3);
        let stats = MatrixStats::from_coo(&toy(6, 10, 3)); // wrong m
        let plan = SketchPlan::new(DistributionKind::L1, 10);
        assert!(
            sketch_stream(VecStream::new(&a), &stats, &plan, &PipelineConfig::default())
                .is_err()
        );
    }
}
