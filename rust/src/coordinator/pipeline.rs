//! The leader/worker streaming pipeline — a thin façade over the unified
//! [`crate::engine`] in sharded mode. The mechanics (row-hash routing,
//! worker reservoirs, bounded-spill backpressure, deterministic seeded
//! merge) live in `engine/{shard, backpressure, merge}`.

use crate::distributions::MatrixStats;
use crate::engine::{self, SketchMode};
use crate::error::Result;
use crate::sketch::{Sketch, SketchPlan};
use crate::stream::EntryStream;

pub use crate::engine::{PipelineConfig, PipelineMetrics};

/// The streaming pipeline object (reusable across runs).
pub struct Pipeline {
    cfg: PipelineConfig,
}

impl Pipeline {
    /// Create with a config.
    pub fn new(cfg: PipelineConfig) -> Pipeline {
        Pipeline { cfg }
    }

    /// Run the full streaming sketch on one entry stream.
    ///
    /// `stats` must describe the same matrix the stream yields (pass 1 of
    /// the two-pass algorithm, or a-priori row-norm estimates — see §3 of
    /// the paper; only row-norm *ratios* matter for Bernstein/Row-L1).
    pub fn run<S: EntryStream>(
        &self,
        stream: S,
        stats: &MatrixStats,
        plan: &SketchPlan,
    ) -> Result<(Sketch, PipelineMetrics)> {
        engine::sketch_entry_stream(SketchMode::Sharded, stream, stats, plan, &self.cfg)
    }
}

/// One-call façade over [`Pipeline::run`].
pub fn sketch_stream<S: EntryStream>(
    stream: S,
    stats: &MatrixStats,
    plan: &SketchPlan,
    cfg: &PipelineConfig,
) -> Result<(Sketch, PipelineMetrics)> {
    Pipeline::new(cfg.clone()).run(stream, stats, plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::DistributionKind;
    use crate::sparse::Coo;
    use crate::stream::{ShuffledStream, VecStream};
    use crate::util::rng::Rng;

    fn toy(m: usize, n: usize, seed: u64) -> Coo {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(m, n);
        for i in 0..m as u32 {
            for _ in 0..12 {
                coo.push(i, rng.usize_below(n) as u32, rng.normal() as f32 + 2.0);
            }
        }
        coo.normalize();
        coo
    }

    #[test]
    fn total_sample_count_is_s() {
        let a = toy(20, 200, 0);
        let stats = MatrixStats::from_coo(&a);
        let plan = SketchPlan::new(DistributionKind::Bernstein, 777).with_seed(3);
        let (sk, metrics) =
            sketch_stream(VecStream::new(&a), &stats, &plan, &PipelineConfig::default())
                .unwrap();
        assert_eq!(metrics.merged_samples, 777);
        assert_eq!(sk.entries.iter().map(|e| e.count as u64).sum::<u64>(), 777);
        assert_eq!(metrics.ingested, a.nnz() as u64);
    }

    #[test]
    fn stream_order_does_not_matter() {
        // same matrix, different arrival orders → statistically identical
        // sketches; verify expectation over several seeds.
        let a = toy(10, 50, 1);
        let stats = MatrixStats::from_coo(&a);
        let mut sums = [0.0f64; 2];
        for (which, seed0) in [(0usize, 100u64), (1, 900)] {
            for t in 0..40 {
                let plan =
                    SketchPlan::new(DistributionKind::L1, 200).with_seed(seed0 + t);
                let (sk, _) = sketch_stream(
                    ShuffledStream::new(&a, seed0 * 31 + t),
                    &stats,
                    &plan,
                    &PipelineConfig { workers: 3, ..Default::default() },
                )
                .unwrap();
                sums[which] += sk.entries.iter().map(|e| e.value.abs()).sum::<f64>();
            }
        }
        let rel = (sums[0] - sums[1]).abs() / sums[0];
        assert!(rel < 0.1, "order-dependent bias: {sums:?}");
    }

    #[test]
    fn unbiased_through_pipeline() {
        let a = Coo::from_entries(
            2,
            2,
            vec![
                crate::sparse::Entry::new(0, 0, 4.0),
                crate::sparse::Entry::new(0, 1, -1.0),
                crate::sparse::Entry::new(1, 1, 2.0),
            ],
        )
        .unwrap();
        let stats = MatrixStats::from_coo(&a);
        let trials = 1500u64;
        let mut acc = [[0.0f64; 2]; 2];
        for t in 0..trials {
            let plan = SketchPlan::new(DistributionKind::Bernstein, 6).with_seed(t);
            let (sk, _) = sketch_stream(
                ShuffledStream::new(&a, t),
                &stats,
                &plan,
                &PipelineConfig { workers: 2, ..Default::default() },
            )
            .unwrap();
            for e in &sk.entries {
                acc[e.row as usize][e.col as usize] += e.value;
            }
        }
        let want = [[4.0, -1.0], [0.0, 2.0]];
        for i in 0..2 {
            for j in 0..2 {
                let mean = acc[i][j] / trials as f64;
                assert!(
                    (mean - want[i][j]).abs() < 0.3,
                    "({i},{j}): mean={mean} want={}",
                    want[i][j]
                );
            }
        }
    }

    #[test]
    fn single_worker_matches_many_workers_in_distribution() {
        let a = toy(16, 100, 2);
        let stats = MatrixStats::from_coo(&a);
        let mut totals = [0.0f64; 2];
        for (which, workers) in [(0usize, 1usize), (1, 4)] {
            for t in 0..30 {
                let plan = SketchPlan::new(DistributionKind::RowL1, 400).with_seed(t);
                let (sk, _) = sketch_stream(
                    VecStream::new(&a),
                    &stats,
                    &plan,
                    &PipelineConfig { workers, ..Default::default() },
                )
                .unwrap();
                totals[which] += sk.nnz() as f64;
            }
        }
        let rel = (totals[0] - totals[1]).abs() / totals[0];
        assert!(rel < 0.05, "worker-count bias in distinct-coordinate counts: {totals:?}");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = toy(5, 10, 3);
        let stats = MatrixStats::from_coo(&toy(6, 10, 3)); // wrong m
        let plan = SketchPlan::new(DistributionKind::L1, 10);
        assert!(
            sketch_stream(VecStream::new(&a), &stats, &plan, &PipelineConfig::default())
                .is_err()
        );
    }
}
