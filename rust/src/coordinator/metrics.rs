//! Pipeline observability — re-exported from the unified engine, which
//! produces one [`PipelineMetrics`] per sketcher run in every mode.

pub use crate::engine::metrics::PipelineMetrics;
