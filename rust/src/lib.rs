//! # matsketch
//!
//! A streaming matrix-sketching framework reproducing *Near-Optimal
//! Entrywise Sampling for Data Matrices* (Achlioptas, Karnin, Liberty —
//! NIPS 2013).
//!
//! Given an `m×n` data matrix `A` (`n ≫ m`) arriving as an arbitrary-order
//! stream of non-zero entries, matsketch produces a sparse unbiased sketch
//! `B` minimizing `‖A − B‖₂` by sampling `s` entries i.i.d. from the
//! paper's near-optimal **Bernstein distribution**
//! `p_ij = ρ_i · |A_ij| / ‖A_(i)‖₁` (Algorithm 1), with `O(1)` work per
//! non-zero and `O(log s)` active memory (Appendix A).
//!
//! ## Architecture (three layers)
//!
//! * **L3 — Rust coordinator** (this crate): the unified sketching engine
//!   ([`engine`]: one `Sketcher` trait, offline/streaming/spilling/sharded
//!   modes),
//!   its pipeline façade ([`coordinator`]), sampling distributions
//!   ([`distributions`]),
//!   reservoir/binomial/hypergeometric samplers ([`samplers`]), compressed
//!   sketch codec ([`sketch`]), the serving layer ([`serve`]: persistent
//!   sketch store + compressed-path query engine + multi-threaded
//!   [`serve::QueryServer`]), the unified client API ([`api`]: the
//!   [`api::SketchClient`] trait over typed requests/responses, with
//!   in-process and remote backends answering byte-identically), the
//!   network front ([`net`]: zero-dependency
//!   wire protocol, TCP server, remote client, load generator), the
//!   telemetry registry ([`obs`]: lock-free counters / gauges /
//!   latency histograms every serving layer records into, scrapeable
//!   via the `Stats` wire opcode),
//!   sparse/dense substrates ([`sparse`],
//!   [`linalg`]), dataset generators ([`datasets`]), evaluation harness
//!   ([`eval`], [`metrics`]).
//! * **L2 — JAX graphs** (`python/compile/model.py`): the FLOP-heavy
//!   evaluation compute (Gram/apply/proj block ops, power iteration),
//!   AOT-lowered to HLO text.
//! * **L1 — Pallas kernels** (`python/compile/kernels/`): tiled MXU-style
//!   kernels called by the L2 graphs.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT CPU
//! client (`xla` crate) and exposes them behind the [`runtime::DenseEngine`]
//! trait; a pure-Rust fallback implements the same trait so every consumer
//! is engine-agnostic and the two paths cross-validate in tests.
//!
//! ## Quickstart
//!
//! ```no_run
//! use matsketch::prelude::*;
//!
//! // 1. A data matrix (here: the paper's synthetic CF generator).
//! let a = matsketch::datasets::synthetic_cf(&Default::default());
//! // 2. Sketch it with the Bernstein distribution, s = 100k samples.
//! let plan = SketchPlan::new(DistributionKind::Bernstein, 100_000).with_seed(7);
//! let sketch = sketch_matrix(&a, &plan).unwrap();
//! // 3. Use the sketch: B is sparse, unbiased, and ‖A−B‖₂-near-optimal.
//! let b = sketch.to_csr();
//! println!("kept {} of {} entries", b.nnz(), a.nnz());
//! ```

pub mod analysis;
pub mod api;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod distributions;
pub mod engine;
pub mod error;
pub mod eval;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod samplers;
pub mod serve;
pub mod sketch;
pub mod sparse;
pub mod stream;
pub mod testing;
pub mod util;

pub use error::{Error, Result};

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::api::{
        LocalClient, QueryRequest, QueryResponse, RemoteClient, SketchClient, SketchInfo,
    };
    pub use crate::coordinator::{sketch_matrix, sketch_stream, Pipeline, PipelineConfig};
    pub use crate::distributions::{Distribution, DistributionKind};
    pub use crate::engine::{build_sketcher, sketch_entry_stream, SketchMode, Sketcher};
    pub use crate::error::{Error, Result};
    pub use crate::metrics::MatrixMetrics;
    pub use crate::net::{NetServer, NetServerConfig};
    pub use crate::serve::{QueryServer, ServableSketch, SketchStore, StoreKey};
    pub use crate::sketch::{Sketch, SketchPlan};
    pub use crate::sparse::{Coo, Csr, Dense, Entry};
    pub use crate::util::rng::Rng;
}
