//! Exact multinomial counts via the conditional-binomial decomposition.
//!
//! Used by the coordinator's merge step: `s` global samples are split
//! across shards with probabilities proportional to the shards' total
//! weights; the counts are Multinomial(s, W_w/ΣW).

use super::binomial::binomial;
use crate::util::rng::Rng;

/// Draw Multinomial(`s`; weights) counts exactly. Weights need not be
/// normalized; zero weights get zero counts. Returns a count per weight,
/// summing to `s`.
pub fn multinomial_counts(rng: &mut Rng, s: u64, weights: &[f64]) -> Vec<u64> {
    let mut remaining_weight: f64 = weights.iter().sum();
    let mut remaining = s;
    let mut out = vec![0u64; weights.len()];
    for (i, &w) in weights.iter().enumerate() {
        if remaining == 0 {
            break;
        }
        if w <= 0.0 {
            continue;
        }
        if w >= remaining_weight {
            out[i] = remaining;
            remaining = 0;
            break;
        }
        let c = binomial(rng, remaining, (w / remaining_weight).clamp(0.0, 1.0));
        out[i] = c;
        remaining -= c;
        remaining_weight -= w;
    }
    // numeric leftovers land in the last positive-weight bucket
    if remaining > 0 {
        if let Some(i) = (0..weights.len()).rev().find(|&i| weights[i] > 0.0) {
            out[i] += remaining;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_sum_to_s() {
        let mut rng = Rng::new(0);
        for s in [0u64, 1, 17, 1000] {
            let c = multinomial_counts(&mut rng, s, &[0.1, 0.0, 2.0, 0.5]);
            assert_eq!(c.iter().sum::<u64>(), s);
            assert_eq!(c[1], 0);
        }
    }

    #[test]
    fn means_match_probabilities() {
        let mut rng = Rng::new(1);
        let weights = [1.0, 3.0, 6.0];
        let s = 1000u64;
        let trials = 2000;
        let mut sums = [0f64; 3];
        for _ in 0..trials {
            let c = multinomial_counts(&mut rng, s, &weights);
            for i in 0..3 {
                sums[i] += c[i] as f64;
            }
        }
        for i in 0..3 {
            let mean = sums[i] / trials as f64;
            let want = s as f64 * weights[i] / 10.0;
            assert!((mean - want).abs() / want < 0.02, "bucket {i}: {mean} vs {want}");
        }
    }

    #[test]
    fn single_bucket_gets_everything() {
        let mut rng = Rng::new(2);
        assert_eq!(multinomial_counts(&mut rng, 99, &[5.0]), vec![99]);
    }
}
