//! Exact Hypergeometric(s, ℓ, k) sampling.
//!
//! In the Appendix-A backward replay, `k` balls are thrown into `k`
//! distinct bins out of `s`, of which `ℓ` are empty; the number hitting
//! empty bins is hypergeometric. Two exact methods:
//!
//! * [`hypergeometric`] — inversion with the pmf recurrence walked from
//!   the mode (the HyperQuick idea [Ber07]); O(√variance) expected terms.
//! * [`hypergeometric_seq`] — sequential ball-by-ball simulation, O(k);
//!   kept as an oracle for the distribution tests.

use super::binomial::ln_factorial;
use crate::util::rng::Rng;

/// Draw the number of balls landing in empty bins: population `s`,
/// `l` empty bins, `k` balls into distinct bins (`k ≤ s`, `l ≤ s`).
pub fn hypergeometric(rng: &mut Rng, s: u64, l: u64, k: u64) -> u64 {
    assert!(l <= s && k <= s, "hypergeometric: l={l}, k={k}, s={s}");
    let t_min = k.saturating_sub(s - l);
    let t_max = k.min(l);
    if t_min == t_max {
        return t_min;
    }
    // mode of the hypergeometric
    let mode = (((k + 1) as f64 * (l + 1) as f64) / (s + 2) as f64).floor() as u64;
    let mode = mode.clamp(t_min, t_max);
    let ln_pmf = |t: u64| -> f64 {
        ln_choose(l, t) + ln_choose(s - l, k - t) - ln_choose(s, k)
    };
    let pmf_mode = ln_pmf(mode).exp();
    let u = rng.f64();
    let mut cum = pmf_mode;
    if u < cum {
        return mode;
    }
    // walk outward from the mode using the pmf ratio recurrence:
    // pmf(t+1)/pmf(t) = (l-t)(k-t) / ((t+1)(s-l-k+t+1))
    let (mut up_t, mut up_pmf) = (mode, pmf_mode);
    let (mut down_t, mut down_pmf) = (mode, pmf_mode);
    loop {
        let mut advanced = false;
        if up_t < t_max {
            let num = (l - up_t) as f64 * (k - up_t) as f64;
            let den = (up_t + 1) as f64 * (s - l - k + up_t + 1) as f64;
            up_pmf *= num / den;
            up_t += 1;
            cum += up_pmf;
            advanced = true;
            if u < cum {
                return up_t;
            }
        }
        if down_t > t_min {
            // pmf(t-1)/pmf(t) = t (s-l-k+t) / ((l-t+1)(k-t+1))
            let num = down_t as f64 * (s - l - k + down_t) as f64;
            let den = (l - down_t + 1) as f64 * (k - down_t + 1) as f64;
            down_pmf *= num / den;
            down_t -= 1;
            cum += down_pmf;
            advanced = true;
            if u < cum {
                return down_t;
            }
        }
        if !advanced || cum >= 1.0 - 1e-15 {
            return mode;
        }
    }
}

/// O(k) sequential oracle: throw the k balls one at a time; ball j lands
/// in an empty bin with probability (remaining empties)/(remaining bins).
pub fn hypergeometric_seq(rng: &mut Rng, s: u64, l: u64, k: u64) -> u64 {
    assert!(l <= s && k <= s);
    let mut empties = l;
    let mut bins = s;
    let mut hits = 0;
    for _ in 0..k {
        if rng.f64() * bins as f64 <= empties as f64 {
            hits += 1;
            empties -= 1;
        }
        bins -= 1;
    }
    hits
}

fn ln_choose(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn support_boundaries() {
        let mut rng = Rng::new(0);
        // all bins empty -> every ball hits an empty bin
        assert_eq!(hypergeometric(&mut rng, 10, 10, 4), 4);
        // no empty bins -> no hits
        assert_eq!(hypergeometric(&mut rng, 10, 0, 4), 0);
        // forced: s-l non-empties < k ⇒ at least k-(s-l) hits
        for _ in 0..50 {
            let t = hypergeometric(&mut rng, 10, 8, 5);
            assert!((3..=5).contains(&t));
        }
    }

    #[test]
    fn moments_match_theory() {
        let mut rng = Rng::new(1);
        let (s, l, k) = (1000u64, 300u64, 50u64);
        let n = 30_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let t = hypergeometric(&mut rng, s, l, k) as f64;
            sum += t;
            sumsq += t * t;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        let em = k as f64 * l as f64 / s as f64; // 15
        let ev = em * ((s - l) as f64 / s as f64) * ((s - k) as f64 / (s - 1) as f64);
        assert!((mean - em).abs() < 0.08, "mean={mean} want={em}");
        assert!((var - ev).abs() / ev < 0.08, "var={var} want={ev}");
    }

    #[test]
    fn inversion_matches_sequential_distribution() {
        // chi-square-ish comparison of the two exact samplers
        let (s, l, k) = (60u64, 25u64, 12u64);
        let n = 40_000;
        let mut h1 = vec![0u64; (k + 1) as usize];
        let mut h2 = vec![0u64; (k + 1) as usize];
        let mut r1 = Rng::new(2);
        let mut r2 = Rng::new(3);
        for _ in 0..n {
            h1[hypergeometric(&mut r1, s, l, k) as usize] += 1;
            h2[hypergeometric_seq(&mut r2, s, l, k) as usize] += 1;
        }
        for t in 0..=k as usize {
            let (a, b) = (h1[t] as f64, h2[t] as f64);
            if a + b > 100.0 {
                let rel = (a - b).abs() / (a + b);
                assert!(rel < 0.1, "bucket {t}: {a} vs {b}");
            }
        }
    }
}
