//! Disk-spilling variant of the Appendix-A reservoir.
//!
//! The paper stores the forward sketch on *durable storage* and keeps only
//! O(log s) active memory. [`SpillingReservoir`] reproduces that: sketch
//! records stream to a temp file as they are produced; the backward
//! replay reads the file in reverse block order. Used when
//! `s·log(b·N)` records exceed the in-memory budget.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::PathBuf;

use super::binomial::binomial;
use super::hypergeometric::hypergeometric;
use super::reservoir::WeightedSample;
use crate::error::Result;
use crate::util::rng::Rng;

/// Fixed-size sketch record: payload (row, col, value) + adoption count.
const REC_BYTES: usize = 20;

/// Streaming item payload for the spilling reservoir (matrix entries).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpillItem {
    /// Row.
    pub row: u32,
    /// Column.
    pub col: u32,
    /// Value.
    pub val: f32,
}

/// Appendix-A reservoir with the forward sketch on disk.
pub struct SpillingReservoir {
    s: u64,
    total_weight: f64,
    writer: BufWriter<File>,
    path: PathBuf,
    records: u64,
    rng: Rng,
}

impl SpillingReservoir {
    /// Create with a temp file under `dir`.
    pub fn create(dir: &std::path::Path, s: u64, seed: u64) -> Result<SpillingReservoir> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("reservoir_{seed}_{s}.sketch"));
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&path)?;
        Ok(SpillingReservoir {
            s,
            total_weight: 0.0,
            writer: BufWriter::new(file),
            path,
            records: 0,
            rng: Rng::new(seed),
        })
    }

    /// Records spilled so far (the O(s log bN) bound of Theorem 4.2).
    pub fn records(&self) -> u64 {
        self.records
    }

    /// Push one stream item — O(1) plus an amortized sequential write.
    pub fn push(&mut self, item: SpillItem, w: f64) -> Result<()> {
        debug_assert!(w > 0.0 && w.is_finite());
        self.total_weight += w;
        let k = binomial(&mut self.rng, self.s, w / self.total_weight);
        if k > 0 {
            let mut rec = [0u8; REC_BYTES];
            rec[0..4].copy_from_slice(&item.row.to_le_bytes());
            rec[4..8].copy_from_slice(&item.col.to_le_bytes());
            rec[8..12].copy_from_slice(&item.val.to_le_bytes());
            rec[12..20].copy_from_slice(&k.to_le_bytes());
            self.writer.write_all(&rec)?;
            self.records += 1;
        }
        Ok(())
    }

    /// Backward replay straight off the file; deletes the spill file.
    pub fn finalize(mut self) -> Result<Vec<WeightedSample<SpillItem>>> {
        self.writer.flush()?;
        drop(self.writer);
        let mut file = File::open(&self.path)?;
        let mut out = Vec::new();
        let mut l = self.s;
        // read in reverse blocks of 4096 records
        const BLOCK: u64 = 4096;
        let mut remaining = self.records;
        let mut buf = vec![0u8; (BLOCK as usize) * REC_BYTES];
        while remaining > 0 && l > 0 {
            let take = remaining.min(BLOCK);
            let start = (remaining - take) * REC_BYTES as u64;
            file.seek(SeekFrom::Start(start))?;
            let slice = &mut buf[..(take as usize) * REC_BYTES];
            file.read_exact(slice)?;
            // iterate records inside the block backwards
            for idx in (0..take as usize).rev() {
                if l == 0 {
                    break;
                }
                let rec = &slice[idx * REC_BYTES..(idx + 1) * REC_BYTES];
                let item = SpillItem {
                    row: u32::from_le_bytes(rec[0..4].try_into().unwrap()),
                    col: u32::from_le_bytes(rec[4..8].try_into().unwrap()),
                    val: f32::from_le_bytes(rec[8..12].try_into().unwrap()),
                };
                let k = u64::from_le_bytes(rec[12..20].try_into().unwrap());
                let t = hypergeometric(&mut self.rng, self.s, l, k.min(self.s));
                if t > 0 {
                    l -= t;
                    out.push(WeightedSample { item, count: t });
                }
            }
            remaining -= take;
        }
        let _ = std::fs::remove_file(&self.path);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::samplers::ParallelReservoir;

    fn tmp() -> PathBuf {
        std::env::temp_dir().join("matsketch_spill_test")
    }

    #[test]
    fn total_count_is_s() {
        let mut r = SpillingReservoir::create(&tmp(), 500, 1).unwrap();
        for i in 0..20_000u32 {
            r.push(SpillItem { row: i % 50, col: i, val: 1.0 }, 1.0 + (i % 7) as f64)
                .unwrap();
        }
        let samples = r.finalize().unwrap();
        assert_eq!(samples.iter().map(|s| s.count).sum::<u64>(), 500);
    }

    #[test]
    fn spill_file_removed_after_finalize() {
        let dir = tmp();
        let mut r = SpillingReservoir::create(&dir, 10, 2).unwrap();
        for i in 0..100u32 {
            r.push(SpillItem { row: 0, col: i, val: 1.0 }, 1.0).unwrap();
        }
        let path = r.path.clone();
        let _ = r.finalize().unwrap();
        assert!(!path.exists());
    }

    #[test]
    fn distribution_matches_in_memory_reservoir() {
        // same weighted stream through both engines; compare frequencies
        let items: Vec<(u32, f64)> = (0..40).map(|i| (i, 1.0 + i as f64 * 0.25)).collect();
        let s = 400u64;
        let trials = 150u64;
        let mut disk = vec![0u64; 40];
        let mut mem = vec![0u64; 40];
        for t in 0..trials {
            let mut r1 = SpillingReservoir::create(&tmp(), s, 100 + t).unwrap();
            for &(c, w) in &items {
                r1.push(SpillItem { row: 0, col: c, val: 1.0 }, w).unwrap();
            }
            for smp in r1.finalize().unwrap() {
                disk[smp.item.col as usize] += smp.count;
            }
            let mut r2: ParallelReservoir<u32> = ParallelReservoir::new(s, 500 + t);
            for &(c, w) in &items {
                r2.push(c, w);
            }
            for smp in r2.finalize() {
                mem[smp.item as usize] += smp.count;
            }
        }
        let total_w: f64 = items.iter().map(|x| x.1).sum();
        for i in 0..40 {
            let expect = items[i].1 / total_w;
            let d = disk[i] as f64 / (s * trials) as f64;
            let m = mem[i] as f64 / (s * trials) as f64;
            assert!((d - expect).abs() < 0.012, "disk item {i}: {d} vs {expect}");
            assert!((m - expect).abs() < 0.012, "mem item {i}: {m} vs {expect}");
        }
    }
}
