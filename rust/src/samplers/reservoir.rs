//! The paper's Appendix-A parallel weighted reservoir.
//!
//! Simulates `s` independent weight-proportional reservoir samplers (i.e.
//! `s` i.i.d. samples *with replacement* from the stream's weight
//! distribution) with:
//!
//! * **O(1) work per stream item** — one `binomial(s, w/W)` draw deciding
//!   how many of the `s` virtual samplers would adopt this item;
//! * a forward **sketch** (stack) holding only items adopted by ≥1 sampler
//!   — length O(s·log(b·N)) where `b = max w / min w`;
//! * a backward **replay** that resolves which adoptions were final using
//!   `hypergeometric(s, ℓ, k)` draws and O(log s) live state.
//!
//! This is Theorem 4.2's engine: the streaming sketcher runs one of these
//! per shard with the entry weights of the chosen distribution.

use super::binomial::binomial;
use super::hypergeometric::hypergeometric;
use crate::util::rng::Rng;

/// One resolved output: the stream item (by caller-provided payload) and
/// how many of the `s` samplers committed to it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WeightedSample<T> {
    /// The stream payload.
    pub item: T,
    /// Multiplicity `t ≥ 1` among the `s` samplers.
    pub count: u64,
}

/// Streaming state of the Appendix-A sampler.
#[derive(Clone, Debug)]
pub struct ParallelReservoir<T> {
    s: u64,
    total_weight: f64,
    /// Forward sketch: (item, #samplers that adopted it at push time).
    sketch: Vec<(T, u64)>,
    rng: Rng,
    items_seen: u64,
}

impl<T: Clone> ParallelReservoir<T> {
    /// Create a sampler for `s` parallel virtual reservoirs.
    pub fn new(s: u64, seed: u64) -> Self {
        assert!(s > 0, "need at least one sample");
        Self { s, total_weight: 0.0, sketch: Vec::new(), rng: Rng::new(seed), items_seen: 0 }
    }

    /// Total weight pushed so far.
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    /// Number of items pushed.
    pub fn items_seen(&self) -> u64 {
        self.items_seen
    }

    /// Current forward-sketch length (the O(s log bN) structure).
    pub fn sketch_len(&self) -> usize {
        self.sketch.len()
    }

    /// Push one stream item with weight `w > 0`. O(1): a single binomial
    /// draw (amortized O(1 + k) including pushing the sketch record).
    #[inline]
    pub fn push(&mut self, item: T, w: f64) {
        debug_assert!(w > 0.0 && w.is_finite(), "weights must be positive, got {w}");
        self.items_seen += 1;
        self.total_weight += w;
        let p = w / self.total_weight;
        let k = binomial(&mut self.rng, self.s, p);
        if k > 0 {
            self.sketch.push((item, k));
        }
    }

    /// Merge another reservoir's stream into this one (used by tests; the
    /// coordinator merges via multinomial over shard weights instead).
    pub fn push_all<I: IntoIterator<Item = (T, f64)>>(&mut self, items: I) {
        for (item, w) in items {
            self.push(item, w);
        }
    }

    /// Backward replay: resolve final commitments. Consumes the sampler
    /// and returns the composition of the `s` samplers' final choices,
    /// i.e. exactly `s` samples-with-replacement in aggregated
    /// `(item, count)` form. Returns fewer than `s` total only if the
    /// stream was empty.
    pub fn finalize(mut self) -> Vec<WeightedSample<T>> {
        let mut out = Vec::new();
        let mut l = self.s; // uncommitted samplers ("empty bins")
        while l > 0 {
            let Some((item, k)) = self.sketch.pop() else { break };
            // k of the s samplers adopted this item at push time; going
            // backwards, a sampler's first-seen adoption is its final one.
            let t = hypergeometric(&mut self.rng, self.s, l, k);
            if t > 0 {
                l -= t;
                out.push(WeightedSample { item, count: t });
            }
        }
        out
    }

    /// Naive O(s)-per-item oracle used by distribution tests: run `s`
    /// classic weighted reservoir samplers independently.
    pub fn naive_oracle(
        items: &[(T, f64)],
        s: u64,
        seed: u64,
    ) -> Vec<WeightedSample<T>>
    where
        T: PartialEq,
    {
        let mut rng = Rng::new(seed);
        let mut current: Vec<Option<usize>> = vec![None; s as usize];
        let mut total = 0.0;
        for (idx, (_, w)) in items.iter().enumerate() {
            total += w;
            let p = w / total;
            for slot in current.iter_mut() {
                if rng.f64() < p {
                    *slot = Some(idx);
                }
            }
        }
        let mut counts: std::collections::BTreeMap<usize, u64> = Default::default();
        for slot in current.into_iter().flatten() {
            *counts.entry(slot).or_default() += 1;
        }
        counts
            .into_iter()
            .map(|(idx, count)| WeightedSample { item: items[idx].0.clone(), count })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_count_is_s() {
        let mut r = ParallelReservoir::new(1000, 7);
        for i in 0..5000u32 {
            r.push(i, 1.0 + (i % 13) as f64);
        }
        let samples = r.finalize();
        let total: u64 = samples.iter().map(|x| x.count).sum();
        assert_eq!(total, 1000);
    }

    #[test]
    fn empty_stream_yields_nothing() {
        let r: ParallelReservoir<u32> = ParallelReservoir::new(10, 0);
        assert!(r.finalize().is_empty());
    }

    #[test]
    fn single_item_takes_all() {
        let mut r = ParallelReservoir::new(64, 1);
        r.push(42u32, 3.0);
        let samples = r.finalize();
        assert_eq!(samples.len(), 1);
        assert_eq!(samples[0], WeightedSample { item: 42, count: 64 });
    }

    #[test]
    fn frequencies_proportional_to_weight() {
        // item weights 1:2:7 — empirical sample shares must match
        let items: Vec<(u32, f64)> = vec![(0, 1.0), (1, 2.0), (2, 7.0)];
        let s = 2000u64;
        let trials = 200;
        let mut totals = [0u64; 3];
        for t in 0..trials {
            let mut r = ParallelReservoir::new(s, 100 + t);
            // arbitrary order: rotate
            for k in 0..3 {
                let (item, w) = items[((t as usize) + k) % 3];
                r.push(item, w);
            }
            for smp in r.finalize() {
                totals[smp.item as usize] += smp.count;
            }
        }
        let grand: u64 = totals.iter().sum();
        assert_eq!(grand, s * trials as u64);
        for (i, want) in [(0usize, 0.1), (1, 0.2), (2, 0.7)] {
            let got = totals[i] as f64 / grand as f64;
            assert!((got - want).abs() < 0.01, "item {i}: got {got}, want {want}");
        }
    }

    #[test]
    fn matches_naive_oracle_distribution() {
        // Compare aggregate frequencies of the O(1)/item sampler vs the
        // naive O(s)/item oracle on the same weighted stream.
        let items: Vec<(u32, f64)> = (0..50).map(|i| (i, 1.0 + (i as f64 * 0.3))).collect();
        let s = 500u64;
        let trials = 120u64;
        let mut fast = vec![0u64; 50];
        let mut slow = vec![0u64; 50];
        for t in 0..trials {
            let mut r = ParallelReservoir::new(s, 2000 + t);
            r.push_all(items.iter().cloned());
            for smp in r.finalize() {
                fast[smp.item as usize] += smp.count;
            }
            for smp in ParallelReservoir::naive_oracle(&items, s, 9000 + t) {
                slow[smp.item as usize] += smp.count;
            }
        }
        let total_w: f64 = items.iter().map(|x| x.1).sum();
        for i in 0..50 {
            let expect = items[i].1 / total_w;
            let f = fast[i] as f64 / (s * trials) as f64;
            let sl = slow[i] as f64 / (s * trials) as f64;
            assert!((f - expect).abs() < 0.01, "fast item {i}: {f} vs {expect}");
            assert!((sl - expect).abs() < 0.01, "slow item {i}: {sl} vs {expect}");
        }
    }

    #[test]
    fn sketch_length_is_compact() {
        // Theorem 4.2: sketch length O(s log(bN)), far below N for small s.
        let mut r = ParallelReservoir::new(100, 3);
        for i in 0..200_000u32 {
            r.push(i, 1.0);
        }
        // s·ln(N) ≈ 100 · 12.2 ≈ 1220 ≪ 200k
        assert!(r.sketch_len() < 5_000, "sketch too long: {}", r.sketch_len());
        assert!(r.sketch_len() >= 100);
    }
}
