//! Exact Binomial(n, p) sampling.
//!
//! The streaming reservoir draws `binomial(s, w/W)` once per stream item
//! (Appendix A), where `w/W` is usually tiny — so the expected count is
//! small and geometric skip-sampling (Devroye's "second waiting time"
//! method) is both exact and O(successes + 1). For large `n·p` we switch to
//! the inversion walk from the mode's side, and for `p > 1/2` we use the
//! complement symmetry.

use crate::util::rng::Rng;

/// Draw from Binomial(n, p) exactly.
pub fn binomial(rng: &mut Rng, n: u64, p: f64) -> u64 {
    if n == 0 || p <= 0.0 {
        return 0;
    }
    if p >= 1.0 {
        return n;
    }
    if p > 0.5 {
        return n - binomial(rng, n, 1.0 - p);
    }
    let mean = n as f64 * p;
    if mean <= 30.0 {
        geometric_skip(rng, n, p)
    } else {
        inversion_from_mode(rng, n, p)
    }
}

/// Devroye: count successes by jumping geometric gaps between them.
/// Exact; expected cost O(n·p + 1).
fn geometric_skip(rng: &mut Rng, n: u64, p: f64) -> u64 {
    let mut count = 0u64;
    let mut pos = 0u64;
    loop {
        let g = rng.geometric(p); // failures before next success
        if g >= n - pos {
            return count;
        }
        pos += g + 1;
        count += 1;
        if pos >= n {
            return count;
        }
    }
}

/// Exact inversion around the mode: evaluate the pmf recurrence outward
/// from the mode so the expected number of terms is O(√(n·p·(1−p))).
fn inversion_from_mode(rng: &mut Rng, n: u64, p: f64) -> u64 {
    let q = 1.0 - p;
    let mode = (((n + 1) as f64) * p).floor().min(n as f64) as u64;
    // log pmf at mode via lgamma for numerical stability
    let ln_pmf_mode = ln_choose(n, mode) + mode as f64 * p.ln() + (n - mode) as f64 * q.ln();
    let pmf_mode = ln_pmf_mode.exp();

    let u = rng.f64();
    // walk outward: mode, mode+1, mode-1, mode+2, ...
    let mut cum = pmf_mode;
    if u < cum {
        return mode;
    }
    let mut up_k = mode;
    let mut up_pmf = pmf_mode;
    let mut down_k = mode;
    let mut down_pmf = pmf_mode;
    loop {
        let mut advanced = false;
        if up_k < n {
            // pmf(k+1) = pmf(k) * (n-k)/(k+1) * p/q
            up_pmf *= ((n - up_k) as f64 / (up_k + 1) as f64) * (p / q);
            up_k += 1;
            cum += up_pmf;
            advanced = true;
            if u < cum {
                return up_k;
            }
        }
        if down_k > 0 {
            // pmf(k-1) = pmf(k) * k/(n-k+1) * q/p
            down_pmf *= (down_k as f64 / (n - down_k + 1) as f64) * (q / p);
            down_k -= 1;
            cum += down_pmf;
            advanced = true;
            if u < cum {
                return down_k;
            }
        }
        if !advanced || cum >= 1.0 - 1e-15 {
            // numeric tail: clamp to the boundary we ran against
            return if up_k < n { up_k } else { down_k };
        }
    }
}

/// ln C(n, k) via Stirling/lgamma.
fn ln_choose(n: u64, k: u64) -> f64 {
    ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
}

/// ln(n!) — exact table for small n, Stirling series beyond.
pub(crate) fn ln_factorial(n: u64) -> f64 {
    const TABLE_N: usize = 128;
    static TABLE: std::sync::OnceLock<[f64; TABLE_N]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0.0f64; TABLE_N];
        for i in 2..TABLE_N {
            t[i] = t[i - 1] + (i as f64).ln();
        }
        t
    });
    if (n as usize) < TABLE_N {
        return table[n as usize];
    }
    let x = n as f64 + 1.0;
    // Stirling series for ln Γ(x)
    (x - 0.5) * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI).ln() + 1.0 / (12.0 * x)
        - 1.0 / (360.0 * x.powi(3))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_var(samples: &[u64]) -> (f64, f64) {
        let n = samples.len() as f64;
        let mean = samples.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = samples.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / (n - 1.0);
        (mean, var)
    }

    #[test]
    fn edge_cases() {
        let mut rng = Rng::new(0);
        assert_eq!(binomial(&mut rng, 0, 0.5), 0);
        assert_eq!(binomial(&mut rng, 10, 0.0), 0);
        assert_eq!(binomial(&mut rng, 10, 1.0), 10);
        for _ in 0..100 {
            let x = binomial(&mut rng, 5, 0.5);
            assert!(x <= 5);
        }
    }

    #[test]
    fn small_mean_moments() {
        // geometric-skip regime
        let mut rng = Rng::new(1);
        let (n, p) = (10_000u64, 0.001);
        let samples: Vec<u64> = (0..20_000).map(|_| binomial(&mut rng, n, p)).collect();
        let (mean, var) = mean_var(&samples);
        let em = n as f64 * p;
        let ev = em * (1.0 - p);
        assert!((mean - em).abs() < 0.1, "mean={mean} want≈{em}");
        assert!((var - ev).abs() / ev < 0.05, "var={var} want≈{ev}");
    }

    #[test]
    fn large_mean_moments() {
        // inversion regime
        let mut rng = Rng::new(2);
        let (n, p) = (100_000u64, 0.01);
        let samples: Vec<u64> = (0..20_000).map(|_| binomial(&mut rng, n, p)).collect();
        let (mean, var) = mean_var(&samples);
        let em = n as f64 * p; // 1000
        let ev = em * (1.0 - p);
        assert!((mean - em).abs() < 1.5, "mean={mean} want≈{em}");
        assert!((var - ev).abs() / ev < 0.1, "var={var} want≈{ev}");
    }

    #[test]
    fn high_p_symmetry() {
        let mut rng = Rng::new(3);
        let samples: Vec<u64> = (0..20_000).map(|_| binomial(&mut rng, 100, 0.9)).collect();
        let (mean, _) = mean_var(&samples);
        assert!((mean - 90.0).abs() < 0.3, "mean={mean}");
    }

    #[test]
    fn ln_factorial_sane() {
        assert!((ln_factorial(0) - 0.0).abs() < 1e-12);
        assert!((ln_factorial(5) - 120f64.ln()).abs() < 1e-12);
        // Stirling branch vs sum for n=200
        let exact: f64 = (2..=200u64).map(|i| (i as f64).ln()).sum();
        assert!((ln_factorial(200) - exact).abs() < 1e-8);
    }
}
