//! Random sampling substrate.
//!
//! * [`binomial`] / [`hypergeometric`] — the exact discrete samplers the
//!   paper's Appendix-A streaming algorithm is built from.
//! * [`alias`] — Vose alias tables for the offline (in-memory) sampling
//!   path used by the evaluation harness.
//! * [`multinomial`] — exact multinomial counts (conditional binomials),
//!   used by the coordinator's shard merge.
//! * [`reservoir`] — the paper's O(1)-per-item, O(log s)-active-memory
//!   parallel weighted reservoir (Appendix A).

pub mod alias;
pub mod binomial;
pub mod hypergeometric;
pub mod multinomial;
pub mod reservoir;
pub mod spill;

pub use alias::AliasTable;
pub use binomial::binomial;
pub use hypergeometric::hypergeometric;
pub use multinomial::multinomial_counts;
pub use reservoir::{ParallelReservoir, WeightedSample};
pub use spill::{SpillItem, SpillingReservoir};
