//! Vose alias method: O(n) construction, O(1) sampling from an arbitrary
//! discrete distribution. The offline sketching path builds one alias
//! table over all non-zeros of `A` and draws `s` i.i.d. entries from it.

use crate::util::rng::Rng;

/// Immutable alias table.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from non-negative weights (not necessarily normalized).
    /// Zero-weight buckets are never drawn. Panics on empty/zero-total input.
    pub fn new(weights: &[f64]) -> AliasTable {
        let n = weights.len();
        assert!(n > 0, "alias table needs at least one weight");
        assert!(n <= u32::MAX as usize, "alias table limited to u32 indices");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0 && total.is_finite(), "weights must sum to a positive finite value");
        let mut prob: Vec<f64> = weights.iter().map(|w| w * n as f64 / total).collect();
        let mut alias = vec![0u32; n];
        let mut small: Vec<u32> = Vec::new();
        let mut large: Vec<u32> = Vec::new();
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[l as usize] -= 1.0 - prob[s as usize];
            alias[s as usize] = l;
            if prob[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // leftovers are numerically 1.0
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = 1.0;
            alias[i as usize] = i;
        }
        AliasTable { prob, alias }
    }

    /// Number of buckets.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// Whether the table is empty (never true post-construction).
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one index.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.usize_below(self.prob.len());
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_distribution() {
        let weights = [1.0, 0.0, 3.0, 6.0];
        let t = AliasTable::new(&weights);
        let mut rng = Rng::new(0);
        let n = 200_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[t.sample(&mut rng)] += 1;
        }
        assert_eq!(counts[1], 0);
        let total: f64 = weights.iter().sum();
        for i in [0usize, 2, 3] {
            let want = weights[i] / total;
            let got = counts[i] as f64 / n as f64;
            assert!((got - want).abs() < 0.01, "bucket {i}: got {got} want {want}");
        }
    }

    #[test]
    fn uniform_weights() {
        let t = AliasTable::new(&vec![2.5; 10]);
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[t.sample(&mut rng)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn single_bucket() {
        let t = AliasTable::new(&[42.0]);
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            assert_eq!(t.sample(&mut rng), 0);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_zero_total() {
        AliasTable::new(&[0.0, 0.0]);
    }
}
