//! Algorithm 1's `ComputeRowDistribution` — the Bernstein-optimal row
//! distribution ρ.
//!
//! Given row weights `z_i ∝ ‖A_(i)‖₁`, a budget `s` and confidence `δ`:
//!
//! ```text
//! α = √(ln((m+n)/δ)/s)        β = ln((m+n)/δ)/(3s)
//! ρ_i(ζ) = (αz_i/2ζ + √((αz_i/2ζ)² + βz_i/ζ))²
//! ```
//!
//! and ρ is `ρ_i(ζ₁)` for the unique `ζ₁ > 0` with `Σρ_i(ζ₁) = 1`
//! (Σρ_i(ζ) is strictly decreasing, so binary search converges fast).
//!
//! The interpolation behaviour proved in Lemma 5.4 is visible directly:
//! `β → 0` (large s) gives `ρ_i ∝ z_i²` (Row-L1), `α → 0` gives
//! `ρ_i ∝ z_i` (plain L1).

use crate::error::{Error, Result};

/// ρ_i(ζ) per Algorithm 1, line 9.
#[inline]
pub fn rho_of_zeta(z: f64, alpha: f64, beta: f64, zeta: f64) -> f64 {
    if z <= 0.0 {
        return 0.0;
    }
    let a = alpha * z / (2.0 * zeta);
    let root = (a * a + beta * z / zeta).sqrt();
    let r = a + root;
    r * r
}

/// Compute the Bernstein row distribution for row weights `z` (any
/// positive scale — only ratios matter), budget `s`, column count `n`
/// (enters via `ln((m+n)/δ)`), and failure probability `delta`.
pub fn compute_row_distribution(z: &[f64], s: u64, n: usize, delta: f64) -> Result<Vec<f64>> {
    let m = z.len();
    if m == 0 {
        return Err(Error::invalid("no rows"));
    }
    if s == 0 {
        return Err(Error::invalid("budget s must be positive"));
    }
    if !(0.0..1.0).contains(&delta) || delta <= 0.0 {
        return Err(Error::invalid(format!("delta must be in (0,1), got {delta}")));
    }
    let total_z: f64 = z.iter().sum();
    if total_z <= 0.0 {
        return Err(Error::invalid("row weights must have positive total"));
    }
    // ln((m+n)/δ) as a difference — (m+n)/δ overflows f64 for tiny δ.
    let log_term = (((m + n) as f64).ln() - delta.ln()).max(1e-9);
    let alpha = (log_term / s as f64).sqrt();
    let beta = log_term / (3.0 * s as f64);

    let sum_rho = |zeta: f64| -> f64 {
        z.iter().map(|&zi| rho_of_zeta(zi, alpha, beta, zeta)).sum()
    };

    // Bracket the root: Σρ(ζ) → ∞ as ζ→0⁺ and → 0 as ζ→∞.
    let mut lo = total_z * (alpha + beta) * 1e-12;
    let mut hi = total_z * (alpha + beta).max(1.0);
    let mut guard = 0;
    while sum_rho(lo) < 1.0 {
        lo *= 0.5;
        guard += 1;
        if guard > 200 {
            return Err(Error::Numeric("cannot bracket zeta from below".into()));
        }
    }
    guard = 0;
    while sum_rho(hi) > 1.0 {
        hi *= 2.0;
        guard += 1;
        if guard > 200 {
            return Err(Error::Numeric("cannot bracket zeta from above".into()));
        }
    }
    // Binary search (64 halvings ≫ f64 precision).
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if sum_rho(mid) > 1.0 {
            lo = mid;
        } else {
            hi = mid;
        }
        if (hi - lo) / hi < 1e-14 {
            break;
        }
    }
    let zeta1 = 0.5 * (lo + hi);
    let mut rho: Vec<f64> = z.iter().map(|&zi| rho_of_zeta(zi, alpha, beta, zeta1)).collect();
    // exact normalization of the residual binary-search error
    let total: f64 = rho.iter().sum();
    for r in rho.iter_mut() {
        *r /= total;
    }
    Ok(rho)
}

/// The ε₅ objective of Lemma 5.4 evaluated at a row distribution ρ
/// (with the optimal intra-row q): `max_i [α·z_i/√ρ_i + β·z_i/ρ_i]`.
/// Exposed for the Theorem-4.3 optimality experiments.
pub fn epsilon5(z: &[f64], rho: &[f64], s: u64, n: usize, delta: f64) -> f64 {
    let m = z.len();
    let log_term = (((m + n) as f64).ln() - delta.ln()).max(1e-9);
    let alpha = (log_term / s as f64).sqrt();
    let beta = log_term / (3.0 * s as f64);
    z.iter()
        .zip(rho.iter())
        .filter(|(&zi, _)| zi > 0.0)
        .map(|(&zi, &ri)| {
            if ri <= 0.0 {
                f64::INFINITY
            } else {
                alpha * zi / ri.sqrt() + beta * zi / ri
            }
        })
        .fold(0.0f64, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_z(m: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..m).map(|_| rng.f64_open() * 10.0 + 0.1).collect()
    }

    #[test]
    fn sums_to_one_and_positive() {
        let z = random_z(100, 0);
        for s in [10u64, 1_000, 1_000_000] {
            let rho = compute_row_distribution(&z, s, 10_000, 0.1).unwrap();
            assert!((rho.iter().sum::<f64>() - 1.0).abs() < 1e-12);
            assert!(rho.iter().all(|&r| r > 0.0));
        }
    }

    #[test]
    fn scale_invariant_in_z() {
        let z = random_z(50, 1);
        let z_scaled: Vec<f64> = z.iter().map(|x| x * 1234.5).collect();
        let r1 = compute_row_distribution(&z, 5_000, 1_000, 0.1).unwrap();
        let r2 = compute_row_distribution(&z_scaled, 5_000, 1_000, 0.1).unwrap();
        for (a, b) in r1.iter().zip(r2.iter()) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn zero_weight_rows_get_zero_mass() {
        let mut z = random_z(10, 2);
        z[3] = 0.0;
        let rho = compute_row_distribution(&z, 1_000, 100, 0.1).unwrap();
        assert_eq!(rho[3], 0.0);
        assert!((rho.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn small_s_limit_is_plain_l1() {
        // s → 1 with a huge log term: β dominates, ρ_i → z_i/Σz.
        let z = random_z(20, 3);
        let rho = compute_row_distribution(&z, 1, 1_000_000_000, 1e-300).unwrap();
        let total_z: f64 = z.iter().sum();
        let total_z2: f64 = z.iter().map(|x| x * x).sum();
        let mut tv_l1 = 0.0;
        let mut tv_rl1 = 0.0;
        for (zi, ri) in z.iter().zip(rho.iter()) {
            let want = zi / total_z;
            assert!((ri - want).abs() / want < 0.10, "got {ri} want {want}");
            tv_l1 += (ri - want).abs();
            tv_rl1 += (ri - zi * zi / total_z2).abs();
        }
        // and it is much closer to plain-L1 than to Row-L1
        assert!(tv_l1 < 0.2 * tv_rl1, "tv_l1={tv_l1} tv_rl1={tv_rl1}");
    }

    #[test]
    fn large_s_limit_is_row_l1() {
        // s → ∞: α dominates, ρ_i ∝ z_i²
        let z = random_z(20, 4);
        let rho = compute_row_distribution(&z, 1_000_000_000_000, 100, 0.5).unwrap();
        let total_z2: f64 = z.iter().map(|x| x * x).sum();
        for (zi, ri) in z.iter().zip(rho.iter()) {
            let want = zi * zi / total_z2;
            assert!((ri - want).abs() / want < 0.05, "got {ri} want {want}");
        }
    }

    #[test]
    fn equalizes_the_epsilon5_row_terms() {
        // By construction, every positive row attains the same value of
        // α·z/√ρ + β·z/ρ (= ζ₁).
        let z = random_z(30, 5);
        let (s, n, delta) = (10_000u64, 50_000usize, 0.1f64);
        let rho = compute_row_distribution(&z, s, n, delta).unwrap();
        let log_term = ((30.0 + n as f64) / delta).ln();
        let alpha = (log_term / s as f64).sqrt();
        let beta = log_term / (3.0 * s as f64);
        let vals: Vec<f64> = z
            .iter()
            .zip(rho.iter())
            .map(|(&zi, &ri)| alpha * zi / ri.sqrt() + beta * zi / ri)
            .collect();
        let (mn, mx) = vals.iter().fold((f64::MAX, f64::MIN), |(a, b), &v| (a.min(v), b.max(v)));
        assert!((mx - mn) / mx < 1e-6, "spread: {mn}..{mx}");
    }

    #[test]
    fn beats_naive_distributions_on_epsilon5() {
        // Theorem 4.3 proxy: Bernstein's ρ minimizes ε₅, so it must beat
        // plain-L1 and Row-L1 and 200 random perturbations.
        let z = random_z(25, 6);
        let (s, n, delta) = (2_000u64, 10_000usize, 0.1);
        let rho = compute_row_distribution(&z, s, n, delta).unwrap();
        let ours = epsilon5(&z, &rho, s, n, delta);

        let total_z: f64 = z.iter().sum();
        let l1: Vec<f64> = z.iter().map(|x| x / total_z).collect();
        let total_z2: f64 = z.iter().map(|x| x * x).sum();
        let rl1: Vec<f64> = z.iter().map(|x| x * x / total_z2).collect();
        assert!(ours <= epsilon5(&z, &l1, s, n, delta) * (1.0 + 1e-9));
        assert!(ours <= epsilon5(&z, &rl1, s, n, delta) * (1.0 + 1e-9));

        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let mut pert: Vec<f64> =
                rho.iter().map(|&r| r * (0.3 * rng.normal()).exp()).collect();
            let t: f64 = pert.iter().sum();
            pert.iter_mut().for_each(|p| *p /= t);
            assert!(
                ours <= epsilon5(&z, &pert, s, n, delta) * (1.0 + 1e-9),
                "perturbation beat the optimum"
            );
        }
    }
}
