//! The Achlioptas–McSherry (JACM 2007) hybrid baseline — the original
//! element-wise sparsification scheme the paper builds on (§2).
//!
//! AM07 keeps entry `(i,j)` independently with probability
//! `p_ij = min(1, τ·A_ij²)` and rescales kept entries by `1/p_ij`
//! (unbiased). Its "small wrinkle": entries so small that L2 weighting
//! would blow up the rescaled value (`|A_ij| < θ`) are instead kept with
//! probability proportional to `|A_ij|` — the L1 fallback that motivated
//! the trimming discussion in §2.
//!
//! Unlike the i.i.d.-budget methods this is an independent-coin scheme,
//! so like [`super::ahk06`] it gets its own sketcher with a
//! budget-matching search over τ.

use crate::sparse::{Coo, Csr};
use crate::util::rng::Rng;

/// AM07 parameters.
#[derive(Clone, Copy, Debug)]
pub struct Am07Config {
    /// Global L2 intensity τ: `p = min(1, τ·v²)` for large entries.
    pub tau: f64,
    /// Small-entry threshold θ (entries below it use L1 weighting
    /// `p = min(1, τ·θ·|v|)`), expressed in value units.
    pub theta: f64,
}

impl Am07Config {
    /// Probability of keeping value `v`.
    #[inline]
    pub fn keep_prob(&self, v: f32) -> f64 {
        let a = v.abs() as f64;
        let w = if a >= self.theta { a * a } else { self.theta * a };
        (self.tau * w).min(1.0)
    }

    /// Expected kept entries on `a`.
    pub fn expected_nnz(&self, a: &Csr) -> f64 {
        a.values.iter().map(|&v| self.keep_prob(v)).sum()
    }

    /// Budget-matched configuration: θ set to the RMS entry (the natural
    /// boundary between the L2 and L1 regimes), τ found by binary search
    /// so the expected kept count is ≈ `budget`.
    pub fn for_budget(a: &Csr, budget: u64) -> Am07Config {
        let nnz = a.nnz().max(1);
        let mean_sq: f64 =
            a.values.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / nnz as f64;
        let theta = mean_sq.sqrt();
        if budget as f64 >= nnz as f64 {
            // keep-everything intensity
            return Am07Config { tau: f64::INFINITY, theta };
        }
        let mut lo = 0.0f64;
        let mut hi = 1.0 / mean_sq;
        // grow hi until expected count exceeds budget (or saturates)
        for _ in 0..200 {
            let cfg = Am07Config { tau: hi, theta };
            if cfg.expected_nnz(a) >= budget as f64 {
                break;
            }
            hi *= 2.0;
        }
        for _ in 0..100 {
            let mid = 0.5 * (lo + hi);
            let cfg = Am07Config { tau: mid, theta };
            if cfg.expected_nnz(a) < budget as f64 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Am07Config { tau: 0.5 * (lo + hi), theta }
    }
}

/// Produce the AM07 sketch (independent coins, entries rescaled by 1/p).
pub fn am07_sketch(a: &Csr, cfg: &Am07Config, seed: u64) -> Coo {
    let mut rng = Rng::new(seed ^ 0xA407);
    let mut out = Coo::new(a.m, a.n);
    for i in 0..a.m {
        for (j, v) in a.row(i) {
            let p = cfg.keep_prob(v);
            if p >= 1.0 {
                out.push(i as u32, j, v);
            } else if p > 0.0 && rng.bernoulli(p) {
                out.push(i as u32, j, (v as f64 / p) as f32);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Entry};

    fn toy() -> Csr {
        let mut entries = Vec::new();
        let mut rng = Rng::new(5);
        for i in 0..20u32 {
            for j in 0..50u32 {
                entries.push(Entry::new(i, j, (rng.normal() as f32) * (1.0 + i as f32 * 0.2)));
            }
        }
        Coo::from_entries(20, 50, entries).unwrap().to_csr()
    }

    #[test]
    fn budget_match() {
        let a = toy();
        for budget in [50u64, 200, 600] {
            let cfg = Am07Config::for_budget(&a, budget);
            let e = cfg.expected_nnz(&a);
            assert!((e - budget as f64).abs() / (budget as f64) < 0.02, "{budget}: {e}");
        }
    }

    #[test]
    fn infinite_tau_keeps_all() {
        let a = toy();
        let cfg = Am07Config::for_budget(&a, 10_000_000);
        let b = am07_sketch(&a, &cfg, 0);
        assert_eq!(b.nnz(), a.nnz());
    }

    #[test]
    fn unbiased() {
        let a = toy();
        let cfg = Am07Config::for_budget(&a, 300);
        let trials = 800;
        let target = {
            let coo = a.to_coo();
            coo.entries[7]
        };
        let mut acc = 0.0f64;
        for t in 0..trials {
            let b = am07_sketch(&a, &cfg, t);
            for e in &b.entries {
                if e.row == target.row && e.col == target.col {
                    acc += e.val as f64;
                }
            }
        }
        let mean = acc / trials as f64;
        assert!(
            (mean - target.val as f64).abs() < 0.2 + 0.2 * target.val.abs() as f64,
            "mean={mean} want={}",
            target.val
        );
    }

    #[test]
    fn small_entries_use_l1_weighting() {
        // a tiny entry's keep probability should be linear in |v|, not v²
        let cfg = Am07Config { tau: 1.0, theta: 1.0 };
        let p_small = cfg.keep_prob(0.01);
        let p_half = cfg.keep_prob(0.005);
        assert!((p_small / p_half - 2.0).abs() < 1e-9, "linear regime");
        let p_big1 = cfg.keep_prob(0.9);
        let p_big2 = cfg.keep_prob(0.45);
        // hmm: 0.45 < theta=1 → also linear; use theta=0.1 instead
        let cfg2 = Am07Config { tau: 1.0, theta: 0.1 };
        let q1 = cfg2.keep_prob(0.8);
        let q2 = cfg2.keep_prob(0.4);
        assert!((q1 / q2 - 4.0).abs() < 1e-9, "quadratic regime");
        let _ = (p_big1, p_big2);
    }
}
