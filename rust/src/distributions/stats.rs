//! One-pass matrix statistics — everything a distribution needs to be
//! prepared, computable in a single stream over the non-zeros (or supplied
//! a priori, per §3 of the paper: only the *ratios* of row L1 norms matter
//! and rough estimates suffice).

use crate::sparse::{Coo, Csr, Entry};

/// Streaming-computable statistics of a data matrix.
#[derive(Clone, Debug)]
pub struct MatrixStats {
    /// Rows.
    pub m: usize,
    /// Columns.
    pub n: usize,
    /// Non-zero count.
    pub nnz: u64,
    /// Per-row L1 norms `‖A_(i)‖₁` (or proportional estimates).
    pub row_l1: Vec<f64>,
    /// Per-row sums of squares `Σⱼ a_ij²` (for L2-family shard planning).
    pub row_sq: Vec<f64>,
    /// `‖A‖₁ = Σ|a_ij|`.
    pub sum_abs: f64,
    /// `‖A‖_F² = Σ a_ij²`.
    pub sum_sq: f64,
    /// max |a_ij|.
    pub max_abs: f64,
}

impl MatrixStats {
    /// Empty accumulator for a matrix of known shape.
    pub fn new(m: usize, n: usize) -> MatrixStats {
        MatrixStats {
            m,
            n,
            nnz: 0,
            row_l1: vec![0.0; m],
            row_sq: vec![0.0; m],
            sum_abs: 0.0,
            sum_sq: 0.0,
            max_abs: 0.0,
        }
    }

    /// Fold one stream entry.
    #[inline]
    pub fn push(&mut self, e: &Entry) {
        let a = e.val.abs() as f64;
        self.nnz += 1;
        self.row_l1[e.row as usize] += a;
        self.row_sq[e.row as usize] += a * a;
        self.sum_abs += a;
        self.sum_sq += a * a;
        if a > self.max_abs {
            self.max_abs = a;
        }
    }

    /// Merge a shard's statistics (coordinate-wise sums / max).
    pub fn merge(&mut self, other: &MatrixStats) {
        assert_eq!(self.m, other.m);
        assert_eq!(self.n, other.n);
        self.nnz += other.nnz;
        self.sum_abs += other.sum_abs;
        self.sum_sq += other.sum_sq;
        self.max_abs = self.max_abs.max(other.max_abs);
        for (a, b) in self.row_l1.iter_mut().zip(other.row_l1.iter()) {
            *a += b;
        }
        for (a, b) in self.row_sq.iter_mut().zip(other.row_sq.iter()) {
            *a += b;
        }
    }

    /// One pass over a COO matrix.
    pub fn from_coo(coo: &Coo) -> MatrixStats {
        let mut st = MatrixStats::new(coo.m, coo.n);
        for e in &coo.entries {
            st.push(e);
        }
        st
    }

    /// One pass over a CSR matrix.
    pub fn from_csr(a: &Csr) -> MatrixStats {
        let mut st = MatrixStats::new(a.m, a.n);
        for i in 0..a.m {
            for (j, v) in a.row(i) {
                st.push(&Entry::new(i as u32, j, v));
            }
        }
        st
    }

    /// Replace exact row norms with noisy estimates (multiplicative noise
    /// `exp(σ·N(0,1))`) — models the paper's "rough a-priori estimates"
    /// mode; used by the robustness experiments.
    pub fn with_noisy_rows(mut self, sigma: f64, seed: u64) -> MatrixStats {
        let mut rng = crate::util::rng::Rng::new(seed);
        for z in self.row_l1.iter_mut() {
            if *z > 0.0 {
                *z *= (sigma * rng.normal()).exp();
            }
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    #[test]
    fn accumulates_correctly() {
        let coo = Coo::from_entries(
            2,
            2,
            vec![Entry::new(0, 0, 3.0), Entry::new(0, 1, -4.0), Entry::new(1, 1, 1.0)],
        )
        .unwrap();
        let st = MatrixStats::from_coo(&coo);
        assert_eq!(st.nnz, 3);
        assert_eq!(st.row_l1, vec![7.0, 1.0]);
        assert_eq!(st.sum_abs, 8.0);
        assert_eq!(st.sum_sq, 26.0);
        assert_eq!(st.max_abs, 4.0);
    }

    #[test]
    fn merge_equals_single_pass() {
        let coo = Coo::from_entries(
            2,
            3,
            vec![Entry::new(0, 0, 1.0), Entry::new(1, 1, 2.0), Entry::new(1, 2, -3.0)],
        )
        .unwrap();
        let full = MatrixStats::from_coo(&coo);
        let mut a = MatrixStats::new(2, 3);
        let mut b = MatrixStats::new(2, 3);
        a.push(&coo.entries[0]);
        b.push(&coo.entries[1]);
        b.push(&coo.entries[2]);
        a.merge(&b);
        assert_eq!(a.nnz, full.nnz);
        assert_eq!(a.row_l1, full.row_l1);
        assert_eq!(a.sum_sq, full.sum_sq);
    }

    #[test]
    fn noisy_rows_keep_positivity() {
        let coo = Coo::from_entries(2, 2, vec![Entry::new(0, 0, 1.0), Entry::new(1, 1, 2.0)])
            .unwrap();
        let st = MatrixStats::from_coo(&coo).with_noisy_rows(0.5, 1);
        assert!(st.row_l1.iter().all(|&z| z > 0.0));
    }
}
