//! Entrywise sampling distributions — the paper's contribution
//! ([`bernstein`]) and every baseline in its §6 evaluation and §2/§4
//! related-work comparison.
//!
//! All i.i.d.-sampling distributions reduce to an *unnormalized entry
//! weight* `w_ij = rowscale(i) · |A_ij|^power · 1[|A_ij| > trim]`; the
//! reservoir/alias samplers normalize implicitly. [`ahk06`] is the one
//! non-i.i.d. baseline (deterministic keep + randomized rounding) and gets
//! its own sketcher.

pub mod ahk06;
pub mod am07;
pub mod bernstein;
pub mod stats;

pub use ahk06::{ahk06_sketch, Ahk06Config};
pub use am07::{am07_sketch, Am07Config};
pub use bernstein::compute_row_distribution;
pub use stats::MatrixStats;

use crate::error::{Error, Result};

/// Which sampling distribution to use.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DistributionKind {
    /// The paper's Algorithm-1 distribution: `p_ij = ρ_i·|A_ij|/‖A_(i)‖₁`
    /// with the Bernstein-optimal row distribution ρ.
    Bernstein,
    /// Row-L1: `p_ij ∝ |A_ij|·‖A_(i)‖₁` (the large-s limit of Bernstein).
    RowL1,
    /// Plain L1: `p_ij ∝ |A_ij|` (the small-s limit of Bernstein).
    L1,
    /// L2: `p_ij ∝ A_ij²` [AM07-style, untrimmed].
    L2,
    /// L2 with trimming: `p_ij ∝ A_ij²` when `A_ij² > θ·mean(A²)`, else 0.
    /// The paper's §6 uses θ = 0.1 and θ = 0.01.
    L2Trim(f64),
    /// DZ11: L2 sampling with deterministic truncation of entries below
    /// `ε/(2·√(numeric density))` of the RMS entry — strongest published
    /// L2 guarantee.  Parameter is ε.
    Dz11(f64),
}

impl DistributionKind {
    /// Display name used in reports/plots (matches the paper's legend).
    pub fn name(&self) -> String {
        match self {
            DistributionKind::Bernstein => "Bernstein".into(),
            DistributionKind::RowL1 => "Row-L1".into(),
            DistributionKind::L1 => "L1".into(),
            DistributionKind::L2 => "L2".into(),
            DistributionKind::L2Trim(t) => format!("L2 trim {t}"),
            DistributionKind::Dz11(e) => format!("DZ11 eps={e}"),
        }
    }

    /// The method set reproduced in Figure 1.
    pub fn figure1_set() -> Vec<DistributionKind> {
        vec![
            DistributionKind::Bernstein,
            DistributionKind::RowL1,
            DistributionKind::L1,
            DistributionKind::L2,
            DistributionKind::L2Trim(0.1),
            DistributionKind::L2Trim(0.01),
        ]
    }
}

/// A prepared entrywise distribution: maps `(row, value) → weight`.
#[derive(Clone, Debug)]
pub struct Distribution {
    /// Which distribution this is.
    pub kind: DistributionKind,
    /// Per-row multiplier.
    rowscale: Vec<f64>,
    /// Magnitude power: 1 (L1 family) or 2 (L2 family).
    power: u8,
    /// Entries with `|v| ≤ trim_abs` get weight zero.
    trim_abs: f64,
    /// The Bernstein row distribution ρ (only for `Bernstein`), kept for
    /// the sketch codec's per-row scale `‖A_(i)‖₁/(s·ρ_i)`.
    pub rho: Option<Vec<f64>>,
}

impl Distribution {
    /// Prepare a distribution from streaming-computable matrix statistics.
    ///
    /// * `stats` — one-pass row norms + global norms ([`MatrixStats`]).
    /// * `s` — sampling budget (Bernstein's ρ depends on it).
    /// * `delta` — failure probability (Bernstein's α, β depend on it).
    pub fn prepare(
        kind: DistributionKind,
        stats: &MatrixStats,
        s: u64,
        delta: f64,
    ) -> Result<Distribution> {
        if stats.nnz == 0 {
            return Err(Error::invalid("cannot sample an all-zero matrix"));
        }
        let m = stats.row_l1.len();
        let (rowscale, power, trim_abs, rho) = match kind {
            DistributionKind::Bernstein => {
                let rho = compute_row_distribution(&stats.row_l1, s, stats.n, delta)?;
                let scale: Vec<f64> = rho
                    .iter()
                    .zip(stats.row_l1.iter())
                    .map(|(&r, &z)| if z > 0.0 { r / z } else { 0.0 })
                    .collect();
                (scale, 1u8, 0.0, Some(rho))
            }
            DistributionKind::RowL1 => (stats.row_l1.clone(), 1, 0.0, None),
            DistributionKind::L1 => (vec![1.0; m], 1, 0.0, None),
            DistributionKind::L2 => (vec![1.0; m], 2, 0.0, None),
            DistributionKind::L2Trim(theta) => {
                // zero weight when A_ij² ≤ θ·E[A_ij²]
                let mean_sq = stats.sum_sq / stats.nnz as f64;
                (vec![1.0; m], 2, (theta * mean_sq).sqrt(), None)
            }
            DistributionKind::Dz11(eps) => {
                // truncate below (ε/2)·RMS — the DZ11 "discard small
                // entries deterministically" rule scaled to this matrix.
                let rms = (stats.sum_sq / stats.nnz as f64).sqrt();
                (vec![1.0; m], 2, 0.5 * eps * rms, None)
            }
        };
        Ok(Distribution { kind, rowscale, power, trim_abs, rho })
    }

    /// Unnormalized sampling weight of entry `(i, ·) = v`.
    #[inline]
    pub fn weight(&self, row: u32, v: f32) -> f64 {
        let a = v.abs() as f64;
        if a <= self.trim_abs {
            return 0.0;
        }
        let mag = if self.power == 1 { a } else { a * a };
        self.rowscale[row as usize] * mag
    }

    /// Exact per-row total weights `Σⱼ w_ij`, when derivable from the
    /// one-pass statistics alone: power-1 rows sum to `rowscale·‖A_(i)‖₁`,
    /// power-2 rows to `rowscale·Σa²`. Trimmed distributions return `None`
    /// (their row totals depend on which entries clear the threshold) and
    /// the pipeline falls back to full-budget workers.
    ///
    /// This powers the coordinator's shard-budget pre-split: with exact
    /// shard weights, each worker's reservoir runs at its multinomial
    /// share `s_w` instead of the full `s` — total work `O(s·log N)`
    /// independent of the worker count (see EXPERIMENTS.md §Perf).
    pub fn row_weight_totals(&self, stats: &MatrixStats) -> Option<Vec<f64>> {
        if self.trim_abs > 0.0 {
            return None;
        }
        let per_row = if self.power == 1 { &stats.row_l1 } else { &stats.row_sq };
        Some(
            self.rowscale
                .iter()
                .zip(per_row.iter())
                .map(|(&sc, &z)| sc * z)
                .collect(),
        )
    }

    /// Exact normalized probability table over the given entries
    /// (`(row, value)` pairs) — used by tests and the offline alias path.
    pub fn probabilities(&self, entries: &[(u32, f32)]) -> Vec<f64> {
        let w: Vec<f64> = entries.iter().map(|&(i, v)| self.weight(i, v)).collect();
        let total: f64 = w.iter().sum();
        if total <= 0.0 {
            return w;
        }
        w.into_iter().map(|x| x / total).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Entry};

    fn stats_of(coo: &Coo) -> MatrixStats {
        MatrixStats::from_coo(coo)
    }

    fn toy() -> Coo {
        Coo::from_entries(
            2,
            3,
            vec![
                Entry::new(0, 0, 3.0),
                Entry::new(0, 1, -1.0),
                Entry::new(1, 2, 2.0),
            ],
        )
        .unwrap()
    }

    #[test]
    fn l1_weights_proportional_to_abs() {
        let st = stats_of(&toy());
        let d = Distribution::prepare(DistributionKind::L1, &st, 100, 0.1).unwrap();
        let p = d.probabilities(&[(0, 3.0), (0, -1.0), (1, 2.0)]);
        assert!((p[0] - 0.5).abs() < 1e-12);
        assert!((p[1] - 1.0 / 6.0).abs() < 1e-12);
        assert!((p[2] - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn l2_weights_proportional_to_square() {
        let st = stats_of(&toy());
        let d = Distribution::prepare(DistributionKind::L2, &st, 100, 0.1).unwrap();
        let p = d.probabilities(&[(0, 3.0), (0, -1.0), (1, 2.0)]);
        assert!((p[0] - 9.0 / 14.0).abs() < 1e-12);
        assert!((p[2] - 4.0 / 14.0).abs() < 1e-12);
    }

    #[test]
    fn row_l1_scales_by_row_norm() {
        let st = stats_of(&toy()); // row norms: 4, 2
        let d = Distribution::prepare(DistributionKind::RowL1, &st, 100, 0.1).unwrap();
        // weights: 3*4, 1*4, 2*2 = 12, 4, 4
        let p = d.probabilities(&[(0, 3.0), (0, -1.0), (1, 2.0)]);
        assert!((p[0] - 0.6).abs() < 1e-12);
        assert!((p[1] - 0.2).abs() < 1e-12);
        assert!((p[2] - 0.2).abs() < 1e-12);
    }

    #[test]
    fn l2_trim_zeroes_small_entries() {
        let st = stats_of(&toy()); // mean square = 14/3
        let d = Distribution::prepare(DistributionKind::L2Trim(0.5), &st, 100, 0.1).unwrap();
        // threshold |v| = sqrt(0.5·14/3) ≈ 1.53: the -1.0 entry is trimmed
        assert_eq!(d.weight(0, -1.0), 0.0);
        assert!(d.weight(0, 3.0) > 0.0);
        assert!(d.weight(1, 2.0) > 0.0);
    }

    #[test]
    fn bernstein_probability_form_is_rho_times_intrarow() {
        // p_ij = ρ_i·|A_ij|/‖A_(i)‖₁ ⇒ within a row, proportional to |v|;
        // per-row mass equals ρ_i.
        let st = stats_of(&toy());
        let d = Distribution::prepare(DistributionKind::Bernstein, &st, 1000, 0.1).unwrap();
        let rho = d.rho.clone().unwrap();
        assert!((rho.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let p = d.probabilities(&[(0, 3.0), (0, -1.0), (1, 2.0)]);
        assert!((p[0] + p[1] - rho[0]).abs() < 1e-9);
        assert!((p[2] - rho[1]).abs() < 1e-9);
        assert!((p[0] / p[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn names_stable() {
        assert_eq!(DistributionKind::Bernstein.name(), "Bernstein");
        assert_eq!(DistributionKind::L2Trim(0.1).name(), "L2 trim 0.1");
        assert_eq!(DistributionKind::figure1_set().len(), 6);
    }
}
