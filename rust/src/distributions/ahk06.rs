//! The Arora–Hazan–Kale (RANDOM'06) sparsifier — the paper's non-i.i.d.
//! baseline.
//!
//! AHK06 keeps every entry with `|A_ij| ≥ ε/√n` **deterministically** and
//! randomly rounds each smaller entry to `sign(A_ij)·ε/√n` with probability
//! `|A_ij|·√n/ε` (else 0) — an unbiased estimator with bounded entries.
//! The threshold ε must be known a priori; [`Ahk06Config::for_budget`]
//! binary-searches ε so the *expected* number of kept entries matches a
//! sample budget `s`, making it comparable to the i.i.d. methods.

use crate::sparse::{Coo, Csr};
use crate::util::rng::Rng;

/// AHK06 parameters.
#[derive(Clone, Copy, Debug)]
pub struct Ahk06Config {
    /// The rounding threshold ε (entries ≥ ε/√n are kept exactly).
    pub epsilon: f64,
}

impl Ahk06Config {
    /// Expected number of non-zeros the sketch will keep at this ε.
    pub fn expected_nnz(&self, a: &Csr) -> f64 {
        let cut = self.epsilon / (a.n as f64).sqrt();
        if cut <= 0.0 {
            return a.nnz() as f64;
        }
        a.values
            .iter()
            .map(|v| {
                let x = v.abs() as f64;
                if x >= cut {
                    1.0
                } else {
                    x / cut
                }
            })
            .sum()
    }

    /// Choose ε so that `expected_nnz ≈ budget` (monotone in ε ⇒ binary
    /// search). A `budget ≥ nnz(A)` returns ε = 0 (keep everything).
    pub fn for_budget(a: &Csr, budget: u64) -> Ahk06Config {
        if budget as f64 >= a.nnz() as f64 {
            return Ahk06Config { epsilon: 0.0 };
        }
        let max_abs = a.values.iter().fold(0.0f64, |acc, v| acc.max(v.abs() as f64));
        let mut lo = 0.0f64;
        let mut hi = max_abs * (a.n as f64).sqrt() * 2.0;
        for _ in 0..100 {
            let mid = 0.5 * (lo + hi);
            let cfg = Ahk06Config { epsilon: mid };
            if cfg.expected_nnz(a) > budget as f64 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Ahk06Config { epsilon: 0.5 * (lo + hi) }
    }
}

/// Produce the AHK06 sketch of `a`.
pub fn ahk06_sketch(a: &Csr, cfg: &Ahk06Config, seed: u64) -> Coo {
    let mut rng = Rng::new(seed);
    let cut = (cfg.epsilon / (a.n as f64).sqrt()) as f32;
    let mut out = Coo::new(a.m, a.n);
    for i in 0..a.m {
        for (j, v) in a.row(i) {
            if cut <= 0.0 || v.abs() >= cut {
                out.push(i as u32, j, v);
            } else {
                let p = (v.abs() / cut) as f64;
                if rng.bernoulli(p) {
                    out.push(i as u32, j, v.signum() * cut);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Entry};

    fn toy(n_small: usize) -> Csr {
        // one big entry + many small ones
        let mut entries = vec![Entry::new(0, 0, 100.0)];
        for j in 0..n_small {
            entries.push(Entry::new(1, j as u32, 0.01));
        }
        Coo::from_entries(2, n_small.max(1), entries).unwrap().to_csr()
    }

    #[test]
    fn zero_epsilon_keeps_everything() {
        let a = toy(50);
        let b = ahk06_sketch(&a, &Ahk06Config { epsilon: 0.0 }, 0);
        assert_eq!(b.nnz(), a.nnz());
    }

    #[test]
    fn budget_search_hits_target() {
        let a = toy(5_000);
        for budget in [100u64, 1_000, 3_000] {
            let cfg = Ahk06Config::for_budget(&a, budget);
            let expect = cfg.expected_nnz(&a);
            assert!(
                (expect - budget as f64).abs() / budget as f64 <= 0.02,
                "budget {budget}: expected {expect}"
            );
        }
    }

    #[test]
    fn sketch_is_unbiased() {
        // mean of many sketches approximates A entrywise
        let a = toy(200);
        let cfg = Ahk06Config::for_budget(&a, 100);
        let trials = 600;
        let mut sum_small = 0.0f64;
        for t in 0..trials {
            let b = ahk06_sketch(&a, &cfg, t as u64);
            for e in &b.entries {
                if e.row == 1 && e.col == 0 {
                    sum_small += e.val as f64;
                }
            }
        }
        let mean = sum_small / trials as f64;
        assert!((mean - 0.01).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn large_entries_kept_exactly() {
        let a = toy(1_000);
        let cfg = Ahk06Config::for_budget(&a, 200);
        let b = ahk06_sketch(&a, &cfg, 3);
        let big = b.entries.iter().find(|e| e.row == 0 && e.col == 0).unwrap();
        assert_eq!(big.val, 100.0);
    }

    #[test]
    fn kept_count_concentrates_near_budget() {
        let a = toy(5_000);
        let cfg = Ahk06Config::for_budget(&a, 1_000);
        let b = ahk06_sketch(&a, &cfg, 11);
        let got = b.nnz() as f64;
        assert!((got - 1_000.0).abs() < 150.0, "kept {got}");
    }
}
