//! Matrix metrics (§4 of the paper) and the Figure-1 quality harness.

pub mod quality;

pub use quality::{quality_left, quality_right, QualityReport};

use crate::distributions::MatrixStats;
use crate::linalg::spectral_norm;
use crate::sparse::Csr;

/// The §6 characteristics table row: norms and the derived metrics
/// (stable rank, numeric density, numeric row density) plus the
/// Definition-4.1 data-matrix conditions.
#[derive(Clone, Debug)]
pub struct MatrixMetrics {
    /// Rows.
    pub m: usize,
    /// Columns.
    pub n: usize,
    /// Non-zeros.
    pub nnz: u64,
    /// `‖A‖₁`.
    pub norm_l1: f64,
    /// `‖A‖_F`.
    pub norm_fro: f64,
    /// `‖A‖₂` (power-iteration estimate).
    pub norm_spec: f64,
    /// Stable rank `‖A‖_F²/‖A‖₂²`.
    pub stable_rank: f64,
    /// Numeric density `‖A‖₁²/‖A‖_F²`.
    pub numeric_density: f64,
    /// Numeric row density `Σᵢ‖A_(i)‖₁²/‖A‖_F²`.
    pub numeric_row_density: f64,
    /// Definition 4.1 condition 1: `minᵢ‖A_(i)‖₁ ≥ maxⱼ‖A^(j)‖₁`
    /// (over non-empty rows).
    pub cond1: bool,
    /// Condition 2: `‖A‖₁²/‖A‖₂² ≥ 50m`.
    pub cond2: bool,
    /// Condition 3: `m ≥ 50`.
    pub cond3: bool,
}

impl MatrixMetrics {
    /// Compute all metrics (one stats pass + a power iteration).
    pub fn compute(a: &Csr, power_iters: usize, seed: u64) -> MatrixMetrics {
        let stats = MatrixStats::from_csr(a);
        let norm_spec = spectral_norm(a, power_iters, seed);
        Self::from_parts(a, &stats, norm_spec)
    }

    /// Compute from precomputed statistics and spectral norm.
    pub fn from_parts(a: &Csr, stats: &MatrixStats, norm_spec: f64) -> MatrixMetrics {
        let norm_fro = stats.sum_sq.sqrt();
        let row_sq: f64 = stats.row_l1.iter().map(|z| z * z).sum();
        let col_norms = a.to_coo().col_l1_norms();
        let max_col = col_norms.into_iter().fold(0.0f64, f64::max);
        let min_row = stats
            .row_l1
            .iter()
            .filter(|&&z| z > 0.0)
            .fold(f64::INFINITY, |acc, &z| acc.min(z));
        MatrixMetrics {
            m: stats.m,
            n: stats.n,
            nnz: stats.nnz,
            norm_l1: stats.sum_abs,
            norm_fro,
            norm_spec,
            stable_rank: stats.sum_sq / (norm_spec * norm_spec),
            numeric_density: stats.sum_abs * stats.sum_abs / stats.sum_sq,
            numeric_row_density: row_sq / stats.sum_sq,
            cond1: min_row >= max_col,
            cond2: stats.sum_abs * stats.sum_abs / (norm_spec * norm_spec)
                >= 50.0 * stats.m as f64,
            cond3: stats.m >= 50,
        }
    }

    /// The theoretical sample bound `s₀` of Theorem 4.4 (up to constants):
    /// `nrd·sr/ε²·log(n/δ) + √(sr·nd/ε²·log(n/δ))`.
    pub fn theorem44_s0(&self, eps: f64, delta: f64) -> f64 {
        let log = ((self.n as f64) / delta).ln();
        let sr = self.stable_rank;
        self.numeric_row_density * sr / (eps * eps) * log
            + (sr * self.numeric_density / (eps * eps) * log).sqrt()
    }

    /// Sample bounds of the prior works in the §4 comparison table.
    /// Returns (AM07, DZ11, AHK06) up to constants.
    pub fn prior_bounds(&self, eps: f64) -> (f64, f64, f64) {
        let n = self.n as f64;
        let logn = n.ln();
        let am07 = self.stable_rank * n / (eps * eps) + n * logn * logn;
        let dz11 = self.stable_rank * n / (eps * eps) * logn;
        let ahk06 = (self.numeric_density * n).sqrt() / eps;
        (am07, dz11, ahk06)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Entry};

    #[test]
    fn identity_matrix_metrics() {
        let mut coo = Coo::new(64, 64);
        for i in 0..64u32 {
            coo.push(i, i, 1.0);
        }
        let m = MatrixMetrics::compute(&coo.to_csr(), 80, 0);
        assert!((m.norm_l1 - 64.0).abs() < 1e-9);
        assert!((m.norm_fro - 8.0).abs() < 1e-9);
        assert!((m.norm_spec - 1.0).abs() < 1e-3);
        assert!((m.stable_rank - 64.0).abs() < 0.5);
        assert!((m.numeric_density - 64.0).abs() < 1e-6);
        assert!((m.numeric_row_density - 1.0).abs() < 1e-9);
        assert!(m.cond1); // every row/col norm is 1
        assert!(m.cond2); // 64² / 1 = 4096 ≥ 3200
        assert!(m.cond3);
    }

    #[test]
    fn rank_one_stable_rank_one() {
        let mut coo = Coo::new(50, 100);
        for i in 0..50u32 {
            for j in 0..100u32 {
                coo.push(i, j, 2.0);
            }
        }
        let m = MatrixMetrics::compute(&coo.to_csr(), 60, 1);
        assert!((m.stable_rank - 1.0).abs() < 1e-3, "sr={}", m.stable_rank);
    }

    #[test]
    fn cond1_fails_for_column_matrix() {
        // one dense column: column norm dwarfs row norms
        let mut coo = Coo::new(60, 60);
        for i in 0..60u32 {
            coo.push(i, 0, 1.0);
        }
        coo.push(0, 1, 0.1);
        let m = MatrixMetrics::compute(&coo.to_csr(), 40, 2);
        assert!(!m.cond1);
    }

    #[test]
    fn theorem44_bound_decreases_with_eps() {
        let coo = Coo::from_entries(
            60,
            600,
            (0..60)
                .flat_map(|i| (0..10).map(move |j| Entry::new(i, i * 10 + j, 1.0)))
                .collect(),
        )
        .unwrap();
        let m = MatrixMetrics::compute(&coo.to_csr(), 40, 3);
        assert!(m.theorem44_s0(0.1, 0.1) > m.theorem44_s0(0.5, 0.1));
        let (am07, dz11, ahk06) = m.prior_bounds(0.1);
        assert!(am07 > 0.0 && dz11 > 0.0 && ahk06 > 0.0);
    }
}
