//! The Figure-1 quality measure.
//!
//! For a sketch `B` of `A` and rank `k`:
//!
//! * **left**  — `‖P_k^B A‖_F / ‖A_k‖_F` where `P_k^B` projects onto the
//!   top-k *left* singular vectors of `B`;
//! * **right** — `‖A Q_k^B‖_F / ‖A_k‖_F` where `Q_k^B` projects onto the
//!   top-k *right* singular vectors of `B`.
//!
//! `‖P A‖_F² = ‖UᵀA‖_F²` accumulates column-block-wise through the
//! engine's `proj` op (the Pallas kernel on the XLA path), which is the
//! FLOP-heavy part of reproducing Figure 1.

use crate::error::Result;
use crate::linalg::svd::SvdResult;
use crate::runtime::DenseEngine;
use crate::sparse::{Csr, Dense};

/// One (method, s) measurement for Figure 1.
#[derive(Clone, Debug)]
pub struct QualityReport {
    /// `‖P_k^B A‖_F / ‖A_k‖_F` — column-space capture.
    pub left: f64,
    /// `‖A Q_k^B‖_F / ‖A_k‖_F` — row-space capture.
    pub right: f64,
}

/// `‖UᵀA‖_F` for an orthonormal `m×k` basis `U`, streaming dense blocks
/// of `A` (CSR) through the engine's `proj` op.
pub fn proj_fro_left(
    a: &Csr,
    u: &Dense,
    engine: &dyn DenseEngine,
    col_block: usize,
) -> Result<f64> {
    assert_eq!(u.rows, a.m);
    let mut acc = 0.0f64;
    let mut c0 = 0usize;
    while c0 < a.n {
        let cw = col_block.min(a.n - c0);
        let blk = a.dense_block(0, a.m, c0, cw);
        let p = engine.proj(u, &blk)?;
        acc += p.norm_fro_sq();
        c0 += cw;
    }
    Ok(acc.sqrt())
}

/// `‖A V‖_F` for an orthonormal `n×k` basis `V`: `A·V` via sparse SpMM.
pub fn proj_fro_right(a: &Csr, v: &Dense) -> f64 {
    assert_eq!(v.rows, a.n);
    a.spmm(v).norm_fro()
}

/// Left quality `‖P_k^B A‖_F / ‖A_k‖_F`.
///
/// * `a` — original matrix; `b_svd` — top-≥k SVD of the sketch;
/// * `a_k_fro` — `‖A_k‖_F` from the SVD of `A` itself;
/// * `k` — evaluation rank (the paper uses 20).
pub fn quality_left(
    a: &Csr,
    b_svd: &SvdResult,
    a_k_fro: f64,
    k: usize,
    engine: &dyn DenseEngine,
) -> Result<f64> {
    let k = k.min(b_svd.sigma.len());
    let u = truncate_cols(&b_svd.u, k);
    Ok(proj_fro_left(a, &u, engine, 512)? / a_k_fro)
}

/// Right quality `‖A Q_k^B‖_F / ‖A_k‖_F`.
pub fn quality_right(a: &Csr, b_svd: &SvdResult, a_k_fro: f64, k: usize) -> Result<f64> {
    let k = k.min(b_svd.sigma.len());
    let v = truncate_cols(&b_svd.v, k);
    Ok(proj_fro_right(a, &v) / a_k_fro)
}

/// Keep the first `k` columns of a row-major dense matrix.
pub fn truncate_cols(x: &Dense, k: usize) -> Dense {
    assert!(k <= x.cols);
    let mut out = Dense::zeros(x.rows, k);
    for i in 0..x.rows {
        out.row_mut(i).copy_from_slice(&x.row(i)[..k]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::{rank_k_fro, topk_svd};
    use crate::runtime::RustEngine;
    use crate::sparse::Coo;
    use crate::util::rng::Rng;

    fn random_sparse(m: usize, n: usize, per_row: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(m, n);
        for i in 0..m as u32 {
            for _ in 0..per_row {
                coo.push(i, rng.usize_below(n) as u32, rng.normal() as f32);
            }
        }
        coo.normalize();
        coo.to_csr()
    }

    #[test]
    fn self_sketch_reaches_one() {
        // B = A ⇒ both quality ratios are 1 (up to SVD accuracy).
        let a = random_sparse(40, 160, 25, 0);
        let engine = RustEngine;
        let k = 8;
        let svd_a = topk_svd(&a, k + 4, 12, 1, &engine).unwrap();
        let a_k = rank_k_fro(&svd_a, k);
        let left = quality_left(&a, &svd_a, a_k, k, &engine).unwrap();
        let right = quality_right(&a, &svd_a, a_k, k).unwrap();
        assert!((left - 1.0).abs() < 0.02, "left={left}");
        assert!((right - 1.0).abs() < 0.02, "right={right}");
    }

    #[test]
    fn random_basis_scores_below_true_basis() {
        let a = random_sparse(50, 300, 30, 2);
        let engine = RustEngine;
        let k = 6;
        let svd_a = topk_svd(&a, k, 10, 3, &engine).unwrap();
        let a_k = rank_k_fro(&svd_a, k);
        // random orthonormal basis as a fake "sketch SVD"
        let mut rng = Rng::new(4);
        let ur = crate::linalg::svd::orthonormalize(
            &Dense::randn(a.m, k, &mut rng),
            &engine,
        )
        .unwrap();
        let vr = crate::linalg::svd::orthonormalize(
            &Dense::randn(a.n, k, &mut rng),
            &engine,
        )
        .unwrap();
        let fake = crate::linalg::svd::SvdResult { u: ur, sigma: vec![1.0; k], v: vr };
        let left_fake = quality_left(&a, &fake, a_k, k, &engine).unwrap();
        let left_true = quality_left(&a, &svd_a, a_k, k, &engine).unwrap();
        assert!(left_fake < left_true, "{left_fake} !< {left_true}");
        let right_fake = quality_right(&a, &fake, a_k, k).unwrap();
        assert!(right_fake < 0.9 * left_true);
    }

    #[test]
    fn left_proj_matches_direct_computation() {
        let a = random_sparse(30, 90, 15, 5);
        let engine = RustEngine;
        let svd = topk_svd(&a, 5, 10, 6, &engine).unwrap();
        let u = truncate_cols(&svd.u, 5);
        let via_engine = proj_fro_left(&a, &u, &engine, 37).unwrap(); // odd block size
        // direct: ‖UᵀA‖_F via dense block of the whole matrix
        let full = a.dense_block(0, a.m, 0, a.n);
        let p = crate::linalg::dense_ops::proj(&u, &full);
        assert!((via_engine - p.norm_fro()).abs() < 1e-3);
    }
}
