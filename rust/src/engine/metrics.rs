//! Engine observability: per-run counters the benches and the CLI report.
//!
//! One [`PipelineMetrics`] is produced by every [`super::Sketcher`]
//! finalization, whatever the mode — single-threaded sketchers simply
//! leave the shard-specific counters at their idle values.

use std::time::Duration;

/// Buckets in the per-shard spill-depth histogram: bucket 0 is depth 0
/// (batch went straight to the channel), bucket `i ≥ 1` covers depths
/// `[2^(i-1), 2^i)`, and the last bucket is open-ended.
pub const SPILL_DEPTH_BUCKETS: usize = 8;

/// Human labels for the histogram buckets, index-aligned.
pub const SPILL_DEPTH_LABELS: [&str; SPILL_DEPTH_BUCKETS] =
    ["0", "1", "2-3", "4-7", "8-15", "16-31", "32-63", "64+"];

/// Histogram bucket for an observed spill-queue depth.
#[inline]
pub fn spill_depth_bucket(depth: usize) -> usize {
    if depth == 0 {
        0
    } else {
        let b = (usize::BITS - depth.leading_zeros()) as usize; // floor(log2)+1
        b.min(SPILL_DEPTH_BUCKETS - 1)
    }
}

/// Counters collected by one sketcher run.
#[derive(Clone, Debug, Default)]
pub struct PipelineMetrics {
    /// Non-zeros ingested from the stream.
    pub ingested: u64,
    /// Entries whose distribution weight was zero (trimmed) and skipped.
    pub skipped_zero_weight: u64,
    /// Worker count used (1 for the single-threaded modes).
    pub workers: usize,
    /// Total leader wall time.
    pub wall: Duration,
    /// Time the leader spent blocked on full channels (sampled).
    pub backpressure_wait: Duration,
    /// Per-shard histograms of the leader-side spill-queue depth observed
    /// at each send (index = shard id; see [`spill_depth_bucket`]). Empty
    /// for single-threaded modes. This is the tuning signal for
    /// `spill_cap` / `channel_cap`: persistent mass in the high buckets
    /// means a shard's worker can't keep up with the leader.
    pub spill_depth_hist: Vec<[u64; SPILL_DEPTH_BUCKETS]>,
    /// Sum of forward-sketch lengths across shards (Theorem 4.2 metric);
    /// distinct drawn coordinates for the offline mode.
    pub sketch_records: u64,
    /// Total reservoir samples before merge (`workers · s`).
    pub pre_merge_samples: u64,
    /// Final sample count (= s).
    pub merged_samples: u64,
}

impl PipelineMetrics {
    /// Ingest throughput in entries/second.
    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.ingested as f64 / self.wall.as_secs_f64()
        }
    }

    /// Spill-depth histogram aggregated across shards.
    pub fn spill_depth_total(&self) -> [u64; SPILL_DEPTH_BUCKETS] {
        let mut out = [0u64; SPILL_DEPTH_BUCKETS];
        for shard in &self.spill_depth_hist {
            for (o, &c) in out.iter_mut().zip(shard.iter()) {
                *o += c;
            }
        }
        out
    }

    /// Fraction of sends that found a non-empty spill queue (0 when the
    /// histogram is empty, i.e. a single-threaded mode).
    pub fn spill_nonzero_fraction(&self) -> f64 {
        let total = self.spill_depth_total();
        let all: u64 = total.iter().sum();
        if all == 0 {
            0.0
        } else {
            (all - total[0]) as f64 / all as f64
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} nnz in {:.3}s ({:.2}M nnz/s), {} workers, {} sketch records, backpressure {:.3}s",
            self.ingested,
            self.wall.as_secs_f64(),
            self.throughput() / 1e6,
            self.workers,
            self.sketch_records,
            self.backpressure_wait.as_secs_f64(),
        );
        if !self.spill_depth_hist.is_empty() {
            s.push_str(&format!(
                ", spill depth >0 on {:.1}% of sends",
                self.spill_nonzero_fraction() * 100.0
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_computation() {
        let m = PipelineMetrics {
            ingested: 1_000_000,
            wall: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((m.throughput() - 500_000.0).abs() < 1.0);
        assert!(m.summary().contains("workers"));
    }

    #[test]
    fn zero_wall_safe() {
        let m = PipelineMetrics::default();
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.spill_nonzero_fraction(), 0.0);
        assert!(!m.summary().contains("spill depth"));
    }

    #[test]
    fn spill_buckets_cover_powers_of_two() {
        assert_eq!(spill_depth_bucket(0), 0);
        assert_eq!(spill_depth_bucket(1), 1);
        assert_eq!(spill_depth_bucket(2), 2);
        assert_eq!(spill_depth_bucket(3), 2);
        assert_eq!(spill_depth_bucket(4), 3);
        assert_eq!(spill_depth_bucket(7), 3);
        assert_eq!(spill_depth_bucket(8), 4);
        assert_eq!(spill_depth_bucket(63), 6);
        assert_eq!(spill_depth_bucket(64), 7);
        assert_eq!(spill_depth_bucket(1_000_000), 7);
        assert_eq!(SPILL_DEPTH_LABELS.len(), SPILL_DEPTH_BUCKETS);
    }

    #[test]
    fn spill_aggregation_across_shards() {
        let mut m = PipelineMetrics::default();
        let mut h0 = [0u64; SPILL_DEPTH_BUCKETS];
        h0[0] = 90;
        h0[2] = 10;
        let mut h1 = [0u64; SPILL_DEPTH_BUCKETS];
        h1[0] = 100;
        m.spill_depth_hist = vec![h0, h1];
        let total = m.spill_depth_total();
        assert_eq!(total[0], 190);
        assert_eq!(total[2], 10);
        assert!((m.spill_nonzero_fraction() - 0.05).abs() < 1e-12);
        assert!(m.summary().contains("spill depth"));
    }
}
