//! Engine observability: per-run counters the benches and the CLI report.
//!
//! One [`PipelineMetrics`] is produced by every [`super::Sketcher`]
//! finalization, whatever the mode — single-threaded sketchers simply
//! leave the shard-specific counters at their idle values.

use std::time::Duration;

/// Counters collected by one sketcher run.
#[derive(Clone, Debug, Default)]
pub struct PipelineMetrics {
    /// Non-zeros ingested from the stream.
    pub ingested: u64,
    /// Entries whose distribution weight was zero (trimmed) and skipped.
    pub skipped_zero_weight: u64,
    /// Worker count used (1 for the single-threaded modes).
    pub workers: usize,
    /// Total leader wall time.
    pub wall: Duration,
    /// Time the leader spent blocked on full channels (sampled).
    pub backpressure_wait: Duration,
    /// Sum of forward-sketch lengths across shards (Theorem 4.2 metric);
    /// distinct drawn coordinates for the offline mode.
    pub sketch_records: u64,
    /// Total reservoir samples before merge (`workers · s`).
    pub pre_merge_samples: u64,
    /// Final sample count (= s).
    pub merged_samples: u64,
}

impl PipelineMetrics {
    /// Ingest throughput in entries/second.
    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.ingested as f64 / self.wall.as_secs_f64()
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} nnz in {:.3}s ({:.2}M nnz/s), {} workers, {} sketch records, backpressure {:.3}s",
            self.ingested,
            self.wall.as_secs_f64(),
            self.throughput() / 1e6,
            self.workers,
            self.sketch_records,
            self.backpressure_wait.as_secs_f64(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_computation() {
        let m = PipelineMetrics {
            ingested: 1_000_000,
            wall: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((m.throughput() - 500_000.0).abs() < 1.0);
        assert!(m.summary().contains("workers"));
    }

    #[test]
    fn zero_wall_safe() {
        let m = PipelineMetrics::default();
        assert_eq!(m.throughput(), 0.0);
    }
}
