//! Disk-spilling streaming sketching behind the [`Sketcher`] trait.
//!
//! One Appendix-A reservoir whose forward sketch lives on durable storage
//! ([`crate::samplers::SpillingReservoir`]): O(1) work per non-zero and
//! only O(log s) *active memory*, so budgets where the `s·log(bN)`
//! forward-sketch records exceed RAM still finalize. The sampling law is
//! identical to [`super::ReservoirSketcher`] — only the sketch's home
//! (disk vs heap) differs — so it participates in the cross-mode
//! budget-equality tests like every other mode.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::samplers::{SpillItem, SpillingReservoir};
use crate::sketch::{Sketch, SketchEntry};
use crate::sparse::Entry;

use super::metrics::PipelineMetrics;
use super::{EngineContext, SketchMode, Sketcher};

/// Distinguishes concurrent spilling runs (tests, parallel sketchers)
/// inside one process; combined with the pid for cross-process safety.
static SPILL_RUN: AtomicU64 = AtomicU64::new(0);

/// A private scratch directory removed recursively on drop, so the spill
/// file never outlives its run — success, error, or an abandoned
/// (never-finalized) sketcher alike.
struct ScratchDir(PathBuf);

impl Drop for ScratchDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// The disk-spilling [`Sketcher`].
pub struct SpillingSketcher {
    ctx: EngineContext,
    // field order matters: `res` (and its open file handle) must drop
    // before `dir` removes the directory
    res: SpillingReservoir,
    dir: ScratchDir,
    total_weight: f64,
    ingested: u64,
    skipped: u64,
    t0: Instant,
}

impl SpillingSketcher {
    /// Create with a unique scratch directory under `spill_dir`.
    pub(crate) fn new(ctx: EngineContext, spill_dir: &Path) -> Result<SpillingSketcher> {
        let run = SPILL_RUN.fetch_add(1, Ordering::Relaxed);
        let dir = ScratchDir(spill_dir.join(format!("run-{}-{run}", std::process::id())));
        let res = SpillingReservoir::create(&dir.0, ctx.plan.s, ctx.plan.seed ^ 0x5350_494C)?;
        Ok(SpillingSketcher {
            ctx,
            res,
            dir,
            total_weight: 0.0,
            ingested: 0,
            skipped: 0,
            t0: Instant::now(),
        })
    }
}

impl Sketcher for SpillingSketcher {
    fn mode(&self) -> SketchMode {
        SketchMode::Spilling
    }

    fn ingest(&mut self, batch: &[Entry]) -> Result<()> {
        for e in batch {
            self.ctx.check_entry(e)?;
            self.ingested += 1;
            let w = self.ctx.dist.weight(e.row, e.val);
            if w > 0.0 {
                self.total_weight += w;
                self.res
                    .push(SpillItem { row: e.row, col: e.col, val: e.val }, w)?;
            } else {
                self.skipped += 1;
            }
        }
        Ok(())
    }

    fn finalize(self: Box<Self>) -> Result<(Sketch, PipelineMetrics)> {
        let SpillingSketcher { ctx, res, dir, total_weight, ingested, skipped, t0 } = *self;
        if total_weight <= 0.0 {
            return Err(Error::Pipeline("stream carried no positive-weight entries".into()));
        }
        let sketch_records = res.records();
        let s = ctx.plan.s;
        let samples = res.finalize()?;
        // the reservoir has consumed its file: remove the scratch dir now;
        // error paths and abandoned sketchers clean up via ScratchDir::drop
        drop(dir);
        let drawn: Vec<SketchEntry> = samples
            .iter()
            .map(|smp| {
                let it = smp.item;
                let w = ctx.dist.weight(it.row, it.val);
                let p = w / total_weight;
                SketchEntry {
                    row: it.row,
                    col: it.col,
                    count: smp.count as u32,
                    value: smp.count as f64 * it.val as f64 / (s as f64 * p),
                }
            })
            .collect();

        let mut metrics = PipelineMetrics {
            ingested,
            skipped_zero_weight: skipped,
            workers: 1,
            sketch_records,
            pre_merge_samples: samples.iter().map(|x| x.count).sum(),
            ..Default::default()
        };
        let sketch = ctx.assemble(drawn);
        metrics.merged_samples = sketch.entries.iter().map(|e| e.count as u64).sum();
        metrics.wall = t0.elapsed();
        Ok((sketch, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::{DistributionKind, MatrixStats};
    use crate::engine::{build_sketcher, PipelineConfig};
    use crate::sketch::SketchPlan;
    use crate::sparse::Coo;
    use crate::util::rng::Rng;

    fn toy(m: usize, n: usize, seed: u64) -> Coo {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(m, n);
        for i in 0..m as u32 {
            for _ in 0..10 {
                coo.push(i, rng.usize_below(n) as u32, rng.normal() as f32 + 2.0);
            }
        }
        coo.normalize();
        coo
    }

    #[test]
    fn spilling_mode_produces_budget_s() {
        let a = toy(8, 64, 1);
        let stats = MatrixStats::from_coo(&a);
        let plan = SketchPlan::new(DistributionKind::Bernstein, 300).with_seed(4);
        let cfg = PipelineConfig::default();
        let mut sk = build_sketcher(SketchMode::Spilling, &stats, &plan, &cfg).unwrap();
        assert_eq!(sk.mode(), SketchMode::Spilling);
        sk.ingest(&a.entries).unwrap();
        let (sketch, metrics) = sk.finalize().unwrap();
        assert_eq!(
            sketch.entries.iter().map(|e| e.count as u64).sum::<u64>(),
            300
        );
        assert_eq!(metrics.merged_samples, 300);
        assert_eq!(metrics.ingested, a.nnz() as u64);
        assert!(metrics.sketch_records > 0);
    }

    #[test]
    fn spilling_matches_streaming_sampling_frequencies() {
        // same law as the in-memory reservoir: per-row masses agree
        let a = toy(10, 80, 2);
        let stats = MatrixStats::from_coo(&a);
        let trials = 40u64;
        let s = 400u64;
        let mut mass = vec![[0.0f64; 2]; a.m];
        for t in 0..trials {
            for (which, mode) in [SketchMode::Streaming, SketchMode::Spilling]
                .into_iter()
                .enumerate()
            {
                let plan = SketchPlan::new(DistributionKind::L1, s).with_seed(900 + t);
                let mut sk = build_sketcher(mode, &stats, &plan, &PipelineConfig::default())
                    .unwrap();
                sk.ingest(&a.entries).unwrap();
                let (sketch, _) = sk.finalize().unwrap();
                for e in &sketch.entries {
                    mass[e.row as usize][which] += e.count as f64;
                }
            }
        }
        let total = (s * trials) as f64;
        for i in 0..a.m {
            let d = (mass[i][0] - mass[i][1]).abs() / total;
            assert!(d < 0.03, "row {i}: streaming {} vs spilling {}", mass[i][0], mass[i][1]);
        }
    }
}
