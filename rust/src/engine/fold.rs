//! Public fold entry point: compose independently sampled parts into
//! exactly `s` global i.i.d. draws.
//!
//! This is the deterministic seeded merge the sharded engine has always
//! used internally, promoted to a reusable API so callers outside the
//! engine (live delta folding, cross-machine partial merges, custom
//! sketch composition) can combine part outputs without reaching into
//! `pub(crate)` internals. Both paths are exact and deterministic given
//! the caller's RNG stream (parts are visited in slice order, so callers
//! must present them in a stable order — the engine sorts by shard id):
//!
//! * [`fold_presplit`] — the per-part budgets were drawn up front as
//!   `Multinomial(s, W_w/ΣW)` over a-priori part weights, so every part
//!   already holds exactly its share; the fold only rescales.
//! * [`fold_observed`] — part weights were unknown up front (trimmed
//!   distributions): every part sampled at the full budget `s`; the fold
//!   draws `Multinomial(s, W_w^obs/ΣW^obs)` over the observed weights and
//!   takes a uniformly random subset of each part's exchangeable samples
//!   via a multivariate-hypergeometric chain.
//!
//! [`fold_rng`] reproduces the engine's merge RNG stream for a plan seed,
//! so an external caller folding the same parts in the same order gets a
//! bit-identical result to `SketchMode::Sharded`'s finalize.

use crate::distributions::Distribution;
use crate::error::{Error, Result};
use crate::samplers::{hypergeometric, multinomial_counts, WeightedSample};
use crate::sketch::SketchEntry;
use crate::sparse::Entry;
use crate::util::rng::Rng;

/// A borrowed view over one independently sampled part — a worker shard,
/// a delta sketch, a remote partial: its exchangeable weighted samples
/// plus the total positive weight it observed.
pub struct FoldPart<'a> {
    /// Stable part id. Used in error messages, and as the index into the
    /// `counts`/`q` arrays when folding pre-split budgets.
    pub id: usize,
    /// The part's exchangeable weighted samples.
    pub samples: &'a [WeightedSample<Entry>],
    /// Total positive weight the part observed.
    pub total_weight: f64,
}

impl FoldPart<'_> {
    /// Number of draws this part holds (sum of per-sample counts).
    pub fn draws(&self) -> u64 {
        self.samples.iter().map(|x| x.count).sum()
    }
}

/// The engine's merge RNG stream for a plan seed. External callers that
/// want bit-identity with `SketchMode::Sharded` must fold with this RNG
/// and present parts in shard-id order.
pub fn fold_rng(plan_seed: u64) -> Rng {
    Rng::new(plan_seed ^ 0x4D45_5247)
}

/// Fold parts whose budgets were pre-split: the effective global sampling
/// probability of an entry in part `w` is `q_w · w_ij / W_w(observed)` —
/// exact even when the a-priori weights were rough estimates (§3 one-pass
/// mode).
///
/// `counts[part.id]` is the pre-split budget of each part; a part that
/// was assigned budget but observed no positive-weight entries (the
/// a-priori weights promised mass the stream never delivered) is an error
/// — silently dropping its share would break the exactly-`s`-draws
/// contract.
pub fn fold_presplit(
    parts: &[FoldPart<'_>],
    counts: &[u64],
    q: &[f64],
    dist: &Distribution,
    s: u64,
) -> Result<Vec<SketchEntry>> {
    let mut entries = Vec::new();
    for o in parts {
        let have = o.draws();
        if have != counts[o.id] {
            return Err(Error::Pipeline(format!(
                "part {} produced {have} of its pre-split {} samples — \
                 the stats assigned weight this stream never delivered",
                o.id, counts[o.id]
            )));
        }
        if o.total_weight <= 0.0 {
            continue; // an empty part with a zero budget is normal
        }
        let qw = q[o.id];
        for smp in o.samples {
            let e = smp.item;
            let w = dist.weight(e.row, e.val);
            let p = qw * w / o.total_weight;
            entries.push(SketchEntry {
                row: e.row,
                col: e.col,
                count: smp.count as u32,
                value: smp.count as f64 * e.val as f64 / (s as f64 * p),
            });
        }
    }
    Ok(entries)
}

/// Fold over *observed* part weights: multinomial split of `s`, then a
/// uniformly random subset (hypergeometric chain) of each part's
/// reservoir samples. `total_weight` is the global positive weight (the
/// sum over every part, including any the caller filtered out).
pub fn fold_observed(
    parts: &[FoldPart<'_>],
    rng: &mut Rng,
    dist: &Distribution,
    s: u64,
    total_weight: f64,
) -> Result<Vec<SketchEntry>> {
    let part_weights: Vec<f64> = parts.iter().map(|o| o.total_weight).collect();
    let take = multinomial_counts(rng, s, &part_weights);
    let mut entries = Vec::new();
    for (o, &need_total) in parts.iter().zip(take.iter()) {
        if need_total == 0 {
            continue;
        }
        let have = o.draws();
        if have < need_total {
            return Err(Error::Pipeline(format!(
                "part {} holds {have} samples, needs {need_total}",
                o.id
            )));
        }
        let mut pop = have;
        let mut need = need_total;
        for smp in o.samples {
            if need == 0 {
                break;
            }
            let t = hypergeometric(rng, pop, smp.count, need);
            pop -= smp.count;
            need -= t;
            if t > 0 {
                let e = smp.item;
                let w = dist.weight(e.row, e.val);
                let p = w / total_weight; // global probability
                entries.push(SketchEntry {
                    row: e.row,
                    col: e.col,
                    count: t as u32,
                    value: t as f64 * e.val as f64 / (s as f64 * p),
                });
            }
        }
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::{DistributionKind, MatrixStats};
    use crate::sparse::Coo;

    #[test]
    fn fold_rng_matches_engine_merge_stream() {
        // Same stream as Rng::new(seed ^ 0x4D45_5247) — the sharded
        // engine's merge RNG; pinned so external folds stay bit-identical.
        let mut a = fold_rng(123);
        let mut b = Rng::new(123 ^ 0x4D45_5247);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn observed_fold_is_identical_to_sharded_finalize() {
        // Folding the sharded engine's own parts through the public API
        // with fold_rng must reproduce its merge exactly.
        let coo = Coo::from_entries(
            2,
            3,
            vec![
                crate::sparse::Entry::new(0, 0, 3.0),
                crate::sparse::Entry::new(0, 1, 1.0),
                crate::sparse::Entry::new(1, 2, 2.0),
            ],
        )
        .unwrap();
        let stats = MatrixStats::from_coo(&coo);
        let dist = Distribution::prepare(DistributionKind::L1, &stats, 10, 0.1).unwrap();
        let samples_a = vec![
            WeightedSample { item: Entry::new(0, 0, 3.0), count: 7 },
            WeightedSample { item: Entry::new(0, 1, 1.0), count: 3 },
        ];
        let samples_b = vec![WeightedSample { item: Entry::new(1, 2, 2.0), count: 10 }];
        let parts = vec![
            FoldPart { id: 0, samples: &samples_a, total_weight: 4.0 },
            FoldPart { id: 1, samples: &samples_b, total_weight: 2.0 },
        ];
        let a = fold_observed(&parts, &mut fold_rng(99), &dist, 10, 6.0).unwrap();
        let b = fold_observed(&parts, &mut fold_rng(99), &dist, 10, 6.0).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.iter().map(|e| e.count as u64).sum::<u64>(), 10);
    }
}
