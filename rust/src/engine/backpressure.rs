//! Leader-side flow control for the sharded sketcher.
//!
//! Each shard channel is wrapped in a [`ShardSender`]: batches are
//! `try_send`-ed first; when the channel is full they park in a bounded
//! local spill queue (absorbing short worker stalls without blocking the
//! leader); once the spill bound is exceeded the leader performs a real
//! blocking `send`, which is the actual backpressure — the stream is read
//! no faster than the slowest worker drains. Per-shard FIFO order is
//! preserved (spilled batches always go out before newer ones), and a
//! disconnected worker (panic) is tolerated here and surfaced at join.

use std::collections::VecDeque;
use std::sync::mpsc::{SyncSender, TrySendError};
use std::time::{Duration, Instant};

use crate::sparse::Entry;

use super::metrics::{spill_depth_bucket, SPILL_DEPTH_BUCKETS};

/// What one shard's sender observed over its lifetime, reported at
/// [`ShardSender::finish`] and folded into
/// [`super::PipelineMetrics`].
pub(crate) struct SenderReport {
    /// Total time spent in blocking sends (real backpressure).
    pub blocked: Duration,
    /// Histogram of the spill-queue depth observed after each send.
    pub depth_hist: [u64; SPILL_DEPTH_BUCKETS],
}

/// A shard channel with bounded spill and blocking-send backpressure.
pub(crate) struct ShardSender {
    tx: SyncSender<Vec<Entry>>,
    spill: VecDeque<Vec<Entry>>,
    spill_cap: usize,
    blocked: Duration,
    depth_hist: [u64; SPILL_DEPTH_BUCKETS],
    disconnected: bool,
}

impl ShardSender {
    /// Wrap a channel; up to `spill_cap` batches park locally before the
    /// leader blocks.
    pub(crate) fn new(tx: SyncSender<Vec<Entry>>, spill_cap: usize) -> ShardSender {
        ShardSender {
            tx,
            spill: VecDeque::new(),
            spill_cap,
            blocked: Duration::ZERO,
            depth_hist: [0; SPILL_DEPTH_BUCKETS],
            disconnected: false,
        }
    }

    /// Move spilled batches into the channel while it has room.
    fn try_drain(&mut self) {
        while let Some(b) = self.spill.pop_front() {
            match self.tx.try_send(b) {
                Ok(()) => {}
                Err(TrySendError::Full(b)) => {
                    self.spill.push_front(b);
                    break;
                }
                Err(TrySendError::Disconnected(_)) => {
                    self.disconnected = true;
                    self.spill.clear();
                    break;
                }
            }
        }
    }

    /// Enqueue one batch, preserving per-shard FIFO order. Blocks only
    /// once the local spill bound is exhausted.
    pub(crate) fn send(&mut self, batch: Vec<Entry>) {
        if self.disconnected {
            return;
        }
        self.try_drain();
        if self.spill.is_empty() {
            match self.tx.try_send(batch) {
                Ok(()) => {
                    self.depth_hist[0] += 1;
                    return;
                }
                Err(TrySendError::Full(b)) => self.spill.push_back(b),
                Err(TrySendError::Disconnected(_)) => {
                    self.disconnected = true;
                    return;
                }
            }
        } else {
            self.spill.push_back(batch);
        }
        self.depth_hist[spill_depth_bucket(self.spill.len())] += 1;
        if self.spill.len() > self.spill_cap {
            // spill bound exceeded: real backpressure — block until the
            // worker drains one batch.
            let front = self.spill.pop_front().expect("spill non-empty");
            let t = Instant::now();
            if self.tx.send(front).is_err() {
                self.disconnected = true;
                self.spill.clear();
            }
            self.blocked += t.elapsed();
        }
    }

    /// Flush the remaining spill (blocking where needed), close the
    /// channel, and report what this sender observed.
    pub(crate) fn finish(mut self) -> SenderReport {
        while let Some(b) = self.spill.pop_front() {
            match self.tx.try_send(b) {
                Ok(()) => {}
                Err(TrySendError::Full(b)) => {
                    let t = Instant::now();
                    let ok = self.tx.send(b).is_ok();
                    self.blocked += t.elapsed();
                    if !ok {
                        break;
                    }
                }
                Err(TrySendError::Disconnected(_)) => break,
            }
        }
        SenderReport { blocked: self.blocked, depth_hist: self.depth_hist }
        // `self.tx` drops here, closing this shard's channel.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    fn batch(col: u32) -> Vec<Entry> {
        vec![Entry::new(0, col, 1.0)]
    }

    #[test]
    fn delivers_everything_in_order_through_a_slow_worker() {
        let (tx, rx) = sync_channel(1);
        let mut s = ShardSender::new(tx, 2);
        let consumer = std::thread::spawn(move || {
            let mut cols = Vec::new();
            for b in rx.iter() {
                for e in b {
                    cols.push(e.col);
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            cols
        });
        for i in 0..100u32 {
            s.send(batch(i));
        }
        let _blocked = s.finish();
        let cols = consumer.join().unwrap();
        assert_eq!(cols.len(), 100);
        assert!(cols.windows(2).all(|w| w[0] < w[1]), "order broken: {cols:?}");
    }

    #[test]
    fn blocks_only_past_the_spill_bound() {
        // capacity 1 + spill 4: five batches fit without a consumer...
        let (tx, rx) = sync_channel(1);
        let mut s = ShardSender::new(tx, 4);
        for i in 0..5u32 {
            s.send(batch(i));
        }
        assert!(s.blocked.is_zero(), "blocked early: {:?}", s.blocked);
        // ...and a consumer lets the spill drain at finish.
        let consumer = std::thread::spawn(move || rx.iter().count());
        let _ = s.finish();
        assert_eq!(consumer.join().unwrap(), 5);
    }

    #[test]
    fn depth_histogram_tracks_spill_occupancy() {
        // no consumer, capacity 1, spill 4: the first batch goes to the
        // channel (depth 0), the next ones pile into the spill queue.
        let (tx, rx) = sync_channel(1);
        let mut s = ShardSender::new(tx, 4);
        for i in 0..5u32 {
            s.send(batch(i));
        }
        assert_eq!(s.depth_hist[0], 1, "first send should find depth 0");
        let observed: u64 = s.depth_hist.iter().sum();
        assert_eq!(observed, 5, "every send observed once");
        assert!(s.depth_hist[1..].iter().sum::<u64>() >= 4);
        let consumer = std::thread::spawn(move || rx.iter().count());
        let report = s.finish();
        assert_eq!(report.depth_hist.iter().sum::<u64>(), 5);
        assert_eq!(consumer.join().unwrap(), 5);
    }

    #[test]
    fn disconnected_receiver_is_tolerated() {
        let (tx, rx) = sync_channel(1);
        drop(rx);
        let mut s = ShardSender::new(tx, 1);
        for i in 0..10u32 {
            s.send(batch(i));
        }
        let _ = s.finish(); // must not panic or hang
    }
}
