//! Offline (alias-table) sketching behind the [`Sketcher`] trait.
//!
//! Buffers the full entry set, then draws `s` i.i.d. entries from one
//! Vose alias table — O(nnz) setup, O(1) per draw. This is the
//! evaluation harness's reference path: exact sampling from the prepared
//! distribution with no streaming approximations to reason about.

use std::collections::HashMap;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::samplers::AliasTable;
use crate::sketch::{Sketch, SketchEntry};
use crate::sparse::Entry;
use crate::util::rng::Rng;

use super::metrics::PipelineMetrics;
use super::{EngineContext, SketchMode, Sketcher};

/// The offline [`Sketcher`]: buffer everything, finalize via alias table.
pub struct AliasSketcher {
    ctx: EngineContext,
    entries: Vec<Entry>,
    t0: Instant,
}

impl AliasSketcher {
    pub(crate) fn new(ctx: EngineContext) -> AliasSketcher {
        AliasSketcher { ctx, entries: Vec::new(), t0: Instant::now() }
    }
}

impl Sketcher for AliasSketcher {
    fn mode(&self) -> SketchMode {
        SketchMode::Offline
    }

    fn ingest(&mut self, batch: &[Entry]) -> Result<()> {
        for e in batch {
            self.ctx.check_entry(e)?;
            self.entries.push(*e);
        }
        Ok(())
    }

    fn finalize(self: Box<Self>) -> Result<(Sketch, PipelineMetrics)> {
        let AliasSketcher { ctx, entries, t0 } = *self;
        let mut weights: Vec<f64> = Vec::with_capacity(entries.len());
        let mut total_weight = 0.0f64;
        let mut skipped = 0u64;
        for e in &entries {
            let w = ctx.dist.weight(e.row, e.val);
            if w <= 0.0 {
                skipped += 1;
            }
            total_weight += w;
            weights.push(w);
        }
        if total_weight <= 0.0 {
            return Err(Error::invalid(format!(
                "{} assigns zero weight to every entry",
                ctx.plan.kind.name()
            )));
        }

        let table = AliasTable::new(&weights);
        let mut rng = Rng::new(ctx.plan.seed);
        let mut counts: HashMap<usize, u32> = Default::default();
        for _ in 0..ctx.plan.s {
            *counts.entry(table.sample(&mut rng)).or_default() += 1;
        }

        let s = ctx.plan.s;
        let drawn: Vec<SketchEntry> = counts
            .into_iter()
            .map(|(idx, count)| {
                let e = entries[idx];
                let p = weights[idx] / total_weight;
                SketchEntry {
                    row: e.row,
                    col: e.col,
                    count,
                    value: count as f64 * e.val as f64 / (s as f64 * p),
                }
            })
            .collect();

        let mut metrics = PipelineMetrics {
            ingested: entries.len() as u64,
            skipped_zero_weight: skipped,
            workers: 1,
            pre_merge_samples: s,
            ..Default::default()
        };
        let sketch = ctx.assemble(drawn);
        metrics.sketch_records = sketch.entries.len() as u64;
        metrics.merged_samples = sketch.entries.iter().map(|e| e.count as u64).sum();
        metrics.wall = t0.elapsed();
        Ok((sketch, metrics))
    }
}
