//! Shard-parallel sketching: leader-side routing, worker reservoirs, and
//! the [`ShardedSketcher`] composing them behind the [`Sketcher`] trait.
//!
//! A leader (whoever calls [`Sketcher::ingest`]) routes each non-zero to
//! one of `W` worker threads by a Fibonacci hash of its row id over
//! bounded channels (see [`super::backpressure`]). Each worker runs the
//! paper's Appendix-A [`ParallelReservoir`] with the entry weights of the
//! chosen distribution — O(1) work per non-zero (Theorem 4.2). Finalize
//! joins the workers and composes their samples into `s` exact global
//! i.i.d. draws (see [`super::merge`]).

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::distributions::MatrixStats;
use crate::error::{Error, Result};
use crate::samplers::{multinomial_counts, ParallelReservoir, WeightedSample};
use crate::sketch::Sketch;
use crate::sparse::Entry;
use crate::util::rng::Rng;

use super::backpressure::ShardSender;
use super::metrics::PipelineMetrics;
use super::{merge, EngineContext, SketchMode, Sketcher};

/// Pipeline tuning knobs.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Worker (shard) count. 0 = auto (available_parallelism − 1, min 1).
    pub workers: usize,
    /// Bounded channel capacity per worker, in batches.
    pub channel_cap: usize,
    /// Entries per batch message (amortizes channel overhead).
    pub batch: usize,
    /// Leader-side spill bound per shard, in batches: how many batches may
    /// park locally when a worker's channel is full before the leader
    /// blocks on `send` (real backpressure).
    pub spill_cap: usize,
    /// Scratch directory for [`SketchMode::Spilling`]'s on-disk forward
    /// sketches (each run creates and removes a private subdirectory).
    pub spill_dir: PathBuf,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            workers: 0,
            channel_cap: 64,
            batch: 4096,
            spill_cap: 8,
            spill_dir: std::env::temp_dir().join("matsketch-spill"),
        }
    }
}

impl PipelineConfig {
    /// Resolve `workers == 0` to the auto worker count.
    pub fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        std::thread::available_parallelism()
            .map(|p| p.get().saturating_sub(1).max(1))
            .unwrap_or(1)
    }
}

/// Row → shard assignment: Fibonacci hash + Lemire range reduction
/// (multiply-shift, no integer division on the per-entry hot path). The
/// budget pre-split and the leader's routing must agree on this.
#[inline]
pub(crate) fn shard_of(row: u32, workers: u64) -> usize {
    let h = (row as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    (((h as u128) * (workers as u128)) >> 64) as usize
}

/// One worker's finished output.
pub(crate) struct WorkerOut {
    pub shard: usize,
    pub samples: Vec<WeightedSample<Entry>>,
    pub total_weight: f64,
    pub sketch_records: u64,
    pub skipped: u64,
}

/// The shard-parallel [`Sketcher`]: workers are spawned at construction,
/// fed through [`Sketcher::ingest`], and joined + merged at finalize.
pub struct ShardedSketcher {
    ctx: EngineContext,
    cfg: PipelineConfig,
    workers: usize,
    senders: Vec<ShardSender>,
    handles: Vec<JoinHandle<WorkerOut>>,
    batches: Vec<Vec<Entry>>,
    /// Pre-split per-shard budgets and normalized stats-derived shard
    /// probabilities (`None` for trimmed distributions).
    presplit: Option<(Vec<u64>, Vec<f64>)>,
    merge_rng: Rng,
    metrics: PipelineMetrics,
    t0: Instant,
}

impl ShardedSketcher {
    /// Spawn the worker threads and wire up the shard channels.
    ///
    /// Shard-budget pre-split (§Perf): when per-row weight totals are
    /// derivable from the one-pass stats, the per-shard sample counts are
    /// drawn up front and each worker's reservoir runs at its own
    /// multinomial share `s_w` — total reservoir work O(s·log N)
    /// independent of the worker count. Trimmed distributions fall back to
    /// full-budget workers + the hypergeometric subset merge.
    pub(crate) fn spawn(
        ctx: EngineContext,
        stats: &MatrixStats,
        cfg: &PipelineConfig,
    ) -> ShardedSketcher {
        let workers = cfg.effective_workers();
        let mut merge_rng = Rng::new(ctx.plan.seed ^ 0x4D45_5247);
        let presplit: Option<(Vec<u64>, Vec<f64>)> =
            ctx.dist.row_weight_totals(stats).map(|row_totals| {
                let mut shard_w = vec![0.0f64; workers];
                for (i, &w) in row_totals.iter().enumerate() {
                    shard_w[shard_of(i as u32, workers as u64)] += w;
                }
                let total: f64 = shard_w.iter().sum();
                let counts = multinomial_counts(&mut merge_rng, ctx.plan.s, &shard_w);
                let q: Vec<f64> = shard_w.iter().map(|w| w / total).collect();
                (counts, q)
            });

        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx): (SyncSender<Vec<Entry>>, Receiver<Vec<Entry>>) =
                sync_channel(cfg.channel_cap.max(1));
            senders.push(ShardSender::new(tx, cfg.spill_cap));
            let dist = ctx.dist.clone();
            // pre-split: this worker samples only its multinomial share
            let budget = match &presplit {
                Some((counts, _)) => counts[w],
                None => ctx.plan.s,
            };
            let seed = ctx.plan.seed ^ (0xA5A5_0000 + w as u64);
            handles.push(std::thread::spawn(move || -> WorkerOut {
                let mut res: Option<ParallelReservoir<Entry>> =
                    (budget > 0).then(|| ParallelReservoir::new(budget, seed));
                let mut skipped = 0u64;
                let mut total_weight = 0.0f64;
                for batch in rx.iter() {
                    for e in batch {
                        let wgt = dist.weight(e.row, e.val);
                        if wgt > 0.0 {
                            total_weight += wgt;
                            if let Some(r) = res.as_mut() {
                                r.push(e, wgt);
                            }
                        } else {
                            skipped += 1;
                        }
                    }
                }
                let sketch_records = res.as_ref().map_or(0, |r| r.sketch_len() as u64);
                WorkerOut {
                    shard: w,
                    samples: res.map_or_else(Vec::new, |r| r.finalize()),
                    total_weight,
                    sketch_records,
                    skipped,
                }
            }));
        }

        let batches = (0..workers).map(|_| Vec::with_capacity(cfg.batch)).collect();
        ShardedSketcher {
            ctx,
            cfg: cfg.clone(),
            workers,
            senders,
            handles,
            batches,
            presplit,
            merge_rng,
            metrics: PipelineMetrics { workers, ..Default::default() },
            t0: Instant::now(),
        }
    }
}

impl Sketcher for ShardedSketcher {
    fn mode(&self) -> SketchMode {
        SketchMode::Sharded
    }

    fn ingest(&mut self, batch: &[Entry]) -> Result<()> {
        for e in batch {
            self.ctx.check_entry(e)?;
            self.metrics.ingested += 1;
            // row-based sharding (must match the budget pre-split)
            let shard = shard_of(e.row, self.workers as u64);
            let b = &mut self.batches[shard];
            b.push(*e);
            if b.len() >= self.cfg.batch {
                let full = std::mem::replace(b, Vec::with_capacity(self.cfg.batch));
                self.senders[shard].send(full);
            }
        }
        Ok(())
    }

    fn finalize(mut self: Box<Self>) -> Result<(Sketch, PipelineMetrics)> {
        // flush tail batches, then close every channel (workers exit their
        // rx loop once the sender side is fully dropped)
        for (shard, b) in std::mem::take(&mut self.batches).into_iter().enumerate() {
            if !b.is_empty() {
                self.senders[shard].send(b);
            }
        }
        for sender in std::mem::take(&mut self.senders) {
            let report = sender.finish();
            self.metrics.backpressure_wait += report.blocked;
            self.metrics.spill_depth_hist.push(report.depth_hist);
        }

        let mut outs = Vec::with_capacity(self.workers);
        for h in std::mem::take(&mut self.handles) {
            outs.push(h.join().map_err(|_| Error::Pipeline("worker panicked".into()))?);
        }
        outs.sort_by_key(|o| o.shard);
        for o in &outs {
            self.metrics.skipped_zero_weight += o.skipped;
            self.metrics.sketch_records += o.sketch_records;
            self.metrics.pre_merge_samples += o.samples.iter().map(|s| s.count).sum::<u64>();
        }

        let total_weight: f64 = outs.iter().map(|o| o.total_weight).sum();
        if total_weight <= 0.0 {
            return Err(Error::Pipeline("stream carried no positive-weight entries".into()));
        }
        let entries = match &self.presplit {
            Some((counts, q)) => {
                merge::merge_presplit(&outs, counts, q, &self.ctx.dist, self.ctx.plan.s)?
            }
            None => merge::merge_observed(
                &outs,
                &mut self.merge_rng,
                &self.ctx.dist,
                self.ctx.plan.s,
                total_weight,
            )?,
        };

        let sketch = self.ctx.assemble(entries);
        self.metrics.merged_samples = sketch.entries.iter().map(|e| e.count as u64).sum();
        self.metrics.wall = self.t0.elapsed();
        Ok((sketch, self.metrics.clone()))
    }
}
