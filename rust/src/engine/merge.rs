//! Shard-sample merging: thin `pub(crate)` adapters from the engine's
//! [`WorkerOut`] shards onto the public fold API in [`super::fold`].
//!
//! The actual composition (pre-split rescale, or multinomial +
//! hypergeometric subset over observed weights) lives in
//! [`super::fold`] — exposed so callers outside the engine can combine
//! part outputs the same deterministic way. These adapters only build
//! the borrowed [`FoldPart`] views in shard-id order.

use crate::distributions::Distribution;
use crate::error::Result;
use crate::sketch::SketchEntry;
use crate::util::rng::Rng;

use super::fold::{fold_observed, fold_presplit, FoldPart};
use super::shard::WorkerOut;

fn parts(outs: &[WorkerOut]) -> Vec<FoldPart<'_>> {
    outs.iter()
        .map(|o| FoldPart { id: o.shard, samples: &o.samples, total_weight: o.total_weight })
        .collect()
}

/// Merge when shard budgets were pre-split (see [`fold_presplit`]).
pub(crate) fn merge_presplit(
    outs: &[WorkerOut],
    counts: &[u64],
    q: &[f64],
    dist: &Distribution,
    s: u64,
) -> Result<Vec<SketchEntry>> {
    fold_presplit(&parts(outs), counts, q, dist, s)
}

/// Merge over *observed* shard weights (see [`fold_observed`]).
pub(crate) fn merge_observed(
    outs: &[WorkerOut],
    rng: &mut Rng,
    dist: &Distribution,
    s: u64,
    total_weight: f64,
) -> Result<Vec<SketchEntry>> {
    fold_observed(&parts(outs), rng, dist, s, total_weight)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::{DistributionKind, MatrixStats};
    use crate::samplers::WeightedSample;
    use crate::sparse::{Coo, Entry};

    fn fixture() -> (Distribution, Vec<WorkerOut>) {
        let coo = Coo::from_entries(
            2,
            3,
            vec![Entry::new(0, 0, 3.0), Entry::new(0, 1, 1.0), Entry::new(1, 2, 2.0)],
        )
        .unwrap();
        let stats = MatrixStats::from_coo(&coo);
        let dist = Distribution::prepare(DistributionKind::L1, &stats, 10, 0.1).unwrap();
        let outs = vec![
            WorkerOut {
                shard: 0,
                samples: vec![
                    WeightedSample { item: Entry::new(0, 0, 3.0), count: 7 },
                    WeightedSample { item: Entry::new(0, 1, 1.0), count: 3 },
                ],
                total_weight: 4.0,
                sketch_records: 2,
                skipped: 0,
            },
            WorkerOut {
                shard: 1,
                samples: vec![WeightedSample { item: Entry::new(1, 2, 2.0), count: 10 }],
                total_weight: 2.0,
                sketch_records: 1,
                skipped: 0,
            },
        ];
        (dist, outs)
    }

    #[test]
    fn observed_merge_conserves_s_and_is_seed_deterministic() {
        let (dist, outs) = fixture();
        let run = |seed: u64| {
            let mut rng = Rng::new(seed);
            merge_observed(&outs, &mut rng, &dist, 10, 6.0).unwrap()
        };
        let a = run(42);
        assert_eq!(a.iter().map(|e| e.count as u64).sum::<u64>(), 10);
        let b = run(42);
        assert_eq!(a, b, "same seed must give an identical merge");
    }

    #[test]
    fn observed_merge_rejects_underfull_shards() {
        let (dist, mut outs) = fixture();
        outs[0].samples[0].count = 1; // shard 0 now holds only 2 samples...
        outs[0].samples[1].count = 1;
        outs[1].total_weight = 0.0; // ...and must take all 10 (shard 1 empty)
        let mut rng = Rng::new(7);
        let res = merge_observed(&outs, &mut rng, &dist, 10, 4.0);
        assert!(res.is_err());
    }

    #[test]
    fn presplit_merge_rescales_by_shard_probability() {
        let (dist, outs) = fixture();
        let counts = [10u64, 10];
        let q = [4.0 / 6.0, 2.0 / 6.0];
        let entries = merge_presplit(&outs, &counts, &q, &dist, 20).unwrap();
        assert_eq!(entries.iter().map(|e| e.count as u64).sum::<u64>(), 20);
        // entry (0,0): w=3, q0·w/W0 = (2/3)·(3/4) = 0.5; value = 7·3/(20·0.5)
        let e00 = entries.iter().find(|e| (e.row, e.col) == (0, 0)).unwrap();
        assert!((e00.value - 7.0 * 3.0 / (20.0 * 0.5)).abs() < 1e-12);
    }

    #[test]
    fn presplit_merge_rejects_budget_deficit() {
        // A shard assigned budget but holding no samples (stats promised
        // weight the stream never delivered) must error, not shrink s.
        let (dist, mut outs) = fixture();
        outs[1].samples.clear();
        outs[1].total_weight = 0.0;
        let counts = [10u64, 10];
        let q = [4.0 / 6.0, 2.0 / 6.0];
        let err = merge_presplit(&outs, &counts, &q, &dist, 20).unwrap_err();
        assert!(err.to_string().contains("pre-split"), "unexpected error: {err}");
    }

    #[test]
    fn presplit_merge_tolerates_zero_budget_empty_shards() {
        // workers > occupied rows is normal: empty shard, zero budget
        let (dist, mut outs) = fixture();
        outs[1].samples.clear();
        outs[1].total_weight = 0.0;
        let counts = [10u64, 0];
        let q = [1.0, 0.0];
        let entries = merge_presplit(&outs, &counts, &q, &dist, 10).unwrap();
        assert_eq!(entries.iter().map(|e| e.count as u64).sum::<u64>(), 10);
    }
}
