//! Shard-sample merging: compose per-shard reservoir outputs into exactly
//! `s` global i.i.d. draws.
//!
//! Two paths, both exact and both deterministic given the plan seed (the
//! merge RNG is derived from `plan.seed` alone and shards are visited in
//! shard-id order):
//!
//! * **pre-split** — the per-shard budgets were drawn up front as
//!   `Multinomial(s, W_w/ΣW)` over stats-derived shard weights, so every
//!   worker already holds exactly its share; the merge only rescales.
//! * **observed** — trimmed distributions (stats can't predict shard
//!   weights): every worker sampled at the full budget `s`; the merge
//!   draws `Multinomial(s, W_w^obs/ΣW^obs)` over the observed weights and
//!   takes a uniformly random subset of each shard's exchangeable samples
//!   via a multivariate-hypergeometric chain.

use crate::distributions::Distribution;
use crate::error::{Error, Result};
use crate::samplers::{hypergeometric, multinomial_counts};
use crate::sketch::SketchEntry;
use crate::util::rng::Rng;

use super::shard::WorkerOut;

/// Merge when shard budgets were pre-split: the effective global sampling
/// probability of an entry in shard `w` is `q_w · w_ij / W_w(observed)` —
/// exact even when the stats were rough estimates (§3 one-pass mode).
///
/// `counts` are the pre-split per-shard budgets; a shard that was
/// assigned budget but observed no positive-weight entries (stats claimed
/// weight the stream never delivered) is an error — silently dropping its
/// share would break the engine's exactly-`s`-draws contract.
pub(crate) fn merge_presplit(
    outs: &[WorkerOut],
    counts: &[u64],
    q: &[f64],
    dist: &Distribution,
    s: u64,
) -> Result<Vec<SketchEntry>> {
    let mut entries = Vec::new();
    for o in outs {
        let have: u64 = o.samples.iter().map(|x| x.count).sum();
        if have != counts[o.shard] {
            return Err(Error::Pipeline(format!(
                "shard {} produced {have} of its pre-split {} samples — \
                 the stats assigned weight this stream never delivered",
                o.shard, counts[o.shard]
            )));
        }
        if o.total_weight <= 0.0 {
            continue; // an empty shard with a zero budget is normal
        }
        let qw = q[o.shard];
        for smp in &o.samples {
            let e = smp.item;
            let w = dist.weight(e.row, e.val);
            let p = qw * w / o.total_weight;
            entries.push(SketchEntry {
                row: e.row,
                col: e.col,
                count: smp.count as u32,
                value: smp.count as f64 * e.val as f64 / (s as f64 * p),
            });
        }
    }
    Ok(entries)
}

/// Merge over *observed* shard weights: multinomial split of `s`, then a
/// uniformly random subset (hypergeometric chain) of each shard's `s`
/// reservoir samples.
pub(crate) fn merge_observed(
    outs: &[WorkerOut],
    rng: &mut Rng,
    dist: &Distribution,
    s: u64,
    total_weight: f64,
) -> Result<Vec<SketchEntry>> {
    let shard_weights: Vec<f64> = outs.iter().map(|o| o.total_weight).collect();
    let take = multinomial_counts(rng, s, &shard_weights);
    let mut entries = Vec::new();
    for (o, &need_total) in outs.iter().zip(take.iter()) {
        if need_total == 0 {
            continue;
        }
        let have: u64 = o.samples.iter().map(|x| x.count).sum();
        if have < need_total {
            return Err(Error::Pipeline(format!(
                "shard {} holds {have} samples, needs {need_total}",
                o.shard
            )));
        }
        let mut pop = have;
        let mut need = need_total;
        for smp in &o.samples {
            if need == 0 {
                break;
            }
            let t = hypergeometric(rng, pop, smp.count, need);
            pop -= smp.count;
            need -= t;
            if t > 0 {
                let e = smp.item;
                let w = dist.weight(e.row, e.val);
                let p = w / total_weight; // global probability
                entries.push(SketchEntry {
                    row: e.row,
                    col: e.col,
                    count: t as u32,
                    value: t as f64 * e.val as f64 / (s as f64 * p),
                });
            }
        }
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::{DistributionKind, MatrixStats};
    use crate::samplers::WeightedSample;
    use crate::sparse::{Coo, Entry};

    fn fixture() -> (Distribution, Vec<WorkerOut>) {
        let coo = Coo::from_entries(
            2,
            3,
            vec![Entry::new(0, 0, 3.0), Entry::new(0, 1, 1.0), Entry::new(1, 2, 2.0)],
        )
        .unwrap();
        let stats = MatrixStats::from_coo(&coo);
        let dist = Distribution::prepare(DistributionKind::L1, &stats, 10, 0.1).unwrap();
        let outs = vec![
            WorkerOut {
                shard: 0,
                samples: vec![
                    WeightedSample { item: Entry::new(0, 0, 3.0), count: 7 },
                    WeightedSample { item: Entry::new(0, 1, 1.0), count: 3 },
                ],
                total_weight: 4.0,
                sketch_records: 2,
                skipped: 0,
            },
            WorkerOut {
                shard: 1,
                samples: vec![WeightedSample { item: Entry::new(1, 2, 2.0), count: 10 }],
                total_weight: 2.0,
                sketch_records: 1,
                skipped: 0,
            },
        ];
        (dist, outs)
    }

    #[test]
    fn observed_merge_conserves_s_and_is_seed_deterministic() {
        let (dist, outs) = fixture();
        let run = |seed: u64| {
            let mut rng = Rng::new(seed);
            merge_observed(&outs, &mut rng, &dist, 10, 6.0).unwrap()
        };
        let a = run(42);
        assert_eq!(a.iter().map(|e| e.count as u64).sum::<u64>(), 10);
        let b = run(42);
        assert_eq!(a, b, "same seed must give an identical merge");
    }

    #[test]
    fn observed_merge_rejects_underfull_shards() {
        let (dist, mut outs) = fixture();
        outs[0].samples[0].count = 1; // shard 0 now holds only 2 samples...
        outs[0].samples[1].count = 1;
        outs[1].total_weight = 0.0; // ...and must take all 10 (shard 1 empty)
        let mut rng = Rng::new(7);
        let res = merge_observed(&outs, &mut rng, &dist, 10, 4.0);
        assert!(res.is_err());
    }

    #[test]
    fn presplit_merge_rescales_by_shard_probability() {
        let (dist, outs) = fixture();
        let counts = [10u64, 10];
        let q = [4.0 / 6.0, 2.0 / 6.0];
        let entries = merge_presplit(&outs, &counts, &q, &dist, 20).unwrap();
        assert_eq!(entries.iter().map(|e| e.count as u64).sum::<u64>(), 20);
        // entry (0,0): w=3, q0·w/W0 = (2/3)·(3/4) = 0.5; value = 7·3/(20·0.5)
        let e00 = entries.iter().find(|e| (e.row, e.col) == (0, 0)).unwrap();
        assert!((e00.value - 7.0 * 3.0 / (20.0 * 0.5)).abs() < 1e-12);
    }

    #[test]
    fn presplit_merge_rejects_budget_deficit() {
        // A shard assigned budget but holding no samples (stats promised
        // weight the stream never delivered) must error, not shrink s.
        let (dist, mut outs) = fixture();
        outs[1].samples.clear();
        outs[1].total_weight = 0.0;
        let counts = [10u64, 10];
        let q = [4.0 / 6.0, 2.0 / 6.0];
        let err = merge_presplit(&outs, &counts, &q, &dist, 20).unwrap_err();
        assert!(err.to_string().contains("pre-split"), "unexpected error: {err}");
    }

    #[test]
    fn presplit_merge_tolerates_zero_budget_empty_shards() {
        // workers > occupied rows is normal: empty shard, zero budget
        let (dist, mut outs) = fixture();
        outs[1].samples.clear();
        outs[1].total_weight = 0.0;
        let counts = [10u64, 0];
        let q = [1.0, 0.0];
        let entries = merge_presplit(&outs, &counts, &q, &dist, 10).unwrap();
        assert_eq!(entries.iter().map(|e| e.count as u64).sum::<u64>(), 10);
    }
}
