//! The unified sketching engine — one [`Sketcher`] contract, four
//! execution modes, every distribution.
//!
//! The paper's promise is O(1)-per-nonzero sketching of a stream presented
//! in arbitrary order; this module is the single seam through which every
//! consumer (CLI, eval harness, benches, examples) exercises it. A
//! sketcher's lifecycle is always *ingest batches → finalize → sketch*:
//!
//! ```text
//!            build_sketcher(mode, stats, plan, cfg)
//!                             │
//!     ┌──────────────┬────────┴───────┬────────────────┐
//!     ▼              ▼                ▼                ▼
//!  ::Offline      ::Streaming     ::Spilling       ::Sharded
//!  (offline.rs)   (reservoir.rs)  (spilling.rs)    (shard.rs)
//!  alias table    one Appendix-A  reservoir with   W worker reservoirs
//!  over buffered  reservoir,      forward sketch   + exact seeded
//!  entries        O(s log bN)     on disk          merge
//!     │              │                │                │
//!     └──────────────┴────────┬───────┴────────────────┘
//!                             ▼
//!               ingest(&[Entry])* → finalize()
//!                             ▼
//!                  (Sketch, PipelineMetrics)
//! ```
//!
//! ## Module layout
//!
//! * `mod.rs` — the [`Sketcher`] trait, [`SketchMode`], the
//!   [`build_sketcher`] factory, and the stream/matrix drivers
//!   ([`sketch_entry_stream`], [`sketch_coo`], [`sketch_csr`]).
//! * [`offline`] — [`AliasSketcher`]: buffer + Vose alias table (the
//!   evaluation reference path).
//! * [`reservoir`] — [`ReservoirSketcher`]: one O(1)-per-item Appendix-A
//!   reservoir, single-threaded.
//! * [`spilling`] — [`SpillingSketcher`]: the same reservoir with its
//!   forward sketch on durable storage (O(log s) active memory), for
//!   budgets where `s·log(bN)` records exceed RAM.
//! * [`shard`] — [`ShardedSketcher`] + [`PipelineConfig`]: row-hash
//!   routing to worker reservoirs with shard-budget pre-splitting.
//! * [`fold`] — the public fold entry point: the deterministic seeded
//!   merge (pre-split rescale or multinomial + hypergeometric subset over
//!   observed weights), reusable outside the engine ([`FoldPart`],
//!   [`fold_presplit`], [`fold_observed`], [`fold_rng`]).
//! * [`merge`] — `pub(crate)` adapters from worker shards onto [`fold`].
//! * [`backpressure`] — leader-side bounded spill + blocking-send flow
//!   control for the sharded mode.
//! * [`metrics`] — [`PipelineMetrics`], produced by every mode.
//!
//! All modes draw `s` i.i.d. samples from the same prepared
//! [`Distribution`], so sketches are exchangeable across modes — the
//! cross-mode test in `rust/tests/integration_engine.rs` pins that down
//! for every [`crate::distributions::DistributionKind::figure1_set`]
//! member. Later scaling work (async ingestion, multi-backend dispatch,
//! sketch caching) plugs in as new `SketchMode`s or new `Sketcher` impls
//! without touching any consumer.

pub mod backpressure;
pub mod fold;
pub mod merge;
pub mod metrics;
pub mod offline;
pub mod reservoir;
pub mod shard;
pub mod spilling;

pub use fold::{fold_observed, fold_presplit, fold_rng, FoldPart};
pub use metrics::PipelineMetrics;
pub use offline::AliasSketcher;
pub use reservoir::ReservoirSketcher;
pub use shard::{PipelineConfig, ShardedSketcher};
pub use spilling::SpillingSketcher;

use crate::distributions::{Distribution, MatrixStats};
use crate::error::{Error, Result};
use crate::sketch::{Sketch, SketchEntry, SketchPlan};
use crate::sparse::{Coo, Csr, Entry};
use crate::stream::{EntryStream, ShuffledStream};

/// Which execution strategy a [`Sketcher`] uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SketchMode {
    /// Buffer all entries, then draw from one alias table (exact offline
    /// reference; O(nnz) memory).
    Offline,
    /// One streaming Appendix-A reservoir (O(1)/entry, single thread).
    Streaming,
    /// The streaming reservoir with its forward sketch spilled to disk
    /// (O(1)/entry, O(log s) active memory) — for budgets whose
    /// `s·log(bN)` sketch records exceed RAM.
    Spilling,
    /// Leader + worker-per-shard reservoirs with an exact merge
    /// (O(1)/entry, scales with cores).
    Sharded,
}

impl SketchMode {
    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            SketchMode::Offline => "offline",
            SketchMode::Streaming => "streaming",
            SketchMode::Spilling => "spilling",
            SketchMode::Sharded => "sharded",
        }
    }

    /// Every mode, for cross-mode tests and sweeps.
    pub fn all() -> [SketchMode; 4] {
        [
            SketchMode::Offline,
            SketchMode::Streaming,
            SketchMode::Spilling,
            SketchMode::Sharded,
        ]
    }

    /// Parse a CLI/config spelling.
    pub fn parse(name: &str) -> Option<SketchMode> {
        match name.to_ascii_lowercase().as_str() {
            "offline" | "alias" => Some(SketchMode::Offline),
            "streaming" | "reservoir" => Some(SketchMode::Streaming),
            "spilling" | "spill" => Some(SketchMode::Spilling),
            "sharded" | "pipeline" => Some(SketchMode::Sharded),
            _ => None,
        }
    }
}

/// A sketching engine: ingest entry batches, then finalize into a
/// [`Sketch`]. All implementations draw `s` i.i.d. samples from the
/// distribution prepared at construction, so any two sketchers built from
/// the same `(stats, plan)` are statistically interchangeable.
pub trait Sketcher {
    /// Which execution mode this sketcher runs.
    fn mode(&self) -> SketchMode;

    /// Feed one batch of stream entries (any order, any batching).
    /// Rejects out-of-shape coordinates.
    fn ingest(&mut self, batch: &[Entry]) -> Result<()>;

    /// Finish the stream: produce the sketch and the run metrics.
    fn finalize(self: Box<Self>) -> Result<(Sketch, PipelineMetrics)>;
}

/// Everything a sketcher mode needs about the run, prepared once by
/// [`build_sketcher`]: the distribution, the plan, the matrix shape, and
/// the codec row scales.
pub(crate) struct EngineContext {
    pub dist: Distribution,
    pub plan: SketchPlan,
    pub m: usize,
    pub n: usize,
    /// Per-row codec scale `‖A_(i)‖₁/(s·ρ_i)` for the L1 family.
    pub row_scale: Option<Vec<f64>>,
}

impl EngineContext {
    pub(crate) fn prepare(stats: &MatrixStats, plan: &SketchPlan) -> Result<EngineContext> {
        if plan.s == 0 {
            return Err(Error::invalid("sample budget must be positive"));
        }
        if stats.row_l1.len() != stats.m {
            return Err(Error::shape(format!(
                "stats row_l1 length {} != m {}",
                stats.row_l1.len(),
                stats.m
            )));
        }
        let dist = Distribution::prepare(plan.kind, stats, plan.s, plan.delta)?;
        let row_scale = dist.rho.as_ref().map(|rho| {
            rho.iter()
                .zip(stats.row_l1.iter())
                .map(|(&r, &z)| if r > 0.0 { z / (plan.s as f64 * r) } else { 0.0 })
                .collect()
        });
        Ok(EngineContext {
            dist,
            plan: plan.clone(),
            m: stats.m,
            n: stats.n,
            row_scale,
        })
    }

    /// Reject out-of-shape stream entries.
    #[inline]
    pub(crate) fn check_entry(&self, e: &Entry) -> Result<()> {
        if (e.row as usize) >= self.m || (e.col as usize) >= self.n {
            return Err(Error::shape(format!(
                "stream entry ({}, {}) outside {}x{}",
                e.row, e.col, self.m, self.n
            )));
        }
        Ok(())
    }

    /// Assemble the final normalized sketch from merged entries.
    pub(crate) fn assemble(&self, entries: Vec<SketchEntry>) -> Sketch {
        let mut sketch = Sketch {
            m: self.m,
            n: self.n,
            s: self.plan.s,
            entries,
            row_scale: self.row_scale.clone(),
            method: self.plan.kind.name(),
        };
        sketch.normalize();
        sketch
    }
}

/// Build a sketcher for the given mode. `stats` must describe the matrix
/// the entries will come from (pass 1 of the two-pass algorithm, or
/// a-priori row-norm estimates — only row-norm *ratios* matter for the
/// L1-family distributions, §3 of the paper).
pub fn build_sketcher(
    mode: SketchMode,
    stats: &MatrixStats,
    plan: &SketchPlan,
    cfg: &PipelineConfig,
) -> Result<Box<dyn Sketcher>> {
    let ctx = EngineContext::prepare(stats, plan)?;
    Ok(match mode {
        SketchMode::Offline => Box::new(AliasSketcher::new(ctx)),
        SketchMode::Streaming => Box::new(ReservoirSketcher::new(ctx)),
        SketchMode::Spilling => Box::new(SpillingSketcher::new(ctx, &cfg.spill_dir)?),
        SketchMode::Sharded => Box::new(ShardedSketcher::spawn(ctx, stats, cfg)),
    })
}

/// Drive an [`EntryStream`] through a sketcher of the given mode to
/// completion. Validates the stream shape against `stats` up front and
/// surfaces stream-source errors (e.g. a truncated file) immediately.
pub fn sketch_entry_stream<S: EntryStream>(
    mode: SketchMode,
    mut stream: S,
    stats: &MatrixStats,
    plan: &SketchPlan,
    cfg: &PipelineConfig,
) -> Result<(Sketch, PipelineMetrics)> {
    let (m, n) = stream.shape();
    if m != stats.m || n != stats.n {
        return Err(Error::shape(format!(
            "stats {}x{} != stream {m}x{n}",
            stats.m, stats.n
        )));
    }
    let mut sketcher = build_sketcher(mode, stats, plan, cfg)?;
    let cap = cfg.batch.max(1);
    let mut buf: Vec<Entry> = Vec::with_capacity(cap);
    while let Some(e) = stream.next_entry()? {
        buf.push(e);
        if buf.len() == cap {
            sketcher.ingest(&buf)?;
            buf.clear();
        }
    }
    if !buf.is_empty() {
        sketcher.ingest(&buf)?;
    }
    sketcher.finalize()
}

/// Sketch an in-memory COO matrix with the given mode: stats pass, then a
/// seeded shuffled-order sampling pass (the paper's "arbitrary order"
/// stream model).
pub fn sketch_coo(
    mode: SketchMode,
    a: &Coo,
    plan: &SketchPlan,
    cfg: &PipelineConfig,
) -> Result<(Sketch, PipelineMetrics)> {
    let stats = MatrixStats::from_coo(a);
    let stream = ShuffledStream::new(a, plan.seed ^ 0xD1CE);
    sketch_entry_stream(mode, stream, &stats, plan, cfg)
}

/// Row-major [`EntryStream`] view over a CSR matrix (no copy of the
/// underlying arrays).
struct CsrEntryStream<'a> {
    a: &'a Csr,
    row: usize,
    idx: usize,
}

impl EntryStream for CsrEntryStream<'_> {
    fn shape(&self) -> (usize, usize) {
        (self.a.m, self.a.n)
    }
    fn next_entry(&mut self) -> Result<Option<Entry>> {
        if self.idx >= self.a.nnz() {
            return Ok(None);
        }
        while self.idx >= self.a.indptr[self.row + 1] {
            self.row += 1;
        }
        let e = Entry::new(self.row as u32, self.a.indices[self.idx], self.a.values[self.idx]);
        self.idx += 1;
        Ok(Some(e))
    }
    fn size_hint(&self) -> Option<usize> {
        Some(self.a.nnz() - self.idx)
    }
}

/// Sketch an in-memory CSR matrix with the given mode (row-major entry
/// order; order is irrelevant to every mode's sampling law).
pub fn sketch_csr(
    mode: SketchMode,
    a: &Csr,
    plan: &SketchPlan,
    cfg: &PipelineConfig,
) -> Result<(Sketch, PipelineMetrics)> {
    let stats = MatrixStats::from_csr(a);
    sketch_entry_stream(mode, CsrEntryStream { a, row: 0, idx: 0 }, &stats, plan, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributions::DistributionKind;
    use crate::sparse::Coo;
    use crate::util::rng::Rng;

    fn toy(m: usize, n: usize, seed: u64) -> Coo {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(m, n);
        for i in 0..m as u32 {
            for _ in 0..10 {
                coo.push(i, rng.usize_below(n) as u32, rng.normal() as f32 + 2.0);
            }
        }
        coo.normalize();
        coo
    }

    #[test]
    fn factory_builds_every_mode() {
        let a = toy(8, 40, 1);
        let stats = MatrixStats::from_coo(&a);
        let plan = SketchPlan::new(DistributionKind::Bernstein, 100).with_seed(2);
        for mode in SketchMode::all() {
            let sk = build_sketcher(mode, &stats, &plan, &PipelineConfig::default()).unwrap();
            assert_eq!(sk.mode(), mode);
        }
    }

    #[test]
    fn zero_budget_rejected_in_every_mode() {
        let a = toy(4, 16, 3);
        let stats = MatrixStats::from_coo(&a);
        let plan = SketchPlan::new(DistributionKind::L1, 0);
        for mode in SketchMode::all() {
            assert!(build_sketcher(mode, &stats, &plan, &PipelineConfig::default()).is_err());
        }
    }

    #[test]
    fn out_of_shape_entries_rejected() {
        let a = toy(4, 16, 4);
        let stats = MatrixStats::from_coo(&a);
        let plan = SketchPlan::new(DistributionKind::L1, 10);
        for mode in SketchMode::all() {
            let mut sk =
                build_sketcher(mode, &stats, &plan, &PipelineConfig::default()).unwrap();
            let bad = [Entry::new(99, 0, 1.0)];
            assert!(sk.ingest(&bad).is_err(), "{:?}", mode);
        }
    }

    #[test]
    fn mode_names_parse_back() {
        for mode in SketchMode::all() {
            assert_eq!(SketchMode::parse(mode.name()), Some(mode));
        }
        assert_eq!(SketchMode::parse("pipeline"), Some(SketchMode::Sharded));
        assert_eq!(SketchMode::parse("nope"), None);
    }

    #[test]
    fn sketch_coo_runs_all_modes_at_equal_budget() {
        let a = toy(10, 60, 5);
        let plan = SketchPlan::new(DistributionKind::RowL1, 250).with_seed(9);
        for mode in SketchMode::all() {
            let (sk, metrics) =
                sketch_coo(mode, &a, &plan, &PipelineConfig::default()).unwrap();
            assert_eq!(sk.entries.iter().map(|e| e.count as u64).sum::<u64>(), 250);
            assert_eq!(metrics.merged_samples, 250);
            assert_eq!(metrics.ingested, a.nnz() as u64);
        }
    }
}
