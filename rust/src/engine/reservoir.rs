//! Single-threaded streaming sketching behind the [`Sketcher`] trait.
//!
//! One Appendix-A [`ParallelReservoir`] at the full budget `s`: O(1) work
//! per non-zero, O(s·log(bN)) forward-sketch memory, no worker threads or
//! merge step. This is the minimal-footprint mode — the sharded mode is
//! this sampler replicated per shard plus an exact merge.

use std::time::Instant;

use crate::error::{Error, Result};
use crate::samplers::ParallelReservoir;
use crate::sketch::{Sketch, SketchEntry};
use crate::sparse::Entry;

use super::metrics::PipelineMetrics;
use super::{EngineContext, SketchMode, Sketcher};

/// The single-thread streaming [`Sketcher`].
pub struct ReservoirSketcher {
    ctx: EngineContext,
    res: ParallelReservoir<Entry>,
    ingested: u64,
    skipped: u64,
    t0: Instant,
}

impl ReservoirSketcher {
    pub(crate) fn new(ctx: EngineContext) -> ReservoirSketcher {
        let res = ParallelReservoir::new(ctx.plan.s, ctx.plan.seed ^ 0x5245_5356);
        ReservoirSketcher { ctx, res, ingested: 0, skipped: 0, t0: Instant::now() }
    }
}

impl Sketcher for ReservoirSketcher {
    fn mode(&self) -> SketchMode {
        SketchMode::Streaming
    }

    fn ingest(&mut self, batch: &[Entry]) -> Result<()> {
        for e in batch {
            self.ctx.check_entry(e)?;
            self.ingested += 1;
            let w = self.ctx.dist.weight(e.row, e.val);
            if w > 0.0 {
                self.res.push(*e, w);
            } else {
                self.skipped += 1;
            }
        }
        Ok(())
    }

    fn finalize(self: Box<Self>) -> Result<(Sketch, PipelineMetrics)> {
        let ReservoirSketcher { ctx, res, ingested, skipped, t0 } = *self;
        let total_weight = res.total_weight();
        if total_weight <= 0.0 {
            return Err(Error::Pipeline("stream carried no positive-weight entries".into()));
        }
        let sketch_records = res.sketch_len() as u64;
        let s = ctx.plan.s;
        let samples = res.finalize();
        let drawn: Vec<SketchEntry> = samples
            .iter()
            .map(|smp| {
                let e = smp.item;
                let w = ctx.dist.weight(e.row, e.val);
                let p = w / total_weight;
                SketchEntry {
                    row: e.row,
                    col: e.col,
                    count: smp.count as u32,
                    value: smp.count as f64 * e.val as f64 / (s as f64 * p),
                }
            })
            .collect();

        let mut metrics = PipelineMetrics {
            ingested,
            skipped_zero_weight: skipped,
            workers: 1,
            sketch_records,
            pre_merge_samples: samples.iter().map(|x| x.count).sum(),
            ..Default::default()
        };
        let sketch = ctx.assemble(drawn);
        metrics.merged_samples = sketch.entries.iter().map(|e| e.count as u64).sum();
        metrics.wall = t0.elapsed();
        Ok((sketch, metrics))
    }
}
