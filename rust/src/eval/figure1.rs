//! E2 — Figure 1: left/right projection quality vs sample budget, per
//! dataset × method.
//!
//! For each dataset: compute the top-k SVD of `A` once (→ `‖A_k‖_F`), then
//! for each method and each budget `s` in a log-spaced sweep, sketch,
//! take the sketch's top-k SVD, and record
//! `‖P_k^B A‖_F/‖A_k‖_F` and `‖A Q_k^B‖_F/‖A_k‖_F`.

use std::path::Path;

use crate::datasets::DatasetId;
use crate::distributions::{ahk06_sketch, Ahk06Config, DistributionKind};
use crate::engine::{sketch_csr, PipelineConfig, SketchMode};
use crate::error::Result;
use crate::linalg::svd::{rank_k_fro, topk_svd};
use crate::metrics::quality::{quality_left, quality_right};
use crate::runtime::DenseEngine;
use crate::sketch::SketchPlan;
use crate::sparse::Csr;
use crate::util::log_space;

use super::report::{fixed, Table};

/// Figure-1 sweep parameters.
#[derive(Clone, Debug)]
pub struct Figure1Config {
    /// Evaluation rank (paper: 20).
    pub k: usize,
    /// Subspace-iteration rounds for each SVD.
    pub svd_iters: usize,
    /// Number of budget points.
    pub budget_points: usize,
    /// Budget range as a fraction of nnz: `[lo·nnz, hi·nnz]`.
    pub budget_lo: f64,
    /// Upper fraction.
    pub budget_hi: f64,
    /// Include the AHK06 baseline (expected-nnz-matched).
    pub include_ahk06: bool,
    /// Base seed.
    pub seed: u64,
    /// Use the small dataset variants.
    pub small: bool,
    /// Which [`crate::engine::Sketcher`] mode produces the sketches
    /// (offline is the evaluation reference; all modes sample the same
    /// distribution).
    pub mode: SketchMode,
}

impl Default for Figure1Config {
    fn default() -> Self {
        Figure1Config {
            k: 20,
            svd_iters: 8,
            budget_points: 8,
            budget_lo: 0.02,
            budget_hi: 2.0,
            include_ahk06: false,
            seed: 0,
            small: false,
            mode: SketchMode::Offline,
        }
    }
}

/// One measurement row.
#[derive(Clone, Debug)]
pub struct Figure1Point {
    /// Dataset.
    pub dataset: String,
    /// Method name.
    pub method: String,
    /// Budget s.
    pub s: u64,
    /// Left quality.
    pub left: f64,
    /// Right quality.
    pub right: f64,
}

/// Sweep one dataset.
pub fn figure1_dataset(
    name: &str,
    a: &Csr,
    cfg: &Figure1Config,
    engine: &dyn DenseEngine,
) -> Result<Vec<Figure1Point>> {
    let k = cfg.k;
    let svd_a = topk_svd(a, k + 4, cfg.svd_iters, cfg.seed ^ 1, engine)?;
    let a_k_fro = rank_k_fro(&svd_a, k);
    let budgets = log_space(
        ((a.nnz() as f64 * cfg.budget_lo) as usize).max(k * 8),
        ((a.nnz() as f64 * cfg.budget_hi) as usize).max(k * 16),
        cfg.budget_points,
    );
    let mut out = Vec::new();
    for kind in DistributionKind::figure1_set() {
        for &s in &budgets {
            let plan = SketchPlan::new(kind, s as u64).with_seed(cfg.seed ^ s as u64);
            let sketch = match sketch_csr(cfg.mode, a, &plan, &PipelineConfig::default()) {
                Ok((sk, _metrics)) => sk,
                Err(err) => {
                    crate::warn_log!("fig1 {name}/{}/s={s}: {err}", kind.name());
                    continue;
                }
            };
            let b = sketch.to_csr();
            let svd_b = topk_svd(&b, k + 4, cfg.svd_iters, cfg.seed ^ 2, engine)?;
            let left = quality_left(a, &svd_b, a_k_fro, k, engine)?;
            let right = quality_right(a, &svd_b, a_k_fro, k)?;
            crate::debug_log!(
                "fig1 {name} {:<12} s={s:<9} left={left:.3} right={right:.3}",
                kind.name()
            );
            out.push(Figure1Point {
                dataset: name.to_string(),
                method: kind.name(),
                s: s as u64,
                left,
                right,
            });
        }
    }
    if cfg.include_ahk06 {
        for &s in &budgets {
            let ahk = Ahk06Config::for_budget(a, s as u64);
            let b = ahk06_sketch(a, &ahk, cfg.seed ^ (s as u64) ^ 0xA4).to_csr();
            let svd_b = topk_svd(&b, k + 4, cfg.svd_iters, cfg.seed ^ 3, engine)?;
            out.push(Figure1Point {
                dataset: name.to_string(),
                method: "AHK06".to_string(),
                s: s as u64,
                left: quality_left(a, &svd_b, a_k_fro, k, engine)?,
                right: quality_right(a, &svd_b, a_k_fro, k)?,
            });
        }
    }
    Ok(out)
}

/// Full Figure-1 run over the four datasets; writes `figure1.csv` (one row
/// per point) and a per-dataset markdown summary.
pub fn run_figure1(
    dir: &Path,
    cfg: &Figure1Config,
    engine: &dyn DenseEngine,
    datasets: &[DatasetId],
) -> Result<Vec<Figure1Point>> {
    let mut all = Vec::new();
    for id in datasets {
        let coo = if cfg.small { id.generate_small(cfg.seed) } else { id.generate(cfg.seed) };
        let a = coo.to_csr();
        crate::info!(
            "figure1: {} ({}x{}, nnz={}) on engine={}",
            id.name(),
            a.m,
            a.n,
            a.nnz(),
            engine.name()
        );
        let pts = figure1_dataset(id.name(), &a, cfg, engine)?;
        all.extend(pts);
    }
    write_figure1(dir, &all)?;
    Ok(all)
}

/// Emit the CSV + markdown for a set of points.
pub fn write_figure1(dir: &Path, points: &[Figure1Point]) -> Result<()> {
    let mut t = Table::new(
        "figure1",
        &["dataset", "method", "s", "log10_s", "left", "right"],
    );
    for p in points {
        t.push(vec![
            p.dataset.clone(),
            p.method.clone(),
            p.s.to_string(),
            fixed((p.s as f64).log10(), 3),
            fixed(p.left, 4),
            fixed(p.right, 4),
        ]);
    }
    t.write(dir)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{synthetic_cf, SyntheticConfig};
    use crate::runtime::RustEngine;

    #[test]
    fn sweep_monotone_and_bounded() {
        // On a small matrix: quality ∈ (0, 1.05], and the largest budget
        // beats the smallest for the Bernstein method.
        let a = synthetic_cf(&SyntheticConfig { n: 800, ..Default::default() }).to_csr();
        let cfg = Figure1Config {
            k: 8,
            svd_iters: 6,
            budget_points: 3,
            budget_lo: 0.05,
            budget_hi: 2.0,
            seed: 5,
            ..Default::default()
        };
        let pts = figure1_dataset("synthetic", &a, &cfg, &RustEngine).unwrap();
        assert!(!pts.is_empty());
        for p in &pts {
            assert!(p.left > 0.0 && p.left < 1.10, "{p:?}");
            assert!(p.right > 0.0 && p.right < 1.10, "{p:?}");
        }
        let bern: Vec<&Figure1Point> =
            pts.iter().filter(|p| p.method == "Bernstein").collect();
        let lo = bern.iter().min_by_key(|p| p.s).unwrap();
        let hi = bern.iter().max_by_key(|p| p.s).unwrap();
        assert!(hi.left >= lo.left - 0.02, "lo={:?} hi={:?}", lo, hi);
        assert!(hi.right >= lo.right - 0.02);
    }
}
