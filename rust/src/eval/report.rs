//! Report emission: CSV files and markdown tables under a reports dir.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::error::Result;

/// A simple row-oriented table writer (CSV + aligned markdown).
#[derive(Clone, Debug)]
pub struct Table {
    /// Table name (file stem).
    pub name: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// String-rendered rows.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table.
    pub fn new(name: &str, headers: &[&str]) -> Table {
        Table {
            name: name.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header count).
    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.headers.len(), "table {}", self.name);
        self.rows.push(row);
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Render as a markdown table.
    pub fn to_markdown(&self) -> String {
        let ncols = self.headers.len();
        let mut width = vec![0usize; ncols];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            s.push('\n');
            s
        };
        let mut out = fmt_row(&self.headers);
        out.push('|');
        for w in &width {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Write `<dir>/<name>.csv` and `<dir>/<name>.md`.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        fs::create_dir_all(dir)?;
        let csv_path = dir.join(format!("{}.csv", self.name));
        fs::write(&csv_path, self.to_csv())?;
        let md_path = dir.join(format!("{}.md", self.name));
        let mut f = fs::File::create(&md_path)?;
        writeln!(f, "# {}\n", self.name)?;
        f.write_all(self.to_markdown().as_bytes())?;
        Ok(csv_path)
    }
}

/// Per-shard spill-depth histogram table (engine backpressure telemetry):
/// one row per `(run, shard)`, one column per depth bucket. Runs whose
/// metrics carry no histogram (single-threaded modes, cache hits) are
/// skipped.
pub fn spill_depth_table(
    name: &str,
    runs: &[(String, crate::engine::PipelineMetrics)],
) -> Table {
    use crate::engine::metrics::SPILL_DEPTH_LABELS;
    let mut headers: Vec<&str> = vec!["run", "shard"];
    headers.extend(SPILL_DEPTH_LABELS.iter().copied());
    let mut t = Table::new(name, &headers);
    for (label, m) in runs {
        for (shard, hist) in m.spill_depth_hist.iter().enumerate() {
            let mut row = vec![label.clone(), shard.to_string()];
            row.extend(hist.iter().map(|c| c.to_string()));
            t.push(row);
        }
    }
    t
}

/// Render a telemetry snapshot (or a [`diff`](crate::obs::MetricsSnapshot::diff)
/// between two scrapes) as the `server_metrics` report table: one row per
/// counter / gauge / histogram, plus the derived open-cache hit rate.
/// Histogram rows carry the observation count, interpolated p50/p95/p99
/// (µs), and the non-empty log₂ buckets as `lo-hi:count` cells — the
/// freshness-lag and per-op latency shapes survive into the CSV.
pub fn server_metrics_table(snap: &crate::obs::MetricsSnapshot) -> Table {
    let mut t = Table::new(
        "server_metrics",
        &["metric", "kind", "value", "p50_us", "p95_us", "p99_us", "buckets"],
    );
    let scalar = |name: &str, kind: &str, value: String| {
        vec![
            name.to_string(),
            kind.to_string(),
            value,
            String::new(),
            String::new(),
            String::new(),
            String::new(),
        ]
    };
    for (name, v) in &snap.counters {
        t.push(scalar(name, "counter", v.to_string()));
    }
    let hits = snap.counter("open_cache_hit");
    let misses = snap.counter("open_cache_miss");
    let rate = if hits + misses == 0 { 0.0 } else { hits as f64 / (hits + misses) as f64 };
    t.push(scalar("open_cache_hit_rate", "derived", fixed(rate, 3)));
    // per-second rates over the uptime counter: a cumulative snapshot
    // yields lifetime averages, and because uptime_us is monotone, a
    // scrape diff yields true interval rates (the `stats --watch` view)
    let uptime_s = snap.counter("uptime_us") as f64 / 1e6;
    let per_s = |v: u64| if uptime_s > 0.0 { v as f64 / uptime_s } else { 0.0 };
    let req_total: u64 = snap
        .counters
        .iter()
        .filter(|(n, _)| n.starts_with("req_"))
        .map(|(_, v)| *v)
        .sum();
    t.push(scalar("qps", "derived", fixed(per_s(req_total), 1)));
    t.push(scalar(
        "net_bytes_in_per_s",
        "derived",
        fixed(per_s(snap.counter("net_bytes_in")), 1),
    ));
    t.push(scalar(
        "net_bytes_out_per_s",
        "derived",
        fixed(per_s(snap.counter("net_bytes_out")), 1),
    ));
    for (name, v) in &snap.gauges {
        t.push(scalar(name, "gauge", v.to_string()));
    }
    for (name, buckets) in &snap.hists {
        let count: u64 = buckets.iter().sum();
        let cells: Vec<String> = buckets
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(i, &c)| {
                let (lo, hi) = crate::obs::hist_bucket_bounds(i);
                format!("{}-{}:{c}", lo as u64, hi as u64)
            })
            .collect();
        t.push(vec![
            name.clone(),
            "hist".to_string(),
            count.to_string(),
            fixed(snap.hist_quantile(name, 0.50), 1),
            fixed(snap.hist_quantile(name, 0.95), 1),
            fixed(snap.hist_quantile(name, 0.99), 1),
            cells.join(" "),
        ]);
    }
    t
}

/// Render completed request traces as the slow-query report table: one
/// row per span — trace id, span id / parent link, stage name, start
/// offset and duration (µs), and the span's `key=value` notes. Written
/// as `reports/slow_queries.{csv,md}` by the net-bench and live-bench
/// harnesses from the server's trace retention rings.
pub fn trace_table(name: &str, traces: &[crate::obs::TraceRecord]) -> Table {
    let mut t = Table::new(
        name,
        &["trace", "span", "parent", "name", "start_us", "dur_us", "notes"],
    );
    for rec in traces {
        for s in &rec.spans {
            let notes: Vec<String> =
                s.notes.iter().map(|(k, v)| format!("{k}={v}")).collect();
            t.push(vec![
                format!("{:016x}", rec.trace),
                s.id.to_string(),
                s.parent.to_string(),
                s.name.clone(),
                s.start_us.to_string(),
                s.duration_us().to_string(),
                notes.join(" "),
            ]);
        }
    }
    t
}

/// Scientific-notation cell matching the paper's table style (`1.3e+4`).
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    format!("{x:.1e}")
}

/// Fixed-precision cell.
pub fn fixed(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_and_markdown_render() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.push(vec!["1".into(), "x".into()]);
        t.push(vec!["22".into(), "yyy".into()]);
        let csv = t.to_csv();
        assert!(csv.starts_with("a,bb\n1,x\n"));
        let md = t.to_markdown();
        assert!(md.contains("| a  | bb  |"));
        assert!(md.lines().count() == 4);
    }

    #[test]
    fn writes_files() {
        let dir = std::env::temp_dir().join("matsketch_report_test");
        let mut t = Table::new("demo", &["a"]);
        t.push(vec!["1".into()]);
        let p = t.write(&dir).unwrap();
        assert!(p.exists());
        assert!(dir.join("demo.md").exists());
    }

    #[test]
    fn sci_format() {
        assert_eq!(sci(13000.0), "1.3e4");
        assert_eq!(sci(0.0), "0");
    }

    #[test]
    fn server_metrics_table_renders_every_section() {
        use crate::obs::{hist_bucket, MetricsSnapshot, HIST_BUCKETS};
        let mut counts = vec![0u64; HIST_BUCKETS];
        counts[hist_bucket(100)] = 10;
        let snap = MetricsSnapshot {
            counters: vec![
                ("req_matvec".into(), 10),
                ("open_cache_hit".into(), 3),
                ("open_cache_miss".into(), 1),
                ("uptime_us".into(), 2_000_000),
                ("net_bytes_in".into(), 4_000),
            ],
            gauges: vec![("net_connections".into(), 2)],
            hists: vec![("exec_matvec_us".into(), counts)],
        };
        let t = server_metrics_table(&snap);
        assert_eq!(t.name, "server_metrics");
        // 5 counters + 4 derived (hit rate, qps, bytes in/out per s)
        // + 1 gauge + 1 hist
        assert_eq!(t.rows.len(), 11);
        let rate = t.rows.iter().find(|r| r[0] == "open_cache_hit_rate").unwrap();
        assert_eq!(rate[2], "0.750");
        // 10 req_* over 2 s of uptime
        let qps = t.rows.iter().find(|r| r[0] == "qps").unwrap();
        assert_eq!(qps[2], "5.0");
        let bin = t.rows.iter().find(|r| r[0] == "net_bytes_in_per_s").unwrap();
        assert_eq!(bin[2], "2000.0");
        let hist = t.rows.iter().find(|r| r[0] == "exec_matvec_us").unwrap();
        assert_eq!(hist[2], "10");
        assert!(hist[6].contains("64-128:10"), "{:?}", hist[6]);
        // CSV-safe: no cell smuggles a comma
        assert!(!t.to_csv().lines().any(|l| l.matches(',').count() != 6));
    }

    #[test]
    fn rates_are_zero_without_uptime() {
        use crate::obs::MetricsSnapshot;
        let snap = MetricsSnapshot {
            counters: vec![("req_ping".into(), 7)],
            gauges: vec![],
            hists: vec![],
        };
        let t = server_metrics_table(&snap);
        let qps = t.rows.iter().find(|r| r[0] == "qps").unwrap();
        assert_eq!(qps[2], "0.0");
    }

    #[test]
    fn trace_table_one_row_per_span() {
        use crate::obs::{SpanRecord, TraceRecord};
        let rec = TraceRecord {
            trace: 0xBEEF,
            spans: vec![
                SpanRecord {
                    id: 1,
                    parent: 0,
                    name: "request".into(),
                    start_us: 0,
                    end_us: 900,
                    notes: vec![("op".into(), "matvec".into())],
                },
                SpanRecord {
                    id: 2,
                    parent: 1,
                    name: "queue_wait".into(),
                    start_us: 5,
                    end_us: 40,
                    notes: vec![],
                },
            ],
        };
        let t = trace_table("slow_queries", &[rec]);
        assert_eq!(t.name, "slow_queries");
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "000000000000beef");
        assert_eq!(t.rows[0][3], "request");
        assert_eq!(t.rows[0][5], "900");
        assert_eq!(t.rows[0][6], "op=matvec");
        assert_eq!(t.rows[1][2], "1");
        assert!(!t.to_csv().lines().any(|l| l.matches(',').count() != 6));
    }

    #[test]
    fn spill_table_rows_per_shard() {
        use crate::engine::metrics::SPILL_DEPTH_BUCKETS;
        use crate::engine::PipelineMetrics;
        let mut m = PipelineMetrics::default();
        let mut h = [0u64; SPILL_DEPTH_BUCKETS];
        h[0] = 3;
        m.spill_depth_hist = vec![h, h];
        let runs = vec![
            ("sharded".to_string(), m),
            ("offline".to_string(), PipelineMetrics::default()),
        ];
        let t = spill_depth_table("spill_depth", &runs);
        assert_eq!(t.rows.len(), 2); // two shards, zero for the offline run
        assert_eq!(t.headers.len(), 2 + SPILL_DEPTH_BUCKETS);
        assert_eq!(t.rows[1][1], "1");
    }
}
