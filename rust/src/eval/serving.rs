//! E9 — serving-layer throughput: concurrent-reader queries/sec against
//! the compressed sketch, measured through the unified client API
//! ([`crate::api::SketchClient`]) over a [`LocalClient`], fed from the
//! persistent sketch store.
//!
//! For each dataset the driver resolves the sketch through the store
//! (building + persisting on the first run, hitting the cache on repeats),
//! then measures batched-matvec throughput at several reader counts.
//! Because the harness only sees `dyn SketchClient`, the same
//! measurement runs unmodified against a remote backend — the
//! `net_serving.*` tables from `eval::netbench` are directly comparable.
//! Three tables land in the report directory:
//!
//! * `serving` — dataset × readers → queries/sec (the ≥1
//!   concurrent-reader throughput numbers);
//! * `serving_batch` — dataset × batch size k → single-pass
//!   [`QueryRequest::MatvecBatch`] vs k independent matvecs (the
//!   payload-decode amortization win);
//! * `serving_spill_depth` — per-shard spill-depth histograms from the
//!   sharded sketch builds that fed the store (backpressure telemetry);
//! * `live_serving` (from [`run_live_bench`]) — mixed ingest+query runs
//!   against a live generation chain: queries/sec and latency
//!   percentiles measured *while* the stream is arriving, plus the
//!   freshness lag (entry arrival → generation live) p50/p95.
//! * `slow_queries` (from [`run_live_bench`]) — the run's slowest
//!   request span trees from the in-process trace collector, flattened
//!   to one row per span (sampling is forced to every request for the
//!   bench's duration).

use std::path::Path;
use std::time::Instant;

use crate::api::{BoxedSketchClient, LocalClient, QueryRequest, SketchClient};
use crate::datasets::DatasetId;
use crate::distributions::DistributionKind;
use crate::engine::{self, PipelineConfig, SketchMode};
use crate::error::Result;
use crate::net::{run_live_load, LoadGenConfig, LoadOp};
use crate::serve::{LiveConfig, LiveSketch, SketchStore, StoreKey};
use crate::sketch::SketchPlan;
use crate::sparse::Entry;
use crate::util::rng::Rng;

use super::report::{fixed, spill_depth_table, Table};

/// Serve-bench knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Concurrent reader (worker) counts to measure.
    pub readers: Vec<usize>,
    /// Queries per measurement.
    pub queries: usize,
    /// Batch sizes for the single-pass SpMM table (`MatvecBatch` with k
    /// right-hand sides vs k independent matvecs).
    pub batch_ks: Vec<usize>,
    /// Budget as `s = nnz / budget_frac` (min 1000).
    pub budget_frac: u64,
    /// Sketching / query seed.
    pub seed: u64,
    /// Use reduced-size dataset variants.
    pub small: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            readers: vec![1, 2, 4],
            queries: 64,
            batch_ks: vec![1, 4, 16],
            budget_frac: 10,
            seed: 0,
            small: true,
        }
    }
}

/// One throughput measurement.
#[derive(Clone, Debug)]
pub struct ServePoint {
    /// Dataset name.
    pub dataset: String,
    /// Distribution name.
    pub method: String,
    /// Sample budget.
    pub s: u64,
    /// Concurrent readers.
    pub readers: usize,
    /// Queries issued.
    pub queries: u64,
    /// Measured queries/second.
    pub qps: f64,
    /// Whether the sketch came from the store cache.
    pub cache_hit: bool,
}

/// One batched-SpMM measurement: `MatvecBatch` with `k` right-hand sides
/// (one payload pass) vs `k` independent matvecs (`k` passes), on one
/// worker so the comparison isolates decode amortization.
#[derive(Clone, Debug)]
pub struct BatchPoint {
    /// Dataset name.
    pub dataset: String,
    /// Distribution name.
    pub method: String,
    /// Sample budget.
    pub s: u64,
    /// Right-hand sides per batch.
    pub k: usize,
    /// Batches timed.
    pub reps: usize,
    /// Mean µs per `MatvecBatch(k)` request.
    pub batch_us: f64,
    /// Mean µs for the k independent matvecs it replaces.
    pub indep_us: f64,
}

impl BatchPoint {
    /// Independent-path time over batched-path time (> 1 = batching
    /// wins).
    pub fn speedup(&self) -> f64 {
        if self.batch_us > 0.0 { self.indep_us / self.batch_us } else { 0.0 }
    }
}

/// Run the serving benchmark; writes `serving.csv`/`.md`,
/// `serving_batch.csv`/`.md`, and `serving_spill_depth.csv`/`.md` under
/// `dir`, using (and populating) the sketch store at `store_dir`.
pub fn run_serve_bench(
    dir: &Path,
    store_dir: &Path,
    cfg: &ServeConfig,
    datasets: &[DatasetId],
) -> Result<Vec<ServePoint>> {
    let store = SketchStore::open(store_dir)?;
    let kind = DistributionKind::Bernstein;
    let mut points = Vec::new();
    let mut batch_points = Vec::new();
    let mut build_metrics: Vec<(String, engine::PipelineMetrics)> = Vec::new();

    for id in datasets {
        let coo = if cfg.small { id.generate_small(cfg.seed) } else { id.generate(cfg.seed) };
        let s = (coo.nnz() as u64 / cfg.budget_frac.max(1)).max(1_000);
        let plan = SketchPlan::new(kind, s).with_seed(cfg.seed);
        // content fingerprint ties the cache entry to this exact input
        // matrix: a regenerated dataset reads back as a stale miss
        let key = StoreKey::new(id.name(), &kind.name(), s, cfg.seed)
            .with_fingerprint(crate::serve::coo_fingerprint(&coo));

        let mut metrics_slot: Option<engine::PipelineMetrics> = None;
        let (_, cache_hit) = store.get_or_build(&key, || {
            let (sk, metrics) =
                engine::sketch_coo(SketchMode::Sharded, &coo, &plan, &PipelineConfig::default())?;
            metrics_slot = Some(metrics);
            Ok(sk)
        })?;
        if let Some(m) = metrics_slot {
            crate::info!("serving: built {} ({})", key.file_name(), m.summary());
            build_metrics.push((id.name().to_string(), m));
        } else {
            crate::info!("serving: store cache hit for {}", key.file_name());
        }

        let n = coo.n;
        let mut rng = Rng::new(cfg.seed ^ 0x51_52_59);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

        for &readers in &cfg.readers {
            // one client per reader count: its worker pool is the
            // concurrency under test
            let mut client =
                LocalClient::new(SketchStore::open(store_dir)?).with_workers(readers);
            client.open(&key)?;
            // build the query batch outside the timed window and hand it
            // over by value, so qps measures serving, not
            // submission-side vector clones
            let batch = vec![QueryRequest::Matvec(x.clone()); cfg.queries];
            let t0 = Instant::now();
            for answer in client.query_batch(&key, batch)? {
                answer?;
            }
            let wall = t0.elapsed().as_secs_f64();
            client.close()?;
            let qps = if wall > 0.0 { cfg.queries as f64 / wall } else { 0.0 };
            points.push(ServePoint {
                dataset: id.name().to_string(),
                method: kind.name(),
                s,
                readers,
                queries: cfg.queries as u64,
                qps,
                cache_hit,
            });
        }

        batch_points.extend(measure_batches(store_dir, &key, id.name(), s, cfg, &x)?);
    }

    let mut t = Table::new(
        "serving",
        &["dataset", "method", "s", "readers", "queries", "qps", "cache"],
    );
    for p in &points {
        t.push(vec![
            p.dataset.clone(),
            p.method.clone(),
            p.s.to_string(),
            p.readers.to_string(),
            p.queries.to_string(),
            fixed(p.qps, 1),
            if p.cache_hit { "hit".into() } else { "build".into() },
        ]);
    }
    t.write(dir)?;
    serving_batch_table(&batch_points).write(dir)?;
    spill_depth_table("serving_spill_depth", &build_metrics).write(dir)?;
    Ok(points)
}

/// Time `MatvecBatch(k)` against k independent matvecs through one
/// single-worker client: same compute resources, so the ratio isolates
/// what the one-pass SpMM saves in repeated payload decodes.
fn measure_batches(
    store_dir: &Path,
    key: &StoreKey,
    dataset: &str,
    s: u64,
    cfg: &ServeConfig,
    x: &[f64],
) -> Result<Vec<BatchPoint>> {
    let mut out = Vec::new();
    let mut client = LocalClient::new(SketchStore::open(store_dir)?).with_workers(1);
    client.open(key)?;
    let reps = (cfg.queries / 8).clamp(2, 16);
    for &k in &cfg.batch_ks {
        if k == 0 {
            continue;
        }
        // all requests are pre-built outside the timed windows and
        // submitted by value, so both sides time pure serving; the
        // single worker drains each batch sequentially
        let xs: Vec<Vec<f64>> = vec![x.to_vec(); k];
        let batched = vec![QueryRequest::MatvecBatch(xs); reps];
        let independent = vec![QueryRequest::Matvec(x.to_vec()); k * reps];

        let t0 = Instant::now();
        for answer in client.query_batch(key, batched)? {
            answer?;
        }
        let batch_us = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;

        let t0 = Instant::now();
        for answer in client.query_batch(key, independent)? {
            answer?;
        }
        let indep_us = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;

        out.push(BatchPoint {
            dataset: dataset.to_string(),
            method: key.method.clone(),
            s,
            k,
            reps,
            batch_us,
            indep_us,
        });
    }
    client.close()?;
    Ok(out)
}

/// Live serve-bench knobs (the `live_serving` table).
#[derive(Clone, Debug)]
pub struct LiveBenchConfig {
    /// Stream shape (rows × cols).
    pub m: usize,
    /// Stream columns.
    pub n: usize,
    /// Stream entries ingested per run.
    pub entries: usize,
    /// Entries per published generation (the epoch tick).
    pub epoch_entries: usize,
    /// Sample budget `s`.
    pub s: u64,
    /// Concurrent query-client counts to measure.
    pub clients: Vec<usize>,
    /// Queries per client per run.
    pub queries_per_client: usize,
    /// Stream + sketching seed.
    pub seed: u64,
}

impl Default for LiveBenchConfig {
    fn default() -> Self {
        LiveBenchConfig {
            m: 64,
            n: 256,
            entries: 20_000,
            epoch_entries: 2_048,
            s: 2_000,
            clients: vec![2, 4],
            queries_per_client: 64,
            seed: 0,
        }
    }
}

/// One mixed ingest+query measurement.
#[derive(Clone, Debug)]
pub struct LivePoint {
    /// Dataset label (`synthetic-live`).
    pub dataset: String,
    /// Distribution name.
    pub method: String,
    /// Sample budget.
    pub s: u64,
    /// Concurrent query clients.
    pub clients: usize,
    /// Stream entries ingested during the run.
    pub entries: u64,
    /// Generations published during the run.
    pub generations: u64,
    /// Queries/second while the ingest writer was running.
    pub qps: f64,
    /// Median query latency under ingest (µs).
    pub p50_us: f64,
    /// 95th-percentile query latency under ingest (µs).
    pub p95_us: f64,
    /// Median freshness lag: epoch's first entry → generation live (ms).
    pub lag_p50_ms: f64,
    /// 95th-percentile freshness lag (ms).
    pub lag_p95_ms: f64,
}

/// A deterministic synthetic entry stream for the live bench.
fn live_stream(m: usize, n: usize, count: usize, seed: u64) -> Vec<Entry> {
    let mut rng = Rng::new(seed ^ 0x11FE);
    (0..count)
        .map(|_| {
            Entry::new(
                rng.usize_below(m) as u32,
                rng.usize_below(n) as u32,
                rng.normal() as f32 + 1.0,
            )
        })
        .collect()
}

/// Run the mixed ingest+query benchmark: for each client count, a fresh
/// live chain ingests the synthetic stream (publishing on the epoch
/// tick) while closed-loop [`LocalClient`] readers attached to the chain
/// query it. Writes `live_serving.csv`/`.md` under `dir`. The numbers to
/// watch: qps should hold up against the frozen `serving` table (reads
/// never block on ingest — publication is one pointer swap) and the
/// freshness lag is the cost of each offline prefix rebuild.
pub fn run_live_bench(
    dir: &Path,
    store_dir: &Path,
    cfg: &LiveBenchConfig,
) -> Result<Vec<LivePoint>> {
    let kind = DistributionKind::Bernstein;
    let plan = SketchPlan::new(kind, cfg.s).with_seed(cfg.seed);
    let key = StoreKey::new("synthetic-live", &kind.name(), cfg.s, cfg.seed);
    let stream = live_stream(cfg.m, cfg.n, cfg.entries, cfg.seed);
    // the clients resolve the key through a (possibly empty) store dir;
    // the live attachment wins before any disk lookup happens
    std::fs::create_dir_all(store_dir)?;
    let mut points = Vec::new();

    // trace every request for the bench's duration so the slow-query
    // table is populated: the local backend samples in-process (see
    // `api::local`), so the trees land in the global collector. Restored
    // after the measurement loop.
    let prev_one_in_n = crate::obs::trace::global().one_in_n();
    crate::obs::trace::set_trace_one_in_n(1);

    for &clients in &cfg.clients {
        let live_cfg =
            LiveConfig { epoch_entries: cfg.epoch_entries, retain: 4, workers: 2 };
        let live = LiveSketch::start(cfg.m, cfg.n, &plan, &live_cfg)?;
        let reader = live.reader();
        let lcfg = LoadGenConfig {
            clients,
            queries_per_client: cfg.queries_per_client,
            duration: None,
            ops: vec![LoadOp::Matvec, LoadOp::Row, LoadOp::TopK],
            top_k: 10,
            batch_k: 4,
            seed: cfg.seed,
        };
        let report = run_live_load(
            |_| {
                let mut client =
                    LocalClient::new(SketchStore::open(store_dir)?).with_workers(1);
                client.attach_live(&key, reader.clone());
                Ok(Box::new(client) as BoxedSketchClient)
            },
            &key,
            &lcfg,
            live,
            &stream,
            256,
        )?;
        crate::info!(
            "live-bench: {clients} clients, {} gens, {:.1} qps under ingest",
            report.generations,
            report.load.qps
        );
        points.push(LivePoint {
            dataset: "synthetic-live".into(),
            method: kind.name(),
            s: cfg.s,
            clients,
            entries: report.entries_ingested,
            generations: report.generations,
            qps: report.load.qps,
            p50_us: report.load.p50_us,
            p95_us: report.load.p95_us,
            lag_p50_ms: report.lag_p50_s * 1e3,
            lag_p95_ms: report.lag_p95_s * 1e3,
        });
    }

    crate::obs::trace::set_trace_one_in_n(prev_one_in_n);

    live_serving_table(&points).write(dir)?;
    super::report::trace_table("slow_queries", &crate::obs::trace::dump_slowest(16))
        .write(dir)?;
    Ok(points)
}

/// Render live points as the `live_serving` report table.
pub fn live_serving_table(points: &[LivePoint]) -> Table {
    let mut t = Table::new(
        "live_serving",
        &[
            "dataset",
            "method",
            "s",
            "clients",
            "entries",
            "generations",
            "qps",
            "p50_us",
            "p95_us",
            "lag_p50_ms",
            "lag_p95_ms",
        ],
    );
    for p in points {
        t.push(vec![
            p.dataset.clone(),
            p.method.clone(),
            p.s.to_string(),
            p.clients.to_string(),
            p.entries.to_string(),
            p.generations.to_string(),
            fixed(p.qps, 1),
            fixed(p.p50_us, 1),
            fixed(p.p95_us, 1),
            fixed(p.lag_p50_ms, 2),
            fixed(p.lag_p95_ms, 2),
        ]);
    }
    t
}

/// Render batch points as the `serving_batch` report table.
pub fn serving_batch_table(points: &[BatchPoint]) -> Table {
    let mut t = Table::new(
        "serving_batch",
        &["dataset", "method", "s", "k", "reps", "batch_us", "indep_us", "speedup"],
    );
    for p in points {
        t.push(vec![
            p.dataset.clone(),
            p.method.clone(),
            p.s.to_string(),
            p.k.to_string(),
            p.reps.to_string(),
            fixed(p.batch_us, 1),
            fixed(p.indep_us, 1),
            fixed(p.speedup(), 2),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_bench_reports_throughput_and_hits_cache_on_rerun() {
        let base = std::env::temp_dir()
            .join(format!("matsketch_serving_eval_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let out = base.join("reports");
        let store = base.join("store");
        let cfg = ServeConfig {
            readers: vec![1, 2],
            queries: 8,
            batch_ks: vec![1, 4],
            ..Default::default()
        };
        let datasets = [DatasetId::Synthetic];
        let pts = run_serve_bench(&out, &store, &cfg, &datasets).unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts.iter().all(|p| p.qps > 0.0));
        assert!(pts.iter().all(|p| !p.cache_hit));
        assert!(out.join("serving.csv").exists());
        assert!(out.join("serving_batch.csv").exists());
        assert!(out.join("serving_spill_depth.csv").exists());

        // second run must come from the store
        let pts2 = run_serve_bench(&out, &store, &cfg, &datasets).unwrap();
        assert!(pts2.iter().all(|p| p.cache_hit));
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn live_bench_reports_qps_and_freshness_under_ingest() {
        let base = std::env::temp_dir()
            .join(format!("matsketch_live_eval_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let out = base.join("reports");
        let store = base.join("store");
        let cfg = LiveBenchConfig {
            m: 16,
            n: 64,
            entries: 2_000,
            epoch_entries: 500,
            s: 400,
            clients: vec![2],
            queries_per_client: 16,
            seed: 1,
        };
        let pts = run_live_bench(&out, &store, &cfg).unwrap();
        assert_eq!(pts.len(), 1);
        let p = &pts[0];
        assert!(p.qps > 0.0, "qps {}", p.qps);
        assert!(p.generations >= 1, "generations {}", p.generations);
        assert_eq!(p.entries, 2_000);
        assert!(p.lag_p95_ms >= p.lag_p50_ms);
        assert!(out.join("live_serving.csv").exists());
        assert!(out.join("live_serving.md").exists());
        // the forced-sampling run leaves span trees in the collector;
        // the flattened slow-query table must hold local request roots
        let slow = std::fs::read_to_string(out.join("slow_queries.csv")).unwrap();
        assert!(
            slow.lines().any(|l| l.split(',').nth(3) == Some("request")),
            "no request root in slow_queries.csv:\n{slow}"
        );
        let _ = std::fs::remove_dir_all(&base);
    }
}
