//! E9 — serving-layer throughput: concurrent-reader queries/sec against
//! the compressed sketch, fed from the persistent [`SketchStore`].
//!
//! For each dataset the driver resolves the sketch through the store
//! (building + persisting on the first run, hitting the cache on repeats),
//! then measures [`QueryServer`] matvec throughput at several reader
//! counts. Two tables land in the report directory:
//!
//! * `serving` — dataset × readers → queries/sec (the ≥1
//!   concurrent-reader throughput numbers);
//! * `serving_spill_depth` — per-shard spill-depth histograms from the
//!   sharded sketch builds that fed the store (backpressure telemetry).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::datasets::DatasetId;
use crate::distributions::DistributionKind;
use crate::engine::{self, PipelineConfig, SketchMode};
use crate::error::Result;
use crate::serve::{Query, QueryServer, ServableSketch, SketchStore, StoreKey};
use crate::sketch::SketchPlan;
use crate::util::rng::Rng;

use super::report::{fixed, spill_depth_table, Table};

/// Serve-bench knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Concurrent reader (worker) counts to measure.
    pub readers: Vec<usize>,
    /// Queries per measurement.
    pub queries: usize,
    /// Budget as `s = nnz / budget_frac` (min 1000).
    pub budget_frac: u64,
    /// Sketching / query seed.
    pub seed: u64,
    /// Use reduced-size dataset variants.
    pub small: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            readers: vec![1, 2, 4],
            queries: 64,
            budget_frac: 10,
            seed: 0,
            small: true,
        }
    }
}

/// One throughput measurement.
#[derive(Clone, Debug)]
pub struct ServePoint {
    /// Dataset name.
    pub dataset: String,
    /// Distribution name.
    pub method: String,
    /// Sample budget.
    pub s: u64,
    /// Concurrent readers.
    pub readers: usize,
    /// Queries issued.
    pub queries: u64,
    /// Measured queries/second.
    pub qps: f64,
    /// Whether the sketch came from the store cache.
    pub cache_hit: bool,
}

/// Run the serving benchmark; writes `serving.csv`/`.md` and
/// `serving_spill_depth.csv`/`.md` under `dir`, using (and populating)
/// the sketch store at `store_dir`.
pub fn run_serve_bench(
    dir: &Path,
    store_dir: &Path,
    cfg: &ServeConfig,
    datasets: &[DatasetId],
) -> Result<Vec<ServePoint>> {
    let store = SketchStore::open(store_dir)?;
    let kind = DistributionKind::Bernstein;
    let mut points = Vec::new();
    let mut build_metrics: Vec<(String, engine::PipelineMetrics)> = Vec::new();

    for id in datasets {
        let coo = if cfg.small { id.generate_small(cfg.seed) } else { id.generate(cfg.seed) };
        let s = (coo.nnz() as u64 / cfg.budget_frac.max(1)).max(1_000);
        let plan = SketchPlan::new(kind, s).with_seed(cfg.seed);
        // content fingerprint ties the cache entry to this exact input
        // matrix: a regenerated dataset reads back as a stale miss
        let key = StoreKey::new(id.name(), &kind.name(), s, cfg.seed)
            .with_fingerprint(crate::serve::coo_fingerprint(&coo));

        let mut metrics_slot: Option<engine::PipelineMetrics> = None;
        let (enc, cache_hit) = store.get_or_build(&key, || {
            let (sk, metrics) =
                engine::sketch_coo(SketchMode::Sharded, &coo, &plan, &PipelineConfig::default())?;
            metrics_slot = Some(metrics);
            Ok(sk)
        })?;
        if let Some(m) = metrics_slot {
            crate::info!("serving: built {} ({})", key.file_name(), m.summary());
            build_metrics.push((id.name().to_string(), m));
        } else {
            crate::info!("serving: store cache hit for {}", key.file_name());
        }

        let sketch = Arc::new(ServableSketch::new(enc, kind.name())?);
        let (_, n) = sketch.shape();
        let mut rng = Rng::new(cfg.seed ^ 0x51_52_59);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

        for &readers in &cfg.readers {
            // build the query batch outside the timed window so qps
            // measures serving, not submission-side vector clones
            let batch: Vec<Query> = vec![Query::Matvec(x.clone()); cfg.queries];
            let server = QueryServer::start(Arc::clone(&sketch), readers);
            let t0 = Instant::now();
            let pending = server.submit_batch(batch);
            for p in pending {
                p.wait()?;
            }
            let wall = t0.elapsed().as_secs_f64();
            let stats = server.shutdown();
            debug_assert_eq!(stats.total(), cfg.queries as u64);
            let qps = if wall > 0.0 { cfg.queries as f64 / wall } else { 0.0 };
            points.push(ServePoint {
                dataset: id.name().to_string(),
                method: kind.name(),
                s,
                readers,
                queries: cfg.queries as u64,
                qps,
                cache_hit,
            });
        }
    }

    let mut t = Table::new(
        "serving",
        &["dataset", "method", "s", "readers", "queries", "qps", "cache"],
    );
    for p in &points {
        t.push(vec![
            p.dataset.clone(),
            p.method.clone(),
            p.s.to_string(),
            p.readers.to_string(),
            p.queries.to_string(),
            fixed(p.qps, 1),
            if p.cache_hit { "hit".into() } else { "build".into() },
        ]);
    }
    t.write(dir)?;
    spill_depth_table("serving_spill_depth", &build_metrics).write(dir)?;
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_bench_reports_throughput_and_hits_cache_on_rerun() {
        let base = std::env::temp_dir()
            .join(format!("matsketch_serving_eval_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let out = base.join("reports");
        let store = base.join("store");
        let cfg = ServeConfig {
            readers: vec![1, 2],
            queries: 8,
            ..Default::default()
        };
        let datasets = [DatasetId::Synthetic];
        let pts = run_serve_bench(&out, &store, &cfg, &datasets).unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts.iter().all(|p| p.qps > 0.0));
        assert!(pts.iter().all(|p| !p.cache_hit));
        assert!(out.join("serving.csv").exists());
        assert!(out.join("serving_spill_depth.csv").exists());

        // second run must come from the store
        let pts2 = run_serve_bench(&out, &store, &cfg, &datasets).unwrap();
        assert!(pts2.iter().all(|p| p.cache_hit));
        let _ = std::fs::remove_dir_all(&base);
    }
}
