//! E9 — serving-layer throughput: concurrent-reader queries/sec against
//! the compressed sketch, measured through the unified client API
//! ([`crate::api::SketchClient`]) over a [`LocalClient`], fed from the
//! persistent sketch store.
//!
//! For each dataset the driver resolves the sketch through the store
//! (building + persisting on the first run, hitting the cache on repeats),
//! then measures batched-matvec throughput at several reader counts.
//! Because the harness only sees `dyn SketchClient`, the same
//! measurement runs unmodified against a remote backend — the
//! `net_serving.*` tables from `eval::netbench` are directly comparable.
//! Three tables land in the report directory:
//!
//! * `serving` — dataset × readers → queries/sec (the ≥1
//!   concurrent-reader throughput numbers);
//! * `serving_batch` — dataset × batch size k → single-pass
//!   [`QueryRequest::MatvecBatch`] vs k independent matvecs (the
//!   payload-decode amortization win);
//! * `serving_spill_depth` — per-shard spill-depth histograms from the
//!   sharded sketch builds that fed the store (backpressure telemetry).

use std::path::Path;
use std::time::Instant;

use crate::api::{LocalClient, QueryRequest, SketchClient};
use crate::datasets::DatasetId;
use crate::distributions::DistributionKind;
use crate::engine::{self, PipelineConfig, SketchMode};
use crate::error::Result;
use crate::serve::{SketchStore, StoreKey};
use crate::sketch::SketchPlan;
use crate::util::rng::Rng;

use super::report::{fixed, spill_depth_table, Table};

/// Serve-bench knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Concurrent reader (worker) counts to measure.
    pub readers: Vec<usize>,
    /// Queries per measurement.
    pub queries: usize,
    /// Batch sizes for the single-pass SpMM table (`MatvecBatch` with k
    /// right-hand sides vs k independent matvecs).
    pub batch_ks: Vec<usize>,
    /// Budget as `s = nnz / budget_frac` (min 1000).
    pub budget_frac: u64,
    /// Sketching / query seed.
    pub seed: u64,
    /// Use reduced-size dataset variants.
    pub small: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            readers: vec![1, 2, 4],
            queries: 64,
            batch_ks: vec![1, 4, 16],
            budget_frac: 10,
            seed: 0,
            small: true,
        }
    }
}

/// One throughput measurement.
#[derive(Clone, Debug)]
pub struct ServePoint {
    /// Dataset name.
    pub dataset: String,
    /// Distribution name.
    pub method: String,
    /// Sample budget.
    pub s: u64,
    /// Concurrent readers.
    pub readers: usize,
    /// Queries issued.
    pub queries: u64,
    /// Measured queries/second.
    pub qps: f64,
    /// Whether the sketch came from the store cache.
    pub cache_hit: bool,
}

/// One batched-SpMM measurement: `MatvecBatch` with `k` right-hand sides
/// (one payload pass) vs `k` independent matvecs (`k` passes), on one
/// worker so the comparison isolates decode amortization.
#[derive(Clone, Debug)]
pub struct BatchPoint {
    /// Dataset name.
    pub dataset: String,
    /// Distribution name.
    pub method: String,
    /// Sample budget.
    pub s: u64,
    /// Right-hand sides per batch.
    pub k: usize,
    /// Batches timed.
    pub reps: usize,
    /// Mean µs per `MatvecBatch(k)` request.
    pub batch_us: f64,
    /// Mean µs for the k independent matvecs it replaces.
    pub indep_us: f64,
}

impl BatchPoint {
    /// Independent-path time over batched-path time (> 1 = batching
    /// wins).
    pub fn speedup(&self) -> f64 {
        if self.batch_us > 0.0 { self.indep_us / self.batch_us } else { 0.0 }
    }
}

/// Run the serving benchmark; writes `serving.csv`/`.md`,
/// `serving_batch.csv`/`.md`, and `serving_spill_depth.csv`/`.md` under
/// `dir`, using (and populating) the sketch store at `store_dir`.
pub fn run_serve_bench(
    dir: &Path,
    store_dir: &Path,
    cfg: &ServeConfig,
    datasets: &[DatasetId],
) -> Result<Vec<ServePoint>> {
    let store = SketchStore::open(store_dir)?;
    let kind = DistributionKind::Bernstein;
    let mut points = Vec::new();
    let mut batch_points = Vec::new();
    let mut build_metrics: Vec<(String, engine::PipelineMetrics)> = Vec::new();

    for id in datasets {
        let coo = if cfg.small { id.generate_small(cfg.seed) } else { id.generate(cfg.seed) };
        let s = (coo.nnz() as u64 / cfg.budget_frac.max(1)).max(1_000);
        let plan = SketchPlan::new(kind, s).with_seed(cfg.seed);
        // content fingerprint ties the cache entry to this exact input
        // matrix: a regenerated dataset reads back as a stale miss
        let key = StoreKey::new(id.name(), &kind.name(), s, cfg.seed)
            .with_fingerprint(crate::serve::coo_fingerprint(&coo));

        let mut metrics_slot: Option<engine::PipelineMetrics> = None;
        let (_, cache_hit) = store.get_or_build(&key, || {
            let (sk, metrics) =
                engine::sketch_coo(SketchMode::Sharded, &coo, &plan, &PipelineConfig::default())?;
            metrics_slot = Some(metrics);
            Ok(sk)
        })?;
        if let Some(m) = metrics_slot {
            crate::info!("serving: built {} ({})", key.file_name(), m.summary());
            build_metrics.push((id.name().to_string(), m));
        } else {
            crate::info!("serving: store cache hit for {}", key.file_name());
        }

        let n = coo.n;
        let mut rng = Rng::new(cfg.seed ^ 0x51_52_59);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

        for &readers in &cfg.readers {
            // one client per reader count: its worker pool is the
            // concurrency under test
            let mut client =
                LocalClient::new(SketchStore::open(store_dir)?).with_workers(readers);
            client.open(&key)?;
            // build the query batch outside the timed window and hand it
            // over by value, so qps measures serving, not
            // submission-side vector clones
            let batch = vec![QueryRequest::Matvec(x.clone()); cfg.queries];
            let t0 = Instant::now();
            for answer in client.query_batch(&key, batch)? {
                answer?;
            }
            let wall = t0.elapsed().as_secs_f64();
            client.close()?;
            let qps = if wall > 0.0 { cfg.queries as f64 / wall } else { 0.0 };
            points.push(ServePoint {
                dataset: id.name().to_string(),
                method: kind.name(),
                s,
                readers,
                queries: cfg.queries as u64,
                qps,
                cache_hit,
            });
        }

        batch_points.extend(measure_batches(store_dir, &key, id.name(), s, cfg, &x)?);
    }

    let mut t = Table::new(
        "serving",
        &["dataset", "method", "s", "readers", "queries", "qps", "cache"],
    );
    for p in &points {
        t.push(vec![
            p.dataset.clone(),
            p.method.clone(),
            p.s.to_string(),
            p.readers.to_string(),
            p.queries.to_string(),
            fixed(p.qps, 1),
            if p.cache_hit { "hit".into() } else { "build".into() },
        ]);
    }
    t.write(dir)?;
    serving_batch_table(&batch_points).write(dir)?;
    spill_depth_table("serving_spill_depth", &build_metrics).write(dir)?;
    Ok(points)
}

/// Time `MatvecBatch(k)` against k independent matvecs through one
/// single-worker client: same compute resources, so the ratio isolates
/// what the one-pass SpMM saves in repeated payload decodes.
fn measure_batches(
    store_dir: &Path,
    key: &StoreKey,
    dataset: &str,
    s: u64,
    cfg: &ServeConfig,
    x: &[f64],
) -> Result<Vec<BatchPoint>> {
    let mut out = Vec::new();
    let mut client = LocalClient::new(SketchStore::open(store_dir)?).with_workers(1);
    client.open(key)?;
    let reps = (cfg.queries / 8).clamp(2, 16);
    for &k in &cfg.batch_ks {
        if k == 0 {
            continue;
        }
        // all requests are pre-built outside the timed windows and
        // submitted by value, so both sides time pure serving; the
        // single worker drains each batch sequentially
        let xs: Vec<Vec<f64>> = vec![x.to_vec(); k];
        let batched = vec![QueryRequest::MatvecBatch(xs); reps];
        let independent = vec![QueryRequest::Matvec(x.to_vec()); k * reps];

        let t0 = Instant::now();
        for answer in client.query_batch(key, batched)? {
            answer?;
        }
        let batch_us = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;

        let t0 = Instant::now();
        for answer in client.query_batch(key, independent)? {
            answer?;
        }
        let indep_us = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;

        out.push(BatchPoint {
            dataset: dataset.to_string(),
            method: key.method.clone(),
            s,
            k,
            reps,
            batch_us,
            indep_us,
        });
    }
    client.close()?;
    Ok(out)
}

/// Render batch points as the `serving_batch` report table.
pub fn serving_batch_table(points: &[BatchPoint]) -> Table {
    let mut t = Table::new(
        "serving_batch",
        &["dataset", "method", "s", "k", "reps", "batch_us", "indep_us", "speedup"],
    );
    for p in points {
        t.push(vec![
            p.dataset.clone(),
            p.method.clone(),
            p.s.to_string(),
            p.k.to_string(),
            p.reps.to_string(),
            fixed(p.batch_us, 1),
            fixed(p.indep_us, 1),
            fixed(p.speedup(), 2),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_bench_reports_throughput_and_hits_cache_on_rerun() {
        let base = std::env::temp_dir()
            .join(format!("matsketch_serving_eval_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let out = base.join("reports");
        let store = base.join("store");
        let cfg = ServeConfig {
            readers: vec![1, 2],
            queries: 8,
            batch_ks: vec![1, 4],
            ..Default::default()
        };
        let datasets = [DatasetId::Synthetic];
        let pts = run_serve_bench(&out, &store, &cfg, &datasets).unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts.iter().all(|p| p.qps > 0.0));
        assert!(pts.iter().all(|p| !p.cache_hit));
        assert!(out.join("serving.csv").exists());
        assert!(out.join("serving_batch.csv").exists());
        assert!(out.join("serving_spill_depth.csv").exists());

        // second run must come from the store
        let pts2 = run_serve_bench(&out, &store, &cfg, &datasets).unwrap();
        assert!(pts2.iter().all(|p| p.cache_hit));
        let _ = std::fs::remove_dir_all(&base);
    }
}
