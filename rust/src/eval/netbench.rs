//! E11 — network serving throughput: closed-loop remote query load
//! against a live wire-protocol server, driven through the unified
//! [`crate::api::SketchClient`] surface (the load generator only sees
//! `dyn SketchClient`) and reported next to the in-process `serving.*`
//! numbers — same harness, different backend, directly comparable.
//!
//! Default mode self-hosts: each dataset's sketch is resolved through
//! the persistent store (build + persist on first run, fingerprint-
//! checked cache hit on repeats), a [`NetServer`] is bound on an
//! ephemeral loopback port, and [`run_load`] drives it at several client
//! counts. Passing `addr` instead points the load at an already-running
//! `matsketch serve` process. Two tables land in the report directory:
//!
//! * `net_serving` — dataset × clients → queries/sec + latency
//!   percentiles (p50/p95/p99 µs).
//! * `server_metrics` — the server's own telemetry over exactly this
//!   run: the [`crate::obs`] registry is scraped (wire `Stats` opcode)
//!   before and after the measurements and the two snapshots diffed, so
//!   per-opcode counts, execute-latency histograms, cache hit rate, and
//!   live freshness-lag buckets cover the bench alone. The per-op
//!   request counts are logged next to the client-side issue totals as a
//!   consistency check.
//! * `slow_queries` — the slowest request traces of the run, fetched
//!   from the server's retention rings (wire `TraceDump` opcode, v5) and
//!   flattened to one row per span. Sampling is forced to every request
//!   for the bench's duration so the table is populated.

use std::path::Path;
use std::time::Duration;

use crate::datasets::DatasetId;
use crate::distributions::DistributionKind;
use crate::engine::{self, PipelineConfig, SketchMode};
use crate::error::Result;
use crate::net::{run_load, scrape_stats, LoadGenConfig, LoadOp, NetServer, NetServerConfig};
use crate::obs::MetricsSnapshot;
use crate::serve::{coo_fingerprint, SketchStore, StoreKey};
use crate::sketch::SketchPlan;

use super::report::{fixed, Table};

/// Net-bench knobs.
#[derive(Clone, Debug)]
pub struct NetBenchConfig {
    /// Concurrent client counts to measure.
    pub clients: Vec<usize>,
    /// Queries per client per measurement (ignored with `duration_secs`).
    pub queries: usize,
    /// Run each measurement for a fixed time instead (the CI smoke).
    pub duration_secs: Option<f64>,
    /// Operation mix, cycled per query.
    pub ops: Vec<LoadOp>,
    /// `k` for top-k queries.
    pub top_k: usize,
    /// Right-hand sides per `matvec-batch` request in the op mix.
    pub batch_k: usize,
    /// Budget as `s = nnz / budget_frac` (min 1000).
    pub budget_frac: u64,
    /// Sketching / query seed.
    pub seed: u64,
    /// Use reduced-size dataset variants.
    pub small: bool,
    /// Server-side query workers per sketch (self-hosted mode).
    pub workers: usize,
}

impl Default for NetBenchConfig {
    fn default() -> Self {
        NetBenchConfig {
            clients: vec![1, 2, 8],
            queries: 64,
            duration_secs: None,
            ops: vec![LoadOp::Matvec, LoadOp::Row, LoadOp::TopK],
            top_k: 10,
            batch_k: 4,
            budget_frac: 10,
            seed: 0,
            small: true,
            workers: 4,
        }
    }
}

/// One remote-throughput measurement.
#[derive(Clone, Debug)]
pub struct NetPoint {
    /// Dataset name.
    pub dataset: String,
    /// Distribution name.
    pub method: String,
    /// Sample budget.
    pub s: u64,
    /// Concurrent clients.
    pub clients: usize,
    /// Successful queries.
    pub queries: u64,
    /// Failed queries.
    pub errors: u64,
    /// Queries per second.
    pub qps: f64,
    /// Median latency (µs).
    pub p50_us: f64,
    /// 95th percentile latency (µs).
    pub p95_us: f64,
    /// 99th percentile latency (µs).
    pub p99_us: f64,
}

/// Run the network serving benchmark; writes `net_serving.csv`/`.md`
/// under `dir`. With `addr = None` the server is self-hosted on loopback
/// over the store at `store_dir` (populating it as needed); with
/// `addr = Some(..)` an external `matsketch serve` is measured and the
/// store is only used to derive the same keys the server holds.
pub fn run_net_bench(
    dir: &Path,
    store_dir: &Path,
    addr: Option<&str>,
    cfg: &NetBenchConfig,
    datasets: &[DatasetId],
) -> Result<Vec<NetPoint>> {
    let store = SketchStore::open(store_dir)?;
    let kind = DistributionKind::Bernstein;
    let mut points = Vec::new();

    // resolve every dataset's key (and, when self-hosting, make sure the
    // store actually holds its sketch) before any server starts
    let mut keys: Vec<(DatasetId, StoreKey)> = Vec::new();
    for id in datasets {
        let coo = if cfg.small { id.generate_small(cfg.seed) } else { id.generate(cfg.seed) };
        let s = (coo.nnz() as u64 / cfg.budget_frac.max(1)).max(1_000);
        let plan = SketchPlan::new(kind, s).with_seed(cfg.seed);
        let key = StoreKey::new(id.name(), &kind.name(), s, cfg.seed)
            .with_fingerprint(coo_fingerprint(&coo));
        if addr.is_none() {
            let (_, cache_hit) = store.get_or_build(&key, || {
                let (sk, _) = engine::sketch_coo(
                    SketchMode::Sharded,
                    &coo,
                    &plan,
                    &PipelineConfig::default(),
                )?;
                Ok(sk)
            })?;
            crate::info!(
                "net-bench: {} {}",
                key.file_name(),
                if cache_hit { "from store cache" } else { "built + persisted" }
            );
        }
        keys.push((*id, key));
    }

    // self-host on an ephemeral loopback port unless aimed at a live server
    let server = match addr {
        Some(_) => None,
        None => Some(NetServer::bind(
            SketchStore::open(store_dir)?,
            "127.0.0.1:0",
            NetServerConfig {
                workers_per_sketch: cfg.workers.max(1),
                // every client holds one connection; leave headroom
                max_connections: cfg.clients.iter().copied().max().unwrap_or(1) * 2 + 8,
                ..Default::default()
            },
        )?),
    };
    let target = match (&server, addr) {
        (Some(srv), _) => srv.local_addr().to_string(),
        (None, Some(a)) => a.to_string(),
        (None, None) => unreachable!("either self-hosted or external"),
    };

    let before = try_scrape(&target);
    // trace every request for the bench's duration so the slow-query
    // table is populated; span recording is a few ring writes per
    // request, noise next to the socket round trip. Restored after.
    let prev_one_in_n = crate::obs::trace::global().one_in_n();
    crate::obs::trace::set_trace_one_in_n(1);
    let result = measure_all(&keys, cfg, &target, &mut points);
    crate::obs::trace::set_trace_one_in_n(prev_one_in_n);
    let after = try_scrape(&target);
    let traces = fetch_slow_traces(&target, 16);
    if let Some(server) = server {
        let stats = server.shutdown();
        crate::info!(
            "net-bench: server served {} frames over {} connections ({} faults)",
            stats.frames,
            stats.connections,
            stats.faults
        );
    }
    result?;

    net_serving_table(&points).write(dir)?;
    if let (Some(before), Some(after)) = (before, after) {
        let delta = after.diff(&before);
        let answered: u64 = [
            "req_matvec",
            "req_matvec_t",
            "req_matvec_batch",
            "req_row",
            "req_col",
            "req_top_k",
        ]
        .iter()
        .map(|n| delta.counter(n))
        .sum();
        let issued: u64 = points.iter().map(|p| p.queries + p.errors).sum();
        crate::info!(
            "net-bench: server-side telemetry counted {answered} query frames; \
             load clients issued {issued}"
        );
        super::report::server_metrics_table(&delta).write(dir)?;
    }
    // always written (header-only when no traces came back), so report
    // consumers can rely on the file existing after every run
    super::report::trace_table("slow_queries", &traces).write(dir)?;
    Ok(points)
}

/// Fetch the slowest completed traces from the target's retention rings
/// (wire `TraceDump`, protocol v5); a failure — an old server without
/// the opcode, say — downgrades the slow-query table to a warning plus
/// an empty table instead of failing the whole bench.
fn fetch_slow_traces(target: &str, n: u32) -> Vec<crate::obs::TraceRecord> {
    match crate::net::RemoteSketchClient::connect(target).and_then(|mut c| c.trace_dump(0, n)) {
        Ok(traces) => traces,
        Err(e) => {
            crate::warn_log!("net-bench: trace dump of {target} failed: {e}");
            Vec::new()
        }
    }
}

/// Scrape the target's telemetry (`Stats`, protocol v4); a failure — an
/// old server without the opcode, say — downgrades the server-metrics
/// table to a warning instead of failing the whole bench.
fn try_scrape(target: &str) -> Option<MetricsSnapshot> {
    match scrape_stats(target) {
        Ok(snap) => Some(snap),
        Err(e) => {
            crate::warn_log!("net-bench: stats scrape of {target} failed: {e}");
            None
        }
    }
}

/// Drive every `(dataset, key) × client-count` measurement against
/// `target`, collecting points (split out so the caller can always shut
/// the self-hosted server down, even on error).
fn measure_all(
    keys: &[(DatasetId, StoreKey)],
    cfg: &NetBenchConfig,
    target: &str,
    points: &mut Vec<NetPoint>,
) -> Result<()> {
    for (id, key) in keys {
        for &clients in &cfg.clients {
            let load_cfg = LoadGenConfig {
                clients,
                queries_per_client: cfg.queries,
                duration: cfg.duration_secs.map(Duration::from_secs_f64),
                ops: cfg.ops.clone(),
                top_k: cfg.top_k,
                batch_k: cfg.batch_k,
                seed: cfg.seed,
            };
            let report = run_load(target, key, &load_cfg)?;
            crate::info!(
                "net-bench: {} clients={} -> {:.1} q/s (p50 {:.0} µs, p99 {:.0} µs)",
                id.name(),
                clients,
                report.qps,
                report.p50_us,
                report.p99_us
            );
            points.push(NetPoint {
                dataset: id.name().to_string(),
                method: key.method.clone(),
                s: key.s,
                clients,
                queries: report.queries,
                errors: report.errors,
                qps: report.qps,
                p50_us: report.p50_us,
                p95_us: report.p95_us,
                p99_us: report.p99_us,
            });
        }
    }
    Ok(())
}

/// Render net-bench points as the `net_serving` report table.
pub fn net_serving_table(points: &[NetPoint]) -> Table {
    let mut t = Table::new(
        "net_serving",
        &[
            "dataset", "method", "s", "clients", "queries", "errors", "qps", "p50_us",
            "p95_us", "p99_us",
        ],
    );
    for p in points {
        t.push(vec![
            p.dataset.clone(),
            p.method.clone(),
            p.s.to_string(),
            p.clients.to_string(),
            p.queries.to_string(),
            p.errors.to_string(),
            fixed(p.qps, 1),
            fixed(p.p50_us, 1),
            fixed(p.p95_us, 1),
            fixed(p.p99_us, 1),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_bench_self_hosts_and_reports() {
        let base =
            std::env::temp_dir().join(format!("matsketch_netbench_eval_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let out = base.join("reports");
        let store = base.join("store");
        let cfg = NetBenchConfig {
            clients: vec![1, 2],
            queries: 6,
            ..Default::default()
        };
        let datasets = [DatasetId::Synthetic];
        let pts = run_net_bench(&out, &store, None, &cfg, &datasets).unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts.iter().all(|p| p.qps > 0.0 && p.errors == 0));
        assert!(pts.iter().all(|p| p.p50_us <= p.p95_us && p.p95_us <= p.p99_us));
        assert!(out.join("net_serving.csv").exists());
        assert!(out.join("net_serving.md").exists());
        // the before/after telemetry scrape writes the server-metrics
        // table, and the diff covers at least this run's queries
        let metrics = std::fs::read_to_string(out.join("server_metrics.csv")).unwrap();
        assert!(out.join("server_metrics.md").exists());
        let issued: u64 = pts.iter().map(|p| p.queries).sum();
        let matvec_row = metrics
            .lines()
            .find(|l| l.starts_with("req_matvec,"))
            .expect("req_matvec row present");
        let count: u64 = matvec_row.split(',').nth(2).unwrap().parse().unwrap();
        assert!(count >= issued / 3, "matvec count {count} vs {issued} issued");
        // the trace fetch flattens the run's slowest span trees into the
        // slow-query table; with sampling forced to every request, the
        // self-hosted run must retain server-side `request` roots
        let slow = std::fs::read_to_string(out.join("slow_queries.csv")).unwrap();
        assert!(out.join("slow_queries.md").exists());
        assert!(
            slow.lines().any(|l| l.split(',').nth(3) == Some("request")),
            "no request root in slow_queries.csv:\n{slow}"
        );
        let _ = std::fs::remove_dir_all(&base);
    }
}
