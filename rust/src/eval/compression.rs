//! E3 — the §1 compression experiment: bits per sample of the sketch
//! codec, and the disc-size ratio against the standard row-column-value
//! list format (both raw and DEFLATE-compressed via a dependency-free
//! size *estimate* — see below).

use std::path::Path;

use crate::datasets::DatasetId;
use crate::distributions::DistributionKind;
use crate::error::Result;
use crate::sketch::{encode_sketch, sketch_offline, SketchPlan};
use crate::sparse::Csr;
use crate::util::log_space;

use super::report::{fixed, Table};

/// One measurement.
#[derive(Clone, Debug)]
pub struct CompressionPoint {
    /// Dataset.
    pub dataset: String,
    /// Budget.
    pub s: u64,
    /// Codec bits per sample (total).
    pub bits_per_sample: f64,
    /// Codec body bits per sample.
    pub body_bits_per_sample: f64,
    /// Codec size / raw COO size.
    pub vs_raw_coo: f64,
    /// Codec size / entropy-bound COO size (proxy for a compressed file).
    pub vs_compressed_coo: f64,
}

/// Entropy-style lower-bound estimate (bits) for a general-purpose
/// compressor on the COO list: `nnz·(log2(m) + log2(n) + value_bits)`
/// with `value_bits = 32` for arbitrary f32 payloads. General-purpose
/// compressors cannot beat the index entropy, so this is a *favourable*
/// stand-in for the paper's gzip baseline.
fn compressed_coo_bits(nnz: usize, m: usize, n: usize) -> f64 {
    nnz as f64 * ((m as f64).log2() + (n as f64).log2() + 32.0)
}

/// Run the sweep for one matrix.
pub fn compression_dataset(
    name: &str,
    a: &Csr,
    budgets: &[usize],
    seed: u64,
) -> Result<Vec<CompressionPoint>> {
    let mut out = Vec::new();
    for &s in budgets {
        let plan = SketchPlan::new(DistributionKind::Bernstein, s as u64).with_seed(seed);
        let sk = sketch_offline(a, &plan)?;
        let enc = encode_sketch(&sk)?;
        let raw_coo_bits = sk.nnz() as f64 * 96.0; // u32,u32,f32
        out.push(CompressionPoint {
            dataset: name.to_string(),
            s: s as u64,
            bits_per_sample: enc.bits_per_sample(),
            body_bits_per_sample: enc.body_bits_per_sample(),
            vs_raw_coo: enc.total_bits() as f64 / raw_coo_bits,
            vs_compressed_coo: enc.total_bits() as f64
                / compressed_coo_bits(sk.nnz(), sk.m, sk.n),
        });
    }
    Ok(out)
}

/// Full E3 run; writes `compression.csv`/`.md`.
pub fn run_compression(dir: &Path, small: bool, seed: u64) -> Result<Vec<CompressionPoint>> {
    let mut all = Vec::new();
    for id in DatasetId::all() {
        let coo = if small { id.generate_small(seed) } else { id.generate(seed) };
        let a = coo.to_csr();
        let budgets = log_space(
            (a.nnz() / 20).max(1_000),
            (a.nnz() * 2).max(2_000),
            5,
        );
        crate::info!("compression: {} nnz={} budgets={budgets:?}", id.name(), a.nnz());
        all.extend(compression_dataset(id.name(), &a, &budgets, seed)?);
    }
    let mut t = Table::new(
        "compression",
        &[
            "dataset", "s", "bits/sample", "body bits/sample",
            "codec/rawCOO", "codec/complessedCOO",
        ],
    );
    for p in &all {
        t.push(vec![
            p.dataset.clone(),
            p.s.to_string(),
            fixed(p.bits_per_sample, 2),
            fixed(p.body_bits_per_sample, 2),
            fixed(p.vs_raw_coo, 3),
            fixed(p.vs_compressed_coo, 3),
        ]);
    }
    t.write(dir)?;
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{synthetic_cf, SyntheticConfig};

    #[test]
    fn codec_beats_compressed_coo_stand_in() {
        let a = synthetic_cf(&SyntheticConfig { n: 4_000, ..Default::default() }).to_csr();
        let pts = compression_dataset("synthetic", &a, &[50_000], 0).unwrap();
        let p = &pts[0];
        // §1 claim: factor 2–5 over the compressed COO file
        assert!(p.vs_compressed_coo < 0.6, "ratio={}", p.vs_compressed_coo);
        assert!(p.bits_per_sample < 40.0, "bps={}", p.bits_per_sample);
    }

    #[test]
    fn bits_per_sample_decreases_with_oversampling() {
        // as s ≫ distinct coordinates, counts grow and per-sample cost drops
        let a = synthetic_cf(&SyntheticConfig { n: 400, ..Default::default() }).to_csr();
        let pts =
            compression_dataset("synthetic", &a, &[5_000, 500_000], 1).unwrap();
        assert!(pts[1].body_bits_per_sample < pts[0].body_bits_per_sample);
    }
}
