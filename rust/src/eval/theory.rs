//! E6 — empirical checks of the optimality theory (Theorems 4.3/4.4).
//!
//! The ε₅ objective (Lemma 5.4) is the quantity Algorithm 1 provably
//! minimizes; this driver (a) verifies the closed form beats a large
//! family of alternative row distributions on real matrix row-norm
//! profiles, and (b) traces the Bernstein→Row-L1/L1 interpolation as the
//! budget grows, reproducing the §1 "distributions depend on the budget"
//! insight as a table.

use std::path::Path;

use crate::datasets::DatasetId;
use crate::distributions::bernstein::{compute_row_distribution, epsilon5};
use crate::error::Result;
use crate::util::rng::Rng;

use super::report::{fixed, sci, Table};

/// One optimality measurement.
#[derive(Clone, Debug)]
pub struct TheoryPoint {
    /// Dataset.
    pub dataset: String,
    /// Budget.
    pub s: u64,
    /// ε₅ at the Bernstein ρ.
    pub eps5_bernstein: f64,
    /// ε₅ at plain-L1 ρ (ρ ∝ z).
    pub eps5_l1: f64,
    /// ε₅ at Row-L1 ρ (ρ ∝ z²).
    pub eps5_rowl1: f64,
    /// best ε₅ among random perturbations of the Bernstein ρ.
    pub eps5_best_perturbed: f64,
    /// total-variation distance of Bernstein ρ from plain-L1 ρ.
    pub tv_from_l1: f64,
    /// total-variation distance from Row-L1 ρ.
    pub tv_from_rowl1: f64,
}

fn tv(a: &[f64], b: &[f64]) -> f64 {
    0.5 * a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).sum::<f64>()
}

/// Run the checks on one row-norm profile.
pub fn theory_for_profile(
    dataset: &str,
    z: &[f64],
    n: usize,
    budgets: &[u64],
    delta: f64,
    seed: u64,
) -> Result<Vec<TheoryPoint>> {
    let total_z: f64 = z.iter().sum();
    let total_z2: f64 = z.iter().map(|x| x * x).sum();
    let l1: Vec<f64> = z.iter().map(|x| x / total_z).collect();
    let rowl1: Vec<f64> = z.iter().map(|x| x * x / total_z2).collect();
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for &s in budgets {
        let rho = compute_row_distribution(z, s, n, delta)?;
        let ours = epsilon5(z, &rho, s, n, delta);
        let mut best_pert = f64::INFINITY;
        for _ in 0..300 {
            let mut pert: Vec<f64> =
                rho.iter().map(|&r| if r > 0.0 { r * (0.2 * rng.normal()).exp() } else { 0.0 }).collect();
            let t: f64 = pert.iter().sum();
            pert.iter_mut().for_each(|p| *p /= t);
            best_pert = best_pert.min(epsilon5(z, &pert, s, n, delta));
        }
        out.push(TheoryPoint {
            dataset: dataset.to_string(),
            s,
            eps5_bernstein: ours,
            eps5_l1: epsilon5(z, &l1, s, n, delta),
            eps5_rowl1: epsilon5(z, &rowl1, s, n, delta),
            eps5_best_perturbed: best_pert,
            tv_from_l1: tv(&rho, &l1),
            tv_from_rowl1: tv(&rho, &rowl1),
        });
    }
    Ok(out)
}

/// Full E6 run over the four datasets' row-norm profiles.
pub fn run_theory(dir: &Path, small: bool, seed: u64) -> Result<Vec<TheoryPoint>> {
    let mut all = Vec::new();
    for id in DatasetId::all() {
        let coo = if small { id.generate_small(seed) } else { id.generate(seed) };
        let z = coo.row_l1_norms();
        let nnz = coo.nnz() as u64;
        let budgets = [nnz / 100, nnz / 10, nnz, nnz * 10, nnz * 100];
        all.extend(theory_for_profile(id.name(), &z, coo.n, &budgets, 0.1, seed)?);
    }
    let mut t = Table::new(
        "theory_eps5",
        &[
            "dataset", "s", "eps5(Bernstein)", "eps5(L1)", "eps5(Row-L1)",
            "eps5(best of 300 perturbations)", "TV(rho, L1)", "TV(rho, Row-L1)",
        ],
    );
    for p in &all {
        t.push(vec![
            p.dataset.clone(),
            p.s.to_string(),
            sci(p.eps5_bernstein),
            sci(p.eps5_l1),
            sci(p.eps5_rowl1),
            sci(p.eps5_best_perturbed),
            fixed(p.tv_from_l1, 4),
            fixed(p.tv_from_rowl1, 4),
        ]);
    }
    t.write(dir)?;
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bernstein_never_loses_and_interpolates() {
        let mut rng = Rng::new(0);
        let z: Vec<f64> = (0..60).map(|_| rng.f64_open() * 5.0 + 0.1).collect();
        let pts =
            theory_for_profile("t", &z, 10_000, &[10, 10_000, 100_000_000], 0.1, 1).unwrap();
        for p in &pts {
            assert!(p.eps5_bernstein <= p.eps5_l1 * (1.0 + 1e-9), "{p:?}");
            assert!(p.eps5_bernstein <= p.eps5_rowl1 * (1.0 + 1e-9), "{p:?}");
            assert!(p.eps5_bernstein <= p.eps5_best_perturbed * (1.0 + 1e-9), "{p:?}");
        }
        // interpolation: small budget near L1, large budget near Row-L1
        assert!(pts[0].tv_from_l1 < pts[0].tv_from_rowl1);
        assert!(pts[2].tv_from_rowl1 < pts[2].tv_from_l1);
    }
}
