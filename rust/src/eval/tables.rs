//! E1/E4: the §6 matrix-characteristics table and the §4
//! sample-complexity comparison table.

use std::path::Path;

use crate::datasets::DatasetId;
use crate::error::Result;
use crate::metrics::MatrixMetrics;
use crate::sparse::Csr;

use super::report::{fixed, sci, Table};

/// One row of the characteristics table.
#[derive(Clone, Debug)]
pub struct TableRow {
    /// Dataset name.
    pub name: String,
    /// Computed metrics.
    pub metrics: MatrixMetrics,
}

/// Compute the characteristics row for one matrix.
pub fn characteristics(name: &str, a: &Csr, seed: u64) -> TableRow {
    TableRow {
        name: name.to_string(),
        metrics: MatrixMetrics::compute(a, 120, seed),
    }
}

/// Run E1 + E4 over the four paper datasets (small = CI scale) and write
/// `table_characteristics` and `table_sample_complexity` under `dir`.
pub fn run_tables(dir: &Path, small: bool, seed: u64) -> Result<Vec<TableRow>> {
    let mut rows = Vec::new();
    for id in DatasetId::all() {
        let coo = if small { id.generate_small(seed) } else { id.generate(seed) };
        crate::info!("tables: {} generated ({}x{}, nnz={})", id.name(), coo.m, coo.n, coo.nnz());
        rows.push(characteristics(id.name(), &coo.to_csr(), seed));
    }
    write_tables(dir, &rows)?;
    Ok(rows)
}

/// Emit both tables for precomputed rows.
pub fn write_tables(dir: &Path, rows: &[TableRow]) -> Result<()> {
    let mut t1 = Table::new(
        "table_characteristics",
        &[
            "Measure", "m", "n", "nnz(A)", "|A|_1", "|A|_F", "|A|_2", "sr", "nd", "nrd",
            "cond1", "cond2", "cond3",
        ],
    );
    for r in rows {
        let m = &r.metrics;
        t1.push(vec![
            r.name.clone(),
            sci(m.m as f64),
            sci(m.n as f64),
            sci(m.nnz as f64),
            sci(m.norm_l1),
            sci(m.norm_fro),
            sci(m.norm_spec),
            sci(m.stable_rank),
            sci(m.numeric_density),
            sci(m.numeric_row_density),
            m.cond1.to_string(),
            m.cond2.to_string(),
            m.cond3.to_string(),
        ]);
    }
    t1.write(dir)?;

    // E4: sample bounds at ε = 0.1 (constants dropped, as in the paper's
    // comparison) and the improvement ratios of Theorem 4.4.
    let eps = 0.1;
    let mut t2 = Table::new(
        "table_sample_complexity",
        &[
            "Measure", "s0 (Thm 4.4)", "AM07", "DZ11", "AHK06",
            "DZ11/ours", "AHK06/ours", "nrd/n",
        ],
    );
    for r in rows {
        let m = &r.metrics;
        let ours = m.theorem44_s0(eps, 0.1);
        let (am07, dz11, ahk06) = m.prior_bounds(eps);
        t2.push(vec![
            r.name.clone(),
            sci(ours),
            sci(am07),
            sci(dz11),
            sci(ahk06),
            fixed(dz11 / ours, 1),
            fixed(ahk06 / ours, 3),
            sci(m.numeric_row_density / m.n as f64),
        ]);
    }
    t2.write(dir)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{synthetic_cf, SyntheticConfig};

    #[test]
    fn characteristics_row_sane() {
        let a = synthetic_cf(&SyntheticConfig { n: 1_000, ..Default::default() }).to_csr();
        let row = characteristics("synthetic", &a, 0);
        let m = &row.metrics;
        assert!(m.stable_rank >= 1.0 && m.stable_rank < m.m as f64);
        assert!(m.numeric_density <= m.nnz as f64 + 1.0);
        assert!(m.numeric_row_density <= m.n as f64);
    }

    #[test]
    fn write_tables_produces_files() {
        let dir = std::env::temp_dir().join("matsketch_tables_test");
        let a = synthetic_cf(&SyntheticConfig { n: 600, ..Default::default() }).to_csr();
        let rows = vec![characteristics("synthetic", &a, 0)];
        write_tables(&dir, &rows).unwrap();
        assert!(dir.join("table_characteristics.csv").exists());
        assert!(dir.join("table_sample_complexity.md").exists());
    }
}
