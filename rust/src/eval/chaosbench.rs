//! E13 — serving under faults: the net-bench closed loop pointed at a
//! self-hosted server with a seeded [`FaultPlan`] injecting disconnects,
//! partial writes, corrupted frames, and tarpits, plus queue-depth load
//! shedding engaged via a low high-water mark.
//!
//! The interesting numbers are the *resilience* ones: goodput (answers
//! that actually landed per second), how many client retries the fault
//! schedule forced, how many requests the server shed with an
//! `overloaded` pushback, and the accepted-work tail latency — all next
//! to the injected-fault count so a report row is interpretable on its
//! own. Counters come from the process-global [`crate::obs`] registry,
//! snapshotted around each measurement (client and self-hosted server
//! share the registry, so one diff covers both sides).
//!
//! One table lands in the report directory: `chaos_serving` — dataset ×
//! clients → goodput, errors, retries, deadline misses, shed count +
//! rate, injected faults, p50/p99 µs of accepted queries.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::datasets::DatasetId;
use crate::distributions::DistributionKind;
use crate::engine::{self, PipelineConfig, SketchMode};
use crate::error::Result;
use crate::net::{
    run_load, FaultPlan, LoadGenConfig, LoadOp, NetServer, NetServerConfig, RemoteSketchClient,
};
use crate::serve::{coo_fingerprint, SketchStore, StoreKey};
use crate::sketch::SketchPlan;

use super::report::{fixed, Table};

/// Chaos-bench knobs.
#[derive(Clone, Debug)]
pub struct ChaosBenchConfig {
    /// Concurrent client counts to measure.
    pub clients: Vec<usize>,
    /// Queries per client per measurement (ignored with `duration_secs`).
    pub queries: usize,
    /// Run each measurement for a fixed time instead (the CI smoke).
    pub duration_secs: Option<f64>,
    /// Operation mix, cycled per query.
    pub ops: Vec<LoadOp>,
    /// `k` for top-k queries.
    pub top_k: usize,
    /// Right-hand sides per `matvec-batch` request in the op mix.
    pub batch_k: usize,
    /// Budget as `s = nnz / budget_frac` (min 1000).
    pub budget_frac: u64,
    /// Sketching / query seed.
    pub seed: u64,
    /// Use reduced-size dataset variants.
    pub small: bool,
    /// Server-side query workers per sketch.
    pub workers: usize,
    /// Fault-plan spec, [`FaultPlan::parse`] grammar (same as
    /// `matsketch serve --chaos`).
    pub chaos: String,
    /// Queue-depth high-water mark; queries at or past it are shed with
    /// an `overloaded` pushback (0 disables shedding).
    pub shed_high_water: usize,
}

impl Default for ChaosBenchConfig {
    fn default() -> Self {
        ChaosBenchConfig {
            clients: vec![2, 8],
            queries: 64,
            duration_secs: None,
            ops: vec![LoadOp::Matvec, LoadOp::Row, LoadOp::TopK],
            top_k: 10,
            batch_k: 4,
            budget_frac: 10,
            seed: 0,
            small: true,
            workers: 2,
            chaos: "seed=7,disconnect=0.02,partial=0.01,corrupt=0.005,tarpit=0.02:3".into(),
            shed_high_water: 2,
        }
    }
}

/// One serving-under-faults measurement.
#[derive(Clone, Debug)]
pub struct ChaosPoint {
    /// Dataset name.
    pub dataset: String,
    /// Distribution name.
    pub method: String,
    /// Sample budget.
    pub s: u64,
    /// Concurrent clients.
    pub clients: usize,
    /// Queries answered successfully (goodput numerator).
    pub queries: u64,
    /// Queries that failed after the client's retry policy gave up.
    pub errors: u64,
    /// Successful queries per second under faults.
    pub qps: f64,
    /// Client-side retries the fault schedule forced.
    pub retries: u64,
    /// Operations abandoned because a retry would overrun the deadline.
    pub deadline_misses: u64,
    /// Queries the server shed with an `overloaded` pushback.
    pub shed: u64,
    /// Shed fraction of query arrivals: `shed / (shed + answered)`.
    pub shed_rate: f64,
    /// Faults the plan injected during the measurement.
    pub injected: u64,
    /// Median latency of accepted queries (µs).
    pub p50_us: f64,
    /// 99th percentile latency of accepted queries (µs).
    pub p99_us: f64,
}

/// Run the chaos serving benchmark; writes `chaos_serving.csv`/`.md`
/// under `dir`. Always self-hosted: the fault plan and shedding
/// high-water mark are server construction knobs, so there is no
/// external-address mode — point `matsketch serve --chaos` at the same
/// spec to reproduce a schedule by hand.
pub fn run_chaos_bench(
    dir: &Path,
    store_dir: &Path,
    cfg: &ChaosBenchConfig,
    datasets: &[DatasetId],
) -> Result<Vec<ChaosPoint>> {
    let store = SketchStore::open(store_dir)?;
    let kind = DistributionKind::Bernstein;
    let mut points = Vec::new();

    let (plan, store_fault) = FaultPlan::parse(&cfg.chaos)?;
    if store_fault.is_some() {
        // the bench only reads the store (sketches are resolved before
        // the server starts), so a store= clause would never fire
        crate::warn_log!("chaos-bench: store= fault in spec ignored (bench is read-only)");
    }
    let plan = Arc::new(plan);

    // resolve every dataset's key and make sure the store holds its
    // sketch before the chaos'd server starts
    let mut keys: Vec<(DatasetId, StoreKey)> = Vec::new();
    for id in datasets {
        let coo = if cfg.small { id.generate_small(cfg.seed) } else { id.generate(cfg.seed) };
        let s = (coo.nnz() as u64 / cfg.budget_frac.max(1)).max(1_000);
        let plan_sk = SketchPlan::new(kind, s).with_seed(cfg.seed);
        let key = StoreKey::new(id.name(), &kind.name(), s, cfg.seed)
            .with_fingerprint(coo_fingerprint(&coo));
        let (_, cache_hit) = store.get_or_build(&key, || {
            let (sk, _) = engine::sketch_coo(
                SketchMode::Sharded,
                &coo,
                &plan_sk,
                &PipelineConfig::default(),
            )?;
            Ok(sk)
        })?;
        crate::info!(
            "chaos-bench: {} {}",
            key.file_name(),
            if cache_hit { "from store cache" } else { "built + persisted" }
        );
        keys.push((*id, key));
    }

    let server = NetServer::bind(
        SketchStore::open(store_dir)?,
        "127.0.0.1:0",
        NetServerConfig {
            workers_per_sketch: cfg.workers.max(1),
            // every client holds one connection, and injected disconnects
            // force extra redials; leave generous headroom
            max_connections: cfg.clients.iter().copied().max().unwrap_or(1) * 2 + 8,
            shed_high_water: cfg.shed_high_water,
            chaos: Some(Arc::clone(&plan)),
            ..Default::default()
        },
    )?;
    let target = server.local_addr().to_string();

    let result = measure_all(&keys, cfg, &target, &mut points);
    // liveness under standing chaos: control ops are never shed and the
    // client retries through injected faults, so ping must still answer
    let ping_ok = RemoteSketchClient::connect(&target).and_then(|mut c| c.ping()).is_ok();
    let stats = server.shutdown();
    crate::info!(
        "chaos-bench: ping under chaos {}; {} faults injected over {} connections \
         ({} frames)",
        if ping_ok { "answered" } else { "FAILED" },
        plan.injected().len(),
        stats.connections,
        stats.frames
    );
    result?;

    chaos_serving_table(&points).write(dir)?;
    Ok(points)
}

/// Drive every `(dataset, key) × client-count` measurement against the
/// chaos'd server, snapshotting the process-global telemetry around each
/// point so retries / sheds / injections are attributed per row (split
/// out so the caller can always shut the server down, even on error).
fn measure_all(
    keys: &[(DatasetId, StoreKey)],
    cfg: &ChaosBenchConfig,
    target: &str,
    points: &mut Vec<ChaosPoint>,
) -> Result<()> {
    for (id, key) in keys {
        for &clients in &cfg.clients {
            let load_cfg = LoadGenConfig {
                clients,
                queries_per_client: cfg.queries,
                duration: cfg.duration_secs.map(Duration::from_secs_f64),
                ops: cfg.ops.clone(),
                top_k: cfg.top_k,
                batch_k: cfg.batch_k,
                seed: cfg.seed,
            };
            let before = crate::obs::global().snapshot();
            let report = run_load(target, key, &load_cfg)?;
            let delta = crate::obs::global().snapshot().diff(&before);
            let retries = delta.counter("client_retry");
            let deadline_misses = delta.counter("client_deadline");
            let shed = delta.counter("fault_overloaded");
            let injected = delta.counter("chaos_injected");
            let shed_rate = if shed + report.queries > 0 {
                shed as f64 / (shed + report.queries) as f64
            } else {
                0.0
            };
            crate::info!(
                "chaos-bench: {} clients={} -> {:.1} q/s good ({} retries, {} shed, \
                 {} injected, p99 {:.0} µs)",
                id.name(),
                clients,
                report.qps,
                retries,
                shed,
                injected,
                report.p99_us
            );
            points.push(ChaosPoint {
                dataset: id.name().to_string(),
                method: key.method.clone(),
                s: key.s,
                clients,
                queries: report.queries,
                errors: report.errors,
                qps: report.qps,
                retries,
                deadline_misses,
                shed,
                shed_rate,
                injected,
                p50_us: report.p50_us,
                p99_us: report.p99_us,
            });
        }
    }
    Ok(())
}

/// Render chaos-bench points as the `chaos_serving` report table.
pub fn chaos_serving_table(points: &[ChaosPoint]) -> Table {
    let mut t = Table::new(
        "chaos_serving",
        &[
            "dataset", "method", "s", "clients", "queries", "errors", "qps", "retries",
            "deadline_misses", "shed", "shed_rate", "injected", "p50_us", "p99_us",
        ],
    );
    for p in points {
        t.push(vec![
            p.dataset.clone(),
            p.method.clone(),
            p.s.to_string(),
            p.clients.to_string(),
            p.queries.to_string(),
            p.errors.to_string(),
            fixed(p.qps, 1),
            p.retries.to_string(),
            p.deadline_misses.to_string(),
            p.shed.to_string(),
            fixed(p.shed_rate, 4),
            p.injected.to_string(),
            fixed(p.p50_us, 1),
            fixed(p.p99_us, 1),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_bench_self_hosts_and_reports() {
        let base =
            std::env::temp_dir().join(format!("matsketch_chaosbench_eval_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let out = base.join("reports");
        let store = base.join("store");
        let cfg = ChaosBenchConfig {
            clients: vec![2],
            queries: 8,
            chaos: "seed=3,disconnect=0.05,tarpit=0.05:2".into(),
            shed_high_water: 1,
            ..Default::default()
        };
        let datasets = [DatasetId::Synthetic];
        let pts = run_chaos_bench(&out, &store, &cfg, &datasets).unwrap();
        assert_eq!(pts.len(), 1);
        // goodput survives the fault schedule: the retry policy keeps
        // answers flowing even though faults were injected
        assert!(pts[0].queries > 0 && pts[0].qps > 0.0, "{pts:?}");
        assert!(pts[0].shed_rate >= 0.0 && pts[0].shed_rate <= 1.0);
        let csv = std::fs::read_to_string(out.join("chaos_serving.csv")).unwrap();
        assert!(out.join("chaos_serving.md").exists());
        assert!(csv.lines().count() >= 2, "header + one row:\n{csv}");
        let _ = std::fs::remove_dir_all(&base);
    }
}
