//! E7-ablations — design-choice sweeps DESIGN.md calls out:
//!
//! * **row-norm estimate noise** (§3's "rough estimates suffice" claim):
//!   quality of the Bernstein sketch as the one-pass row-norm estimates
//!   degrade from exact to uniform;
//! * **δ sensitivity**: the failure-probability knob moves α/β together,
//!   so quality should be nearly flat in δ;
//! * **worker count**: sketch quality must be invariant to pipeline
//!   parallelism (the pre-split merge is exact).

use std::path::Path;

use crate::datasets::{synthetic_cf, SyntheticConfig};
use crate::distributions::{DistributionKind, MatrixStats};
use crate::engine::{sketch_entry_stream, PipelineConfig, SketchMode};
use crate::error::Result;
use crate::linalg::svd::{rank_k_fro, topk_svd};
use crate::metrics::quality::{quality_left, quality_right};
use crate::runtime::DenseEngine;
use crate::sketch::SketchPlan;
use crate::sparse::{Coo, Csr};
use crate::stream::ShuffledStream;

use super::report::{fixed, Table};

/// One ablation measurement.
#[derive(Clone, Debug)]
pub struct AblationPoint {
    /// Which ablation.
    pub experiment: String,
    /// The varied parameter (rendered).
    pub param: String,
    /// Left quality.
    pub left: f64,
    /// Right quality.
    pub right: f64,
}

fn eval_sketch(
    a: &Csr,
    coo: &Coo,
    stats: &MatrixStats,
    plan: &SketchPlan,
    workers: usize,
    k: usize,
    a_k: f64,
    engine: &dyn DenseEngine,
) -> Result<(f64, f64)> {
    let cfg = PipelineConfig { workers, ..Default::default() };
    let (sk, _) = sketch_entry_stream(
        SketchMode::Sharded,
        ShuffledStream::new(coo, plan.seed),
        stats,
        plan,
        &cfg,
    )?;
    let b = sk.to_csr();
    let svd_b = topk_svd(&b, k + 4, 8, plan.seed ^ 5, engine)?;
    Ok((
        quality_left(a, &svd_b, a_k, k, engine)?,
        quality_right(a, &svd_b, a_k, k)?,
    ))
}

/// Run all three ablations on the synthetic matrix; writes `ablation.*`.
pub fn run_ablation(dir: &Path, seed: u64, engine: &dyn DenseEngine) -> Result<Vec<AblationPoint>> {
    let coo = synthetic_cf(&SyntheticConfig { n: 4_000, seed, ..Default::default() });
    let a = coo.to_csr();
    let exact = MatrixStats::from_coo(&coo);
    let k = 10;
    let svd_a = topk_svd(&a, k + 4, 8, seed ^ 1, engine)?;
    let a_k = rank_k_fro(&svd_a, k);
    let s = (a.nnz() / 5) as u64;
    let mut out = Vec::new();

    // 1. row-norm estimate noise
    for sigma in [0.0f64, 0.25, 0.5, 1.0, 2.0] {
        let stats = if sigma == 0.0 {
            exact.clone()
        } else {
            exact.clone().with_noisy_rows(sigma, seed ^ 77)
        };
        let plan = SketchPlan::new(DistributionKind::Bernstein, s).with_seed(seed ^ 2);
        let (l, r) = eval_sketch(&a, &coo, &stats, &plan, 4, k, a_k, engine)?;
        out.push(AblationPoint {
            experiment: "row-norm-noise".into(),
            param: format!("sigma={sigma}"),
            left: l,
            right: r,
        });
    }
    // uniform row norms (the "assume all ratios are 1" mode of §3)
    {
        let mut stats = exact.clone();
        stats.row_l1.iter_mut().for_each(|z| *z = if *z > 0.0 { 1.0 } else { 0.0 });
        stats.row_sq.iter_mut().for_each(|z| *z = if *z > 0.0 { 1.0 } else { 0.0 });
        let plan = SketchPlan::new(DistributionKind::Bernstein, s).with_seed(seed ^ 2);
        let (l, r) = eval_sketch(&a, &coo, &stats, &plan, 4, k, a_k, engine)?;
        out.push(AblationPoint {
            experiment: "row-norm-noise".into(),
            param: "uniform".into(),
            left: l,
            right: r,
        });
    }

    // 2. delta sensitivity
    for delta in [0.5f64, 0.1, 0.01, 1e-4] {
        let plan = SketchPlan::new(DistributionKind::Bernstein, s)
            .with_seed(seed ^ 3)
            .with_delta(delta);
        let (l, r) = eval_sketch(&a, &coo, &exact, &plan, 4, k, a_k, engine)?;
        out.push(AblationPoint {
            experiment: "delta".into(),
            param: format!("delta={delta}"),
            left: l,
            right: r,
        });
    }

    // 3. worker count invariance
    for workers in [1usize, 2, 4, 8] {
        let plan = SketchPlan::new(DistributionKind::Bernstein, s).with_seed(seed ^ 4);
        let (l, r) = eval_sketch(&a, &coo, &exact, &plan, workers, k, a_k, engine)?;
        out.push(AblationPoint {
            experiment: "workers".into(),
            param: format!("workers={workers}"),
            left: l,
            right: r,
        });
    }

    let mut t = Table::new("ablation", &["experiment", "param", "left", "right"]);
    for p in &out {
        t.push(vec![
            p.experiment.clone(),
            p.param.clone(),
            fixed(p.left, 4),
            fixed(p.right, 4),
        ]);
    }
    t.write(dir)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::RustEngine;

    #[test]
    fn ablation_runs_and_shows_robustness() {
        let dir = std::env::temp_dir().join("matsketch_ablation_test");
        let pts = run_ablation(&dir, 3, &RustEngine).unwrap();
        assert!(pts.len() >= 14);
        // §3 claim: moderate noise degrades gracefully — sigma=0.5 stays
        // within 0.15 of exact (still a highly usable sketch), and even
        // the uniform-row-norm mode stays above half the exact quality.
        let exact = pts.iter().find(|p| p.param == "sigma=0").unwrap();
        let noisy = pts.iter().find(|p| p.param == "sigma=0.5").unwrap();
        assert!((exact.left - noisy.left).abs() < 0.15, "{exact:?} vs {noisy:?}");
        let uniform = pts.iter().find(|p| p.param == "uniform").unwrap();
        assert!(uniform.left > 0.5 * exact.left, "{uniform:?} vs {exact:?}");
        // worker-count invariance: spread below 0.05
        let wk: Vec<&AblationPoint> =
            pts.iter().filter(|p| p.experiment == "workers").collect();
        let lo = wk.iter().map(|p| p.left).fold(f64::MAX, f64::min);
        let hi = wk.iter().map(|p| p.left).fold(f64::MIN, f64::max);
        assert!(hi - lo < 0.05, "worker-count sensitivity: {lo}..{hi}");
    }
}
