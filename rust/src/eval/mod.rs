//! Experiment drivers — one per paper artifact (DESIGN.md §3).
//!
//! * [`tables`] — E1 (matrix characteristics) + E4 (sample-complexity
//!   comparison).
//! * [`figure1`] — E2 (the 8-panel quality-vs-budget sweep).
//! * [`compression`] — E3 (bits/sample and the disc-size comparison).
//! * [`theory`] — E6 (ε₅ near-optimality checks).
//! * [`serving`] — E9 (store-fed concurrent query-serving throughput,
//!   plus the mixed ingest+query live-serving bench).
//! * [`netbench`] — E11 (remote wire-protocol serving throughput +
//!   latency percentiles).
//! * [`chaosbench`] — E13 (serving goodput, retries, and shed rate under
//!   injected faults and load shedding).
//! * [`report`] — CSV/markdown emission shared by all drivers.

pub mod ablation;
pub mod chaosbench;
pub mod compression;
pub mod figure1;
pub mod netbench;
pub mod report;
pub mod serving;
pub mod tables;
pub mod theory;

pub use ablation::run_ablation;
pub use chaosbench::{run_chaos_bench, ChaosBenchConfig, ChaosPoint};
pub use compression::run_compression;
pub use figure1::{run_figure1, Figure1Config};
pub use netbench::{run_net_bench, NetBenchConfig, NetPoint};
pub use report::server_metrics_table;
pub use serving::{
    run_live_bench, run_serve_bench, BatchPoint, LiveBenchConfig, LivePoint, ServeConfig,
    ServePoint,
};
pub use tables::{run_tables, TableRow};
pub use theory::run_theory;
