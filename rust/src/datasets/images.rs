//! Images-profile generator: synthetic "building-like" grayscale images
//! (random rectangles + illumination gradient + noise), Haar-wavelet
//! transformed per column — the structure of the paper's Oxford-buildings
//! matrix (dense, rapidly decaying coefficient magnitudes, stable rank
//! close to 1 because of the shared DC/low-frequency mass).

use super::wavelet::haar2d;
use crate::sparse::Coo;
use crate::util::rng::Rng;

/// Generator parameters (64×64 images vs the paper's 128×128 — same decay
/// profile, 4× fewer rows; default 2 000 images).
#[derive(Clone, Debug)]
pub struct ImagesConfig {
    /// Image side (power of two). Rows = side².
    pub side: usize,
    /// Number of images (columns).
    pub n_images: usize,
    /// Rectangles per image.
    pub rects: usize,
    /// Additive pixel noise σ.
    pub noise: f64,
    /// Seed.
    pub seed: u64,
}

impl Default for ImagesConfig {
    fn default() -> Self {
        ImagesConfig { side: 64, n_images: 2_000, rects: 8, noise: 0.02, seed: 0 }
    }
}

/// Generate the wavelet-coefficient matrix (rows = wavelet coefficients,
/// columns = images). Coefficients below a tiny threshold are dropped
/// (they are numerically zero; keeps the matrix storable as sparse while
/// remaining effectively dense like the paper's).
pub fn images_like(cfg: &ImagesConfig) -> Coo {
    assert!(cfg.side.is_power_of_two());
    let size = cfg.side;
    let m = size * size;
    let mut rng = Rng::new(cfg.seed ^ 0x494D47);
    let mut coo = Coo::new(m, cfg.n_images);
    let mut img = vec![0.0f64; m];
    for j in 0..cfg.n_images {
        // base illumination gradient
        let (gx, gy) = (rng.f64() * 0.6, rng.f64() * 0.6);
        let base = 0.2 + 0.5 * rng.f64();
        for r in 0..size {
            for c in 0..size {
                img[r * size + c] =
                    base + gx * (c as f64 / size as f64) + gy * (r as f64 / size as f64);
            }
        }
        // facade-like rectangles
        for _ in 0..cfg.rects {
            let w = 2 + rng.usize_below(size / 2);
            let h = 2 + rng.usize_below(size / 2);
            let r0 = rng.usize_below(size - h.min(size - 1));
            let c0 = rng.usize_below(size - w.min(size - 1));
            let dv = (rng.f64() - 0.5) * 0.8;
            for r in r0..(r0 + h).min(size) {
                for c in c0..(c0 + w).min(size) {
                    img[r * size + c] += dv;
                }
            }
        }
        // noise
        for p in img.iter_mut() {
            *p += cfg.noise * rng.normal();
        }
        haar2d(&mut img, size);
        for (i, &v) in img.iter().enumerate() {
            if v.abs() > 1e-4 {
                coo.push(i as u32, j as u32, v as f32);
            }
        }
    }
    coo
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Coo {
        images_like(&ImagesConfig { side: 32, n_images: 150, ..Default::default() })
    }

    #[test]
    fn effectively_dense() {
        let a = small();
        let density = a.nnz() as f64 / (a.m * a.n) as f64;
        assert!(density > 0.5, "density={density}");
    }

    #[test]
    fn stable_rank_near_one() {
        // shared low-frequency mass ⇒ σ₁ carries most of the energy
        let a = small();
        let st = crate::distributions::MatrixStats::from_coo(&a);
        let s1 = crate::linalg::spectral_norm(&a.to_csr(), 60, 1);
        let sr = st.sum_sq / (s1 * s1);
        assert!(sr < 6.0, "sr={sr}");
    }

    #[test]
    fn dc_row_dominates() {
        let a = small();
        let norms = a.row_l1_norms();
        let max = norms.iter().cloned().fold(0.0f64, f64::max);
        assert_eq!(norms[0], max, "row 0 is the DC coefficient");
    }
}
