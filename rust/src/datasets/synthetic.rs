//! The paper's §6 Synthetic matrix — implemented exactly as described:
//!
//! > "Each row corresponds to an item and each column to a user. Each user
//! > and each item was first assigned a random latent vector (i.i.d.
//! > Gaussian). Each value in the matrix is the dot product of the
//! > corresponding latent vectors plus additional Gaussian noise. We
//! > simulated the fact that some items are more popular than others by
//! > retaining each entry of each item i with probability 1 − i/m."

use crate::sparse::Coo;
use crate::util::rng::Rng;

/// Generator parameters (defaults = the paper's 1.0e2 × 1.0e4 with
/// ≈ 5.0e5 retained entries).
#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    /// Items (rows).
    pub m: usize,
    /// Users (columns).
    pub n: usize,
    /// Latent dimensionality.
    pub rank: usize,
    /// Noise standard deviation relative to signal.
    pub noise: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig { m: 100, n: 10_000, rank: 12, noise: 0.5, seed: 0 }
    }
}

/// Generate the collaborative-filtering matrix.
pub fn synthetic_cf(cfg: &SyntheticConfig) -> Coo {
    let mut rng = Rng::new(cfg.seed ^ 0x53_59_4E);
    let r = cfg.rank;
    // latent vectors
    let items: Vec<f64> = (0..cfg.m * r).map(|_| rng.normal()).collect();
    let users: Vec<f64> = (0..cfg.n * r).map(|_| rng.normal()).collect();
    let mut coo = Coo::new(cfg.m, cfg.n);
    for i in 0..cfg.m {
        let keep_p = 1.0 - i as f64 / cfg.m as f64;
        let iv = &items[i * r..(i + 1) * r];
        for j in 0..cfg.n {
            if !rng.bernoulli(keep_p) {
                continue;
            }
            let uv = &users[j * r..(j + 1) * r];
            let dot: f64 = iv.iter().zip(uv.iter()).map(|(a, b)| a * b).sum();
            let v = dot + cfg.noise * rng.normal();
            if v != 0.0 {
                coo.push(i as u32, j as u32, v as f32);
            }
        }
    }
    coo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_scale_matches_paper() {
        let a = synthetic_cf(&SyntheticConfig { n: 2_000, ..Default::default() });
        assert_eq!(a.m, 100);
        // retention ≈ Σ(1 - i/m)·n = n·(m+1)/2 ≈ 0.5·m·n
        let expect = 0.5 * 100.0 * 2_000.0;
        assert!(
            (a.nnz() as f64 - expect).abs() / expect < 0.05,
            "nnz={} expect≈{expect}",
            a.nnz()
        );
    }

    #[test]
    fn popularity_gradient_present() {
        let a = synthetic_cf(&SyntheticConfig { n: 3_000, ..Default::default() });
        let mut per_row = vec![0usize; a.m];
        for e in &a.entries {
            per_row[e.row as usize] += 1;
        }
        // first decile much denser than last decile
        let head: usize = per_row[..10].iter().sum();
        let tail: usize = per_row[90..].iter().sum();
        assert!(head > 5 * tail, "head={head} tail={tail}");
    }

    #[test]
    fn low_stable_rank() {
        // dot-product structure ⇒ stable rank ≈ O(rank), far below m
        let a = synthetic_cf(&SyntheticConfig { n: 2_000, ..Default::default() });
        let st = crate::distributions::MatrixStats::from_coo(&a);
        let sigma1 = crate::linalg::spectral_norm(&a.to_csr(), 60, 0);
        let sr = st.sum_sq / (sigma1 * sigma1);
        assert!(sr < 40.0, "sr={sr}");
        assert!(sr > 1.0);
    }

    #[test]
    fn deterministic_for_seed() {
        let c = SyntheticConfig { n: 500, seed: 9, ..Default::default() };
        let a = synthetic_cf(&c);
        let b = synthetic_cf(&c);
        assert_eq!(a.entries, b.entries);
    }
}
