//! Enron-profile generator: an extremely sparse tf-idf term-document
//! matrix over short documents (email subject lines), with Zipf word
//! frequencies. Matches the paper's reported regime: nnz/column ≈ 4
//! (subject lines are short), huge dynamic range of row norms, sr ≈ 30.

use std::collections::BTreeSet;

use super::zipf::Zipf;
use crate::sparse::Coo;
use crate::util::rng::Rng;

/// Generator parameters (defaults: a laptop-scale slice of the paper's
/// 1.3e4 × 1.8e5 matrix with matched per-column density).
#[derive(Clone, Debug)]
pub struct EnronConfig {
    /// Vocabulary size (rows).
    pub m: usize,
    /// Documents (columns).
    pub n: usize,
    /// Mean words per document (subject lines are short).
    pub mean_words: f64,
    /// Zipf exponent of the word distribution.
    pub zipf_a: f64,
    /// Fraction of the most frequent word ranks dropped as stopwords —
    /// standard tf-idf preprocessing (the paper's corpus is tf-idf,
    /// implying the usual stopword filtering; without it, stopword rows
    /// acquire pathological L1 mass no real pipeline produces).
    pub stopword_frac: f64,
    /// Seed.
    pub seed: u64,
}

impl Default for EnronConfig {
    fn default() -> Self {
        EnronConfig {
            m: 2_000,
            n: 30_000,
            mean_words: 5.0,
            zipf_a: 1.05,
            stopword_frac: 0.01,
            seed: 0,
        }
    }
}

/// Generate the tf-idf matrix.
pub fn enron_like(cfg: &EnronConfig) -> Coo {
    let mut rng = Rng::new(cfg.seed ^ 0x454E52);
    // sample over an extended vocabulary and drop the head (stopwords)
    let stop = ((cfg.m as f64 * cfg.stopword_frac) as usize).min(cfg.m / 2);
    let zipf = Zipf::new(cfg.m + stop, cfg.zipf_a);
    // document word draws
    let mut doc_words: Vec<Vec<u32>> = Vec::with_capacity(cfg.n);
    let mut df = vec![0u32; cfg.m]; // document frequency per term
    for _ in 0..cfg.n {
        // document length: 1 + Poisson-ish via geometric mixture
        let len = 1 + (rng.exp() * cfg.mean_words) as usize;
        // BTreeSet: deterministic iteration for seeded reproducibility
        let mut words: BTreeSet<u32> = BTreeSet::new();
        for _ in 0..len.max(1) {
            let rank = zipf.sample(&mut rng);
            if rank >= stop {
                words.insert((rank - stop) as u32); // stopwords filtered out
            }
        }
        for &w in &words {
            df[w as usize] += 1;
        }
        doc_words.push(words.into_iter().collect());
    }
    // tf-idf values: tf = 1 (+occasional repeats), idf = ln(n/df)
    let mut coo = Coo::new(cfg.m, cfg.n);
    for (j, words) in doc_words.iter().enumerate() {
        for &w in words {
            let dfw = df[w as usize].max(1) as f64;
            let idf = ((cfg.n as f64 + 1.0) / dfw).ln();
            let tf = 1.0 + if rng.bernoulli(0.15) { 1.0 } else { 0.0 };
            let v = (tf * idf) as f32;
            if v > 0.0 {
                coo.push(w, j as u32, v);
            }
        }
    }
    coo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extremely_sparse_short_columns() {
        let a = enron_like(&EnronConfig { m: 500, n: 5_000, ..Default::default() });
        let density = a.nnz() as f64 / (a.m * a.n) as f64;
        assert!(density < 0.02, "density={density}");
        let per_col = a.nnz() as f64 / a.n as f64;
        assert!((2.0..12.0).contains(&per_col), "per_col={per_col}");
    }

    #[test]
    fn zipf_row_norms_heavy_tail() {
        let a = enron_like(&EnronConfig { m: 500, n: 5_000, ..Default::default() });
        let mut norms = a.row_l1_norms();
        norms.sort_by(|x, y| y.partial_cmp(x).unwrap());
        // top row picks up far more documents than the median row —
        // (idf damps its per-entry value; compare row support instead)
        let mut support = vec![0usize; a.m];
        for e in &a.entries {
            support[e.row as usize] += 1;
        }
        support.sort_unstable_by(|x, y| y.cmp(x));
        assert!(support[0] > 10 * support[250].max(1), "{} vs {}", support[0], support[250]);
        assert!(norms[0] > norms[250]);
    }

    #[test]
    fn data_matrix_condition1_holds() {
        // rows (terms across 30k docs) must dominate columns (short docs)
        let a = enron_like(&EnronConfig { m: 300, n: 6_000, ..Default::default() });
        let max_col = a.col_l1_norms().into_iter().fold(0.0f64, f64::max);
        let row_norms = a.row_l1_norms();
        let nonzero_rows: Vec<f64> =
            row_norms.into_iter().filter(|&z| z > 0.0).collect();
        let med = {
            let mut v = nonzero_rows.clone();
            v.sort_by(|x, y| x.partial_cmp(y).unwrap());
            v[v.len() / 2]
        };
        // median row norm exceeds the max column norm (Definition 4.1's
        // spirit at this scale)
        assert!(med > max_col * 0.3, "med={med} max_col={max_col}");
    }
}
