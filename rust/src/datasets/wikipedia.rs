//! Wikipedia-profile generator: a large tf-idf term-document matrix over
//! *long* documents — denser columns than Enron, larger vocabulary, the
//! regime where the paper's Bernstein sampling dominates most decisively
//! (its Figure-1 Wikipedia panel).

use super::zipf::Zipf;
use crate::sparse::Coo;
use crate::util::rng::Rng;

/// Generator parameters (laptop-scaled from the paper's 4.4e5 × 3.4e6).
#[derive(Clone, Debug)]
pub struct WikipediaConfig {
    /// Vocabulary size (rows).
    pub m: usize,
    /// Documents (columns).
    pub n: usize,
    /// Mean distinct words per document (articles are long).
    pub mean_words: f64,
    /// Zipf exponent.
    pub zipf_a: f64,
    /// Seed.
    pub seed: u64,
}

impl Default for WikipediaConfig {
    fn default() -> Self {
        WikipediaConfig { m: 4_000, n: 50_000, mean_words: 24.0, zipf_a: 1.1, seed: 0 }
    }
}

/// Generate the term-document tf-idf matrix.
pub fn wikipedia_like(cfg: &WikipediaConfig) -> Coo {
    let mut rng = Rng::new(cfg.seed ^ 0x57_49_4B);
    let zipf = Zipf::new(cfg.m, cfg.zipf_a);
    // first pass: choose words per document, accumulate df
    let mut doc_words: Vec<Vec<(u32, u16)>> = Vec::with_capacity(cfg.n);
    let mut df = vec![0u32; cfg.m];
    // BTreeMap: deterministic iteration order (seeded generators must be
    // bit-reproducible; HashMap order varies per process).
    let mut scratch: std::collections::BTreeMap<u32, u16> = Default::default();
    for _ in 0..cfg.n {
        let len = 2 + (rng.exp() * cfg.mean_words) as usize;
        scratch.clear();
        for _ in 0..len {
            *scratch.entry(zipf.sample(&mut rng) as u32).or_default() += 1;
        }
        let words: Vec<(u32, u16)> = scratch.iter().map(|(&w, &c)| (w, c)).collect();
        for &(w, _) in &words {
            df[w as usize] += 1;
        }
        doc_words.push(words);
    }
    let mut coo = Coo::new(cfg.m, cfg.n);
    for (j, words) in doc_words.iter().enumerate() {
        for &(w, tf) in words {
            let dfw = df[w as usize].max(1) as f64;
            let idf = ((cfg.n as f64 + 1.0) / dfw).ln();
            // sub-linear tf damping, standard tf-idf practice
            let v = ((1.0 + (tf as f64).ln()) * idf) as f32;
            if v > 0.0 {
                coo.push(w, j as u32, v);
            }
        }
    }
    coo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn denser_columns_than_enron() {
        let a = wikipedia_like(&WikipediaConfig { m: 800, n: 5_000, ..Default::default() });
        let per_col = a.nnz() as f64 / a.n as f64;
        assert!(per_col > 10.0, "per_col={per_col}");
    }

    #[test]
    fn stopword_rows_have_tiny_values_but_many_entries() {
        let a = wikipedia_like(&WikipediaConfig { m: 800, n: 8_000, ..Default::default() });
        let mut support = vec![0usize; a.m];
        let mut maxval = vec![0.0f32; a.m];
        for e in &a.entries {
            support[e.row as usize] += 1;
            maxval[e.row as usize] = maxval[e.row as usize].max(e.val.abs());
        }
        // rank-0 word: near-ubiquitous support, tiny idf value
        assert!(support[0] as f64 > 0.5 * a.n as f64);
        let mid = 400;
        assert!(maxval[0] < maxval[mid], "idf should damp stopwords");
    }

    #[test]
    fn deterministic() {
        let cfg = WikipediaConfig { m: 200, n: 1_000, seed: 3, ..Default::default() };
        assert_eq!(wikipedia_like(&cfg).entries, wikipedia_like(&cfg).entries);
    }
}
