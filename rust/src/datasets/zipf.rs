//! Zipf-distributed sampling for the text-corpus generators.
//!
//! Word frequencies in natural-language corpora follow a Zipf law
//! `P(rank k) ∝ 1/k^a`; the tf-idf generators draw word ids from this to
//! reproduce the extreme-sparsity/heavy-tail profile of the Enron and
//! Wikipedia matrices.

use crate::util::rng::Rng;

/// Precomputed Zipf sampler over ranks `1..=n` with exponent `a`.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build (O(n) setup).
    pub fn new(n: usize, a: f64) -> Zipf {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(a);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Draw a 0-based rank.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // binary search for the first cdf ≥ u
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Number of ranks.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// Never empty.
    pub fn is_empty(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_heavier_than_tail() {
        let z = Zipf::new(1000, 1.1);
        let mut rng = Rng::new(0);
        let mut counts = vec![0usize; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[200]);
        // rank-1 frequency ratio approximately 2^a vs rank 2
        let ratio = counts[0] as f64 / counts[1] as f64;
        assert!((ratio - 2f64.powf(1.1)).abs() < 0.35, "ratio={ratio}");
    }

    #[test]
    fn all_ranks_reachable() {
        let z = Zipf::new(5, 0.5);
        let mut rng = Rng::new(1);
        let mut seen = [false; 5];
        for _ in 0..10_000 {
            seen[z.sample(&mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
