//! 2-D Haar discrete wavelet transform — the substrate behind the Images
//! dataset (the paper's image columns are "the wavelet transform of a
//! single 128×128 pixel grayscale image").

/// One level of the 1-D Haar transform in place (length must be even):
/// first half ← scaled averages, second half ← scaled differences.
fn haar_1d_step(data: &mut [f64], len: usize) {
    let half = len / 2;
    let mut tmp = vec![0.0f64; len];
    let s = std::f64::consts::FRAC_1_SQRT_2;
    for i in 0..half {
        tmp[i] = (data[2 * i] + data[2 * i + 1]) * s;
        tmp[half + i] = (data[2 * i] - data[2 * i + 1]) * s;
    }
    data[..len].copy_from_slice(&tmp);
}

/// Inverse of [`haar_1d_step`].
fn haar_1d_unstep(data: &mut [f64], len: usize) {
    let half = len / 2;
    let mut tmp = vec![0.0f64; len];
    let s = std::f64::consts::FRAC_1_SQRT_2;
    for i in 0..half {
        tmp[2 * i] = (data[i] + data[half + i]) * s;
        tmp[2 * i + 1] = (data[i] - data[half + i]) * s;
    }
    data[..len].copy_from_slice(&tmp);
}

/// Full multilevel 2-D Haar DWT of a square `size×size` image (row-major,
/// `size` a power of two). Orthonormal: Parseval-preserving.
pub fn haar2d(img: &mut [f64], size: usize) {
    assert!(size.is_power_of_two());
    assert_eq!(img.len(), size * size);
    let mut len = size;
    let mut col = vec![0.0f64; size];
    while len >= 2 {
        // rows
        for r in 0..len {
            haar_1d_step(&mut img[r * size..r * size + len], len);
        }
        // cols
        for c in 0..len {
            for r in 0..len {
                col[r] = img[r * size + c];
            }
            haar_1d_step(&mut col, len);
            for r in 0..len {
                img[r * size + c] = col[r];
            }
        }
        len /= 2;
    }
}

/// Inverse multilevel 2-D Haar DWT.
pub fn ihaar2d(img: &mut [f64], size: usize) {
    assert!(size.is_power_of_two());
    assert_eq!(img.len(), size * size);
    let mut len = 2;
    let mut col = vec![0.0f64; size];
    while len <= size {
        for c in 0..len {
            for r in 0..len {
                col[r] = img[r * size + c];
            }
            haar_1d_unstep(&mut col, len);
            for r in 0..len {
                img[r * size + c] = col[r];
            }
        }
        for r in 0..len {
            haar_1d_unstep(&mut img[r * size..r * size + len], len);
        }
        len *= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_identity() {
        let size = 16;
        let mut rng = Rng::new(0);
        let orig: Vec<f64> = (0..size * size).map(|_| rng.normal()).collect();
        let mut img = orig.clone();
        haar2d(&mut img, size);
        ihaar2d(&mut img, size);
        for (a, b) in img.iter().zip(orig.iter()) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        let size = 32;
        let mut rng = Rng::new(1);
        let mut img: Vec<f64> = (0..size * size).map(|_| rng.normal()).collect();
        let e0: f64 = img.iter().map(|x| x * x).sum();
        haar2d(&mut img, size);
        let e1: f64 = img.iter().map(|x| x * x).sum();
        assert!((e0 - e1).abs() / e0 < 1e-10);
    }

    #[test]
    fn constant_image_concentrates_in_dc() {
        let size = 8;
        let mut img = vec![3.0f64; size * size];
        haar2d(&mut img, size);
        // All energy in the (0,0) coefficient
        assert!((img[0] - 3.0 * size as f64).abs() < 1e-10);
        let rest: f64 = img[1..].iter().map(|x| x.abs()).sum();
        assert!(rest < 1e-9);
    }

    #[test]
    fn smooth_images_have_decaying_coefficients() {
        // a smooth gradient image must compress: most coefficients tiny
        let size = 64;
        let mut img: Vec<f64> = (0..size * size)
            .map(|i| {
                let (r, c) = (i / size, i % size);
                (r as f64 / size as f64) + 0.5 * (c as f64 / size as f64)
            })
            .collect();
        haar2d(&mut img, size);
        let mut mags: Vec<f64> = img.iter().map(|x| x.abs()).collect();
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let total: f64 = mags.iter().map(|x| x * x).sum();
        let top32: f64 = mags[..32].iter().map(|x| x * x).sum();
        assert!(top32 / total > 0.99, "smooth image should compress");
    }
}
