//! Dataset generators reproducing the metric profiles of the paper's four
//! evaluation matrices (§6). The real corpora (Enron subject lines, an
//! English-Wikipedia fragment, the Oxford buildings images) are not
//! redistributable, so each generator synthesizes a matrix with the same
//! *structure* — the distributional properties (sparsity pattern, tf-idf
//! magnitudes, wavelet decay, stable rank / numeric-density regime) the
//! sampling behaviour actually depends on. See DESIGN.md §4.

pub mod enron;
pub mod images;
pub mod synthetic;
pub mod wavelet;
pub mod wikipedia;
pub mod zipf;

pub use enron::{enron_like, EnronConfig};
pub use images::{images_like, ImagesConfig};
pub use synthetic::{synthetic_cf, SyntheticConfig};
pub use wikipedia::{wikipedia_like, WikipediaConfig};

use crate::sparse::Coo;

/// The four paper datasets at their default (laptop-scaled) sizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetId {
    /// §6 "Synthetic" collaborative-filtering matrix (paper-exact recipe).
    Synthetic,
    /// §6 "Enron" subject-line tf-idf profile.
    Enron,
    /// §6 "Images" wavelet-transformed image collection profile.
    Images,
    /// §6 "Wikipedia" term-document tf-idf profile.
    Wikipedia,
}

impl DatasetId {
    /// All four, in the paper's table order.
    pub fn all() -> [DatasetId; 4] {
        [DatasetId::Synthetic, DatasetId::Enron, DatasetId::Images, DatasetId::Wikipedia]
    }

    /// Stable lower-case name.
    pub fn name(&self) -> &'static str {
        match self {
            DatasetId::Synthetic => "synthetic",
            DatasetId::Enron => "enron",
            DatasetId::Images => "images",
            DatasetId::Wikipedia => "wikipedia",
        }
    }

    /// Parse a name.
    pub fn parse(s: &str) -> Option<DatasetId> {
        match s.to_ascii_lowercase().as_str() {
            "synthetic" => Some(DatasetId::Synthetic),
            "enron" => Some(DatasetId::Enron),
            "images" => Some(DatasetId::Images),
            "wikipedia" | "wiki" => Some(DatasetId::Wikipedia),
            _ => None,
        }
    }

    /// Generate at default scale with the given seed.
    pub fn generate(&self, seed: u64) -> Coo {
        match self {
            DatasetId::Synthetic => {
                synthetic_cf(&SyntheticConfig { seed, ..Default::default() })
            }
            DatasetId::Enron => enron_like(&EnronConfig { seed, ..Default::default() }),
            DatasetId::Images => images_like(&ImagesConfig { seed, ..Default::default() }),
            DatasetId::Wikipedia => {
                wikipedia_like(&WikipediaConfig { seed, ..Default::default() })
            }
        }
    }

    /// Generate a reduced-size variant (for fast CI sweeps): dimensions
    /// scaled down by roughly `factor`.
    pub fn generate_small(&self, seed: u64) -> Coo {
        match self {
            DatasetId::Synthetic => synthetic_cf(&SyntheticConfig {
                seed,
                n: 2_000,
                ..Default::default()
            }),
            DatasetId::Enron => enron_like(&EnronConfig {
                seed,
                m: 500,
                n: 4_000,
                ..Default::default()
            }),
            DatasetId::Images => images_like(&ImagesConfig {
                seed,
                n_images: 300,
                ..Default::default()
            }),
            DatasetId::Wikipedia => wikipedia_like(&WikipediaConfig {
                seed,
                m: 800,
                n: 8_000,
                ..Default::default()
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for id in DatasetId::all() {
            assert_eq!(DatasetId::parse(id.name()), Some(id));
        }
        assert_eq!(DatasetId::parse("wiki"), Some(DatasetId::Wikipedia));
        assert_eq!(DatasetId::parse("nope"), None);
    }

    #[test]
    fn small_variants_generate_nonempty() {
        for id in DatasetId::all() {
            let a = id.generate_small(7);
            assert!(a.nnz() > 1_000, "{}: nnz={}", id.name(), a.nnz());
            assert!(a.m >= 50, "{}", id.name());
        }
    }
}
