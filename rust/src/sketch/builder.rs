//! Sketch construction: the shared plan type and the offline entry point,
//! routed through the unified [`crate::engine`] (alias-table mode). The
//! streaming paths live behind the same [`crate::engine::Sketcher`] trait.

use crate::distributions::DistributionKind;
use crate::engine::{self, PipelineConfig, SketchMode};
use crate::error::Result;
use crate::sparse::Csr;

use super::Sketch;

/// How to sketch a matrix.
#[derive(Clone, Debug)]
pub struct SketchPlan {
    /// Sampling distribution.
    pub kind: DistributionKind,
    /// Sample budget `s` (i.i.d. draws with replacement).
    pub s: u64,
    /// Failure probability δ (enters Bernstein's α, β).
    pub delta: f64,
    /// RNG seed — all sketches are reproducible.
    pub seed: u64,
}

impl SketchPlan {
    /// Plan with δ = 0.1 and seed 0.
    pub fn new(kind: DistributionKind, s: u64) -> SketchPlan {
        SketchPlan { kind, s, delta: 0.1, seed: 0 }
    }

    /// Override the seed.
    pub fn with_seed(mut self, seed: u64) -> SketchPlan {
        self.seed = seed;
        self
    }

    /// Override δ.
    pub fn with_delta(mut self, delta: f64) -> SketchPlan {
        self.delta = delta;
        self
    }
}

/// Build a sketch of an in-memory CSR matrix by drawing `s` i.i.d. entries
/// from the plan's distribution via one alias table (O(nnz) setup, O(1)
/// per draw). Equivalent to [`engine::sketch_csr`] in
/// [`SketchMode::Offline`] with the run metrics dropped.
pub fn sketch_offline(a: &Csr, plan: &SketchPlan) -> Result<Sketch> {
    let (sketch, _metrics) =
        engine::sketch_csr(SketchMode::Offline, a, plan, &PipelineConfig::default())?;
    Ok(sketch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Entry};
    use crate::util::rng::Rng;

    fn toy_csr() -> Csr {
        let mut coo = Coo::new(4, 8);
        let mut rng = Rng::new(99);
        for i in 0..4u32 {
            for j in 0..8u32 {
                coo.push(i, j, (rng.normal() as f32) * (1.0 + i as f32));
            }
        }
        coo.to_csr()
    }

    #[test]
    fn total_count_is_s() {
        let a = toy_csr();
        for kind in DistributionKind::figure1_set() {
            let sk = sketch_offline(&a, &SketchPlan::new(kind, 500).with_seed(1)).unwrap();
            let total: u64 = sk.entries.iter().map(|e| e.count as u64).sum();
            assert_eq!(total, 500, "{}", sk.method);
            assert_eq!(sk.s, 500);
        }
    }

    #[test]
    fn sketch_is_unbiased_estimator() {
        // E[B_ij] = A_ij: average many sketches and compare entrywise.
        let a = Coo::from_entries(
            2,
            2,
            vec![
                Entry::new(0, 0, 5.0),
                Entry::new(0, 1, -2.0),
                Entry::new(1, 0, 1.0),
                Entry::new(1, 1, 4.0),
            ],
        )
        .unwrap()
        .to_csr();
        let trials = 3000u64;
        let mut acc = vec![0.0f64; 4];
        for t in 0..trials {
            let sk = sketch_offline(
                &a,
                &SketchPlan::new(DistributionKind::Bernstein, 8).with_seed(t),
            )
            .unwrap();
            for e in &sk.entries {
                acc[(e.row * 2 + e.col) as usize] += e.value;
            }
        }
        let want = [5.0, -2.0, 1.0, 4.0];
        for i in 0..4 {
            let mean = acc[i] / trials as f64;
            assert!(
                (mean - want[i]).abs() < 0.25,
                "entry {i}: mean={mean} want={}",
                want[i]
            );
        }
    }

    #[test]
    fn bernstein_values_are_row_constants() {
        // For the L1 family, |B_ij|/count must equal the row scale.
        let a = toy_csr();
        let sk = sketch_offline(
            &a,
            &SketchPlan::new(DistributionKind::Bernstein, 2_000).with_seed(5),
        )
        .unwrap();
        let scale = sk.row_scale.as_ref().unwrap();
        for e in &sk.entries {
            let per_draw = e.value.abs() / e.count as f64;
            let want = scale[e.row as usize];
            assert!(
                (per_draw - want).abs() / want < 1e-9,
                "row {}: {per_draw} vs {want}",
                e.row
            );
        }
    }

    #[test]
    fn entries_sorted_row_major() {
        let a = toy_csr();
        let sk = sketch_offline(&a, &SketchPlan::new(DistributionKind::L1, 300)).unwrap();
        assert!(sk
            .entries
            .windows(2)
            .all(|w| (w[0].row, w[0].col) < (w[1].row, w[1].col)));
    }

    #[test]
    fn rejects_zero_budget() {
        let a = toy_csr();
        assert!(sketch_offline(&a, &SketchPlan::new(DistributionKind::L1, 0)).is_err());
    }
}
